//! Q7 — diamond DAG under load: trade filter → (left leg ∥ right leg) →
//! hedge join, driven by the generic N-ingress/M-egress harness with the
//! topology-aware [`DagController`] co-scheduling all four stages
//! against a global core budget from their per-stage `in_backlog`.
//!
//! Partway through the low-rate phase a scripted fault stalls one hedge
//! worker for 400 ms; the attached [`SupervisorPolicy`] must detect the
//! frozen progress epoch and the run records the detection→healed
//! latency as `mttr_ms` (informational — never a bench-diff gate).
//!
//! Writes `BENCH_q7_dag.json`: end-to-end throughput/latency, per-stage
//! final parallelism and reconfiguration counts, and the recovery MTTR —
//! the perf trajectory record for the DAG layer.
//!
//! ```sh
//! cargo bench --bench bench_q7_dag                  # full run
//! cargo bench --bench bench_q7_dag -- --budget-ms 10  # CI smoke
//! ```

use std::time::Duration;
use stretch::cli::OrExit;
use stretch::elastic::DagController;
use stretch::engine::dag::DagBuilder;
use stretch::engine::VsnOptions;
use stretch::harness::{
    drive, DagControllerPolicy, FaultPlan, FaultPolicy, Job, JobPolicy, LaunchConfig,
    RecoveryLog, SupervisorConfig, SupervisorPolicy,
};
use stretch::workloads::nyse::{
    hedge_join_op, left_leg_op, right_leg_op, trade_filter_op, NyseConfig, Trade,
    TradeStream,
};
use stretch::workloads::RateSchedule;

fn main() {
    let args = stretch::cli::Cli::new("bench_q7_dag", "diamond DAG + global-budget controller")
        .opt("budget-ms", "wall-clock budget for the paced run (ms)", Some("3000"))
        .opt("cores", "global core budget for the DagController", Some("6"))
        .opt("lo", "low offered rate (t/s)", Some("500"))
        .opt("hi", "high offered rate (t/s)", Some("4000"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let budget_ms = args.u64_or("budget-ms", 3_000).or_exit().max(1);
    let cores = args.usize_or("cores", 6).or_exit();
    let lo = args.f64_or("lo", 500.0).or_exit();
    let hi = args.f64_or("hi", 4_000.0).or_exit();

    // compress wall time: `time_scale` event seconds replay per wall
    // second; duration follows the wall budget
    let time_scale = 8.0f64;
    let duration_s = ((budget_ms as f64 / 1e3) * time_scale).ceil().max(2.0) as u32;
    let step_at = duration_s / 2;

    println!("Q7 — diamond DAG (fan-out + fan-in) under a {lo}→{hi} t/s step\n");
    println!(
        "  {duration_s} event-s at {time_scale}× compression, core budget {cores}, \
         step at {step_at} s"
    );

    let ws_ms = 1_000i64;
    let mut b = DagBuilder::<Trade>::new();
    let s = b.source(
        trade_filter_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 1 << 14, ..Default::default() },
    );
    let l = b.node(
        left_leg_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 1 << 14, ..Default::default() },
        &[s],
    );
    let r = b.node(
        right_leg_op(64),
        VsnOptions { initial: 1, max: 2, gate_capacity: 1 << 14, ..Default::default() },
        &[s],
    );
    let j = b.node(
        hedge_join_op(ws_ms, 64),
        VsnOptions { initial: 1, max: 4, gate_capacity: 1 << 14, ..Default::default() },
        &[l, r],
    );
    let pipeline = b.build(&[j]).expect("diamond is a valid DAG");

    let source = TradeStream::new(&NyseConfig { symbols: 10, ..Default::default() }, lo);
    // Scripted chaos: stall one hedge worker for 400 ms during the
    // low-rate phase. A stall (not a kill) keeps the scenario
    // deterministic under the DagController — it may have shrunk any
    // stage to a single worker, and healing a stall needs no survivors;
    // worker 0 always exists (resizes keep the lowest ids).
    let fault_at = (step_at / 2).max(1);
    let pools = [("trade-filter", 2), ("left-leg", 2), ("right-leg", 2), ("hedge", 4)];
    let plan = FaultPlan::parse(&[format!("{fault_at} -> stall hedge:0 400")], &pools)
        .expect("scripted fault is well-formed");
    let handle = Job::new(pipeline, source)
        .with_config(LaunchConfig {
            name: "q7_dag".into(),
            schedule: RateSchedule::step(duration_s, step_at, lo, hi),
            time_scale,
            flush_slack_ms: ws_ms + 10_000,
            drain: Duration::from_millis(300),
            ingress_batch: 256,
            stall_after_ms: 120,
            ..LaunchConfig::default()
        })
        .launch()
        .expect("diamond topology is well-formed");
    let log = RecoveryLog::new();
    let mut policies: Vec<Box<dyn JobPolicy>> = vec![
        Box::new(DagControllerPolicy::new(
            DagController::new(cores).with_thresholds(2_048, 64).with_cooldown(1),
            1,
        )),
        Box::new(FaultPolicy::new(plan)),
        Box::new(SupervisorPolicy::new(SupervisorConfig::default(), log.clone())),
    ];
    drive(&handle, &mut policies);
    let out = handle.shutdown();
    log.close_unresolved();
    let recoveries = log.tickets();
    let r = out.result;

    let mut report = stretch::metrics::BenchReport::new("q7_dag");
    report
        .set("duration_event_s", duration_s as u64)
        .set("core_budget", cores as u64)
        .set("rate_lo_tps", lo)
        .set("rate_hi_tps", hi)
        .set("egress_matches", r.egress_count)
        .set("latency_p50_us", r.latency_p50_us)
        .set("latency_mean_us", r.latency_mean_us);
    let mut total_reconfigs = 0usize;
    let mut peak_total_threads = 0usize;
    for s in r.stages.iter() {
        let final_threads = s.samples.last().map(|x| x.threads).unwrap_or(0);
        let peak = s.samples.iter().map(|x| x.threads).max().unwrap_or(0);
        let max_backlog = s.samples.iter().map(|x| x.backlog).max().unwrap_or(0);
        total_reconfigs += s.reconfigs.len();
        println!(
            "  stage {:<12} Π_final={final_threads} Π_peak={peak} reconfigs={} max_backlog={}",
            s.name,
            s.reconfigs.len(),
            max_backlog
        );
        report
            .set(&format!("{}_final_threads", s.name), final_threads as u64)
            .set(&format!("{}_peak_threads", s.name), peak as u64)
            .set(&format!("{}_reconfigs", s.name), s.reconfigs.len() as u64)
            .set(&format!("{}_max_backlog", s.name), max_backlog);
    }
    // budget check over the sampled timeline: Σ threads per sample ≤
    // cores. A single over-budget sample can be a legitimate transient
    // (a shrink+grow wave installs asynchronously per stage); TWO
    // consecutive over-budget samples is a DagController regression.
    let samples = r.stages[0].samples.len();
    let mut over_streak = 0usize;
    let mut max_over_streak = 0usize;
    for i in 0..samples {
        let total: usize =
            r.stages.iter().filter_map(|s| s.samples.get(i)).map(|x| x.threads).sum();
        peak_total_threads = peak_total_threads.max(total);
        over_streak = if total > cores { over_streak + 1 } else { 0 };
        max_over_streak = max_over_streak.max(over_streak);
    }
    report.set("total_reconfigs", total_reconfigs as u64);
    report.set("peak_total_threads", peak_total_threads as u64);
    // Recovery MTTR from the injected stall. `mttr_ms` classifies as an
    // informational field in bench-diff (never a throughput/latency
    // gate); at tiny CI budgets the stall may outlive the run, in which
    // case the ticket closes Failed and the field is simply absent.
    let healed: Vec<f64> = recoveries.iter().filter_map(|t| t.mttr_ms()).collect();
    report.set("recoveries", recoveries.len() as u64);
    if !healed.is_empty() {
        let mttr_ms = healed.iter().sum::<f64>() / healed.len() as f64;
        report.set("mttr_ms", mttr_ms);
        println!(
            "  fault recovery: {}/{} healed, mttr {mttr_ms:.1} ms",
            healed.len(),
            recoveries.len()
        );
    } else if !recoveries.is_empty() {
        println!(
            "  fault recovery: {} ticket(s) unresolved at end-of-stream \
             (budget too small for the stall to heal in-run)",
            recoveries.len()
        );
    }
    if log.degraded() {
        println!("  note: supervisor marked the job DEGRADED");
    }
    report.set(
        "machine",
        std::env::var("STRETCH_BENCH_MACHINE").unwrap_or_else(|_| "unnamed".into()),
    );
    println!(
        "\n  {} matches at the egress, e2e p50 {} µs, {total_reconfigs} reconfigs, \
         peak Σ threads {peak_total_threads} (budget {cores})",
        r.egress_count, r.latency_p50_us
    );
    if peak_total_threads > cores {
        println!("  note: transient over-budget sample (reconfig wave in flight)");
    }
    match report.write() {
        Ok(p) => println!("  json: {}", p.display()),
        Err(e) => eprintln!("  BENCH_q7_dag.json write failed: {e}"),
    }
    if max_over_streak >= 2 {
        eprintln!(
            "  FAIL: core budget {cores} exceeded for {max_over_streak} consecutive samples \
             — DagController regression"
        );
        std::process::exit(1);
    }
}
