//! Q6 / Fig. 13 — real-world-shaped workload: the NYSE hedge self-join
//! under the bursty intraday trace, with elastic thread adjustment.
//!
//! (a) REAL threaded run of the hedge `J+` over a scaled trace segment;
//! (b) paper-scale fluid replay (0-8000 t/s, reactive controller) for
//! the Fig. 13 time-series shape.

use stretch::cli::OrExit;
use std::time::{Duration, Instant};
use stretch::elastic::{Controller, Decision, JoinCostModel, Observation, ReactiveController, Thresholds};
use stretch::engine::{EgressDriver, VsnEngine, VsnOptions};
use stretch::metrics::CsvWriter;
use stretch::operator::join::scalejoin_op;
use stretch::sim::{calibrate, Arch, FluidSim};
use stretch::tuple::Tuple;
use stretch::workloads::nyse::{HedgePredicate, NyseConfig, NyseGen, Trade};

fn real_hedge_run(duration_s: u32, peak: f64) -> (u64, u64, f64, f64, u64, u64) {
    let (rates, trades) = NyseGen::new(NyseConfig {
        duration_s,
        peak_rate: peak,
        floor_rate: peak / 20.0,
        ..Default::default()
    })
    .generate();
    let _ = rates;
    // hedge self-join: the same stream feeds both inputs (§8.6)
    let def = scalejoin_op("hedge", 5_000, HedgePredicate, 64);
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: 2, max: 4, upstreams: 1, ..Default::default() },
    );
    let clock = engine.clock.clone();
    let metrics = engine.metrics.clone();
    let mut ing = ingress.remove(0);
    let mut egress = EgressDriver::new(readers.remove(0), clock.clone());
    let n = trades.len();
    // pace the feed by the trace's event time (4x compressed), so the
    // latency metric measures processing, not free-run queueing
    let scale = 4.0f64;
    let feeder = std::thread::spawn(move || {
        let t0 = Instant::now();
        for t in trades {
            let due_us = (t.ts as f64 / scale * 1e3) as u64;
            let now_us = t0.elapsed().as_micros() as u64;
            if due_us > now_us + 500 {
                std::thread::sleep(Duration::from_micros(due_us - now_us));
            }
            let ingest = clock.now_us();
            // self-join: deliver on input 0 and input 1
            let l: Tuple<stretch::operator::join::Either<Trade, Trade>> =
                Tuple::data_on(t.ts, 0, stretch::operator::join::Either::L(t.payload))
                    .with_ingest(ingest);
            let r: Tuple<stretch::operator::join::Either<Trade, Trade>> =
                Tuple::data_on(t.ts, 1, stretch::operator::join::Either::R(t.payload))
                    .with_ingest(ingest);
            ing.add(l).unwrap();
            ing.add(r).unwrap();
        }
        ing.heartbeat(i64::MAX / 16).unwrap();
    });
    let t0 = Instant::now();
    let mut quiet = Instant::now();
    loop {
        if egress.poll() > 0 {
            quiet = Instant::now();
        } else {
            if feeder.is_finished() && quiet.elapsed() > Duration::from_millis(300) {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    feeder.join().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    let snap = metrics.snapshot();
    let matches = egress.count;
    let lat = egress.latency_us.mean() / 1e3;
    let lat_p50 = egress.latency_us.p50();
    let lat_p99 = egress.latency_us.p99();
    engine.shutdown();
    (2 * n as u64, matches, snap.comparisons as f64 / dt, lat, lat_p50, lat_p99)
}

fn main() {
    let args = stretch::cli::Cli::new("bench_q6_nyse", "Fig. 13: NYSE hedge self-join")
        .opt("duration", "real trace seconds", Some("30"))
        .opt("peak", "real peak rate t/s", Some("900"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));

    println!("Q6 (Fig. 13) — NYSE hedge self-join\n");
    let (tuples, matches, cps, lat, lat_p50, lat_p99) = real_hedge_run(
        args.u64_or("duration", 30).or_exit() as u32,
        args.f64_or("peak", 900.0).or_exit(),
    );
    println!("real threaded run (Π=2):");
    println!("  {tuples} trade tuples → {matches} hedge matches");
    println!("  {:.2}M comparisons/s, mean latency {:.1} ms (paper: ~1-21 ms)", cps / 1e6, lat);
    let mut report = stretch::metrics::BenchReport::new("q6_nyse");
    report
        .set("real_tuples", tuples)
        .set("real_matches", matches)
        .set("real_cmp_per_s", cps)
        .set("real_lat_mean_ms", lat)
        .set("real_lat_p50_us", lat_p50)
        .set("real_lat_p99_us", lat_p99);
    match report.write() {
        Ok(p) => println!("  json: {}", p.display()),
        Err(e) => eprintln!("  BENCH_q6_nyse.json write failed: {e}"),
    }

    // paper-scale fluid replay with the reactive controller
    let cal = calibrate();
    let (rates, _) = NyseGen::new(NyseConfig {
        duration_s: 600,
        peak_rate: 8_000.0,
        floor_rate: 100.0,
        ..Default::default()
    })
    .generate();
    let model = JoinCostModel::new(cal.cmp_per_sec, 30.0); // WS = 30 s (paper)
    let ctl_model = model;
    let mut ctl = ReactiveController::new(ctl_model, Thresholds::default()).with_cooldown(2);
    let mut sim = FluidSim::new(Arch::StretchJoin { ws_s: 30.0, overhead: 1.2 }, cal, 1);
    let mut csv = CsvWriter::create(
        "results/q6_nyse.csv",
        &["t_s", "rate_tps", "served_tps", "latency_ms", "threads"],
    )
    .unwrap();
    let mut reconfigs = 0;
    let mut lat_acc = 0.0;
    let mut peak_threads = 0;
    for (s, &rate) in rates.iter().enumerate() {
        let sample = sim.step(rate, 1.0);
        let obs = Observation {
            in_rate: rate,
            cmp_per_s: sample.cmp_per_s,
            backlog: sample.backlog as u64,
            dt: 1.0,
            active: (0..sim.threads).collect(),
            max: 72,
        };
        if let Decision::Reconfigure(set) = ctl.tick(&obs) {
            sim.set_threads(set.len());
            reconfigs += 1;
        }
        peak_threads = peak_threads.max(sim.threads);
        lat_acc += sample.latency_ms;
        stretch::csv_row!(
            csv, s, format!("{rate:.0}"), format!("{:.0}", sample.served_tps),
            format!("{:.1}", sample.latency_ms), sim.threads
        );
    }
    csv.flush().unwrap();
    println!("\npaper-scale replay (fluid sim, 600 s, rates 0-8000 t/s):");
    println!(
        "  {reconfigs} reconfigurations, avg latency {:.1} ms, peak threads {peak_threads}",
        lat_acc / rates.len() as f64
    );
    println!("  paper: small thread counts most of the time, bursts absorbed by provisioning");
    println!("csv: results/q6_nyse.csv");
}
