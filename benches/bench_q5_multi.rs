//! Q5 / Fig. 11-12 (+ App. F Figs. 16-19) — STRETCH under multiple
//! reconfigurations: abrupt random rate phases with the proactive
//! model-based controller.
//!
//! Two parts: (a) a REAL threaded run (rates scaled to this box's 1-core
//! capacity, wall time compressed) measuring actual reconfiguration
//! times + latency; (b) the calibrated fluid simulation replaying the
//! paper's full [500, 8000] t/s 20-minute schedule with the same
//! controller code, producing the Fig. 11 series shape.

use stretch::cli::OrExit;
use stretch::elastic::{Controller, Decision, JoinCostModel, Observation, ProactiveController};
use stretch::harness::{run_elastic_join, JoinRunConfig};
use stretch::metrics::CsvWriter;
use stretch::sim::{calibrate, Arch, FluidSim};
use stretch::workloads::rates::RateSchedule;

fn main() {
    let args = stretch::cli::Cli::new("bench_q5_multi", "Fig. 11/12: multi-reconfiguration stress")
        .opt("ws-ms", "window size ms (paper: 60000)", Some("2000"))
        .opt("real-duration", "real run duration (event s)", Some("60"))
        .opt("seed", "schedule seed", Some("11"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let ws_ms = args.u64_or("ws-ms", 2_000).or_exit() as i64;
    let seed = args.u64_or("seed", 11).or_exit();

    let cal = calibrate();

    // ---- (a) real threaded run -------------------------------------
    let max = 4usize;
    let model = JoinCostModel::new(cal.cmp_per_sec / max as f64, ws_ms as f64 / 1e3);
    // scale the paper's [500, 8000] t/s band to fit Π ∈ [1, max] here
    let r_hi = model.max_rate(max) * 0.85;
    let r_lo = r_hi / 16.0;
    let dur = args.u64_or("real-duration", 60).or_exit() as u32;
    let schedule = RateSchedule::q5(seed, dur, r_lo, r_hi, 8, 20);
    println!(
        "Q5 real run: {dur}s event time, rates [{r_lo:.0}, {r_hi:.0}] t/s, WS={ws_ms}ms, proactive controller"
    );
    let mut ctl = ProactiveController::new(model);
    ctl.horizon = 3.0;
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        initial: 1,
        max,
        schedule: schedule.clone(),
        time_scale: 4.0,
        controller: Some(Box::new(ctl)),
        controller_period_s: 2,
        seed,
        ..Default::default()
    });
    let mut csv = CsvWriter::create(
        "results/q5_real.csv",
        &["t_s", "offered_tps", "in_tps", "cmp_per_s", "lat_mean_us", "threads", "backlog", "cv_pct"],
    )
    .unwrap();
    for s in &r.samples {
        stretch::csv_row!(
            csv, s.t_s, format!("{:.0}", s.offered_tps), format!("{:.0}", s.in_tps),
            format!("{:.3e}", s.cmp_per_s), format!("{:.0}", s.latency_mean_us),
            s.threads, s.backlog, format!("{:.2}", s.load_cv_pct)
        );
    }
    csv.flush().unwrap();
    let lat_avg = r.samples.iter().map(|s| s.latency_mean_us).sum::<f64>()
        / r.samples.len().max(1) as f64
        / 1e3;
    let times: Vec<f64> = r.reconfigs.iter().map(|&(_, ms)| ms).collect();
    let worst = times.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "  {} reconfigurations, worst {:.1} ms (paper bound: 40 ms), avg latency {:.1} ms",
        times.len(),
        worst,
        lat_avg
    );
    println!("  thread trajectory: {:?}", r.samples.iter().map(|s| s.threads).collect::<Vec<_>>());
    assert!(!times.is_empty(), "controller never reconfigured — schedule too tame");
    let lat_p50 = {
        let mut v: Vec<u64> = r.samples.iter().map(|s| s.latency_p50_us).collect();
        v.sort_unstable();
        v.get(v.len() / 2).copied().unwrap_or(0)
    };
    let in_tps_avg =
        r.samples.iter().map(|s| s.in_tps).sum::<f64>() / r.samples.len().max(1) as f64;
    let mut report = stretch::metrics::BenchReport::new("q5_multi");
    report
        .set("real_duration_s", dur as u64)
        .set("real_in_tps_avg", in_tps_avg)
        .set("real_lat_mean_ms", lat_avg)
        .set("real_lat_p50_us", lat_p50)
        .set("real_reconfig_count", times.len())
        .set("real_reconfig_worst_ms", worst)
        .set("real_reconfig_ms", times.clone());
    match report.write() {
        Ok(p) => println!("  json: {}", p.display()),
        Err(e) => eprintln!("  BENCH_q5_multi.json write failed: {e}"),
    }

    // ---- (b) paper-scale fluid replay --------------------------------
    println!("\nQ5 paper-scale replay (fluid sim, same controller code):");
    let paper_model = JoinCostModel::new(cal.cmp_per_sec, 60.0); // WS = 1 min
    let mut ctl = ProactiveController::new(paper_model);
    ctl.horizon = 5.0;
    let schedule = RateSchedule::q5(seed, 1200, 500.0, 8000.0, 100, 300);
    let arch = Arch::StretchJoin { ws_s: 60.0, overhead: 1.2 };
    let mut sim = FluidSim::new(arch, cal, 1);
    let mut csv = CsvWriter::create(
        "results/q5_sim.csv",
        &["t_s", "rate_tps", "served_tps", "cmp_per_s", "latency_ms", "threads"],
    )
    .unwrap();
    let mut reconfig_count = 0;
    let mut lat_acc = 0.0;
    let mut max_threads = 0;
    for (s, &rate) in schedule.per_second().iter().enumerate() {
        let sample = sim.step(rate, 1.0);
        let obs = Observation {
            in_rate: rate,
            cmp_per_s: sample.cmp_per_s,
            backlog: sample.backlog as u64,
            dt: 1.0,
            active: (0..sim.threads).collect(),
            max: 72,
        };
        if let Decision::Reconfigure(set) = ctl.tick(&obs) {
            sim.set_threads(set.len());
            reconfig_count += 1;
        }
        lat_acc += sample.latency_ms;
        max_threads = max_threads.max(sim.threads);
        stretch::csv_row!(
            csv, s, format!("{rate:.0}"), format!("{:.0}", sample.served_tps),
            format!("{:.3e}", sample.cmp_per_s), format!("{:.1}", sample.latency_ms),
            sim.threads
        );
    }
    csv.flush().unwrap();
    println!(
        "  1200 s, {} reconfigurations, avg latency {:.1} ms, peak threads {}",
        reconfig_count,
        lat_acc / 1200.0,
        max_threads
    );
    println!("  paper: threads track the rate; avg latency ≈ 20 ms; spikes recover < 10 s");
    println!("csv: results/q5_real.csv, results/q5_sim.csv");
}
