//! Q1 / Fig. 6 — VSN (STRETCH) vs SN (Flink-model) throughput + latency
//! for wordcount and paircount at L/M/H duplication levels.
//!
//! Both engines run the same counting aggregate over the same synthetic
//! tweet corpus; SN pays Corollary-1 duplication (one clone per
//! responsible instance), VSN shares each tuple through the ESG.
//! Writes results/q1_wordcount.csv; prints the paper-style summary.

use stretch::cli::OrExit;
use std::time::{Duration, Instant};
use stretch::engine::{SnEngine, SnOptions, VsnEngine, VsnOptions};
use stretch::metrics::reporter::Table;
use stretch::metrics::CsvWriter;
use stretch::operator::aggregate::count_per_key_op;
use stretch::time::WindowSpec;
use stretch::tuple::{Key, Tuple};
use stretch::workloads::tweets::{
    duplication_factor, paircount_keys, wordcount_keys, Tweet, TweetGen, TweetGenConfig,
};

const END_TS: i64 = i64::MAX / 16;

struct Outcome {
    tput_tps: f64,
    lat_p50_us: u64,
    lat_p99_us: u64,
    forwarded_per_tuple: f64,
}

fn key_fn(level: &str) -> Box<dyn Fn(&Tuple<Tweet>, &mut Vec<Key>) + Send + Sync> {
    match level {
        "wordcount" => Box::new(wordcount_keys),
        "pair-L" => Box::new(paircount_keys(3)),
        "pair-M" => Box::new(paircount_keys(10)),
        "pair-H" => Box::new(paircount_keys(usize::MAX)),
        _ => unreachable!(),
    }
}

fn corpus(n: usize) -> Vec<Tuple<Tweet>> {
    TweetGen::new(TweetGenConfig { vocab: 5_000, max_words: 12, seed: 6, ..Default::default() })
        .take(n)
}

fn run_vsn(
    level: &str,
    tuples: &[Tuple<Tweet>],
    pi: usize,
    tuning: &stretch::config::BatchTuning,
) -> Outcome {
    let spec = WindowSpec::new(10_000, 10_000);
    let def = count_per_key_op("q1", spec, key_fn(level));
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: pi, max: pi, upstreams: 1, ..Default::default() }
            .with_batch(tuning),
    );
    let clock = engine.clock.clone();
    let mut ing = ingress.remove(0);
    let mut reader = readers.remove(0);
    let t0 = Instant::now();
    let feed = tuples.to_vec();
    let feeder = std::thread::spawn(move || {
        for mut t in feed {
            t.ingest_us = clock.now_us();
            ing.add(t).unwrap();
        }
        ing.heartbeat(END_TS).unwrap();
    });
    // drain until quiet after feeder ends
    let clock2 = engine.clock.clone();
    let lat = stretch::metrics::Histogram::new();
    let mut last_data = Instant::now();
    loop {
        match reader.get() {
            Some(t) => {
                if t.kind.is_data() {
                    lat.record(clock2.now_us().saturating_sub(t.ingest_us));
                }
                last_data = Instant::now();
            }
            None => {
                if feeder.is_finished() && last_data.elapsed() > Duration::from_millis(300) {
                    break;
                }
                std::thread::sleep(Duration::from_micros(100));
            }
        }
    }
    feeder.join().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    engine.shutdown();
    Outcome {
        tput_tps: tuples.len() as f64 / dt,
        lat_p50_us: lat.p50(),
        lat_p99_us: lat.p99(),
        forwarded_per_tuple: 1.0, // VSN: one shared add per tuple
    }
}

fn run_sn(
    level: &str,
    tuples: &[Tuple<Tweet>],
    pi: usize,
    tuning: &stretch::config::BatchTuning,
) -> Outcome {
    // The SN pipeline per Corollary 1 (what Flink actually runs): an M
    // stage materializes ONE single-key tuple per key of the tweet, and
    // the key-by routes each to its instance — that materialization IS
    // the duplication overhead of Theorem 1.
    let spec = WindowSpec::new(10_000, 10_000);
    let def = count_per_key_op::<Key, _>("q1-sn", spec, |t, keys| keys.push(t.payload));
    let (mut engine, mut ingress, mut egress) = SnEngine::setup(
        def,
        SnOptions { parallelism: pi, upstreams: 1, ..Default::default() }.with_batch(tuning),
    );
    let clock = engine.clock.clone();
    let mut ing = ingress.remove(0);
    let t0 = Instant::now();
    let feed = tuples.to_vec();
    let kf = key_fn(level);
    let feeder = std::thread::spawn(move || {
        let mut keys = Vec::new();
        let mut run: Vec<Tuple<Key>> = Vec::with_capacity(256);
        for t in feed {
            let ingest = clock.now_us();
            keys.clear();
            kf(&t, &mut keys);
            // M: one materialized tuple per key (Alg. 7/9)
            for &k in &keys {
                run.push(Tuple::data(t.ts, k).with_ingest(ingest));
            }
            if run.len() >= 256 {
                ing.forward_batch(&mut run);
            }
        }
        ing.forward_batch(&mut run);
        ing.heartbeat(END_TS);
    });
    let mut last_data = Instant::now();
    loop {
        if egress.poll() > 0 {
            last_data = Instant::now();
        } else {
            if feeder.is_finished() && last_data.elapsed() > Duration::from_millis(300) {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
    }
    feeder.join().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    let forwarded = engine.forwarded.load(std::sync::atomic::Ordering::Relaxed);
    let lat = egress.latency_us.clone();
    engine.shutdown();
    Outcome {
        tput_tps: tuples.len() as f64 / dt,
        lat_p50_us: lat.p50(),
        lat_p99_us: lat.p99(),
        forwarded_per_tuple: forwarded as f64 / tuples.len() as f64,
    }
}

fn main() {
    let args = stretch::cli::Cli::new("bench_q1_wordcount", "Fig. 6: VSN vs SN by duplication level")
        .opt("tuples", "tweets per run", Some("12000"))
        .opt("pi", "parallelism degree", Some("3"))
        .opt("batch", "data-plane batch size (worker + SN queue hops)", Some("128"))
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let n = args.usize_or("tuples", 12_000).or_exit();
    let pi = args.usize_or("pi", 3).or_exit();
    let b = args.usize_or("batch", 128).or_exit().max(1);
    let tuning = stretch::config::BatchTuning { worker: b, ingress: b.max(256), queue: b };
    let tuples = corpus(n);

    let mut csv = CsvWriter::create(
        "results/q1_wordcount.csv",
        &["level", "dup_factor", "vsn_tps", "sn_tps", "tput_gain_pct", "vsn_p50_us", "sn_p50_us", "sn_forwarded_per_tuple"],
    )
    .unwrap();
    let mut table = Table::new(&[
        "level", "dup", "VSN t/s", "SN t/s", "Δtput", "VSN p50 µs", "SN p50 µs", "SN copies/t",
    ]);
    println!("Q1 (Fig. 6): {n} tweets, Π={pi} — higher duplication should widen the VSN win\n");
    let mut levels_json: Vec<stretch::metrics::Json> = Vec::new();
    for level in ["wordcount", "pair-L", "pair-M", "pair-H"] {
        let dup = duplication_factor(&tuples, key_fn(level));
        let v = run_vsn(level, &tuples, pi, &tuning);
        let s = run_sn(level, &tuples, pi, &tuning);
        let gain = (v.tput_tps / s.tput_tps - 1.0) * 100.0;
        levels_json.push(stretch::metrics::Json::obj(vec![
            ("level", level.into()),
            ("dup_factor", dup.into()),
            ("vsn_tput_tps", v.tput_tps.into()),
            ("sn_tput_tps", s.tput_tps.into()),
            ("tput_gain_pct", gain.into()),
            ("vsn_lat_p50_us", v.lat_p50_us.into()),
            ("vsn_lat_p99_us", v.lat_p99_us.into()),
            ("sn_lat_p50_us", s.lat_p50_us.into()),
            ("sn_lat_p99_us", s.lat_p99_us.into()),
            ("sn_forwarded_per_tuple", s.forwarded_per_tuple.into()),
        ]));
        stretch::csv_row!(
            csv, level, format!("{dup:.2}"), format!("{:.0}", v.tput_tps),
            format!("{:.0}", s.tput_tps), format!("{gain:.1}"),
            v.lat_p50_us, s.lat_p50_us, format!("{:.2}", s.forwarded_per_tuple)
        );
        table.row(&[
            level.into(),
            format!("{dup:.2}"),
            format!("{:.0}", v.tput_tps),
            format!("{:.0}", s.tput_tps),
            format!("{gain:+.0}%"),
            format!("{}", v.lat_p50_us),
            format!("{}", s.lat_p50_us),
            format!("{:.2}", s.forwarded_per_tuple),
        ]);
    }
    csv.flush().unwrap();
    table.print();
    let mut report = stretch::metrics::BenchReport::new("q1_wordcount");
    report
        .set("tuples", n)
        .set("pi", pi)
        .set("batch", b)
        .set("levels", stretch::metrics::Json::Arr(levels_json));
    match report.write() {
        Ok(p) => println!("\njson: {}", p.display()),
        Err(e) => eprintln!("\nBENCH_q1_wordcount.json write failed: {e}"),
    }
    println!("\npaper: wordcount +17% tput / −94% latency; pair-L/M/H +137/+237/+283% tput");
    println!("csv: results/q1_wordcount.csv");
}
