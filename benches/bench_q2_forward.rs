//! Q2 / Fig. 7 — max throughput & min latency vs Π(O+) for the
//! forwarding Operator 6 (I = 2): STRETCH (VSN) vs the SN baseline.
//!
//! Scaling beyond one core uses the calibrated simulator (DESIGN.md §5);
//! a real threaded spot-check anchors the Π ∈ {1, 2} points on this box.

use stretch::cli::OrExit;
use std::time::{Duration, Instant};
use stretch::engine::{VsnEngine, VsnOptions};
use stretch::metrics::reporter::Table;
use stretch::metrics::CsvWriter;
use stretch::sim::{calibrate, Arch};
use stretch::tuple::Tuple;
use stretch::workloads::forward_op;

/// Real threaded measurement of the VSN forwarding operator at Π.
fn real_vsn_forward(pi: usize, n: usize) -> f64 {
    let def = forward_op::<u64>(pi);
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions { initial: pi, max: pi, upstreams: 2, ..Default::default() },
    );
    let mut reader = readers.remove(0);
    let mut ing1 = ingress.remove(0);
    let mut ing0 = ingress.remove(0);
    let t0 = Instant::now();
    let feeder = std::thread::spawn(move || {
        for i in 0..n as i64 {
            // two logical inputs, interleaved
            ing0.add(Tuple::data_on(i, 0, i as u64)).unwrap();
            ing1.add(Tuple::data_on(i, 1, i as u64)).unwrap();
        }
        ing0.heartbeat(i64::MAX / 16).unwrap();
        ing1.heartbeat(i64::MAX / 16).unwrap();
    });
    let expect = (2 * n * pi) as u64; // each instance forwards every tuple
    let mut got = 0u64;
    let deadline = Instant::now() + Duration::from_secs(30);
    while got < expect && Instant::now() < deadline {
        match reader.get() {
            Some(t) if t.kind.is_data() => got += 1,
            Some(_) => {}
            None => std::thread::sleep(Duration::from_micros(50)),
        }
    }
    feeder.join().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    engine.shutdown();
    2.0 * n as f64 / dt // input tuples per second
}

fn main() {
    let args = stretch::cli::Cli::new("bench_q2_forward", "Fig. 7: Operator 6 scalability sweep")
        .opt("tuples", "tuples per real spot-check", Some("30000"))
        .flag("no-real", "skip the threaded spot-check")
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));

    println!("calibrating per-tuple costs on this machine...");
    let cal = calibrate();
    println!(
        "  gate={:.2}µs/t queue={:.3}µs/t sort={:.3}µs/t cmp={:.1}M c/s\n",
        cal.gate_tuple_s * 1e6,
        cal.queue_tuple_s * 1e6,
        cal.sort_tuple_s * 1e6,
        cal.cmp_per_sec / 1e6
    );

    let mut csv = CsvWriter::create(
        "results/q2_forward.csv",
        &["pi", "stretch_tps", "sn_tps", "ratio", "stretch_lat_ms", "sn_lat_ms"],
    )
    .unwrap();
    let mut table =
        Table::new(&["Π", "STRETCH t/s", "SN t/s", "ratio", "STRETCH lat ms", "SN lat ms"]);
    let st = Arch::StretchForward;
    let sn = Arch::SnForward;
    let mut sweep_json: Vec<stretch::metrics::Json> = Vec::new();
    for pi in [2usize, 4, 8, 12, 16, 24, 36] {
        let rs = st.max_rate(&cal, pi);
        let rn = sn.max_rate(&cal, pi);
        let ls = st.base_latency_ms(&cal, pi);
        // the paper's Flink latency floor (>100 ms) is dominated by its
        // buffer timeout; we report our SN baseline's model latency and
        // note the difference in EXPERIMENTS.md
        let ln = sn.base_latency_ms(&cal, pi);
        stretch::csv_row!(
            csv, pi, format!("{rs:.0}"), format!("{rn:.0}"),
            format!("{:.1}", rs / rn), format!("{ls:.1}"), format!("{ln:.1}")
        );
        table.row(&[
            pi.to_string(),
            format!("{rs:.0}"),
            format!("{rn:.0}"),
            format!("{:.1}×", rs / rn),
            format!("{ls:.1}"),
            format!("{ln:.1}"),
        ]);
        sweep_json.push(stretch::metrics::Json::obj(vec![
            ("pi", pi.into()),
            ("stretch_tput_tps", rs.into()),
            ("sn_tput_tps", rn.into()),
            ("ratio", (rs / rn).into()),
            ("stretch_lat_ms", ls.into()),
            ("sn_lat_ms", ln.into()),
        ]));
    }
    csv.flush().unwrap();
    println!("Q2 (Fig. 7) — simulated sweep (calibrated):");
    table.print();
    println!("\npaper: STRETCH 120k→100k t/s; Flink 40k→2k t/s; 3×-50× ratio; <30ms vs >100ms lat");

    let mut real_json: Vec<stretch::metrics::Json> = Vec::new();
    if !args.flag("no-real") {
        let n = args.usize_or("tuples", 30_000).or_exit();
        println!("\nreal threaded spot-check (1-core box, both instances share the core):");
        for pi in [1usize, 2] {
            let tps = real_vsn_forward(pi, n);
            println!("  Π={pi}: VSN forwarding sustained {tps:.0} t/s (wall-clock, threaded)");
            real_json.push(stretch::metrics::Json::obj(vec![
                ("pi", pi.into()),
                ("vsn_tput_tps", tps.into()),
            ]));
        }
    }
    let mut report = stretch::metrics::BenchReport::new("q2_forward");
    report
        .set("sim_sweep", stretch::metrics::Json::Arr(sweep_json))
        .set("real_spot_checks", stretch::metrics::Json::Arr(real_json));
    match report.write() {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("BENCH_q2_forward.json write failed: {e}"),
    }
    println!("csv: results/q2_forward.csv");
}
