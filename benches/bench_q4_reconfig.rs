//! Q4 / Fig. 9 + Table 4 + Fig. 10 — reconfiguration times and the
//! provisioning/decommissioning dynamics, measured on the REAL threaded
//! engine (the paper's headline: < 40 ms even when provisioning tens of
//! instances; at most 2% load imbalance).
//!
//! Default mode: for each starting Π, trigger one provisioning and one
//! decommissioning reconfiguration under load; report wall-clock
//! reconfiguration time and the coefficient of variation of per-thread
//! load. `--dynamics` replays the Fig. 10 rate step and prints the
//! rate/throughput/latency time series.

use stretch::cli::OrExit;
use stretch::elastic::{JoinCostModel, ReactiveController, Thresholds};
use stretch::harness::{run_elastic_join, JoinRunConfig};
use stretch::metrics::reporter::Table;
use stretch::metrics::CsvWriter;
use stretch::sim::calibrate;
use stretch::workloads::rates::RateSchedule;

/// Protocol-time measurement: steady 60%-of-capacity load, one scripted
/// reconfiguration (no controller). This isolates the paper's <40 ms
/// claim — the epoch-switch protocol itself (γ trigger → barrier →
/// membership → index rebuild) — from backlog queueing, which on a
/// 1-core container cannot drain in parallel the way the paper's
/// 72-thread testbed does (see the loaded runs + EXPERIMENTS.md).
fn protocol_run(
    start_pi: usize,
    target: Vec<usize>,
    ws_ms: i64,
    max: usize,
    model: JoinCostModel,
) -> (Option<usize>, Vec<f64>, f64) {
    let base = model.max_rate(start_pi) * 0.6;
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        initial: start_pi,
        max,
        schedule: RateSchedule::constant(10, base),
        time_scale: 2.0,
        manual_reconfigs: vec![(5, target)],
        gate_capacity: 2048,
        ..Default::default()
    });
    let end_pi = r.samples.last().map(|s| s.threads);
    let times: Vec<f64> = r.reconfigs.iter().map(|&(_, ms)| ms).collect();
    let cv = r.samples.iter().rev().take(3).map(|s| s.load_cv_pct).fold(0.0f64, f64::max);
    (end_pi, times, cv)
}

/// Loaded run (the paper's §8.4 protocol: 70% → 120%/30% rate step with
/// the reactive controller). The measured time includes the backlog the
/// overload creates — on this 1-core box the surplus cannot drain in
/// parallel, so these are upper bounds (reported separately).
fn reconfig_run(
    start_pi: usize,
    max: usize,
    ws_ms: i64,
    provision: bool,
    model: JoinCostModel,
) -> (Option<usize>, Vec<f64>, f64) {
    let base = model.max_rate(start_pi.min(1).max(start_pi));
    let lead_s = 4u32;
    let (r0, r1) = if provision { (0.7 * base, 1.2 * base) } else { (0.7 * base, 0.3 * base) };
    let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(2);
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        initial: start_pi,
        max,
        schedule: RateSchedule::step(12, lead_s, r0, r1),
        time_scale: 2.0,
        controller: Some(Box::new(ctl)),
        controller_period_s: 1,
        gate_capacity: 1024,
        ..Default::default()
    });
    let end_pi = r.samples.last().map(|s| s.threads);
    let times: Vec<f64> = r.reconfigs.iter().map(|&(_, ms)| ms).collect();
    let cv = r
        .samples
        .iter()
        .rev()
        .take(3)
        .map(|s| s.load_cv_pct)
        .fold(0.0f64, f64::max);
    (end_pi, times, cv)
}

fn main() {
    let args = stretch::cli::Cli::new("bench_q4_reconfig", "Fig. 9/10 + Table 4: reconfiguration")
        .opt("ws-ms", "window size ms", Some("3000"))
        .opt("max", "max parallelism n", Some("6"))
        .flag("dynamics", "run the Fig. 10 time-series instead")
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let ws_ms = args.u64_or("ws-ms", 3_000).or_exit() as i64;
    let max = args.usize_or("max", 6).or_exit();

    let cal = calibrate();
    // model calibrated to this box, shared by controller and rate choice;
    // divide by max so the multi-threads-on-one-core runs stay feasible
    let model = JoinCostModel::new(cal.cmp_per_sec / max as f64, ws_ms as f64 / 1e3);

    if args.flag("dynamics") {
        println!("Q4 dynamics (Fig. 10): rate step with reactive controller\n");
        let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(2);
        let base = model.max_rate(2);
        let r = run_elastic_join(JoinRunConfig {
            ws_ms,
            initial: 2,
            max,
            schedule: RateSchedule::step(16, 6, 0.7 * base, 1.3 * base),
            time_scale: 2.0,
            controller: Some(Box::new(ctl)),
            ..Default::default()
        });
        let mut csv = CsvWriter::create(
            "results/q4_dynamics.csv",
            &["t_s", "offered_tps", "in_tps", "cmp_per_s", "lat_mean_us", "threads", "backlog"],
        )
        .unwrap();
        println!("  t  offered   served    cmp/s      lat(ms) Π backlog");
        for s in &r.samples {
            stretch::csv_row!(
                csv, s.t_s, format!("{:.0}", s.offered_tps), format!("{:.0}", s.in_tps),
                format!("{:.2e}", s.cmp_per_s), format!("{:.0}", s.latency_mean_us),
                s.threads, s.backlog
            );
            println!(
                "{:>4} {:>8.0} {:>8.0} {:>10.2e} {:>8.1} {} {:>7}",
                s.t_s,
                s.offered_tps,
                s.in_tps,
                s.cmp_per_s,
                s.latency_mean_us / 1e3,
                s.threads,
                s.backlog
            );
        }
        csv.flush().unwrap();
        let times: Vec<f64> = r.reconfigs.iter().map(|&(_, ms)| ms).collect();
        let lat_mean = r.samples.iter().map(|s| s.latency_mean_us).sum::<f64>()
            / r.samples.len().max(1) as f64;
        let mut report = stretch::metrics::BenchReport::new("q4_reconfig");
        report
            .set("mode", "dynamics")
            .set("reconfig_ms", times)
            .set("lat_mean_us", lat_mean)
            .set("peak_threads", r.samples.iter().map(|s| s.threads).max().unwrap_or(0));
        match report.write() {
            Ok(p) => println!("json: {}", p.display()),
            Err(e) => eprintln!("BENCH_q4_reconfig.json write failed: {e}"),
        }
        println!("\nreconfigs: {:?} (ms)", r.reconfigs);
        println!("csv: results/q4_dynamics.csv");
        return;
    }

    let mut csv = CsvWriter::create(
        "results/q4_reconfig.csv",
        &["mode", "start_pi", "action", "end_pi", "reconfig_ms", "load_cv_pct"],
    )
    .unwrap();
    let mut table = Table::new(&["mode", "start Π", "action", "end Π", "reconfig ms", "load CV %"]);
    let mut runs_json: Vec<stretch::metrics::Json> = Vec::new();
    let starts: Vec<usize> = (1..max).collect();
    println!("Q4 (Fig. 9 / Table 4): measured reconfiguration times (threaded engine)\n");
    // (a) protocol time: steady load, scripted switch — the <40ms claim
    for &pi in &starts {
        for provision in [true, false] {
            if !provision && pi == 1 {
                continue;
            }
            let target: Vec<usize> = if provision {
                (0..max).collect()
            } else {
                (0..pi.div_ceil(2)).collect()
            };
            let action = if provision { "provision" } else { "decommission" };
            let (end, times, cv) = protocol_run(pi, target, ws_ms, max, model);
            runs_json.push(stretch::metrics::Json::obj(vec![
                ("mode", "protocol".into()),
                ("start_pi", pi.into()),
                ("action", action.into()),
                ("end_pi", end.unwrap_or(0).into()),
                ("reconfig_ms", times.clone().into()),
                ("load_cv_pct", cv.into()),
            ]));
            for ms in &times {
                stretch::csv_row!(
                    csv, "protocol", pi, action, end.unwrap_or(0), format!("{ms:.2}"), format!("{cv:.2}")
                );
                table.row(&[
                    "protocol".into(),
                    pi.to_string(),
                    action.into(),
                    end.map(|e| e.to_string()).unwrap_or_default(),
                    format!("{ms:.2}"),
                    format!("{cv:.2}"),
                ]);
            }
        }
    }
    // (b) loaded runs: the paper's 70%→120%/30% protocol with controller
    for &pi in &[1usize, 2, 3] {
        for provision in [true, false] {
            if !provision && pi == 1 {
                continue;
            }
            let (end, times, cv) = reconfig_run(pi, max, ws_ms, provision, model);
            let action = if provision { "provision" } else { "decommission" };
            let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
            runs_json.push(stretch::metrics::Json::obj(vec![
                ("mode", "loaded".into()),
                ("start_pi", pi.into()),
                ("action", action.into()),
                ("end_pi", end.unwrap_or(0).into()),
                ("reconfig_ms", times.clone().into()),
                ("load_cv_pct", cv.into()),
            ]));
            for ms in &times {
                stretch::csv_row!(
                    csv, "loaded", pi, action, end.unwrap_or(0), format!("{ms:.2}"), format!("{cv:.2}")
                );
            }
            table.row(&[
                "loaded".into(),
                pi.to_string(),
                action.into(),
                end.map(|e| e.to_string()).unwrap_or_default(),
                if best.is_finite() { format!("{best:.2}") } else { "-".into() },
                format!("{cv:.2}"),
            ]);
        }
    }
    csv.flush().unwrap();
    table.print();
    let mut report = stretch::metrics::BenchReport::new("q4_reconfig");
    report.set("mode", "protocol+loaded").set("runs", stretch::metrics::Json::Arr(runs_json));
    match report.write() {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("BENCH_q4_reconfig.json write failed: {e}"),
    }
    println!("\npaper: all reconfiguration times < 40 ms; load imbalance ≤ 2%");
    println!("protocol rows isolate the epoch switch; loaded rows include 1-core backlog drain");
    println!("csv: results/q4_reconfig.csv");
}
