//! Micro-benchmarks + ablations: the per-component costs behind every
//! other bench — headlined by the per-tuple vs batched ESG data-plane
//! comparison (§Perf; the acceptance gate is batched ≥ 2× per-tuple) —
//! and the PJRT-offload batch-size sweep (the L1↔L3 crossover study
//! referenced by DESIGN.md §Hardware-Adaptation). The gate-placement
//! experiment measures cross-thread ESG throughput under the best vs the
//! worst placement the machine offers (NUMA local-vs-cross on a
//! multi-socket box) — the data behind `[placement]`.
//!
//! `--budget-ms N` bounds each component measurement (CI smoke uses a
//! tiny budget so bench bit-rot fails the pipeline). Writes
//! `BENCH_micro.json` next to the human output.

use std::time::Instant;
use stretch::cli::OrExit;
use stretch::metrics::reporter::Table;
use stretch::metrics::{alloc_snapshot, BenchReport, CountingAlloc, Json};
use stretch::runtime::{artifacts_available, CoreMap, JoinKernel};
use stretch::sim::calibrate::{
    calibrate_with, measure_gate_batch_cost, measure_gate_cost_threaded, GATE_BATCH,
};
use stretch::tuple::Tuple;
use stretch::util::Rng;

/// Count every allocation this binary makes (§Perf memory discipline):
/// the steady-state experiments below measure allocator traffic, not
/// time, so their numbers are deterministic enough for the 1.2×
/// `bench-diff --gate-kinds alloc` CI gate.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn offload_sweep(table: &mut Table) {
    if !artifacts_available() {
        println!("(skipping offload sweep: run `make artifacts`)");
        return;
    }
    let mut kernel = JoinKernel::load().unwrap();
    let mut rng = Rng::new(5);
    for w in [128usize, 512, 2048, 8192] {
        let wa: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 10_000.0)).collect();
        let wb: Vec<f32> = (0..w).map(|_| rng.f32_range(0.0, 10_000.0)).collect();
        let mut idx = Vec::new();
        // warm
        kernel.probe_indices(5_000.0, 5_000.0, &wa, &wb, &mut idx).unwrap();
        let t0 = Instant::now();
        let mut calls = 0u64;
        while t0.elapsed().as_millis() < 200 {
            kernel.probe_indices(5_000.0, 5_000.0, &wa, &wb, &mut idx).unwrap();
            calls += 1;
        }
        let per_call_us = t0.elapsed().as_secs_f64() * 1e6 / calls as f64;
        // scalar comparison loop over the same window
        let t1 = Instant::now();
        let mut loops = 0u64;
        let mut acc = 0u64;
        while t1.elapsed().as_millis() < 100 {
            for i in 0..w {
                let m = (5_000.0 - wa[i]).abs() <= 10.0 && (5_000.0 - wb[i]).abs() <= 10.0;
                acc += m as u64;
            }
            loops += 1;
        }
        std::hint::black_box(acc);
        let scalar_us = t1.elapsed().as_secs_f64() * 1e6 / loops as f64;
        table.row(&[
            format!("offload W={w}"),
            format!("{per_call_us:.1} µs/probe-call"),
            format!("scalar {scalar_us:.2} µs"),
            format!("{:.0}× PJRT overhead", per_call_us / scalar_us.max(0.001)),
        ]);
    }
}

/// Outcome of the gate-placement experiment (the tentpole's measurable
/// claim: reader locality matters on the gate hot path).
struct PlacementResult {
    /// What the machine could express: `local_vs_cross` (≥ 2 sockets),
    /// `pinned_vs_unpinned` (≥ 2 cores, one socket), or `single_core`.
    mode: &'static str,
    sockets: usize,
    cores: usize,
    local_tps: f64,
    remote_tps: f64,
}

/// Cross-thread gate throughput under the best placement the machine
/// offers vs the worst (or no) placement. On a multi-socket box this is
/// the NUMA local-vs-cross comparison the tentpole is about; on a
/// single-socket box pinned-vs-unpinned still shows the scheduler-churn
/// cost; a 1-core container degrades to one unpinned probe.
fn placement_experiment(budget_ms: u64) -> PlacementResult {
    let map = CoreMap::discover();
    let ms = budget_ms.max(10);
    let (mode, local_tps, remote_tps) = if map.sockets() >= 2 {
        let s0 = map.cores_on(0);
        let s1 = map.cores_on(1);
        let local = measure_gate_cost_threaded(ms, Some(s0[0]), Some(s0[1 % s0.len()]));
        let remote = measure_gate_cost_threaded(ms, Some(s0[0]), Some(s1[0]));
        ("local_vs_cross", local, remote)
    } else if map.len() >= 2 {
        let cores = map.cores_on(0);
        let pinned = measure_gate_cost_threaded(ms, Some(cores[0]), Some(cores[1]));
        let floating = measure_gate_cost_threaded(ms, None, None);
        ("pinned_vs_unpinned", pinned, floating)
    } else {
        let tput = measure_gate_cost_threaded(ms, None, None);
        ("single_core", tput, tput)
    };
    PlacementResult { mode, sockets: map.sockets(), cores: map.len(), local_tps, remote_tps }
}

/// Steady-state allocation discipline of the batched-gate hot path:
/// the same add_batch → merge → get_batch loop as
/// [`measure_gate_batch_cost`], but COUNT-based — 16 warm rounds settle
/// every pool and scratch capacity, then 64 measured rounds are divided
/// by tuples moved. Returns (allocs/tuple, bytes/tuple); the
/// steady-state contract is ≈ 0 (anything per-tuple would show up as
/// ≥ 1.0 here).
fn gate_alloc_experiment(batch: usize) -> (f64, f64) {
    let (_g, mut src, mut rdr) = stretch::scalegate::scale_gate::<Tuple<u64>>(1, 1, 1 << 14);
    let mut ts = 0i64;
    let mut run: Vec<Tuple<u64>> = Vec::with_capacity(batch);
    let mut out: Vec<Tuple<u64>> = Vec::with_capacity(batch);
    let mut round = |ts: &mut i64, run: &mut Vec<Tuple<u64>>, out: &mut Vec<Tuple<u64>>| {
        for _ in 0..batch {
            *ts += 1;
            run.push(Tuple::data(*ts, 1));
        }
        src[0].add_batch(run).unwrap();
        while rdr[0].get_batch(out, batch) > 0 {}
        out.clear();
    };
    for _ in 0..16 {
        round(&mut ts, &mut run, &mut out);
    }
    const ROUNDS: u64 = 64;
    let before = alloc_snapshot();
    for _ in 0..ROUNDS {
        round(&mut ts, &mut run, &mut out);
    }
    let d = alloc_snapshot().delta(before);
    let tuples = (ROUNDS * batch as u64) as f64;
    (d.allocs as f64 / tuples, d.bytes as f64 / tuples)
}

/// Allocation traffic of a live 4-stage diamond DAG
/// (filter → L-leg ∥ R-leg → hedge join) in steady state: warm half the
/// corpus, quiesce, then count the allocator traffic of the second
/// half. Threaded — worker scheduling adds cross-run variance — so the
/// recorded fields carry the `diamond_` prefix and stay Info (recorded,
/// never gated) in `bench-diff`.
fn diamond_alloc_experiment() -> (f64, f64) {
    use stretch::engine::dag::DagBuilder;
    use stretch::engine::{StretchIngress, VsnOptions};
    use stretch::scalegate::ReaderHandle;
    use stretch::workloads::nyse::{
        hedge_join_op, left_leg_op, right_leg_op, trade_filter_op, HedgeOut, NyseConfig, Trade,
        TradeStream,
    };

    // chunked feed + drain from one thread: 2048 < every gate capacity,
    // so neither the in-gate nor the out backlog can wedge the feeder
    fn feed_chunked(
        ing: &mut StretchIngress<Trade>,
        reader: &mut ReaderHandle<Tuple<HedgeOut>>,
        trades: &[Tuple<Trade>],
        buf: &mut Vec<Tuple<HedgeOut>>,
    ) {
        for chunk in trades.chunks(2048) {
            for t in chunk {
                ing.add(t.clone()).unwrap();
            }
            while reader.get_batch(buf, 256) > 0 {
                buf.clear();
            }
        }
    }

    // drain until the DAG goes quiet (all stages idle at their gates)
    fn quiesce(reader: &mut ReaderHandle<Tuple<HedgeOut>>, buf: &mut Vec<Tuple<HedgeOut>>) {
        let mut empty = 0u32;
        while empty < 100 {
            if reader.get_batch(buf, 256) == 0 {
                empty += 1;
                std::thread::sleep(std::time::Duration::from_micros(200));
            } else {
                empty = 0;
                buf.clear();
            }
        }
    }

    let opts = || VsnOptions { initial: 1, max: 2, gate_capacity: 8192, ..Default::default() };
    let mut b = DagBuilder::<Trade>::new();
    let s = b.source(trade_filter_op(64), opts());
    let l = b.node(left_leg_op(64), opts(), &[s]);
    let r = b.node(right_leg_op(64), opts(), &[s]);
    let j = b.node(hedge_join_op(400, 32), opts(), &[l, r]);
    let mut pipeline = b.build(&[j]).expect("diamond is a valid DAG");
    let mut ing = pipeline.ingress.remove(0);
    let mut reader = pipeline.egress.remove(0);

    const WARM: usize = 6_000;
    const MEASURED: usize = 6_000;
    let cfg = NyseConfig { symbols: 8, ..Default::default() };
    let mut stream = TradeStream::new(&cfg, 1_000.0);
    let trades: Vec<_> = (0..WARM + MEASURED).map(|_| stream.next()).collect();
    let horizon = trades.last().unwrap().ts + 10_000;

    let mut buf = Vec::new();
    feed_chunked(&mut ing, &mut reader, &trades[..WARM], &mut buf);
    quiesce(&mut reader, &mut buf);
    let before = alloc_snapshot();
    feed_chunked(&mut ing, &mut reader, &trades[WARM..], &mut buf);
    quiesce(&mut reader, &mut buf);
    let d = alloc_snapshot().delta(before);
    ing.heartbeat(horizon).unwrap();
    quiesce(&mut reader, &mut buf);
    pipeline.shutdown();
    (d.allocs as f64 / MEASURED as f64, d.bytes as f64 / MEASURED as f64)
}

fn main() {
    let args = stretch::cli::Cli::new("bench_micro", "per-component costs + ESG batching win")
        .opt("budget-ms", "measurement budget per component (ms)", Some("100"))
        .flag("no-offload", "skip the PJRT offload sweep")
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let budget_ms = args.u64_or("budget-ms", 100).or_exit().max(5);

    println!("micro-benchmarks (release numbers feed the simulator + EXPERIMENTS.md §Perf)\n");
    let cal = calibrate_with(budget_ms);
    let speedup = cal.gate_tuple_s / cal.gate_batch_tuple_s.max(1e-12);
    let mut table = Table::new(&["component", "cost", "reference", "note"]);
    table.row(&[
        "ESG add+merge+get (per-tuple)".into(),
        format!("{:.3} µs/tuple", cal.gate_tuple_s * 1e6),
        format!("{:.1}M t/s", 1.0 / cal.gate_tuple_s / 1e6),
        "pre-batching data plane".into(),
    ]);
    table.row(&[
        format!("ESG batched (runs of {GATE_BATCH})"),
        format!("{:.3} µs/tuple", cal.gate_batch_tuple_s * 1e6),
        format!("{:.1}M t/s", 1.0 / cal.gate_batch_tuple_s / 1e6),
        format!("{speedup:.1}× vs per-tuple"),
    ]);
    table.row(&[
        "SPSC push+pop".into(),
        format!("{:.3} µs/tuple", cal.queue_tuple_s * 1e6),
        format!("{:.1}M t/s", 1.0 / cal.queue_tuple_s / 1e6),
        "SN dedicated queue hop".into(),
    ]);
    table.row(&[
        "merge-sort ingest".into(),
        format!("{:.3} µs/tuple", cal.sort_tuple_s * 1e6),
        format!("{:.1}M t/s", 1.0 / cal.sort_tuple_s / 1e6),
        "SN per-instance sorter".into(),
    ]);
    table.row(&[
        "band predicate (1T loop)".into(),
        format!("{:.1}M cmp/s", cal.cmp_per_sec / 1e6),
        format!("{:.2} ns/cmp", 1e9 / cal.cmp_per_sec),
        "the paper's c/s metric".into(),
    ]);
    let (gate_apt, gate_bpt) = gate_alloc_experiment(GATE_BATCH);
    table.row(&[
        "batched gate allocs/tuple".into(),
        format!("{gate_apt:.4}"),
        format!("{gate_bpt:.1} B/tuple"),
        "steady-state contract ≈ 0".into(),
    ]);
    let (dia_apt, dia_bpt) = diamond_alloc_experiment();
    table.row(&[
        "diamond DAG allocs/tuple".into(),
        format!("{dia_apt:.3}"),
        format!("{dia_bpt:.1} B/tuple"),
        "threaded; recorded, not gated".into(),
    ]);
    let placement = placement_experiment(budget_ms);
    table.row(&[
        format!("gate placement ({})", placement.mode),
        format!("{:.1}M t/s local", placement.local_tps / 1e6),
        format!("{:.1}M t/s remote", placement.remote_tps / 1e6),
        format!(
            "{:.2}× ({} socket(s), {} core(s))",
            placement.local_tps / placement.remote_tps.max(1.0),
            placement.sockets,
            placement.cores
        ),
    ]);
    if !args.flag("no-offload") {
        offload_sweep(&mut table);
    }
    table.print();

    // batch-size sweep for the trajectory record
    let mut sweep = Vec::new();
    for b in [16usize, 64, 256, 1024] {
        let cost = measure_gate_batch_cost(b, budget_ms / 2);
        sweep.push(Json::obj(vec![
            ("batch", Json::from(b)),
            ("us_per_tuple", Json::from(cost * 1e6)),
            ("tput_tps", Json::from(1.0 / cost)),
        ]));
    }

    let mut report = BenchReport::new("micro");
    report
        .set("budget_ms", budget_ms)
        .set("esg_per_tuple_tps", 1.0 / cal.gate_tuple_s)
        .set("esg_batched_tps", 1.0 / cal.gate_batch_tuple_s)
        .set("esg_batch_size", GATE_BATCH)
        .set("esg_batched_speedup", speedup)
        .set("esg_batched_speedup_target", 2.0)
        .set("esg_batched_meets_target", speedup >= 2.0)
        .set("esg_batch_sweep", Json::Arr(sweep))
        .set("spsc_tps", 1.0 / cal.queue_tuple_s)
        .set("mergesort_tps", 1.0 / cal.sort_tuple_s)
        .set("cmp_per_s", cal.cmp_per_sec)
        .set("allocs_per_tuple_batched_gate", gate_apt)
        .set("bytes_per_tuple_batched_gate", gate_bpt)
        .set("diamond_allocs_per_tuple", dia_apt)
        .set("diamond_bytes_per_tuple", dia_bpt)
        .set("placement_mode", placement.mode)
        .set("placement_sockets", placement.sockets)
        .set("placement_cores", placement.cores)
        .set("gate_local_tps", placement.local_tps)
        .set("gate_remote_tps", placement.remote_tps)
        .set("gate_local_speedup", placement.local_tps / placement.remote_tps.max(1.0))
        .set(
            "machine",
            std::env::var("STRETCH_BENCH_MACHINE").unwrap_or_else(|_| "unnamed".into()),
        );
    match report.write() {
        Ok(p) => println!("\njson: {}", p.display()),
        Err(e) => eprintln!("\nBENCH_micro.json write failed: {e}"),
    }

    println!(
        "\nbatched ESG data plane: {speedup:.1}× the per-tuple path (target ≥ 2×, runs of {GATE_BATCH})"
    );
    println!("interpretation: on CPU-PJRT (interpret-mode Pallas) the per-call dispatch");
    println!("dominates, so the scalar loop wins at every window size — the offload is");
    println!("compile-only on this box; the TPU roofline estimate is in DESIGN.md §6.");
    println!(
        "steady-state allocation discipline: {gate_apt:.4} allocs/tuple on the batched gate \
         (contract < 0.01), diamond DAG {dia_apt:.3} (recorded, not gated)"
    );
    // count-based, so no budget escape hatch: the number is deterministic
    // at any budget, and a regression here means a hot path re-learned
    // how to allocate
    assert!(
        gate_apt < 0.01,
        "batched-gate steady state allocates {gate_apt:.4}/tuple — the ≈0 contract is broken"
    );
    assert!(
        speedup >= 2.0 || budget_ms < 20,
        "batched ESG speedup {speedup:.2}× below the 2× acceptance bar"
    );
}
