//! Q3 / Fig. 8 — ScaleJoin benchmark: sustainable input rate, comparison
//! throughput (c/s) and latency vs Π(J+): STRETCH vs ad-hoc ScaleJoin vs
//! the optimized 1T baseline.
//!
//! The Π sweep uses the calibrated simulator; the 1T point and the
//! STRETCH Π = 1 point are *measured* on this box (real threaded runs),
//! anchoring the curves.

use stretch::cli::OrExit;
use std::time::Instant;
use stretch::harness::{run_elastic_join, JoinRunConfig};
use stretch::metrics::reporter::Table;
use stretch::metrics::CsvWriter;
use stretch::sim::{calibrate, Arch};
use stretch::workloads::rates::RateSchedule;
use stretch::workloads::scalejoin_bench::{OneT, SjGen};

/// Measured 1T comparison throughput at saturation.
fn measure_1t(ws_ms: i64) -> (f64, f64) {
    let mut gen = SjGen::new(3, 20_000.0);
    let mut j = OneT::new(ws_ms);
    for t in gen.take(4_000) {
        j.process(&t); // warm the window
    }
    let c0 = j.comparisons;
    let n0 = 4_000u64;
    let mut n = n0;
    let t0 = Instant::now();
    while t0.elapsed().as_millis() < 500 {
        for t in gen.take(1024) {
            j.process(&t);
        }
        n += 1024;
    }
    let dt = t0.elapsed().as_secs_f64();
    (((n - n0) as f64) / dt, (j.comparisons - c0) as f64 / dt)
}

fn main() {
    let args = stretch::cli::Cli::new("bench_q3_scalejoin", "Fig. 8: ScaleJoin scalability")
        .opt("ws-ms", "window size ms (paper: 300000)", Some("5000"))
        .flag("no-real", "skip real measured anchors")
        .parse()
        .unwrap_or_else(|e| panic!("{e}"));
    let ws_ms: i64 = args.u64_or("ws-ms", 5_000).or_exit() as i64;
    let ws_s = ws_ms as f64 / 1e3;

    println!("calibrating...");
    let cal = calibrate();
    let stretch_arch = Arch::StretchJoin { ws_s, overhead: 1.2 };
    let scalejoin_arch = Arch::ScaleJoinSn { ws_s };
    let onet_arch = Arch::OneTJoin { ws_s };

    let mut csv = CsvWriter::create(
        "results/q3_scalejoin.csv",
        &["pi", "stretch_rate", "scalejoin_rate", "onet_rate", "stretch_cps", "scalejoin_cps", "stretch_lat_ms", "onet_lat_ms"],
    )
    .unwrap();
    let mut table = Table::new(&[
        "Π", "STRETCH t/s", "ScaleJoin t/s", "1T t/s", "STRETCH c/s", "lat ms", "1T lat ms",
    ]);
    let mut sweep_json: Vec<stretch::metrics::Json> = Vec::new();
    for pi in [1usize, 2, 4, 8, 16, 24, 36, 48, 60, 72] {
        let rs = stretch_arch.max_rate(&cal, pi);
        let rj = scalejoin_arch.max_rate(&cal, pi);
        let r1 = onet_arch.max_rate(&cal, pi);
        sweep_json.push(stretch::metrics::Json::obj(vec![
            ("pi", pi.into()),
            ("stretch_rate_tps", rs.into()),
            ("scalejoin_rate_tps", rj.into()),
            ("onet_rate_tps", r1.into()),
            ("stretch_cmp_per_s", stretch_arch.cmp_throughput(rs).into()),
            ("stretch_lat_ms", stretch_arch.base_latency_ms(&cal, pi).into()),
        ]));
        stretch::csv_row!(
            csv, pi, format!("{rs:.0}"), format!("{rj:.0}"), format!("{r1:.0}"),
            format!("{:.3e}", stretch_arch.cmp_throughput(rs)),
            format!("{:.3e}", scalejoin_arch.cmp_throughput(rj)),
            format!("{:.1}", stretch_arch.base_latency_ms(&cal, pi)),
            format!("{:.2}", onet_arch.base_latency_ms(&cal, pi))
        );
        table.row(&[
            pi.to_string(),
            format!("{rs:.0}"),
            format!("{rj:.0}"),
            format!("{r1:.0}"),
            format!("{:.2e}", stretch_arch.cmp_throughput(rs)),
            format!("{:.1}", stretch_arch.base_latency_ms(&cal, pi)),
            format!("{:.2}", onet_arch.base_latency_ms(&cal, pi)),
        ]);
    }
    csv.flush().unwrap();
    println!("Q3 (Fig. 8) — sweep (WS={ws_s}s; paper uses 300s):");
    table.print();
    println!("\npaper shape: STRETCH grows ~linearly with Π, matches ScaleJoin (small gap),");
    println!("1T flat with lowest latency; HT degradation beyond 36 threads");

    let mut report = stretch::metrics::BenchReport::new("q3_scalejoin");
    report.set("ws_ms", ws_ms).set("sim_sweep", stretch::metrics::Json::Arr(sweep_json));
    if !args.flag("no-real") {
        println!("\nmeasured anchors on this box:");
        let (tps_1t, cps_1t) = measure_1t(ws_ms);
        println!("  1T:          {tps_1t:.0} t/s sustained, {:.2}M c/s", cps_1t / 1e6);
        // STRETCH Π=1 real: drive at ~70% of sim capacity, verify sustained
        let target = stretch_arch.max_rate(&cal, 1) * 0.7;
        let r = run_elastic_join(JoinRunConfig {
            ws_ms,
            initial: 1,
            max: 1,
            schedule: RateSchedule::constant(5, target),
            time_scale: 1.0,
            ..Default::default()
        });
        let avg_cps: f64 =
            r.samples.iter().map(|s| s.cmp_per_s).sum::<f64>() / r.samples.len() as f64;
        let avg_lat: f64 =
            r.samples.iter().map(|s| s.latency_mean_us).sum::<f64>() / r.samples.len() as f64;
        let p50 = {
            let mut v: Vec<u64> = r.samples.iter().map(|s| s.latency_p50_us).collect();
            v.sort_unstable();
            v.get(v.len() / 2).copied().unwrap_or(0)
        };
        println!(
            "  STRETCH Π=1: offered {target:.0} t/s → {:.2}M c/s, mean latency {:.1} ms (threaded)",
            avg_cps / 1e6,
            avg_lat / 1e3
        );
        println!(
            "  generic-O+ overhead vs 1T: {:.1}% (paper: STRETCH ≈ ScaleJoin ≈ 1T at Π=1)",
            (cps_1t / avg_cps.max(1.0) - 1.0) * 100.0
        );
        report
            .set("real_1t_tput_tps", tps_1t)
            .set("real_1t_cmp_per_s", cps_1t)
            .set("real_stretch_pi1_offered_tps", target)
            .set("real_stretch_pi1_cmp_per_s", avg_cps)
            .set("real_stretch_pi1_lat_mean_us", avg_lat)
            .set("real_stretch_pi1_lat_p50_us", p50);
    }
    match report.write() {
        Ok(p) => println!("json: {}", p.display()),
        Err(e) => eprintln!("BENCH_q3_scalejoin.json write failed: {e}"),
    }
    println!("csv: results/q3_scalejoin.csv");
}
