//! Experiment/query configuration system.
//!
//! Parses a TOML-subset (sections, `key = value`, strings, ints, floats,
//! bools, comments) — enough for real experiment configs without the
//! (offline-unavailable) serde/toml stack — and exposes typed accessors
//! plus the experiment config structs consumed by the CLI launcher.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// Data-plane batch sizes (§Perf): how many tuples move per gate/queue
/// synchronization on each hot path. Parsed from a config's `[batch]`
/// section; engine option structs consume it via
/// `VsnOptions::with_batch` / `SnOptions::with_batch`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchTuning {
    /// VSN worker gate synchronization granularity (ESG get_batch /
    /// add_batch per instance loop iteration).
    pub worker: usize,
    /// Ingress run length: tuples an upstream accumulates before one
    /// batched `addSTRETCH` / `forwardSN`.
    pub ingress: usize,
    /// SN instance queue hop granularity (SPSC push_slice / pop_chunk).
    pub queue: usize,
    /// Adaptive worker-batch sizing (`[batch] adaptive = true`): the
    /// harness re-derives each stage's effective worker batch from its
    /// observed `in_backlog` every controller tick — cold stages flush
    /// small for latency, hot stages batch large for throughput.
    pub adaptive: bool,
    /// Lower clamp of the adaptive worker batch.
    pub worker_min: usize,
    /// Upper clamp of the adaptive worker batch (≥ `worker_min`).
    pub worker_max: usize,
}

impl Default for BatchTuning {
    fn default() -> Self {
        BatchTuning {
            worker: 128,
            ingress: 256,
            queue: 128,
            adaptive: false,
            worker_min: 16,
            worker_max: 1024,
        }
    }
}

impl BatchTuning {
    /// Read the `[batch]` section (missing keys keep defaults; values
    /// are clamped to ≥ 1 so a zero can never stall a loop, and
    /// `worker_max` is clamped to ≥ `worker_min`).
    ///
    /// Adding a key here? Also register it in
    /// `harness::JOB_SECTION_KEYS`, or job configs using it will be
    /// rejected as typos.
    pub fn from_config(c: &Config) -> Self {
        let d = BatchTuning::default();
        let worker_min = (c.int_or("batch.worker_min", d.worker_min as i64).max(1)) as usize;
        BatchTuning {
            worker: (c.int_or("batch.worker", d.worker as i64).max(1)) as usize,
            ingress: (c.int_or("batch.ingress", d.ingress as i64).max(1)) as usize,
            queue: (c.int_or("batch.queue", d.queue as i64).max(1)) as usize,
            adaptive: c.bool_or("batch.adaptive", d.adaptive),
            worker_min,
            worker_max: (c.int_or("batch.worker_max", d.worker_max as i64).max(1) as usize)
                .max(worker_min),
        }
    }
}

/// Core/NUMA placement knobs (see `runtime::placement`): parsed from a
/// config's `[placement]` section. Off by default — pinning is a win on
/// dedicated machines and a hazard on oversubscribed shared runners.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementConfig {
    /// Master switch: compute a `PlacementPlan` for the job and pin
    /// threads / first-touch gate memory accordingly.
    pub enabled: bool,
    /// Pin the `JobHandle` runtime thread (feed/drain/sampling) to the
    /// plan's runtime core.
    pub pin_runtime: bool,
    /// Pin worker threads and run gate first-touch initialization on
    /// the owning stage's socket.
    pub pin_workers: bool,
}

impl Default for PlacementConfig {
    fn default() -> Self {
        PlacementConfig { enabled: false, pin_runtime: true, pin_workers: true }
    }
}

impl PlacementConfig {
    /// Read the `[placement]` section (missing keys keep defaults).
    ///
    /// Adding a key here? Also register it in
    /// `harness::JOB_SECTION_KEYS`, or job configs using it will be
    /// rejected as typos.
    pub fn from_config(c: &Config) -> Self {
        let d = PlacementConfig::default();
        PlacementConfig {
            enabled: c.bool_or("placement.enabled", d.enabled),
            pin_runtime: c.bool_or("placement.pin_runtime", d.pin_runtime),
            pin_workers: c.bool_or("placement.pin_workers", d.pin_workers),
        }
    }
}

/// Fault-injection & supervision knobs: parsed from a config's
/// `[faults]` section (the chaos face of `harness::faults`). Absent
/// section ⇒ `enabled = false` and NO supervisor is attached — healthy
/// jobs keep exactly their pre-supervision behavior.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultsConfig {
    /// True iff the config has any `faults.*` key: the opt-in switch for
    /// the whole supervision machinery (detector thresholds, supervisor
    /// policy, recovery tickets).
    pub enabled: bool,
    /// Attach a `SupervisorPolicy` so injected faults self-heal (default
    /// true); `false` runs the raw containment story — workers die and
    /// stay dead, for experiments that measure degradation itself.
    pub supervise: bool,
    /// Stall detector window (ms): a worker whose progress epoch hasn't
    /// advanced for this long while its stage has backlog is classified
    /// stalled.
    pub stall_after_ms: u64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig { enabled: false, supervise: true, stall_after_ms: 250 }
    }
}

impl FaultsConfig {
    /// Read the `[faults]` section (missing keys keep defaults). The
    /// scripted `steps` list is parsed separately —
    /// `harness::FaultPlan::parse` needs the declared stage names.
    ///
    /// Adding a key here? Also register it in
    /// `harness::JOB_SECTION_KEYS`, or job configs using it will be
    /// rejected as typos.
    pub fn from_config(c: &Config) -> Self {
        let d = FaultsConfig::default();
        FaultsConfig {
            enabled: c.keys().any(|k| k.starts_with("faults.")),
            supervise: c.bool_or("faults.supervise", d.supervise),
            stall_after_ms: c.int_or("faults.stall_after_ms", d.stall_after_ms as i64).max(1)
                as u64,
        }
    }
}

/// Multi-job server knobs: parsed from a server config's `[server]`
/// section (`stretch serve`). The budget and thresholds feed the
/// fleet-level `elastic::ServerController`; the period paces its
/// arbitration waves in WALL time (jobs keep independent event clocks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerConfig {
    /// Global core budget arbitrated across every admitted job.
    pub budget: usize,
    /// Arbitration wave period (wall ms).
    pub period_ms: u64,
    /// Backlog at/above which a stage requests one more core.
    pub grow_backlog: u64,
    /// Backlog at/below which a stage releases one core.
    pub shrink_backlog: u64,
    /// Arbitration waves a job holds still after a reconfiguration.
    pub cooldown_ticks: u32,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            budget: 8,
            period_ms: 250,
            grow_backlog: 4096,
            shrink_backlog: 64,
            cooldown_ticks: 1,
        }
    }
}

impl ServerConfig {
    /// Read the `[server]` section (missing keys keep defaults; the
    /// budget and period are clamped to ≥ 1).
    ///
    /// Adding a key here? Also register it in
    /// `harness::server::SERVER_SECTION_KEYS`, or server configs using it
    /// will be rejected as typos.
    pub fn from_config(c: &Config) -> Self {
        let d = ServerConfig::default();
        ServerConfig {
            budget: c.int_or("server.budget", d.budget as i64).max(1) as usize,
            period_ms: c.int_or("server.period_ms", d.period_ms as i64).max(1) as u64,
            grow_backlog: c.int_or("server.grow_backlog", d.grow_backlog as i64).max(1) as u64,
            shrink_backlog: c.int_or("server.shrink_backlog", d.shrink_backlog as i64).max(0)
                as u64,
            cooldown_ticks: c.int_or("server.cooldown_ticks", d.cooldown_ticks as i64).max(0)
                as u32,
        }
    }
}

/// Parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    /// Homogeneous-ish list (elements parsed individually).
    List(Vec<ConfigValue>),
}

impl fmt::Display for ConfigValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigValue::Str(s) => write!(f, "{s}"),
            ConfigValue::Int(v) => write!(f, "{v}"),
            ConfigValue::Float(v) => write!(f, "{v}"),
            ConfigValue::Bool(b) => write!(f, "{b}"),
            ConfigValue::List(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Configuration parse/validation errors (hand-rolled Display/Error —
/// the crate is std-only, no thiserror).
#[derive(Debug)]
pub enum ConfigError {
    Parse { line: usize, msg: String },
    Missing(String),
    Type { key: String, expected: &'static str, got: String },
    Io(std::io::Error),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            ConfigError::Missing(key) => write!(f, "missing key `{key}`"),
            ConfigError::Type { key, expected, got } => {
                write!(f, "key `{key}`: expected {expected}, got `{got}`")
            }
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

/// A parsed config: `section.key` → value. Keys outside any section live
/// under the empty section "".
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, ConfigValue>,
}

fn parse_scalar(s: &str, line: usize) -> Result<ConfigValue, ConfigError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(ConfigError::Parse { line, msg: "empty value".into() });
    }
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        return Ok(ConfigValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(ConfigValue::Bool(true));
    }
    if s == "false" {
        return Ok(ConfigValue::Bool(false));
    }
    // int with optional underscores
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(ConfigValue::Int(v));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(ConfigValue::Float(v));
    }
    // bare string
    Ok(ConfigValue::Str(s.to_string()))
}

/// Split a list body on commas, respecting quotes.
fn split_list(body: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut quote: Option<char> = None;
    for c in body.chars() {
        match quote {
            Some(q) => {
                cur.push(c);
                if c == q {
                    quote = None;
                }
            }
            None => match c {
                '"' | '\'' => {
                    cur.push(c);
                    quote = Some(c);
                }
                ',' => {
                    parts.push(std::mem::take(&mut cur));
                }
                _ => cur.push(c),
            },
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line_no = ln + 1;
            // strip comments (naive: # outside quotes)
            let mut in_quote: Option<char> = None;
            let mut cut = raw.len();
            for (i, c) in raw.char_indices() {
                match in_quote {
                    Some(q) if c == q => in_quote = None,
                    None if c == '"' || c == '\'' => in_quote = Some(c),
                    None if c == '#' => {
                        cut = i;
                        break;
                    }
                    _ => {}
                }
            }
            let line = raw[..cut].trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ConfigError::Parse {
                        line: line_no,
                        msg: "unterminated section header".into(),
                    });
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ConfigError::Parse {
                line: line_no,
                msg: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ConfigError::Parse { line: line_no, msg: "empty key".into() });
            }
            let vstr = line[eq + 1..].trim();
            let value = if vstr.starts_with('[') && vstr.ends_with(']') {
                let body = &vstr[1..vstr.len() - 1];
                let items = split_list(body)
                    .into_iter()
                    .map(|p| parse_scalar(&p, line_no))
                    .collect::<Result<Vec<_>, _>>()?;
                ConfigValue::List(items)
            } else {
                parse_scalar(vstr, line_no)?
            };
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, value);
        }
        Ok(Config { values })
    }

    /// Load from a file path.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self, ConfigError> {
        Ok(Self::parse(&std::fs::read_to_string(path)?)?)
    }

    pub fn get(&self, key: &str) -> Option<&ConfigValue> {
        self.values.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    fn require(&self, key: &str) -> Result<&ConfigValue, ConfigError> {
        self.get(key).ok_or_else(|| ConfigError::Missing(key.to_string()))
    }

    pub fn int(&self, key: &str) -> Result<i64, ConfigError> {
        match self.require(key)? {
            ConfigValue::Int(v) => Ok(*v),
            other => Err(ConfigError::Type { key: key.into(), expected: "int", got: other.to_string() }),
        }
    }

    pub fn float(&self, key: &str) -> Result<f64, ConfigError> {
        match self.require(key)? {
            ConfigValue::Float(v) => Ok(*v),
            ConfigValue::Int(v) => Ok(*v as f64),
            other => Err(ConfigError::Type { key: key.into(), expected: "float", got: other.to_string() }),
        }
    }

    pub fn str(&self, key: &str) -> Result<&str, ConfigError> {
        match self.require(key)? {
            ConfigValue::Str(s) => Ok(s),
            other => Err(ConfigError::Type { key: key.into(), expected: "string", got: other.to_string() }),
        }
    }

    pub fn bool(&self, key: &str) -> Result<bool, ConfigError> {
        match self.require(key)? {
            ConfigValue::Bool(b) => Ok(*b),
            other => Err(ConfigError::Type { key: key.into(), expected: "bool", got: other.to_string() }),
        }
    }

    pub fn int_list(&self, key: &str) -> Result<Vec<i64>, ConfigError> {
        match self.require(key)? {
            ConfigValue::List(xs) => xs
                .iter()
                .map(|x| match x {
                    ConfigValue::Int(v) => Ok(*v),
                    other => Err(ConfigError::Type {
                        key: key.into(),
                        expected: "int list",
                        got: other.to_string(),
                    }),
                })
                .collect(),
            other => Err(ConfigError::Type { key: key.into(), expected: "list", got: other.to_string() }),
        }
    }

    pub fn str_list(&self, key: &str) -> Result<Vec<String>, ConfigError> {
        match self.require(key)? {
            ConfigValue::List(xs) => xs
                .iter()
                .map(|x| match x {
                    ConfigValue::Str(s) => Ok(s.clone()),
                    other => Err(ConfigError::Type {
                        key: key.into(),
                        expected: "string list",
                        got: other.to_string(),
                    }),
                })
                .collect(),
            other => Err(ConfigError::Type { key: key.into(), expected: "list", got: other.to_string() }),
        }
    }

    /// Typed getter with default.
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.int(key).unwrap_or(default)
    }
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.float(key).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.bool(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "q3-scalejoin"
seed = 42

[operator]
wa_ms = 1
ws_ms = 300_000   # 5 minutes
keys = 1000
wt = "single"

[elastic]
enabled = true
thresholds = [45, 70, 90]
rate_scale = 1.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name").unwrap(), "q3-scalejoin");
        assert_eq!(c.int("seed").unwrap(), 42);
        assert_eq!(c.int("operator.ws_ms").unwrap(), 300_000);
        assert_eq!(c.str("operator.wt").unwrap(), "single");
        assert!(c.bool("elastic.enabled").unwrap());
        assert_eq!(c.int_list("elastic.thresholds").unwrap(), vec![45, 70, 90]);
        assert!((c.float("elastic.rate_scale").unwrap() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn int_coerces_to_float_not_reverse() {
        let c = Config::parse("a = 3\nb = 2.5").unwrap();
        assert!((c.float("a").unwrap() - 3.0).abs() < 1e-12);
        assert!(c.int("b").is_err());
    }

    #[test]
    fn missing_and_defaults() {
        let c = Config::parse("x = 1").unwrap();
        assert!(matches!(c.int("y"), Err(ConfigError::Missing(_))));
        assert_eq!(c.int_or("y", 7), 7);
        assert_eq!(c.str_or("z", "d"), "d");
    }

    #[test]
    fn comments_and_quotes() {
        let c = Config::parse("s = \"has # hash\" # trailing").unwrap();
        assert_eq!(c.str("s").unwrap(), "has # hash");
    }

    #[test]
    fn bad_lines_error_with_line_number() {
        let err = Config::parse("ok = 1\nnot a kv line").unwrap_err();
        match err {
            ConfigError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn list_of_strings() {
        let c = Config::parse("xs = [\"a\", \"b,c\", 'd']").unwrap();
        match c.get("xs").unwrap() {
            ConfigValue::List(v) => {
                assert_eq!(v.len(), 3);
                assert_eq!(v[1], ConfigValue::Str("b,c".into()));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_tuning_defaults_and_overrides() {
        let d = BatchTuning::from_config(&Config::parse("").unwrap());
        assert_eq!(d, BatchTuning::default());
        let c = Config::parse("[batch]\nworker = 32\nqueue = 0").unwrap();
        let t = BatchTuning::from_config(&c);
        assert_eq!(t.worker, 32);
        assert_eq!(t.ingress, BatchTuning::default().ingress);
        assert_eq!(t.queue, 1); // clamped
        assert!(!t.adaptive);
    }

    #[test]
    fn adaptive_batch_bounds_parse_and_clamp() {
        let c =
            Config::parse("[batch]\nadaptive = true\nworker_min = 8\nworker_max = 256").unwrap();
        let t = BatchTuning::from_config(&c);
        assert!(t.adaptive);
        assert_eq!((t.worker_min, t.worker_max), (8, 256));
        // worker_max can never undercut worker_min
        let c = Config::parse("[batch]\nworker_min = 64\nworker_max = 4").unwrap();
        let t = BatchTuning::from_config(&c);
        assert_eq!((t.worker_min, t.worker_max), (64, 64));
    }

    #[test]
    fn placement_defaults_and_overrides() {
        let d = PlacementConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(d, PlacementConfig::default());
        assert!(!d.enabled);
        let c = Config::parse("[placement]\nenabled = true\npin_runtime = false").unwrap();
        let p = PlacementConfig::from_config(&c);
        assert!(p.enabled);
        assert!(!p.pin_runtime);
        assert!(p.pin_workers);
    }

    #[test]
    fn faults_section_defaults_and_overrides() {
        let d = FaultsConfig::from_config(&Config::parse("").unwrap());
        assert_eq!(d, FaultsConfig::default());
        assert!(!d.enabled, "no [faults] section means no supervision machinery");
        let c = Config::parse("[faults]\nsupervise = false\nstall_after_ms = 100").unwrap();
        let f = FaultsConfig::from_config(&c);
        assert!(f.enabled);
        assert!(!f.supervise);
        assert_eq!(f.stall_after_ms, 100);
        // the steps list alone flips the section on
        let c = Config::parse("[faults]\nsteps = [\"1 -> kill a:0\"]").unwrap();
        assert!(FaultsConfig::from_config(&c).enabled);
        assert_eq!(c.str_list("faults.steps").unwrap(), vec!["1 -> kill a:0".to_string()]);
        assert!(c.str_list("faults.missing").is_err(), "missing key is a typed error");
        let c = Config::parse("[faults]\nsteps = [1, 2]").unwrap();
        assert!(c.str_list("faults.steps").is_err(), "non-string elements are typed errors");
    }

    #[test]
    fn bare_strings_allowed() {
        let c = Config::parse("mode = threaded").unwrap();
        assert_eq!(c.str("mode").unwrap(), "threaded");
    }
}
