//! Calibrated multicore simulator (the testbed substitution, DESIGN.md §5).
//!
//! The paper's scaling figures (7, 8, 10-13) ran on a 2×18-core Xeon;
//! this container has one core, so parallel *speedup* cannot be measured
//! directly. The simulator reproduces the scaling *shape* from first
//! principles using the per-tuple/per-comparison costs measured on this
//! build ([`calibrate`]): per-architecture bottleneck analysis gives the
//! capacity curves (Fig. 7/8), and a fluid queueing step gives the
//! elastic time series (Fig. 10-13) with the real controllers in the loop.

pub mod calibrate;

pub use calibrate::{calibrate, Calibration};

/// The modelled system architectures.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arch {
    /// STRETCH running ScaleJoin as an `O+` (VSN: shared gate, shared σ).
    /// `overhead` multiplies the compute share for the generic-operator
    /// bookkeeping (counters, key iteration) vs the ad-hoc ScaleJoin.
    StretchJoin { ws_s: f64, overhead: f64 },
    /// The original ad-hoc ScaleJoin (shared-memory, custom) — Q3 baseline.
    ScaleJoinSn { ws_s: f64 },
    /// Optimized single thread (Π is ignored; capacity is one core).
    OneTJoin { ws_s: f64 },
    /// STRETCH running the Q2 forwarding Operator 6 (I = 2).
    StretchForward,
    /// SN baseline for Operator 6: f_MK = all keys ⇒ the upstream
    /// duplicates every tuple to every instance over dedicated queues.
    SnForward,
}

impl Arch {
    /// Per-*worker-thread* busy-seconds per second at input rate `r` with
    /// `pi` instances (the worker bottleneck).
    pub fn worker_load(&self, c: &Calibration, r: f64, pi: usize) -> f64 {
        let pi_f = pi as f64;
        match *self {
            Arch::StretchJoin { ws_s, overhead } => {
                // every instance reads every tuple from the shared gate
                // (contention grows with readers); compute is split 1/Π
                let gate = r * c.gate_tuple_s * (1.0 + c.contention_alpha * (pi_f - 1.0));
                let cmp = (r * r * ws_s / 2.0) / c.cmp_per_sec * overhead / pi_f;
                gate + cmp
            }
            Arch::ScaleJoinSn { ws_s } => {
                // ad-hoc: same sharing pattern, minimal per-tuple overhead
                let gate = r * c.gate_tuple_s * (1.0 + c.contention_alpha * (pi_f - 1.0));
                let cmp = (r * r * ws_s / 2.0) / c.cmp_per_sec / pi_f;
                gate + cmp
            }
            Arch::OneTJoin { ws_s } => {
                r * c.queue_tuple_s + (r * r * ws_s / 2.0) / c.cmp_per_sec
            }
            Arch::StretchForward => {
                // forward: gate read + emit (gate write) per tuple
                r * c.gate_tuple_s * (1.0 + c.contention_alpha * (pi_f - 1.0)) * 2.0
            }
            Arch::SnForward => {
                // each instance pops its dedicated copy + merge-sorts
                r * (c.queue_tuple_s + c.sort_tuple_s) * 2.0
            }
        }
    }

    /// Upstream (ingress) busy-seconds per second — SN duplication makes
    /// this the Fig. 7 bottleneck.
    pub fn ingress_load(&self, c: &Calibration, r: f64, pi: usize) -> f64 {
        match *self {
            Arch::SnForward => r * c.queue_tuple_s * pi as f64, // Π copies
            Arch::OneTJoin { .. } => 0.0,
            _ => r * c.gate_tuple_s * 0.5, // one shared add
        }
    }

    /// Effective parallel capacity in "core-seconds per second" for Π
    /// threads on a machine with `c.ht_threshold` physical cores.
    fn thread_capacity(&self, c: &Calibration, pi: usize) -> f64 {
        match *self {
            Arch::OneTJoin { .. } => 1.0,
            _ => {
                let phys = pi.min(c.ht_threshold) as f64;
                let ht = pi.saturating_sub(c.ht_threshold) as f64;
                phys + ht * c.ht_factor
            }
        }
    }

    /// Whether the system sustains rate `r` with `pi` instances.
    pub fn sustains(&self, c: &Calibration, r: f64, pi: usize) -> bool {
        let per_thread_cap = match *self {
            // 1T: a single full core regardless of Π
            Arch::OneTJoin { .. } => 1.0,
            _ => self.thread_capacity(c, pi) / pi.max(1) as f64,
        };
        self.worker_load(c, r, pi) <= per_thread_cap && self.ingress_load(c, r, pi) <= 1.0
    }

    /// Maximum sustainable input rate with Π instances (bisection).
    pub fn max_rate(&self, c: &Calibration, pi: usize) -> f64 {
        let mut lo = 0.0f64;
        let mut hi = 1e9f64;
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.sustains(c, mid, pi) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Comparison throughput (c/s) at input rate `r` (join archs).
    pub fn cmp_throughput(&self, r: f64) -> f64 {
        match *self {
            Arch::StretchJoin { ws_s, .. }
            | Arch::ScaleJoinSn { ws_s }
            | Arch::OneTJoin { ws_s } => r * r * ws_s / 2.0,
            _ => 0.0,
        }
    }

    /// Steady-state processing latency estimate (ms) at utilization u:
    /// an M/M/1-ish delay curve on top of a per-tuple base cost.
    pub fn base_latency_ms(&self, c: &Calibration, pi: usize) -> f64 {
        let base = match *self {
            Arch::OneTJoin { .. } => c.queue_tuple_s,
            Arch::SnForward => (c.queue_tuple_s + c.sort_tuple_s) * 2.0,
            _ => c.gate_tuple_s * (1.0 + c.contention_alpha * (pi as f64 - 1.0)) * 2.0,
        };
        // scheduling + batching floor of a few ms (paper: STRETCH < 30 ms,
        // Flink > 100 ms driven by its buffer timeout, modelled separately)
        base * 1e3 + 2.0
    }
}

/// One step of the fluid queueing simulation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimSample {
    pub t_s: f64,
    pub offered_tps: f64,
    pub served_tps: f64,
    pub backlog: f64,
    pub latency_ms: f64,
    pub utilization: f64,
    pub threads: usize,
    pub cmp_per_s: f64,
}

/// Fluid simulation of one operator under a driven rate profile.
pub struct FluidSim {
    pub arch: Arch,
    pub cal: Calibration,
    pub threads: usize,
    pub backlog: f64,
    t_s: f64,
}

impl FluidSim {
    pub fn new(arch: Arch, cal: Calibration, threads: usize) -> Self {
        FluidSim { arch, cal, threads, backlog: 0.0, t_s: 0.0 }
    }

    /// Advance `dt` seconds at offered rate `rate` t/s.
    pub fn step(&mut self, rate: f64, dt: f64) -> SimSample {
        let cap_rate = self.arch.max_rate(&self.cal, self.threads);
        let demand = rate + self.backlog / dt;
        let served = demand.min(cap_rate);
        self.backlog = (self.backlog + (rate - served) * dt).max(0.0);
        let u = if cap_rate > 0.0 { (rate / cap_rate).min(2.0) } else { 2.0 };
        // latency: base + queueing (backlog drain) + utilization knee
        let queue_ms = if served > 0.0 { self.backlog / served * 1e3 } else { 0.0 };
        let knee = if u < 1.0 { 1.0 / (1.0 - 0.9 * u) } else { 10.0 };
        let latency = self.arch.base_latency_ms(&self.cal, self.threads) * knee + queue_ms;
        self.t_s += dt;
        SimSample {
            t_s: self.t_s,
            offered_tps: rate,
            served_tps: served,
            backlog: self.backlog,
            latency_ms: latency,
            utilization: u,
            threads: self.threads,
            cmp_per_s: self.arch.cmp_throughput(served),
        }
    }

    /// Change the parallelism degree (reconfigurations are instantaneous
    /// at this time scale — the measured < 40 ms against 1 s steps).
    pub fn set_threads(&mut self, pi: usize) {
        self.threads = pi.max(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> Calibration {
        // fixed synthetic calibration for deterministic tests
        Calibration {
            cmp_per_sec: 50e6,
            gate_tuple_s: 1e-6,
            gate_batch_tuple_s: 2e-7,
            queue_tuple_s: 2e-7,
            sort_tuple_s: 3e-7,
            contention_alpha: 0.006,
            ht_threshold: 36,
            ht_factor: 0.55,
        }
    }

    #[test]
    fn stretch_join_scales_linearly_then_knees() {
        let c = cal();
        let a = Arch::StretchJoin { ws_s: 300.0, overhead: 1.2 };
        let r1 = a.max_rate(&c, 1);
        let r4 = a.max_rate(&c, 4);
        let r16 = a.max_rate(&c, 16);
        // compute-bound region: R_max ∝ sqrt(Π)
        assert!((r4 / r1 - 2.0).abs() < 0.2, "r4/r1={}", r4 / r1);
        assert!((r16 / r4 - 2.0).abs() < 0.3, "r16/r4={}", r16 / r4);
        // HT knee: going 36 → 72 gains less than sqrt(2)
        let r36 = a.max_rate(&c, 36);
        let r72 = a.max_rate(&c, 72);
        assert!(r72 > r36);
        assert!(r72 / r36 < 1.4);
    }

    #[test]
    fn stretch_matches_scalejoin_with_small_gap() {
        let c = cal();
        let s = Arch::StretchJoin { ws_s: 300.0, overhead: 1.2 };
        let sj = Arch::ScaleJoinSn { ws_s: 300.0 };
        for pi in [1, 8, 32] {
            let rs = s.max_rate(&c, pi);
            let rj = sj.max_rate(&c, pi);
            assert!(rs <= rj, "generic O+ can't beat the ad-hoc impl");
            assert!(rs > 0.85 * rj, "Π={pi}: STRETCH should stay close ({rs} vs {rj})");
        }
    }

    #[test]
    fn onet_is_flat_in_pi() {
        let c = cal();
        let a = Arch::OneTJoin { ws_s: 300.0 };
        assert!((a.max_rate(&c, 1) - a.max_rate(&c, 32)).abs() < 1.0);
    }

    #[test]
    fn sn_forward_collapses_with_pi() {
        // Fig. 7: Flink 40k → 2k as Π grows; STRETCH roughly flat
        let c = cal();
        let sn = Arch::SnForward;
        let st = Arch::StretchForward;
        let sn1 = sn.max_rate(&c, 1);
        let sn36 = sn.max_rate(&c, 36);
        let sn72 = sn.max_rate(&c, 72);
        assert!(sn36 < sn1 / 5.0, "SN must collapse: {sn1} → {sn36}");
        assert!(sn72 < sn36, "SN decays monotonically");
        let st2 = st.max_rate(&c, 2);
        let st36 = st.max_rate(&c, 36);
        assert!(st36 > st2 * 0.7, "STRETCH stays near-flat: {st2} → {st36}");
        // the STRETCH/SN ratio grows with Π (who wins at scale). NOTE:
        // the paper's 3×-50× vs *Flink* also includes Flink's heavier
        // per-tuple runtime costs; our SN baseline is a lean rust
        // implementation, so the low-Π gap is smaller (see EXPERIMENTS.md)
        let r36 = st.max_rate(&c, 36) / sn.max_rate(&c, 36);
        let r72 = st.max_rate(&c, 72) / sn72;
        assert!(r36 > 2.5, "Π=36 ratio={r36}");
        assert!(r72 > 3.5, "Π=72 ratio={r72}");
        assert!(r72 > r36, "ratio grows with Π");
    }

    #[test]
    fn fluid_backlog_grows_beyond_capacity() {
        let c = cal();
        let mut sim = FluidSim::new(Arch::StretchJoin { ws_s: 60.0, overhead: 1.2 }, c, 2);
        let cap = sim.arch.max_rate(&c, 2);
        // drive at 150% capacity: backlog + latency must grow
        let s1 = sim.step(cap * 1.5, 1.0);
        let s5 = (0..4).map(|_| sim.step(cap * 1.5, 1.0)).last().unwrap();
        assert!(s5.backlog > s1.backlog);
        assert!(s5.latency_ms > s1.latency_ms);
        // provisioning more threads drains it
        sim.set_threads(8);
        let mut last = s5;
        for _ in 0..30 {
            last = sim.step(cap * 1.5, 1.0);
        }
        assert!(last.backlog < s5.backlog, "backlog should drain after scaling up");
    }

    #[test]
    fn latency_low_under_capacity() {
        let c = cal();
        let mut sim = FluidSim::new(Arch::StretchJoin { ws_s: 60.0, overhead: 1.2 }, c, 4);
        let cap = sim.arch.max_rate(&c, 4);
        let mut s = SimSample::default();
        for _ in 0..10 {
            s = sim.step(cap * 0.5, 1.0);
        }
        assert!(s.latency_ms < 30.0, "latency {} should be low", s.latency_ms);
        assert!((s.served_tps - cap * 0.5).abs() < 1.0);
    }
}
