//! Cost calibration: measure this build's per-tuple / per-comparison
//! costs on this machine. The multicore simulator (DESIGN.md §5) is
//! parameterized by these *measured* numbers — the only borrowed
//! constants are the contention/hyper-threading shape factors, taken
//! from the paper's observed curves and documented below.

use crate::scalegate::scale_gate;
use crate::tuple::Tuple;
use crate::util::spsc;
use crate::workloads::scalejoin_bench::{OneT, SjGen};
use std::time::Instant;

/// Measured + documented cost parameters.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Band-join comparisons per second, one thread (measured via 1T).
    pub cmp_per_sec: f64,
    /// Per-tuple cost of an ESG add+merge+get round trip (measured,
    /// per-tuple `add`/`get` path).
    pub gate_tuple_s: f64,
    /// Per-tuple cost of the batched ESG path (`add_batch`/`get_batch`
    /// runs of [`GATE_BATCH`]); the §Perf batching win is
    /// `gate_tuple_s / gate_batch_tuple_s`.
    pub gate_batch_tuple_s: f64,
    /// Per-tuple cost of a dedicated SPSC push+pop (measured).
    pub queue_tuple_s: f64,
    /// Per-tuple merge-sort (SN instance ingest) cost (measured).
    pub sort_tuple_s: f64,
    /// Shared-gate contention: each extra concurrent reader inflates the
    /// per-tuple gate cost by this fraction. NOT measurable on a 1-core
    /// container; fitted to the paper's Fig. 7 STRETCH curve
    /// (120k → 100k t/s over Π = 2..36 ⇒ α ≈ 0.006).
    pub contention_alpha: f64,
    /// Physical cores before the hyper-threading knee (paper: 36).
    pub ht_threshold: usize,
    /// Capacity factor of a hyper-thread vs a physical core (Fig. 8's
    /// degradation beyond 36 threads ⇒ ≈ 0.55).
    pub ht_factor: f64,
}

/// Run length used by the batched-gate measurement (matches the default
/// engine `worker_batch` era scale).
pub const GATE_BATCH: usize = 256;

/// Run the full calibration (~0.5 s of measurement).
pub fn calibrate() -> Calibration {
    calibrate_with(100)
}

/// Calibration with an explicit per-component measurement budget in ms
/// (CI smoke runs pass a tiny one).
pub fn calibrate_with(budget_ms: u64) -> Calibration {
    Calibration {
        cmp_per_sec: measure_cmp_per_sec(budget_ms + budget_ms / 2),
        gate_tuple_s: measure_gate_cost(budget_ms),
        gate_batch_tuple_s: measure_gate_batch_cost(GATE_BATCH, budget_ms),
        queue_tuple_s: measure_queue_cost(budget_ms),
        sort_tuple_s: measure_sort_cost(budget_ms),
        contention_alpha: 0.006,
        ht_threshold: 36,
        ht_factor: 0.55,
    }
}

/// Single-thread comparison throughput via the real 1T join inner loop.
pub fn measure_cmp_per_sec(ms: u64) -> f64 {
    let mut gen = SjGen::new(0xCA11B, 50_000.0);
    let mut j = OneT::new(5_000); // ~250-tuple windows
    // warm up the window
    for t in gen.take(2_000) {
        j.process(&t);
    }
    let c0 = j.comparisons;
    let t0 = Instant::now();
    while t0.elapsed().as_millis() < ms as u128 {
        for t in gen.take(512) {
            j.process(&t);
        }
    }
    ((j.comparisons - c0) as f64 / t0.elapsed().as_secs_f64()).max(1.0)
}

/// ESG add + cooperative merge + get, single source/reader, one tuple at
/// a time (the pre-batching data plane).
pub fn measure_gate_cost(ms: u64) -> f64 {
    let (_g, mut src, mut rdr) = scale_gate::<Tuple<u64>>(1, 1, 1 << 14);
    let mut ts = 0i64;
    let n_warm = 1_000;
    for _ in 0..n_warm {
        ts += 1;
        src[0].add(Tuple::data(ts, 1)).unwrap();
        let _ = rdr[0].get();
    }
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed().as_millis() < ms as u128 {
        for _ in 0..256 {
            ts += 1;
            src[0].add(Tuple::data(ts, 1)).unwrap();
            while rdr[0].get().is_some() {}
            n += 1;
        }
    }
    t0.elapsed().as_secs_f64() / n as f64
}

/// Batched ESG round trip: `add_batch` runs of `batch` tuples, drained
/// via `get_batch` — the §Perf data plane. Compare with
/// [`measure_gate_cost`] for the batching win.
pub fn measure_gate_batch_cost(batch: usize, ms: u64) -> f64 {
    let (_g, mut src, mut rdr) = scale_gate::<Tuple<u64>>(1, 1, 1 << 14);
    let mut ts = 0i64;
    let mut run: Vec<Tuple<u64>> = Vec::with_capacity(batch);
    let mut out: Vec<Tuple<u64>> = Vec::with_capacity(batch);
    // warm
    for _ in 0..4 {
        for _ in 0..batch {
            ts += 1;
            run.push(Tuple::data(ts, 1));
        }
        src[0].add_batch(&mut run).unwrap();
        while rdr[0].get_batch(&mut out, batch) > 0 {}
        out.clear();
    }
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed().as_millis() < ms as u128 {
        for _ in 0..batch {
            ts += 1;
            run.push(Tuple::data(ts, 1));
        }
        src[0].add_batch(&mut run).unwrap();
        while rdr[0].get_batch(&mut out, batch) > 0 {}
        out.clear();
        n += batch as u64;
    }
    t0.elapsed().as_secs_f64() / n as f64
}

/// Cross-thread ESG throughput (tuples/s): a feeder thread `add_batch`es
/// 256-tuple runs while a reader thread drains with `get_batch`, each
/// optionally pinned to a core via [`crate::runtime::placement`]. This is
/// the placement experiment's probe — run it once with both threads on
/// the producer's socket and once with the reader on a remote socket to
/// measure the NUMA penalty on the gate hot path (`bench_micro` records
/// both in `BENCH_micro.json`).
pub fn measure_gate_cost_threaded(
    ms: u64,
    src_core: Option<usize>,
    rdr_core: Option<usize>,
) -> f64 {
    use crate::runtime::placement::pin_current;
    use crate::util::Backoff;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let (_g, mut src, mut rdr) = scale_gate::<Tuple<u64>>(1, 1, 1 << 14);
    let mut src0 = src.remove(0);
    let mut rdr0 = rdr.remove(0);
    let stop = Arc::new(AtomicBool::new(false));
    let done_feeding = Arc::new(AtomicBool::new(false));

    let feeder = {
        let stop = stop.clone();
        let done = done_feeding.clone();
        std::thread::spawn(move || {
            if let Some(c) = src_core {
                pin_current(c);
            }
            let mut ts = 0i64;
            let mut run: Vec<Tuple<u64>> = Vec::with_capacity(256);
            while !stop.load(Ordering::Acquire) {
                for _ in 0..256 {
                    ts += 1;
                    run.push(Tuple::data(ts, 1));
                }
                src0.add_batch(&mut run).unwrap();
            }
            // the reader keeps draining until this flips, so a feeder
            // blocked on a full gate always gets space to finish
            done.store(true, Ordering::Release);
        })
    };
    let reader = {
        let done = done_feeding.clone();
        std::thread::spawn(move || {
            if let Some(c) = rdr_core {
                pin_current(c);
            }
            let mut out: Vec<Tuple<u64>> = Vec::with_capacity(256);
            let mut idle = Backoff::active();
            let mut n = 0u64;
            loop {
                let got = rdr0.get_batch(&mut out, 256);
                n += got as u64;
                out.clear();
                if got == 0 {
                    if done.load(Ordering::Acquire) {
                        break;
                    }
                    idle.snooze();
                } else {
                    idle.reset();
                }
            }
            n
        })
    };
    let t0 = Instant::now();
    // lint: allow(sleep) — the measurement window itself: the benchmark
    // runs for a fixed wall-clock duration while worker threads spin.
    std::thread::sleep(std::time::Duration::from_millis(ms.max(1)));
    stop.store(true, Ordering::Release);
    feeder.join().unwrap();
    let n = reader.join().unwrap();
    n as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Dedicated SPSC queue push + pop.
pub fn measure_queue_cost(ms: u64) -> f64 {
    let (mut p, mut c) = spsc::spsc::<Tuple<u64>>(1 << 12);
    let t0 = Instant::now();
    let mut n = 0u64;
    while t0.elapsed().as_millis() < ms as u128 {
        for i in 0..256i64 {
            p.try_push(Tuple::data(i, 0)).ok();
            let _ = c.try_pop();
            n += 1;
        }
    }
    t0.elapsed().as_secs_f64() / n as f64
}

/// Merge-sorter offer + pop (the SN per-instance ingest step).
pub fn measure_sort_cost(ms: u64) -> f64 {
    let mut ms_sorter: crate::watermark::MergeSorter<u64> = crate::watermark::MergeSorter::new(2);
    let t0 = Instant::now();
    let mut n = 0u64;
    let mut ts = 0i64;
    while t0.elapsed().as_millis() < ms as u128 {
        for _ in 0..128 {
            ts += 1;
            ms_sorter.offer(0, Tuple::data(ts, 0));
            ms_sorter.offer(1, Tuple::data(ts, 1));
            while ms_sorter.pop_ready().is_some() {}
            n += 2;
        }
    }
    t0.elapsed().as_secs_f64() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_sane() {
        let c = calibrate_with(40);
        assert!(c.cmp_per_sec > 1e5, "cmp/s={}", c.cmp_per_sec);
        assert!(c.gate_tuple_s > 0.0 && c.gate_tuple_s < 1e-3);
        assert!(c.gate_batch_tuple_s > 0.0 && c.gate_batch_tuple_s < 1e-3);
        assert!(c.queue_tuple_s > 0.0 && c.queue_tuple_s < 1e-3);
        assert!(c.sort_tuple_s > 0.0 && c.sort_tuple_s < 1e-3);
        // a queue hop should not cost more than a gate round trip by much
        assert!(c.queue_tuple_s < c.gate_tuple_s * 50.0);
        // NOTE: the batched-vs-per-tuple perf bar is deliberately NOT
        // asserted here — timing comparisons flake under CI scheduler
        // noise; bench_micro owns that gate (≥ 2× at full budget).
    }

    #[test]
    fn threaded_gate_probe_moves_tuples_pinned_or_not() {
        assert!(measure_gate_cost_threaded(20, None, None) > 0.0);
        // pinning both threads to an allowed core must still flow (on a
        // 1-core box both land on the same core and simply time-share)
        let cores = crate::runtime::placement::allowed_cores();
        if let Some(&c) = cores.first() {
            let pinned = measure_gate_cost_threaded(20, Some(c), Some(*cores.last().unwrap()));
            assert!(pinned > 0.0, "pinned probe moved no tuples");
        }
    }
}
