//! The merged-tuple log backing a ScaleGate.
//!
//! An append-only, segmented log with a single writer at a time (whoever
//! holds the gate's merge lock) and wait-free readers over the published
//! prefix: entries at indices `< ready()` are immutable and safe to read
//! concurrently. Segments below the minimum reader cursor are reclaimed
//! (`truncate_below`), keeping memory proportional to the reader lag bound
//! enforced by flow control.
//!
//! # Memory-ordering protocol
//!
//! One edge carries the whole reader-side guarantee (the paper's Lemma 1
//! ready-order handoff): the merge-lock holder fills slots `[ready,
//! ready+n)` plainly, then publishes them with a single
//! `ready.store(…, Release)`; every reader's `ready.load(Acquire)` pairs
//! with that store, so a reader that observes index `i < ready` also
//! observes the slot writes covering `i`. Writer-side `ready` loads are
//! Relaxed self-reads (the merge lock serializes writers, so the current
//! holder wrote the value it reads). The segment *table* is under an
//! `RwLock`; slot contents are never touched through it after publish.

use crate::util::CachePadded;
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// log2 of segment size.
const SEG_SHIFT: u32 = 10;
/// Entries per segment.
pub const SEG_SIZE: usize = 1 << SEG_SHIFT;
/// Truncated segments retained for reuse (§Perf memory discipline): the
/// steady state cycles one segment per `SEG_SIZE` tuples plus at most
/// one pinned by each reader's `SegCache`, so a few shelved segments
/// make segment turnover allocation-free; anything beyond goes back to
/// the allocator so truncation still releases burst memory.
const FREE_SEGS: usize = 4;

struct Segment<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
}

// SAFETY: a slot is written at most once, by the single merge-lock
// holder, strictly before the Release `ready` publish that covers it;
// concurrent readers only dereference slots below their Acquire-loaded
// `ready`, i.e. after the write happened-before their read, and never
// write. So no `UnsafeCell` is ever accessed mutably and concurrently,
// and sharing a segment is sound for `T: Send + Sync`.
unsafe impl<T: Send + Sync> Sync for Segment<T> {}
// SAFETY: a segment owns its `Option<T>` slots outright; moving it
// between threads moves `T`s, sound for `T: Send` (the `Sync` bound is
// inherited from the shared-reader contract above).
unsafe impl<T: Send + Sync> Send for Segment<T> {}

impl<T> Segment<T> {
    fn new() -> Arc<Self> {
        Arc::new(Segment {
            slots: (0..SEG_SIZE).map(|_| UnsafeCell::new(None)).collect(),
        })
    }

    /// Clear every slot (dropping payloads) so the segment can be
    /// reused at a new base index. Requires `&mut`, i.e. unique
    /// ownership — both call sites prove it via `Arc::get_mut`, so no
    /// reader cache can observe the reset.
    fn reset(&mut self) {
        for slot in self.slots.iter_mut() {
            *slot.get_mut() = None;
        }
    }
}

struct Segments<T> {
    /// Global index of the first entry of `segs[0]`.
    base: u64,
    segs: Vec<Arc<Segment<T>>>,
    /// Truncated segments shelved for reuse (bounded by [`FREE_SEGS`]).
    /// An entry may still be pinned by a reader's `SegCache`; it is only
    /// reused once `Arc::get_mut` proves the last cache moved on.
    free: Vec<Arc<Segment<T>>>,
}

impl<T> Segments<T> {
    /// Pop a shelved segment no reader cache still pins, reset for
    /// reuse at a fresh base index. Pinned entries stay shelved and are
    /// re-checked on the next call (a reader cache pins at most one
    /// truncated segment, and drops it as soon as it crosses into the
    /// next one).
    fn take_recycled(&mut self) -> Option<Arc<Segment<T>>> {
        let i = (0..self.free.len()).find(|&i| Arc::get_mut(&mut self.free[i]).is_some())?;
        let mut seg = self.free.swap_remove(i);
        Arc::get_mut(&mut seg).expect("uniqueness just checked").reset();
        Some(seg)
    }
}

/// The shared log.
pub struct Log<T> {
    segments: RwLock<Segments<T>>,
    /// Number of published entries; indices `< ready` are readable.
    /// Padded: every reader polls it while the merge-lock holder stores
    /// it — it must not share a line with the segment-table lock.
    ready: CachePadded<AtomicU64>,
}

/// A reader-side cache of one segment, avoiding the segment-table lock on
/// every read.
pub struct SegCache<T> {
    base: u64,
    seg: Option<Arc<Segment<T>>>,
}

impl<T> Default for SegCache<T> {
    fn default() -> Self {
        SegCache { base: u64::MAX, seg: None }
    }
}

impl<T: Clone + Send + Sync> Log<T> {
    pub fn new() -> Self {
        Log {
            segments: RwLock::new(Segments {
                base: 0,
                segs: vec![Segment::new()],
                free: Vec::new(),
            }),
            ready: CachePadded::new(AtomicU64::new(0)),
        }
    }

    /// Number of published entries.
    #[inline]
    pub fn ready(&self) -> u64 {
        // ORDERING: Acquire half of the publish edge — pairs with the
        // Release `ready` store in `push`/`push_run`, making every slot
        // below the returned index visible to this reader.
        self.ready.load(Ordering::Acquire)
    }

    /// The segment holding global index range `[seg_no << SEG_SHIFT, …)`,
    /// appending fresh segments as needed. Writer-side only (the
    /// merge-lock holder), so `seg_no` is never below the truncation
    /// point.
    fn segment_for_write(&self, seg_no: u64) -> Arc<Segment<T>> {
        {
            let guard = self.segments.read().unwrap();
            let first_seg_no = guard.base >> SEG_SHIFT;
            let local = (seg_no - first_seg_no) as usize;
            if local < guard.segs.len() {
                return guard.segs[local].clone();
            }
        }
        let mut guard = self.segments.write().unwrap();
        let inner = &mut *guard;
        let first_seg_no = inner.base >> SEG_SHIFT;
        while ((seg_no - first_seg_no) as usize) >= inner.segs.len() {
            // recycle a truncated segment when one is free of reader
            // pins; the allocator is only touched when the shelf is
            // empty (cold start, or a burst outrunning truncation)
            let seg = inner.take_recycled().unwrap_or_else(Segment::new);
            inner.segs.push(seg);
        }
        let local = (seg_no - first_seg_no) as usize;
        inner.segs[local].clone()
    }

    /// Append one entry and publish it. MUST be called by at most one
    /// thread at a time (the merge-lock holder).
    pub fn push(&self, v: T) {
        // ORDERING: Relaxed self-read — the merge lock serializes
        // writers, and the previous holder's lock release/acquire already
        // ordered its `ready` store before our load.
        let idx = self.ready.load(Ordering::Relaxed);
        let seg = self.segment_for_write(idx >> SEG_SHIFT);
        let off = (idx & (SEG_SIZE as u64 - 1)) as usize;
        // SAFETY: slot `idx` is at or above `ready`, so no reader may
        // dereference it yet (readers stay below their Acquire-loaded
        // `ready`), and we are the only writer (single merge-lock holder
        // contract). `off` is masked into the segment, and
        // `segment_for_write` returned the segment covering `idx`.
        unsafe { *seg.slots[off].get() = Some(v) };
        // ORDERING: Release publish — pairs with every reader's Acquire
        // `ready` load; the slot write above happens-before any read of
        // index `idx` (Lemma 1's ready-order handoff).
        self.ready.store(idx + 1, Ordering::Release);
    }

    /// Append a whole run and publish it with ONE `ready` store: readers
    /// see either none or all of the run, and the merge-lock holder pays
    /// one Release fence (plus one segment-table lock per crossed
    /// segment) per run instead of per tuple. Drains `run`. Same
    /// single-writer contract as [`push`](Self::push).
    ///
    /// lint: no-alloc — the merge hot path; segment turnover is served
    /// by the recycling shelf behind `segment_for_write`.
    pub fn push_run(&self, run: &mut Vec<T>) {
        let n = run.len() as u64;
        if n == 0 {
            return;
        }
        // ORDERING: Relaxed self-read under the merge lock (same
        // single-writer argument as `push`).
        let start = self.ready.load(Ordering::Relaxed);
        let end = start + n;
        let mut drain = run.drain(..);
        let mut idx = start;
        while idx < end {
            let seg_no = idx >> SEG_SHIFT;
            let seg = self.segment_for_write(seg_no);
            let chunk_end = end.min((seg_no + 1) << SEG_SHIFT);
            for i in idx..chunk_end {
                let off = (i & (SEG_SIZE as u64 - 1)) as usize;
                // SAFETY: every index in `[start, end)` is at or above
                // the published `ready`, so readers cannot touch these
                // slots until the single Release publish below; we are
                // the only writer (merge-lock holder), and `off` is
                // masked into the segment covering `i`.
                unsafe { *seg.slots[off].get() = Some(drain.next().unwrap()) };
            }
            idx = chunk_end;
        }
        drop(drain);
        // ORDERING: the run's SINGLE Release publish — pairs with the
        // readers' Acquire `ready` loads; all slot writes above become
        // visible atomically, so readers observe none or all of the run.
        self.ready.store(end, Ordering::Release);
    }

    /// Read entry `idx` (must be `< ready()`), using and refreshing the
    /// caller's segment cache. Clones the entry.
    pub fn get(&self, idx: u64, cache: &mut SegCache<T>) -> T {
        debug_assert!(idx < self.ready());
        let hit = cache.seg.is_some()
            && idx >= cache.base
            && idx < cache.base + SEG_SIZE as u64;
        if !hit {
            let guard = self.segments.read().unwrap();
            let first_seg_no = guard.base >> SEG_SHIFT;
            let seg_no = idx >> SEG_SHIFT;
            assert!(
                seg_no >= first_seg_no,
                "read below truncation point: idx={idx} base={}",
                guard.base
            );
            let local = (seg_no - first_seg_no) as usize;
            cache.seg = Some(guard.segs[local].clone());
            cache.base = seg_no << SEG_SHIFT;
        }
        let seg = cache.seg.as_ref().unwrap();
        let off = (idx - cache.base) as usize;
        // SAFETY: the caller's contract `idx < ready()` means an Acquire
        // `ready` load already observed the Release publish covering
        // `idx`, so the slot write happened-before this read and the slot
        // is immutable from here on (single writer never rewrites below
        // `ready`). Shared read-only access is therefore sound; `off` is
        // within the cached segment by the `hit` check above.
        unsafe { (*seg.slots[off].get()).as_ref().expect("published slot empty").clone() }
    }

    /// Retire whole segments strictly below `min_cursor`. Safe because
    /// readers hold `Arc`s to segments they are still traversing.
    /// Retired segments are shelved for reuse (up to [`FREE_SEGS`])
    /// instead of freed, so steady-state segment turnover never touches
    /// the allocator; the overflow goes back to the allocator so a
    /// burst's memory is still released.
    pub fn truncate_below(&self, min_cursor: u64) {
        let mut guard = self.segments.write().unwrap();
        let inner = &mut *guard;
        let first_seg_no = inner.base >> SEG_SHIFT;
        let keep_seg_no = min_cursor >> SEG_SHIFT;
        let drop_n = (keep_seg_no.saturating_sub(first_seg_no)) as usize;
        // never drop the segment currently being written
        let max_droppable = inner.segs.len().saturating_sub(1);
        let drop_n = drop_n.min(max_droppable);
        if drop_n > 0 {
            for mut seg in inner.segs.drain(..drop_n) {
                if inner.free.len() < FREE_SEGS {
                    // eagerly drop payloads when no reader cache pins
                    // the segment (preserves pre-recycling drop timing);
                    // a pinned segment is reset at reuse instead
                    // (`take_recycled`), once its reader moved on
                    if let Some(s) = Arc::get_mut(&mut seg) {
                        s.reset();
                    }
                    inner.free.push(seg);
                }
            }
            inner.base += (drop_n * SEG_SIZE) as u64;
        }
    }

    /// Number of retained segments (for tests / memory accounting).
    pub fn segment_count(&self) -> usize {
        self.segments.read().unwrap().segs.len()
    }

    /// Number of truncated segments currently shelved for reuse (tests
    /// / memory accounting).
    pub fn pooled_segments(&self) -> usize {
        self.segments.read().unwrap().free.len()
    }
}

impl<T: Clone + Send + Sync> Default for Log<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Interpreter-scale budget under Miri; still crosses several segment
    // boundaries (SEG_SIZE is 1024, so use multiples of it instead where
    // segment traversal is the point).
    #[cfg(miri)]
    const STRESS_N: u64 = 2_500;
    #[cfg(not(miri))]
    const STRESS_N: u64 = 100_000;

    #[test]
    fn push_get_roundtrip() {
        let log: Log<u64> = Log::new();
        let mut cache = SegCache::default();
        for i in 0..5000u64 {
            log.push(i * 3);
        }
        assert_eq!(log.ready(), 5000);
        for i in 0..5000u64 {
            assert_eq!(log.get(i, &mut cache), i * 3);
        }
    }

    #[test]
    fn crosses_segments() {
        let log: Log<u64> = Log::new();
        let n = (SEG_SIZE * 3 + 7) as u64;
        for i in 0..n {
            log.push(i);
        }
        assert!(log.segment_count() >= 3);
        let mut cache = SegCache::default();
        // random access pattern across segments
        for i in [0u64, n - 1, SEG_SIZE as u64, 1, n / 2] {
            assert_eq!(log.get(i, &mut cache), i);
        }
    }

    #[test]
    fn push_run_crosses_segments_single_publish() {
        let log: Log<u64> = Log::new();
        // straddle two segment boundaries in one run
        let lead = SEG_SIZE as u64 - 7;
        for i in 0..lead {
            log.push(i);
        }
        let n = (SEG_SIZE + 20) as u64;
        let mut run: Vec<u64> = (lead..lead + n).collect();
        log.push_run(&mut run);
        assert!(run.is_empty());
        assert_eq!(log.ready(), lead + n);
        let mut cache = SegCache::default();
        for i in 0..lead + n {
            assert_eq!(log.get(i, &mut cache), i);
        }
        // empty runs are a no-op
        log.push_run(&mut run);
        assert_eq!(log.ready(), lead + n);
    }

    #[test]
    fn truncation_reclaims_segments() {
        let log: Log<u64> = Log::new();
        let n = (SEG_SIZE * 8) as u64;
        for i in 0..n {
            log.push(i);
        }
        let before = log.segment_count();
        log.truncate_below(SEG_SIZE as u64 * 6);
        assert!(log.segment_count() < before);
        // entries above the cut still readable
        let mut cache = SegCache::default();
        assert_eq!(log.get(SEG_SIZE as u64 * 6, &mut cache), SEG_SIZE as u64 * 6);
        assert_eq!(log.get(n - 1, &mut cache), n - 1);
    }

    #[test]
    fn truncation_recycles_segments_for_reuse() {
        let log: Log<u64> = Log::new();
        let n = (SEG_SIZE * 6) as u64;
        for i in 0..n {
            log.push(i);
        }
        // 5 segments retire; the shelf keeps FREE_SEGS of them
        log.truncate_below(SEG_SIZE as u64 * 5);
        let pooled = log.pooled_segments();
        assert_eq!(pooled, FREE_SEGS);
        // appending two segments' worth reuses shelved segments before
        // touching the allocator
        for i in n..n + (SEG_SIZE * 2) as u64 {
            log.push(i);
        }
        assert_eq!(log.pooled_segments(), pooled - 2);
        // recycled segments serve reads correctly at their new indices
        let mut cache = SegCache::default();
        for i in (SEG_SIZE as u64 * 5)..n + (SEG_SIZE * 2) as u64 {
            assert_eq!(log.get(i, &mut cache), i);
        }
    }

    #[test]
    fn reader_pinned_segment_is_never_reset_for_reuse() {
        let log: Log<u64> = Log::new();
        for i in 0..(SEG_SIZE * 3) as u64 {
            log.push(i);
        }
        // pin segment 0 through a reader cache
        let mut pinned = SegCache::default();
        assert_eq!(log.get(0, &mut pinned), 0);
        // retire segments 0 and 1: both shelved, only 1 is resettable
        log.truncate_below((SEG_SIZE * 2) as u64);
        assert_eq!(log.pooled_segments(), 2);
        // force two reuses: the unpinned segment recycles, the pinned
        // one must be skipped (a fresh segment is allocated instead)
        for i in (SEG_SIZE * 3) as u64..(SEG_SIZE * 5) as u64 {
            log.push(i);
        }
        assert_eq!(log.pooled_segments(), 1, "pinned segment must stay shelved");
        // once the reader cache moves on, the segment becomes reusable
        drop(pinned);
        for i in (SEG_SIZE * 5) as u64..(SEG_SIZE * 6) as u64 {
            log.push(i);
        }
        assert_eq!(log.pooled_segments(), 0);
        let mut cache = SegCache::default();
        for i in (SEG_SIZE * 2) as u64..(SEG_SIZE * 6) as u64 {
            assert_eq!(log.get(i, &mut cache), i);
        }
    }

    #[test]
    fn recycled_segments_drop_stale_payloads() {
        let marker = std::sync::Arc::new(());
        let log: Log<std::sync::Arc<()>> = Log::new();
        for _ in 0..SEG_SIZE * 2 {
            log.push(marker.clone());
        }
        assert_eq!(std::sync::Arc::strong_count(&marker), SEG_SIZE * 2 + 1);
        // retiring the first segment drops its payloads eagerly even
        // though the segment itself is shelved for reuse
        log.truncate_below(SEG_SIZE as u64);
        assert_eq!(std::sync::Arc::strong_count(&marker), SEG_SIZE + 1);
        assert_eq!(log.pooled_segments(), 1);
        drop(log);
        assert_eq!(std::sync::Arc::strong_count(&marker), 1);
    }

    #[test]
    fn never_drops_active_segment() {
        let log: Log<u64> = Log::new();
        for i in 0..10u64 {
            log.push(i);
        }
        log.truncate_below(u64::MAX);
        assert_eq!(log.segment_count(), 1);
        // still writable
        log.push(10);
        assert_eq!(log.ready(), 11);
    }

    #[test]
    fn concurrent_readers_see_published_prefix() {
        let log = std::sync::Arc::new(Log::<u64>::new());
        let writer = {
            let log = log.clone();
            std::thread::spawn(move || {
                for i in 0..STRESS_N {
                    log.push(i);
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let mut cache = SegCache::default();
                    let mut next = 0u64;
                    let mut idle = crate::util::Backoff::active();
                    while next < STRESS_N {
                        let r = log.ready();
                        if next < r {
                            idle.reset();
                        }
                        while next < r {
                            assert_eq!(log.get(next, &mut cache), next);
                            next += 1;
                        }
                        idle.snooze();
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
    }
}
