//! ScaleGate and Elastic ScaleGate — the paper's shared Tuple Buffer (TB).
//!
//! * [`esg::Esg`] — the elastic gate (Table 2's full API);
//! * a plain ScaleGate (§2.4) is an `Esg` whose membership never changes —
//!   use [`scale_gate`] for that.

pub mod esg;
pub mod log;

pub use esg::{AddError, Esg, EsgConfig, GateEntry, ReaderHandle, SourceHandle};

/// Construct a fixed-membership ScaleGate (§2.4): `sources` sources,
/// `readers` readers, no spare slots.
pub fn scale_gate<T: GateEntry>(
    sources: usize,
    readers: usize,
    capacity: usize,
) -> (Esg<T>, Vec<SourceHandle<T>>, Vec<ReaderHandle<T>>) {
    Esg::new(EsgConfig::for_gate(sources, readers, capacity), sources, readers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Tuple;

    #[test]
    fn scale_gate_fixed_membership() {
        let (_g, mut src, mut rdr) = scale_gate::<Tuple<u32>>(2, 2, 1024);
        assert_eq!(src.len(), 2);
        assert_eq!(rdr.len(), 2);
        src[0].add(Tuple::data(1, 0)).unwrap();
        src[1].add(Tuple::data(2, 0)).unwrap();
        assert_eq!(rdr[0].get().unwrap().ts, 1);
        assert_eq!(rdr[1].get().unwrap().ts, 1);
    }
}
