//! The Elastic ScaleGate (ESG): the paper's TB object (Table 2, §6).
//!
//! Semantics (Definition 6):
//! * a set of *sources* concurrently `add` timestamp-sorted streams;
//! * each *ready* tuple (Def. 3: ts ≤ min over active sources of the
//!   latest per-source timestamp) is delivered **exactly once to every
//!   reader**, in non-decreasing timestamp order, the **same order for all
//!   readers**;
//! * sources and readers can be added/removed at runtime (the elastic
//!   extension): `add_readers` seeds new readers at the invoking reader's
//!   position; `add_sources` seeds new sources' clocks at the Lemma-3 safe
//!   lower bound; `remove_sources` acts as the paper's *flush* tuple
//!   (the removed source stops holding back readiness, its queued tuples
//!   still drain in order); `remove_readers` drops reader positions.
//!
//! Implementation: per-source SPSC pending queues feed a shared
//! append-only [`Log`] through a cooperative merge step — whoever calls
//! `add`/`get` and wins the `try_lock` merges; readers consume the
//! published log prefix wait-free through per-slot atomic cursors.
//! This realizes the same ready/ordering semantics as the original
//! skip-list ScaleGate (handles = (queue tail, last_ts) per source,
//! reader handles = cursors), trading the paper's lock-free insertion for
//! a short critical section.
//!
//! §Perf: the data plane is *batch-native* (Prasaad et al.'s
//! run-granularity merging). Sources hand over ts-sorted runs
//! ([`SourceHandle::add_batch`]: one queue-tail publish + one clock
//! publish + one merge attempt per run); the merge, holding the lock
//! once, drains an entire run from the winning source while its head
//! stays the tournament minimum and appends it with one `ready` publish
//! ([`Log::push_run`]); readers take runs wait-free
//! ([`ReaderHandle::get_batch`]). Source/reader slots are
//! [`CachePadded`] so concurrent clock stores and cursor bumps never
//! false-share across the slot `Vec`s. The pre-batching claim that "the
//! merge lock is not the bottleneck" held only at per-tuple granularity
//! because every `add` bought a lock acquisition; post-batching the
//! lock, the clock publish, and the `ready` publish are each paid once
//! per run instead of once per tuple. `bench_micro` measures the
//! batched-vs-per-tuple gate round trip on the current machine and
//! records it in `BENCH_micro.json` (acceptance bar: ≥ 2× at batch
//! 256).
//!
//! §Perf memory discipline: the gate is *allocation-free in steady
//! state* (see `lib.rs` §Perf). Its own scratch (the merge's staged
//! buffers and run under construction) is long-lived and reused under
//! the merge lock, with burst decay back to a bounded capacity; the
//! attached workers' run buffers circulate through a per-gate
//! [`BufferPool`] reachable from every endpoint
//! ([`SourceHandle::pool`]/[`ReaderHandle::pool`]), so a buffer freed
//! by an evicted worker at reconfiguration is reused by the next one
//! instead of going back to the allocator. The hot fns below carry
//! `lint: no-alloc` markers enforced by `stretch lint` (L6).
//!
//! # Memory-ordering protocol
//!
//! The gate's lock-free edges (everything else runs under the `merge`
//! or `membership` mutex, and tuple *data* visibility rides the SPSC
//! queues' and the [`Log`]'s own protocols):
//!
//! * **clock publish** — `SourceSlot::last_ts` advances with a Release
//!   `fetch_max` *after* the queue-tail publish of the tuples it
//!   covers; `bound()`'s Acquire loads pair with it, so a readiness
//!   bound that admits ts happens-after the enqueue of every tuple at
//!   or below ts from that source (readiness never runs ahead of data).
//! * **membership** — `active` flips are Release stores made under the
//!   `membership` mutex; Acquire loads everywhere pair with them, so an
//!   observed-active slot always has its seeded clock (sources) or
//!   seeded cursor/floor (readers) visible too.
//! * **cursor/floor publish** — a reader's Release stores of `cursor`
//!   and `floor` pair with the Acquire scans in `backlog_range`, `gc`,
//!   and `add_readers`, so flow control, segment reclamation, and
//!   reader seeding never run ahead of what the reader has actually
//!   consumed or may still be processing.

use crate::scalegate::log::{Log, SegCache};
use crate::time::{EventTime, TIME_MIN};
use crate::util::pool::{self, BufferPool};
use crate::util::spsc::{self, Consumer, Producer, PushError};
use crate::util::{Backoff, CachePadded};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Tuples pulled from a source's pending queue per chunked pop inside
/// the merge (amortizes the queue-head publish).
const MERGE_CHUNK: usize = 256;

/// Cap on a single merged run: bounds how stale the readiness bound
/// (loaded once per run) can get, and keeps `push_run` within ~one log
/// segment.
const MERGE_RUN_MAX: usize = 1024;

/// Anything that can flow through a gate: must expose its event time.
pub trait GateEntry: Clone + Send + Sync + 'static {
    fn ts(&self) -> EventTime;
}

impl<P: Clone + Send + Sync + 'static> GateEntry for crate::tuple::Tuple<P> {
    #[inline]
    fn ts(&self) -> EventTime {
        self.ts
    }
}

/// Gate construction parameters.
#[derive(Clone, Copy, Debug)]
pub struct EsgConfig {
    /// Max sources ever attachable (slots are pre-allocated).
    pub max_sources: usize,
    /// Max readers ever attachable.
    pub max_readers: usize,
    /// Flow-control bound: max published-but-unconsumed entries
    /// (§8: "putting a bound on ESG's size").
    pub capacity: usize,
    /// Per-source pending-queue capacity.
    pub source_queue: usize,
}

impl Default for EsgConfig {
    fn default() -> Self {
        EsgConfig { max_sources: 8, max_readers: 8, capacity: 1 << 16, source_queue: 1 << 12 }
    }
}

impl EsgConfig {
    /// The one place the per-source pending-queue size is derived from the
    /// gate's flow-control capacity: an even split across sources, clamped
    /// to [64, 2^14]. Every gate construction site (engine in/out gates,
    /// fixed ScaleGates, pipeline hand-off gates) goes through this.
    pub fn for_gate(max_sources: usize, max_readers: usize, capacity: usize) -> Self {
        EsgConfig {
            max_sources,
            max_readers,
            capacity,
            source_queue: (capacity / max_sources.max(1)).clamp(64, 1 << 14),
        }
    }
}

struct SourceSlot {
    active: AtomicBool,
    /// Latest timestamp added by this source (the source "handle clock").
    last_ts: AtomicI64,
}

struct ReaderSlot {
    active: AtomicBool,
    /// Next log index this reader will consume.
    cursor: AtomicU64,
    /// First log index the reader may still be *processing* (batch
    /// consumers advance `cursor` past tuples they have not handled yet;
    /// GC and reader-seeding must not reclaim below this).
    floor: AtomicU64,
}

/// Per-source staging of tuples popped (in chunks) off the SPSC queue
/// but not yet merged. Stored newest-first so the next tuple to merge is
/// `buf.last()` and consumption is an O(1) `pop`.
struct Staged<T> {
    buf: Vec<T>,
}

impl<T: GateEntry> Staged<T> {
    /// Pull the next chunk off the queue (only when empty — partial
    /// chunks keep their order).
    ///
    /// lint: no-alloc — merge hot path: `pop_chunk` reserves into this
    /// long-lived staging buffer, whose capacity persists across
    /// refills (a no-op in steady state); the trim below caps it at
    /// 2×[`MERGE_CHUNK`] so it can never creep past the working set.
    fn refill(&mut self, q: &mut Consumer<T>) {
        debug_assert!(self.buf.is_empty());
        pool::shrink_excess(&mut self.buf, 2 * MERGE_CHUNK);
        q.pop_chunk(&mut self.buf, MERGE_CHUNK);
        self.buf.reverse();
    }

    #[inline]
    fn head(&self) -> Option<&T> {
        self.buf.last()
    }

    #[inline]
    fn take(&mut self) -> T {
        self.buf.pop().expect("take from empty staging")
    }
}

struct MergeState<T> {
    queues: Vec<Consumer<T>>,
    staged: Vec<Staged<T>>,
    /// Scratch for the run under construction: allocated once at gate
    /// construction with [`MERGE_RUN_MAX`] capacity and reused for the
    /// gate's whole life (`push_run` drains it in place, and the merge
    /// loop never grows it past that bound — pool-style recycling with
    /// a pool of exactly one, held under the merge lock).
    run: Vec<T>,
    /// Entries merged since last GC check.
    since_gc: usize,
}

/// Error from `try_add`.
#[derive(Debug, PartialEq, Eq)]
pub enum AddError<T> {
    /// Flow control: gate at capacity — retry (backpressure).
    Full(T),
    /// The source slot is not active.
    Inactive(T),
}

struct Inner<T: GateEntry> {
    log: Log<T>,
    merge: Mutex<MergeState<T>>,
    /// Slots are cache-padded: source clocks are stored by their owning
    /// producer threads and scanned by every `bound()` caller; without
    /// padding adjacent slots in the `Vec` false-share.
    sources: Vec<CachePadded<SourceSlot>>,
    readers: Vec<CachePadded<ReaderSlot>>,
    /// Guards membership changes and GC (see module docs for the
    /// activation/truncation race this prevents).
    membership: Mutex<()>,
    capacity: usize,
    /// Run-buffer pool shared by everything attached to this gate
    /// (§Perf memory discipline): workers draw their batch/out scratch
    /// here and return it on eviction, so reconfiguration churns buffer
    /// *ownership*, not the allocator. Cold-path only — in steady state
    /// each buffer circulates privately inside its worker's loop.
    pool: BufferPool<T>,
}

impl<T: GateEntry> Inner<T> {
    /// min over active sources of last_ts; +∞ when none (drain mode).
    ///
    /// ORDERING: `active` Acquire pairs with membership's Release flips
    /// (an observed-active source has its Lemma-3 seeded clock visible);
    /// `last_ts` Acquire pairs with the sources' Release clock publishes
    /// — a bound admitting ts happens-after the queue-tail publish of
    /// every tuple at or below ts from that source.
    fn bound(&self) -> EventTime {
        let mut b = i64::MAX;
        let mut any = false;
        for s in &self.sources {
            if s.active.load(Ordering::Acquire) {
                any = true;
                b = b.min(s.last_ts.load(Ordering::Acquire));
            }
        }
        if any {
            b
        } else {
            i64::MAX
        }
    }

    /// Published-but-unconsumed entries w.r.t. the slowest active reader.
    fn backlog(&self) -> u64 {
        self.backlog_range(0, self.readers.len())
    }

    /// [`backlog`](Self::backlog) restricted to reader slots `lo..hi` —
    /// the per-consumer-group flow signal on shared fan-out gates, where
    /// each downstream stage owns a contiguous reader-slot range.
    ///
    /// ORDERING: `active` Acquire pairs with membership's Release flips;
    /// `cursor` Acquire pairs with the readers' Release cursor bumps.
    /// The result is a conservative flow signal (a reader may advance
    /// mid-scan), never an exactness claim — see the saturating
    /// subtraction below.
    fn backlog_range(&self, lo: usize, hi: usize) -> u64 {
        let (lo, hi) = (lo.min(self.readers.len()), hi.min(self.readers.len()));
        if lo >= hi {
            return 0; // empty or inverted range: no readers, no backlog
        }
        let ready = self.log.ready();
        let mut min_cur = u64::MAX;
        for r in &self.readers[lo..hi] {
            if r.active.load(Ordering::Acquire) {
                min_cur = min_cur.min(r.cursor.load(Ordering::Acquire));
            }
        }
        if min_cur == u64::MAX {
            0
        } else {
            // `ready` was loaded before the cursor scan; a reader may have
            // advanced past it in the meantime — saturate, don't underflow.
            ready.saturating_sub(min_cur)
        }
    }

    /// The merge step: emit every ready pending tuple into the log, in
    /// (ts, source) order. Caller must hold the merge lock.
    ///
    /// Run-granularity (§Perf): instead of a per-tuple k-way tournament,
    /// each outer iteration picks the winning source once and then drains
    /// a whole *run* from it — every tuple that the per-tuple tournament
    /// would also have assigned to that source, i.e. while its head stays
    /// lexicographically ≤ every other source's head on (ts, slot) and
    /// within the readiness bound — appending the run with one `ready`
    /// publish. The resulting log sequence is identical to the per-tuple
    /// merge's (the property suite proves it), at a fraction of the
    /// atomic/lock traffic.
    ///
    /// lint: no-alloc — THE merge hot path: runs build in the reused
    /// `run` scratch (bounded by [`MERGE_RUN_MAX`]), staging refills
    /// reuse their chunk buffers, and `push_run` appends into recycled
    /// log segments. Steady state touches the allocator zero times.
    fn do_merge(&self, st: &mut MergeState<T>) {
        let MergeState { queues, staged, run, since_gc } = st;
        loop {
            let bound = self.bound();
            // refill empty staging buffers, then tournament over heads
            let mut best: Option<(EventTime, usize)> = None;
            for i in 0..queues.len() {
                if staged[i].head().is_none() {
                    staged[i].refill(&mut queues[i]);
                }
                if let Some(h) = staged[i].head() {
                    let hts = h.ts();
                    if best.map_or(true, |(bts, _)| hts < bts) {
                        best = Some((hts, i));
                    }
                }
            }
            let Some((win_ts, i)) = best else { break };
            if win_ts > bound {
                break;
            }
            // the tightest competing (ts, slot) pair: the run from `i`
            // extends exactly while the per-tuple tournament would keep
            // picking `i` over it
            let mut other: Option<(EventTime, usize)> = None;
            for (j, s) in staged.iter().enumerate() {
                if j == i {
                    continue;
                }
                if let Some(h) = s.head() {
                    let hts = h.ts();
                    if other.map_or(true, |(ots, _)| hts < ots) {
                        other = Some((hts, j));
                    }
                }
            }
            debug_assert!(run.is_empty());
            loop {
                if staged[i].head().is_none() {
                    staged[i].refill(&mut queues[i]);
                }
                let Some(h) = staged[i].head() else { break };
                let hts = h.ts();
                if hts > bound {
                    break;
                }
                if let Some((ots, oj)) = other {
                    if hts > ots || (hts == ots && i > oj) {
                        break;
                    }
                }
                run.push(staged[i].take());
                if run.len() >= MERGE_RUN_MAX {
                    break;
                }
            }
            *since_gc += run.len();
            self.log.push_run(run);
        }
        if *since_gc >= crate::scalegate::log::SEG_SIZE {
            *since_gc = 0;
            self.gc();
        }
    }

    /// Reclaim log segments below the slowest active reader. Uses the
    /// processing *floor*, not the consume cursor: batch readers advance
    /// the cursor past entries they are still working through, and
    /// `add_readers_at` may seed new readers at (floor − 1).
    ///
    /// ORDERING: `active`/`floor` Acquire loads pair with membership's
    /// and the readers' Release stores (including `pin_floor`'s Release
    /// `fetch_min`), so truncation happens-after every log read the
    /// published floors still protect.
    fn gc(&self) {
        let _m = self.membership.lock().unwrap();
        let mut min_floor = u64::MAX;
        for r in &self.readers {
            if r.active.load(Ordering::Acquire) {
                min_floor = min_floor.min(r.floor.load(Ordering::Acquire));
            }
        }
        if min_floor != u64::MAX {
            // keep one entry of slack below the floor (reader re-seeding)
            self.log.truncate_below(min_floor.saturating_sub(1));
        }
    }

    fn try_merge(&self) {
        if let Ok(mut st) = self.merge.try_lock() {
            self.do_merge(&mut st);
        }
    }
}

/// The shared gate object; clone-able handle factory lives in [`Esg`].
pub struct Esg<T: GateEntry> {
    inner: Arc<Inner<T>>,
}

impl<T: GateEntry> Clone for Esg<T> {
    fn clone(&self) -> Self {
        Esg { inner: self.inner.clone() }
    }
}

/// A source endpoint (owns slot `id`'s producer).
pub struct SourceHandle<T: GateEntry> {
    inner: Arc<Inner<T>>,
    id: usize,
    producer: Producer<T>,
}

/// A reader endpoint (owns slot `id`'s cursor + segment cache).
pub struct ReaderHandle<T: GateEntry> {
    inner: Arc<Inner<T>>,
    id: usize,
    cache: SegCache<T>,
    /// When set, `get`/`get_batch` never publish a processing floor above
    /// this log index, so GC keeps `[pin, …)` reclaimable-proof while the
    /// owner still needs to [`ReaderHandle::peek`] it (crash replay).
    floor_pin: Option<u64>,
}

impl<T: GateEntry> Esg<T> {
    /// Build a gate and hand out all source/reader endpoints. Sources
    /// `0..active_sources` and readers `0..active_readers` start active;
    /// the rest are pool slots awaiting `add_sources`/`add_readers`.
    pub fn new(
        cfg: EsgConfig,
        active_sources: usize,
        active_readers: usize,
    ) -> (Esg<T>, Vec<SourceHandle<T>>, Vec<ReaderHandle<T>>) {
        assert!(active_sources <= cfg.max_sources);
        assert!(active_readers <= cfg.max_readers);
        let mut producers = Vec::with_capacity(cfg.max_sources);
        let mut consumers = Vec::with_capacity(cfg.max_sources);
        for _ in 0..cfg.max_sources {
            let (p, c) = spsc::spsc::<T>(cfg.source_queue);
            producers.push(p);
            consumers.push(c);
        }
        let inner = Arc::new(Inner {
            log: Log::new(),
            merge: Mutex::new(MergeState {
                staged: (0..cfg.max_sources).map(|_| Staged { buf: Vec::new() }).collect(),
                queues: consumers,
                run: Vec::with_capacity(MERGE_RUN_MAX),
                since_gc: 0,
            }),
            sources: (0..cfg.max_sources)
                .map(|i| {
                    CachePadded::new(SourceSlot {
                        active: AtomicBool::new(i < active_sources),
                        last_ts: AtomicI64::new(TIME_MIN),
                    })
                })
                .collect(),
            readers: (0..cfg.max_readers)
                .map(|i| {
                    CachePadded::new(ReaderSlot {
                        active: AtomicBool::new(i < active_readers),
                        cursor: AtomicU64::new(0),
                        floor: AtomicU64::new(0),
                    })
                })
                .collect(),
            membership: Mutex::new(()),
            capacity: cfg.capacity,
            pool: BufferPool::new(),
        });
        let src = producers
            .into_iter()
            .enumerate()
            .map(|(id, producer)| SourceHandle { inner: inner.clone(), id, producer })
            .collect();
        let rdr = (0..cfg.max_readers)
            .map(|id| ReaderHandle {
                inner: inner.clone(),
                id,
                cache: SegCache::default(),
                floor_pin: None,
            })
            .collect();
        (Esg { inner }, src, rdr)
    }

    /// `addReaders(R, j)` (Table 2): activate readers in `ids`, each
    /// positioned to retrieve next the tuple reader `j` is *currently*
    /// processing (its last retrieved tuple). Alg. 4 invokes this while
    /// processing the reconfiguration-triggering tuple t, and Theorem 3
    /// requires the newly provisioned instances to process t themselves
    /// (keys that moved to them would otherwise be updated by no one).
    /// Returns `false` unless *all* of `ids` were inactive (the "only one
    /// concurrent caller succeeds" arbitration).
    /// **`get()`-consumers only**: the cursor−1 convention assumes the
    /// invoker's cursor trails its processing by exactly one tuple. A
    /// batch consumer ([`ReaderHandle::get_batch`]) has up to a full
    /// batch of retrieved-but-unprocessed tuples past its cursor and
    /// MUST use [`Esg::add_readers_at`] with its own computed position
    /// (the engine's `do_reconfig` does), or the new readers skip the
    /// invoker's batch remainder.
    pub fn add_readers(&self, ids: &[usize], j: usize) -> bool {
        // ORDERING: Acquire pairs with reader j's Release cursor bumps —
        // the seed position is at least as fresh as j's last `get`.
        let pos = self.inner.readers[j].cursor.load(Ordering::Acquire).saturating_sub(1);
        self.add_readers_at(ids, pos)
    }

    /// `addReaders` with an explicit starting log index. Batch-consuming
    /// readers advance their cursor past tuples they have not processed
    /// yet, so the invoking instance computes the index of the tuple it is
    /// *currently* processing itself (cursor − unconsumed − 1) instead of
    /// relying on the cursor-1 convention of [`Esg::add_readers`]. Same
    /// all-inactive arbitration.
    ///
    /// ORDERING: the `active` Acquire check pairs with prior Release
    /// deactivations (arbitration is additionally serialized by the
    /// membership mutex); the seeding `cursor`/`floor` Release stores
    /// are sequenced before the Release `active` flip, so any Acquire
    /// observer of an active slot also sees its seeded position — never
    /// a stale cursor from the slot's previous incarnation.
    pub fn add_readers_at(&self, ids: &[usize], pos: u64) -> bool {
        let _m = self.inner.membership.lock().unwrap();
        if ids.iter().any(|&i| self.inner.readers[i].active.load(Ordering::Acquire)) {
            return false;
        }
        for &i in ids {
            self.inner.readers[i].cursor.store(pos, Ordering::Release);
            self.inner.readers[i].floor.store(pos, Ordering::Release);
            self.inner.readers[i].active.store(true, Ordering::Release);
        }
        true
    }

    /// `removeReaders(R)`: deactivate readers. Returns `false` unless all
    /// were active.
    ///
    /// ORDERING: Acquire check / Release flip pair with each other across
    /// membership calls; the Acquire scans in `gc`/`backlog_range` stop
    /// counting a slot as soon as they observe the flip.
    pub fn remove_readers(&self, ids: &[usize]) -> bool {
        let _m = self.inner.membership.lock().unwrap();
        if ids.iter().any(|&i| !self.inner.readers[i].active.load(Ordering::Acquire)) {
            return false;
        }
        for &i in ids {
            self.inner.readers[i].active.store(false, Ordering::Release);
        }
        true
    }

    /// `addSources(S)` with the Lemma-3 watermark floor: new sources are
    /// guaranteed to only add tuples with ts ≥ `floor_ts` (the timestamp
    /// of the reconfiguration-triggering tuple). Returns `false` unless
    /// all of `ids` were inactive.
    ///
    /// ORDERING: the Release `last_ts` seed is sequenced before the
    /// Release `active` flip, so `bound()`'s Acquire loads never observe
    /// an active source with an unseeded clock (which would read
    /// `TIME_MIN` and stall readiness gate-wide).
    pub fn add_sources(&self, ids: &[usize], floor_ts: EventTime) -> bool {
        let _m = self.inner.membership.lock().unwrap();
        if ids.iter().any(|&i| self.inner.sources[i].active.load(Ordering::Acquire)) {
            return false;
        }
        for &i in ids {
            // the paper's *dummy* tuple: seed the new handle's clock
            self.inner.sources[i].last_ts.store(floor_ts, Ordering::Release);
            self.inner.sources[i].active.store(true, Ordering::Release);
        }
        true
    }

    /// `removeSources(S)`: the paper's *flush*: the sources stop gating
    /// readiness; their pending tuples still drain in order. Returns
    /// `false` unless all were active.
    ///
    /// ORDERING: Release `active` flips pair with `bound()`'s Acquire
    /// loads — once observed inactive, the slot stops gating readiness;
    /// the trailing merge attempt then publishes anything unblocked.
    pub fn remove_sources(&self, ids: &[usize]) -> bool {
        {
            let _m = self.inner.membership.lock().unwrap();
            if ids.iter().any(|&i| !self.inner.sources[i].active.load(Ordering::Acquire)) {
                return false;
            }
            for &i in ids {
                self.inner.sources[i].active.store(false, Ordering::Release);
            }
        }
        // removing a gating source may make tuples ready
        self.inner.try_merge();
        true
    }

    /// Whether a source slot is currently active.
    ///
    /// ORDERING: Acquire pairs with membership's Release flips.
    pub fn source_active(&self, id: usize) -> bool {
        self.inner.sources[id].active.load(Ordering::Acquire)
    }

    /// Whether a reader slot is currently active.
    ///
    /// ORDERING: Acquire pairs with membership's Release flips.
    pub fn reader_active(&self, id: usize) -> bool {
        self.inner.readers[id].active.load(Ordering::Acquire)
    }

    /// Current published-but-unconsumed backlog (flow-control metric).
    pub fn backlog(&self) -> u64 {
        self.inner.backlog()
    }

    /// Backlog w.r.t. the slowest active reader in slots `lo..hi` only.
    /// On a shared fan-out gate each downstream stage owns a contiguous
    /// reader range; this is that stage's `in_backlog` (a slow sibling
    /// stage holds log entries but is not *this* stage's pending work).
    pub fn backlog_range(&self, lo: usize, hi: usize) -> u64 {
        self.inner.backlog_range(lo, hi)
    }

    /// Current readiness bound: min over active sources of their handle
    /// clocks (+∞ when no source is active). Pipeline control injection
    /// stamps control tuples with this — the Lemma-3-safe "now" of the
    /// gate.
    pub fn clock_bound(&self) -> EventTime {
        self.inner.bound()
    }

    /// Total entries ever published (monotone).
    pub fn published(&self) -> u64 {
        self.inner.log.ready()
    }

    /// Force a merge step (used by drivers at end-of-stream).
    pub fn flush_merge(&self) {
        let mut st = self.inner.merge.lock().unwrap();
        self.inner.do_merge(&mut st);
    }

    /// The gate's shared run-buffer pool (§Perf memory discipline).
    /// Every endpoint of one gate sees the same pool, so buffers
    /// released by a decommissioned worker are reused by its successor.
    pub fn pool(&self) -> &BufferPool<T> {
        &self.inner.pool
    }
}

impl<T: GateEntry> SourceHandle<T> {
    pub fn id(&self) -> usize {
        self.id
    }

    /// ORDERING: Acquire pairs with membership's Release `active` flips.
    pub fn is_active(&self) -> bool {
        self.inner.sources[self.id].active.load(Ordering::Acquire)
    }

    /// Non-blocking add. Tuples from one source MUST be ts-sorted.
    pub fn try_add(&mut self, t: T) -> Result<(), AddError<T>> {
        let slot = &self.inner.sources[self.id];
        // ORDERING: Acquire pairs with membership's Release flips — a
        // decommissioned slot must hand the tuple back, not enqueue it.
        if !slot.active.load(Ordering::Acquire) {
            return Err(AddError::Inactive(t));
        }
        if self.inner.backlog() as usize >= self.inner.capacity {
            // cooperative merge so the backlog can drain
            self.inner.try_merge();
            return Err(AddError::Full(t));
        }
        let ts = t.ts();
        // ORDERING: Acquire (debug-only monotonicity check) — reads our
        // own single-writer clock; any ordering would do here.
        debug_assert!(
            ts >= slot.last_ts.load(Ordering::Acquire),
            "source {} stream not ts-sorted: {ts} < {}",
            self.id,
            slot.last_ts.load(Ordering::Acquire)
        );
        match self.producer.try_push(t) {
            Ok(()) => {}
            Err(PushError::Full(t)) | Err(PushError::Closed(t)) => {
                self.inner.try_merge();
                return Err(AddError::Full(t));
            }
        }
        // ORDERING: Release clock publish, sequenced after the queue-tail
        // publish above — pairs with `bound()`'s Acquire loads, so a
        // readiness bound admitting `ts` proves the tuple is visible to
        // the merge. Weakened from AcqRel: the RMW's Acquire half was
        // unused (the fetched-back value is discarded), and `fetch_max`'s
        // same-location monotonicity is total regardless of ordering.
        slot.last_ts.fetch_max(ts, Ordering::Release);
        self.inner.try_merge();
        Ok(())
    }

    /// Batched [`try_add`](Self::try_add): move the accepted prefix of a
    /// ts-sorted run into this source's pending queue with ONE clock
    /// publish and ONE cooperative-merge attempt, draining that prefix
    /// off `run`. Returns how many were accepted; `Ok(0)` is
    /// backpressure (gate at capacity or pending queue full). The run
    /// must be sorted within itself and against everything this source
    /// added before.
    ///
    /// lint: no-alloc — source hot path: the accepted prefix moves into
    /// preallocated ring slots (`push_slice`) and the residual stays in
    /// the caller's recycled run buffer.
    pub fn try_add_batch(&mut self, run: &mut Vec<T>) -> Result<usize, AddError<()>> {
        let slot = &self.inner.sources[self.id];
        // ORDERING: Acquire pairs with membership's Release flips (see
        // `try_add`).
        if !slot.active.load(Ordering::Acquire) {
            return Err(AddError::Inactive(()));
        }
        if run.is_empty() {
            return Ok(0);
        }
        debug_assert!(
            run.windows(2).all(|w| w[0].ts() <= w[1].ts()),
            "source {} run not ts-sorted",
            self.id
        );
        // ORDERING: Acquire (debug-only monotonicity check) — reads our
        // own single-writer clock; any ordering would do here.
        debug_assert!(
            run[0].ts() >= slot.last_ts.load(Ordering::Acquire),
            "source {} stream not ts-sorted: {} < {}",
            self.id,
            run[0].ts(),
            slot.last_ts.load(Ordering::Acquire)
        );
        // flow control: admit at most the capacity headroom, like the
        // per-tuple path (bounded overshoot of one in-flight run)
        let headroom = self.inner.capacity.saturating_sub(self.inner.backlog() as usize);
        let n = self.producer.free().min(run.len()).min(headroom);
        if n == 0 {
            self.inner.try_merge();
            return Ok(0);
        }
        // `free()` only grows until our next push, so exactly n go in
        let last_ts = run[n - 1].ts();
        let pushed = self.producer.push_slice(run, n);
        debug_assert_eq!(pushed, n);
        // ORDERING: ONE Release clock publish per run, sequenced after
        // the run's single queue-tail publish — see `try_add` for the
        // `bound()` pairing and the AcqRel→Release weakening argument.
        slot.last_ts.fetch_max(last_ts, Ordering::Release);
        self.inner.try_merge();
        Ok(pushed)
    }

    /// Blocking [`try_add_batch`](Self::try_add_batch): backoff until the
    /// whole run is in (generator-side flow control). If the source slot
    /// is decommissioned mid-drain, returns `Err(Inactive)` with the
    /// unconsumed residual still in `run` — the caller decides whether to
    /// re-route it (e.g. through another slot) or drop it deliberately;
    /// the tuples are never silently lost.
    pub fn add_batch(&mut self, run: &mut Vec<T>) -> Result<(), AddError<()>> {
        let mut backoff = Backoff::active();
        while !run.is_empty() {
            match self.try_add_batch(run) {
                Ok(0) => backoff.snooze(),
                Ok(_) => backoff.reset(),
                Err(AddError::Inactive(())) => return Err(AddError::Inactive(())),
                Err(AddError::Full(_)) => unreachable!("try_add_batch signals Full as Ok(0)"),
            }
        }
        Ok(())
    }

    /// Like [`try_add`](Self::try_add) but exempt from the gate's
    /// flow-control capacity bound. For *rare control tuples only*: a
    /// pipeline driver injecting a reconfiguration must not block behind
    /// data backpressure it is itself responsible for draining further
    /// downstream (a deadlockable cycle). The per-source pending queue
    /// still bounds it.
    pub fn force_add(&mut self, t: T) -> Result<(), AddError<T>> {
        let slot = &self.inner.sources[self.id];
        // ORDERING: Acquire pairs with membership's Release flips (see
        // `try_add`).
        if !slot.active.load(Ordering::Acquire) {
            return Err(AddError::Inactive(t));
        }
        let ts = t.ts();
        // ORDERING: Acquire (debug-only check of our own clock).
        debug_assert!(ts >= slot.last_ts.load(Ordering::Acquire));
        match self.producer.try_push(t) {
            Ok(()) => {}
            Err(PushError::Full(t)) | Err(PushError::Closed(t)) => {
                self.inner.try_merge();
                return Err(AddError::Full(t));
            }
        }
        // ORDERING: Release clock publish after the queue-tail publish —
        // same pairing and AcqRel→Release weakening as `try_add`.
        slot.last_ts.fetch_max(ts, Ordering::Release);
        self.inner.try_merge();
        Ok(())
    }

    /// Blocking add with backoff (generator-side flow control). If the
    /// source slot is decommissioned before the tuple is accepted, the
    /// tuple is handed back via `Err(Inactive(t))` instead of aborting —
    /// the caller re-routes or drops it deliberately.
    pub fn add(&mut self, mut t: T) -> Result<(), AddError<T>> {
        let mut backoff = Backoff::active();
        loop {
            match self.try_add(t) {
                Ok(()) => return Ok(()),
                Err(AddError::Inactive(back)) => return Err(AddError::Inactive(back)),
                Err(AddError::Full(back)) => {
                    t = back;
                    backoff.snooze();
                }
            }
        }
    }

    /// The gate this source belongs to (for membership calls from the
    /// source's own thread, Alg. 4 L19-20).
    pub fn gate(&self) -> Esg<T> {
        Esg { inner: self.inner.clone() }
    }

    /// The gate's shared run-buffer pool — draw the out-run scratch that
    /// feeds [`SourceHandle::add_batch`] here and return it when the
    /// worker exits (see [`Esg::pool`]).
    pub fn pool(&self) -> &BufferPool<T> {
        &self.inner.pool
    }

    /// Advance this source's clock without enqueuing anything — the
    /// low-level primitive behind heartbeats at gate level.
    pub fn advance_clock(&mut self, ts: EventTime) {
        let slot = &self.inner.sources[self.id];
        // ORDERING: Release heartbeat publish — pairs with `bound()`'s
        // Acquire loads; nothing was enqueued, so the edge orders only
        // the clock itself (AcqRel→Release: fetched-back value unused).
        slot.last_ts.fetch_max(ts, Ordering::Release);
        self.inner.try_merge();
    }
}

impl<T: GateEntry> ReaderHandle<T> {
    pub fn id(&self) -> usize {
        self.id
    }

    /// ORDERING: Acquire pairs with membership's Release `active` flips.
    pub fn is_active(&self) -> bool {
        self.inner.readers[self.id].active.load(Ordering::Acquire)
    }

    /// `getNextReadyTuple` (§2.4): next ready tuple not yet consumed by
    /// this reader; `None` if none is ready (or the reader is inactive —
    /// pool instances poll and back off, §7).
    ///
    /// ORDERING: `active` Acquire pairs with membership's Release flips;
    /// the `cursor` Acquire loads pair with `add_readers_at`'s seeding
    /// Release store (a just-activated reader starts exactly at its
    /// seed); the `floor`/`cursor` Release stores publish consumption to
    /// the Acquire scans in `gc`/`backlog_range`/`add_readers`. The log
    /// read itself is covered by `Log`'s ready-publish protocol.
    pub fn get(&mut self) -> Option<T> {
        let slot = &self.inner.readers[self.id];
        if !slot.active.load(Ordering::Acquire) {
            return None;
        }
        let cur = slot.cursor.load(Ordering::Acquire);
        if cur < self.inner.log.ready() {
            let v = self.inner.log.get(cur, &mut self.cache);
            slot.floor.store(self.floor_pin.map_or(cur, |p| p.min(cur)), Ordering::Release);
            slot.cursor.store(cur + 1, Ordering::Release);
            return Some(v);
        }
        // nothing published: cooperatively merge, then retry once
        self.inner.try_merge();
        let cur = slot.cursor.load(Ordering::Acquire);
        if cur < self.inner.log.ready() {
            let v = self.inner.log.get(cur, &mut self.cache);
            slot.floor.store(self.floor_pin.map_or(cur, |p| p.min(cur)), Ordering::Release);
            slot.cursor.store(cur + 1, Ordering::Release);
            return Some(v);
        }
        None
    }

    /// Batched `getNextReadyTuple`: append up to `max` ready tuples to
    /// `buf` with ONE cursor update, returning how many were taken. Cuts
    /// the per-tuple atomic/merge overhead on the worker and egress hot
    /// paths (§Perf). The reader's processing floor stays at the batch
    /// start until the next `get`/`get_batch`, so GC never reclaims
    /// entries the caller is still iterating and
    /// [`Esg::add_readers_at`] can seed new readers inside the batch.
    ///
    /// ORDERING: same protocol as [`ReaderHandle::get`] — `active`
    /// Acquire, seeded-`cursor` Acquire, and ONE `floor`-then-`cursor`
    /// Release publish per batch instead of per tuple.
    ///
    /// lint: no-alloc — reader hot path: `reserve` on the caller's
    /// recycled scratch is a no-op in steady state (capacity persists
    /// across refills); the empty-buffer trim below decays capacity a
    /// backlog burst grew, so one burst never pins its high-water
    /// footprint for the rest of the run.
    pub fn get_batch(&mut self, buf: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        if buf.is_empty() {
            // burst decay: only between batches, never under the
            // caller's feet while it still holds unconsumed tuples
            pool::shrink_excess(buf, pool::DEFAULT_SHRINK_CAP);
        }
        let slot = &self.inner.readers[self.id];
        if !slot.active.load(Ordering::Acquire) {
            return 0;
        }
        let cur = slot.cursor.load(Ordering::Acquire);
        let mut ready = self.inner.log.ready();
        if cur >= ready {
            self.inner.try_merge();
            ready = self.inner.log.ready();
            if cur >= ready {
                return 0;
            }
        }
        let n = ((ready - cur) as usize).min(max);
        buf.reserve(n);
        for i in 0..n as u64 {
            buf.push(self.inner.log.get(cur + i, &mut self.cache));
        }
        slot.floor.store(self.floor_pin.map_or(cur, |p| p.min(cur)), Ordering::Release);
        slot.cursor.store(cur + n as u64, Ordering::Release);
        n
    }

    /// This reader's consume cursor (next log index it will take).
    ///
    /// ORDERING: Acquire pairs with the owner's (or the seeder's)
    /// Release cursor stores — a monitoring read.
    pub fn cursor(&self) -> u64 {
        self.inner.readers[self.id].cursor.load(Ordering::Acquire)
    }

    /// Read log index `idx` directly, without touching the cursor or
    /// floor. `None` once `idx` reaches the published prefix. Crash
    /// replay uses this to re-read a [`ReaderHandle::pin_floor`]-retained
    /// range that `get_batch` already consumed.
    pub fn peek(&mut self, idx: u64) -> Option<T> {
        if idx < self.inner.log.ready() {
            Some(self.inner.log.get(idx, &mut self.cache))
        } else {
            None
        }
    }

    /// Pin this reader's processing floor at `pos`: until
    /// [`ReaderHandle::unpin_floor`], `get`/`get_batch` never publish a
    /// floor above `pos`, so GC retains `[pos, …)` even while the reader
    /// keeps consuming past it. Pinning never *raises* the current floor.
    pub fn pin_floor(&mut self, pos: u64) {
        let slot = &self.inner.readers[self.id];
        // ORDERING: Release floor publish — pairs with `gc`'s Acquire
        // scan, so reclamation never runs ahead of the pin. Weakened
        // from AcqRel: the RMW's Acquire half was unused (fetched-back
        // value discarded), and `fetch_min`'s same-location monotonicity
        // is total regardless of ordering.
        slot.floor.fetch_min(pos, Ordering::Release);
        self.floor_pin = Some(pos);
    }

    /// Release a [`ReaderHandle::pin_floor`]; the floor resumes tracking
    /// the consume position at the next `get`/`get_batch`.
    pub fn unpin_floor(&mut self) {
        self.floor_pin = None;
    }

    /// The gate this reader belongs to (for membership calls from the
    /// reader's own thread, Alg. 4 L19-20).
    pub fn gate(&self) -> Esg<T> {
        Esg { inner: self.inner.clone() }
    }

    /// The gate's shared run-buffer pool — draw the batch scratch that
    /// [`ReaderHandle::get_batch`] fills here and return it when the
    /// worker exits (see [`Esg::pool`]).
    pub fn pool(&self) -> &BufferPool<T> {
        &self.inner.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuple::Tuple;

    type T = Tuple<u64>;

    /// Threaded-stress iteration count: scaled down under Miri so the
    /// interpreted interleavings stay within the CI budget while the
    /// same orderings get exercised.
    #[cfg(miri)]
    const STRESS_N: i64 = 300;
    #[cfg(not(miri))]
    const STRESS_N: i64 = 20_000;

    fn gate(ns: usize, nr: usize) -> (Esg<T>, Vec<SourceHandle<T>>, Vec<ReaderHandle<T>>) {
        Esg::new(
            EsgConfig { max_sources: ns + 2, max_readers: nr + 2, ..Default::default() },
            ns,
            nr,
        )
    }

    #[test]
    fn single_source_single_reader() {
        let (_g, mut src, mut rdr) = gate(1, 1);
        for ts in [1i64, 2, 5] {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        // all ready (bound = 5): expect 1, 2, 5
        let out: Vec<i64> = std::iter::from_fn(|| rdr[0].get()).map(|t| t.ts).collect();
        assert_eq!(out, vec![1, 2, 5]);
    }

    #[test]
    fn readiness_gated_by_slowest_source() {
        let (_g, mut src, mut rdr) = gate(2, 1);
        src[0].add(Tuple::data(10, 0)).unwrap();
        src[0].add(Tuple::data(20, 0)).unwrap();
        // source 1 silent: nothing ready
        assert!(rdr[0].get().is_none());
        src[1].add(Tuple::data(15, 1)).unwrap();
        // bound = min(20, 15) = 15: tuples 10 and 15 ready
        assert_eq!(rdr[0].get().unwrap().ts, 10);
        assert_eq!(rdr[0].get().unwrap().ts, 15);
        assert!(rdr[0].get().is_none());
    }

    #[test]
    fn all_readers_see_all_tuples_same_order() {
        let (_g, mut src, mut rdr) = gate(2, 3);
        for i in 0..50i64 {
            src[(i % 2) as usize].add(Tuple::data(i, i as u64)).unwrap();
        }
        // bound = min(48, 49) = 48 → 49 entries ready
        let seqs: Vec<Vec<u64>> = rdr
            .iter_mut()
            .map(|r| std::iter::from_fn(|| r.get()).map(|t| t.payload).collect())
            .collect();
        assert_eq!(seqs[0].len(), 49);
        assert_eq!(seqs[0], seqs[1]);
        assert_eq!(seqs[1], seqs[2]);
        let mut sorted = seqs[0].clone();
        sorted.sort();
        assert_eq!(seqs[0], sorted);
    }

    #[test]
    fn output_is_ts_sorted_under_concurrency() {
        let (_g, src, mut rdr) = gate(4, 1);
        let n = STRESS_N;
        let handles: Vec<_> = src
            .into_iter()
            .take(4)
            .map(|mut s| {
                std::thread::spawn(move || {
                    let mut rng = crate::util::Rng::new(s.id() as u64 + 1);
                    let mut ts = 0i64;
                    for _ in 0..n {
                        ts += rng.gen_range(3) as i64;
                        s.add(Tuple::data(ts, s.id() as u64)).unwrap();
                    }
                    s.advance_clock(i64::MAX / 8);
                })
            })
            .collect();
        let mut last = i64::MIN;
        let mut count = 0;
        let mut backoff = Backoff::active();
        while count < 4 * n {
            match rdr[0].get() {
                Some(t) => {
                    assert!(t.ts >= last, "ts regressed: {} < {last}", t.ts);
                    last = t.ts;
                    count += 1;
                    backoff.reset();
                }
                None => backoff.snooze(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn add_readers_positions_at_invokers_current_tuple() {
        let (g, mut src, mut rdr) = gate(1, 1);
        for ts in 0..10i64 {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        // reader 0 consumes 5 (last retrieved: ts=4, "currently processing")
        for _ in 0..5 {
            rdr[0].get().unwrap();
        }
        assert!(g.add_readers(&[1], 0));
        // reader 1 must re-receive the tuple reader 0 is processing (ts=4):
        // Theorem 3 — keys moved to the new instance during t must have t
        // processed by the new instance.
        assert_eq!(rdr[1].get().unwrap().ts, 4);
        assert_eq!(rdr[1].get().unwrap().ts, 5);
        assert_eq!(rdr[0].get().unwrap().ts, 5);
    }

    #[test]
    fn add_readers_arbitration() {
        let (g, _src, _rdr) = gate(1, 1);
        assert!(g.add_readers(&[1], 0));
        // second activation of same reader fails
        assert!(!g.add_readers(&[1], 0));
        assert!(g.remove_readers(&[1]));
        assert!(!g.remove_readers(&[1]));
    }

    #[test]
    fn add_sources_floor_allows_progress() {
        let (g, mut src, mut rdr) = gate(1, 1);
        src[0].add(Tuple::data(100, 0)).unwrap();
        // activate source 1 with floor 100 (Lemma 3 bound)
        assert!(g.add_sources(&[1], 100));
        // bound = min(100, 100) = 100 → tuple ready without source 1 adding
        assert_eq!(rdr[0].get().unwrap().ts, 100);
        // source 1 may now add from ts >= 100
        src[1].add(Tuple::data(101, 1)).unwrap();
        src[0].add(Tuple::data(102, 0)).unwrap();
        assert_eq!(rdr[0].get().unwrap().ts, 101);
    }

    #[test]
    fn remove_sources_unblocks_readiness() {
        let (g, mut src, mut rdr) = gate(2, 1);
        src[0].add(Tuple::data(10, 0)).unwrap();
        assert!(rdr[0].get().is_none()); // source 1 gating
        assert!(g.remove_sources(&[1]));
        // flush semantics: source 1 no longer gates
        assert_eq!(rdr[0].get().unwrap().ts, 10);
    }

    #[test]
    fn removed_source_pending_still_drains() {
        let (g, mut src, mut rdr) = gate(2, 1);
        src[0].add(Tuple::data(5, 0)).unwrap();
        src[1].add(Tuple::data(3, 1)).unwrap();
        assert!(g.remove_sources(&[1])); // its queued ts=3 must still come out first
        let a = rdr[0].get().unwrap();
        let b = rdr[0].get().unwrap();
        assert_eq!((a.ts, b.ts), (3, 5));
    }

    #[test]
    fn inactive_reader_gets_none() {
        let (_g, mut src, mut rdr) = gate(1, 1);
        src[0].add(Tuple::data(1, 0)).unwrap();
        src[0].add(Tuple::data(2, 0)).unwrap();
        assert!(rdr[1].get().is_none()); // slot 1 inactive (pool)
        assert_eq!(rdr[0].get().unwrap().ts, 1);
    }

    #[test]
    fn flow_control_bounds_backlog() {
        let (g, mut src, _rdr) = gate(1, 1);
        let cfg_cap = 64;
        // rebuild with small capacity
        let (g2, mut src2, _rdr2): (Esg<T>, _, Vec<ReaderHandle<T>>) = Esg::new(
            EsgConfig { max_sources: 1, max_readers: 1, capacity: cfg_cap, source_queue: 8192 },
            1,
            1,
        );
        drop((g, src.pop()));
        let mut rejected = false;
        for ts in 0..10_000i64 {
            if let Err(AddError::Full(_)) = src2[0].try_add(Tuple::data(ts, 0)) {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "flow control never kicked in");
        assert!(g2.backlog() as usize <= cfg_cap + 1);
    }

    #[test]
    fn heartbeat_clock_advance() {
        let (_g, mut src, mut rdr) = gate(2, 1);
        src[0].add(Tuple::data(10, 0)).unwrap();
        assert!(rdr[0].get().is_none());
        // source 1 has no data but advances its clock (heartbeat)
        src[1].advance_clock(50);
        assert_eq!(rdr[0].get().unwrap().ts, 10);
    }

    #[test]
    fn get_batch_drains_in_order() {
        let (_g, mut src, mut rdr) = gate(1, 1);
        for ts in 0..100i64 {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        let mut buf: Vec<T> = Vec::new();
        assert_eq!(rdr[0].get_batch(&mut buf, 64), 64);
        assert_eq!(rdr[0].get_batch(&mut buf, 64), 36);
        assert_eq!(buf.len(), 100);
        assert!(buf.windows(2).all(|w| w[0].ts <= w[1].ts));
        assert_eq!(buf.last().unwrap().ts, 99);
        assert_eq!(rdr[0].get_batch(&mut buf, 64), 0);
        // interleaves with get()
        src[0].add(Tuple::data(100, 100)).unwrap();
        assert_eq!(rdr[0].get().unwrap().ts, 100);
    }

    #[test]
    fn get_batch_respects_max_and_cursor() {
        let (_g, mut src, mut rdr) = gate(1, 2);
        for ts in 0..10i64 {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        let mut buf: Vec<T> = Vec::new();
        assert_eq!(rdr[0].get_batch(&mut buf, 4), 4);
        assert_eq!(rdr[0].cursor(), 4);
        // the second reader is independent
        assert_eq!(rdr[1].get().unwrap().ts, 0);
    }

    #[test]
    fn add_readers_at_seeds_inside_a_batch() {
        let (g, mut src, mut rdr) = gate(1, 1);
        for ts in 0..10i64 {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        let mut buf: Vec<T> = Vec::new();
        assert_eq!(rdr[0].get_batch(&mut buf, 8), 8); // cursor = 8
        // reader 0 is "currently processing" index 3: seed reader 1 there
        assert!(g.add_readers_at(&[1], 3));
        assert_eq!(rdr[1].get().unwrap().ts, 3);
        assert_eq!(rdr[1].get().unwrap().ts, 4);
        // arbitration still applies
        assert!(!g.add_readers_at(&[1], 0));
    }

    #[test]
    fn add_batch_merges_runs_in_order() {
        let (_g, mut src, mut rdr) = gate(2, 2);
        // interleaved sorted runs from two sources
        let mut r0: Vec<T> = [1i64, 3, 5, 7, 9].iter().map(|&ts| Tuple::data(ts, 0)).collect();
        let mut r1: Vec<T> = [2i64, 4, 6, 8, 10].iter().map(|&ts| Tuple::data(ts, 1)).collect();
        src[0].add_batch(&mut r0).unwrap();
        src[1].add_batch(&mut r1).unwrap();
        assert!(r0.is_empty() && r1.is_empty());
        let mut buf: Vec<T> = Vec::new();
        // bound = min(9, 10) = 9 → 9 entries ready
        while rdr[0].get_batch(&mut buf, 64) > 0 {}
        let ts: Vec<i64> = buf.iter().map(|t| t.ts).collect();
        assert_eq!(ts, vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
        // the second reader sees the identical sequence
        let mut buf2: Vec<T> = Vec::new();
        while rdr[1].get_batch(&mut buf2, 3) > 0 {}
        assert_eq!(buf2.iter().map(|t| t.ts).collect::<Vec<_>>(), ts);
    }

    #[test]
    fn add_batch_respects_flow_control() {
        let (g, mut src, _rdr): (Esg<T>, _, Vec<ReaderHandle<T>>) = Esg::new(
            EsgConfig { max_sources: 1, max_readers: 1, capacity: 32, source_queue: 8192 },
            1,
            1,
        );
        let mut run: Vec<T> = (0..100i64).map(|ts| Tuple::data(ts, 0)).collect();
        let mut accepted = 0usize;
        // keep offering: acceptance must stop at the capacity bound
        for _ in 0..8 {
            accepted += src[0].try_add_batch(&mut run).unwrap();
        }
        assert!(accepted < 100, "flow control never kicked in");
        assert!(g.backlog() as usize <= 32 + 1);
        assert_eq!(run.len(), 100 - accepted);
    }

    #[test]
    fn add_batch_long_runs_cross_merge_chunks() {
        let (_g, mut src, mut rdr) = gate(1, 1);
        let n = 5_000i64; // > MERGE_RUN_MAX and > MERGE_CHUNK
        let mut run: Vec<T> = (0..n).map(|ts| Tuple::data(ts, ts as u64)).collect();
        src[0].add_batch(&mut run).unwrap();
        let mut buf: Vec<T> = Vec::new();
        while rdr[0].get_batch(&mut buf, 512) > 0 {}
        assert_eq!(buf.len(), n as usize);
        assert!(buf.windows(2).all(|w| w[0].ts + 1 == w[1].ts));
    }

    #[test]
    fn for_gate_derives_source_queue() {
        let c = EsgConfig::for_gate(4, 2, 1 << 12);
        assert_eq!(c.max_sources, 4);
        assert_eq!(c.max_readers, 2);
        assert_eq!(c.capacity, 1 << 12);
        assert_eq!(c.source_queue, 1 << 10);
        // clamps low and high
        assert_eq!(EsgConfig::for_gate(64, 1, 64).source_queue, 64);
        assert_eq!(EsgConfig::for_gate(1, 1, 1 << 20).source_queue, 1 << 14);
    }

    #[test]
    fn decommission_mid_batch_returns_residual_run() {
        // capacity 8 with an idle reader: only a prefix of the run fits,
        // so the source is decommissioned *mid-drain* with a residual
        let (g, mut src, _rdr): (Esg<T>, _, Vec<ReaderHandle<T>>) = Esg::new(
            EsgConfig { max_sources: 2, max_readers: 1, capacity: 8, source_queue: 8192 },
            2,
            1,
        );
        let mut run: Vec<T> = (0..100i64).map(|ts| Tuple::data(ts, ts as u64)).collect();
        let accepted = src[0].try_add_batch(&mut run).unwrap();
        assert!(accepted > 0 && accepted < 100, "accepted={accepted}");
        assert!(g.remove_sources(&[0]));
        // the residual run comes back instead of aborting the process
        assert_eq!(src[0].try_add_batch(&mut run), Err(AddError::Inactive(())));
        assert_eq!(run.len(), 100 - accepted, "residual run lost");
        assert_eq!(run[0].ts, accepted as i64, "residual must start at the unconsumed prefix");
        // the blocking wrapper surfaces the same typed error, residual intact
        assert_eq!(src[0].add_batch(&mut run), Err(AddError::Inactive(())));
        assert_eq!(run.len(), 100 - accepted);
        // per-tuple path: the tuple itself is handed back
        assert!(g.remove_sources(&[1]));
        match src[1].add(Tuple::data(500, 7)) {
            Err(AddError::Inactive(t)) => assert_eq!((t.ts, t.payload), (500, 7)),
            other => panic!("expected Inactive with the tuple back, got {other:?}"),
        }
    }

    #[test]
    fn backlog_range_isolates_reader_groups() {
        // two "stages" on one gate: group A = reader 0, group B = reader 1
        let (g, mut src, mut rdr) = gate(1, 2);
        for ts in 0..10i64 {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        // both groups start with the full backlog
        assert_eq!(g.backlog_range(0, 1), g.backlog_range(1, 2));
        let full = g.backlog_range(0, 1);
        assert!(full >= 9, "expected most entries published, got {full}");
        // group A drains; group B still holds its backlog
        let mut buf: Vec<T> = Vec::new();
        while rdr[0].get_batch(&mut buf, 64) > 0 {}
        assert_eq!(g.backlog_range(0, 1), 0);
        assert_eq!(g.backlog_range(1, 2), full);
        // whole-gate backlog is the max over groups (slowest reader)
        assert_eq!(g.backlog(), full);
        // a range with no active readers reports zero
        assert_eq!(g.backlog_range(3, 4), 0);
    }

    #[test]
    fn force_add_bypasses_capacity() {
        let (g, mut src, _rdr): (Esg<T>, _, Vec<ReaderHandle<T>>) = Esg::new(
            EsgConfig { max_sources: 1, max_readers: 1, capacity: 8, source_queue: 8192 },
            1,
            1,
        );
        let mut ts = 0i64;
        // fill past the flow-control bound
        loop {
            ts += 1;
            if let Err(AddError::Full(_)) = src[0].try_add(Tuple::data(ts, 0)) {
                break;
            }
        }
        // a control-style add still goes through
        assert!(src[0].force_add(Tuple::data(ts + 1, 99)).is_ok());
        assert!(g.backlog() > 8);
    }

    #[test]
    fn peek_reads_published_entries_without_consuming() {
        let (_g, mut src, mut rdr) = gate(1, 1);
        for ts in 0..10i64 {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        let mut buf: Vec<T> = Vec::new();
        let n = rdr[0].get_batch(&mut buf, 64) as u64;
        assert!(n > 0);
        // peek re-reads consumed entries and leaves the cursor alone
        assert_eq!(rdr[0].peek(0).unwrap().ts, 0);
        assert_eq!(rdr[0].peek(n - 1).unwrap().ts, (n - 1) as i64);
        assert_eq!(rdr[0].cursor(), n);
        // past the published prefix: None, not a panic
        assert!(rdr[0].peek(1 << 20).is_none());
    }

    #[test]
    fn pin_floor_survives_gc_and_unpin_releases() {
        let (_g, mut src, mut rdr) = gate(1, 1);
        let n = (2 * crate::scalegate::log::SEG_SIZE) as i64;
        for ts in 0..n {
            src[0].add(Tuple::data(ts, ts as u64)).unwrap();
        }
        rdr[0].pin_floor(0);
        // consume everything — more than SEG_SIZE entries merge, so GC
        // runs; the pin must keep index 0 readable throughout
        let mut buf: Vec<T> = Vec::new();
        let mut got = 0u64;
        while rdr[0].get_batch(&mut buf, 256) > 0 {
            got += buf.len() as u64;
            buf.clear();
        }
        assert!(got >= crate::scalegate::log::SEG_SIZE as u64);
        assert_eq!(rdr[0].peek(0).unwrap().ts, 0);
        assert_eq!(rdr[0].peek(got - 1).unwrap().ts, (got - 1) as i64);
        // release the pin: the floor resumes tracking consumption at the
        // next gate synchronization (no panic, no stuck retention)
        rdr[0].unpin_floor();
        src[0].add(Tuple::data(n + 1, 0)).unwrap();
        src[0].advance_clock(n + 10);
        while rdr[0].get_batch(&mut buf, 256) > 0 {
            buf.clear();
        }
        assert_eq!(rdr[0].cursor(), got + 1);
    }

    #[test]
    fn get_batch_scratch_decays_after_a_burst() {
        // a backlog burst inflates the reader's scratch to the burst
        // size; the next between-batches refill must trim it back to
        // the pool shrink cap instead of pinning the high-water mark
        let n = 3 * pool::DEFAULT_SHRINK_CAP;
        let (_g, mut src, mut rdr): (Esg<T>, _, _) = Esg::new(
            EsgConfig { max_sources: 1, max_readers: 1, capacity: 1 << 17, source_queue: 1 << 14 },
            1,
            1,
        );
        let mut run: Vec<T> = (0..n as i64).map(|ts| Tuple::data(ts, ts as u64)).collect();
        src[0].add_batch(&mut run).unwrap();
        src[0].advance_clock(n as i64 + 1);
        let mut buf: Vec<T> = Vec::new();
        let first = rdr[0].get_batch(&mut buf, n);
        assert!(first > pool::DEFAULT_SHRINK_CAP, "burst batch too small: {first}");
        assert!(buf.capacity() > pool::DEFAULT_SHRINK_CAP, "burst never inflated the scratch");
        let mut got = first;
        loop {
            buf.clear();
            let k = rdr[0].get_batch(&mut buf, n);
            got += k;
            if k == 0 {
                break;
            }
        }
        assert_eq!(got, n);
        // the empty-handed refill above applied the between-batches decay
        assert!(
            buf.capacity() <= pool::DEFAULT_SHRINK_CAP,
            "burst capacity {} persisted past the shrink cap",
            buf.capacity()
        );
    }

    #[test]
    fn endpoints_share_one_gate_pool() {
        let (g, src, rdr) = gate(1, 1);
        // all endpoints expose the same pool instance…
        assert!(std::ptr::eq(g.pool(), src[0].pool()));
        assert!(std::ptr::eq(src[0].pool(), rdr[0].pool()));
        // …so a buffer an evicted worker returns via its source handle
        // is what a re-grown worker draws via its reader handle
        src[0].pool().put(Vec::with_capacity(256));
        let buf = rdr[0].pool().get(200);
        assert!(buf.capacity() >= 200 && buf.capacity() <= 512);
        assert_eq!(g.pool().pooled(), 0);
    }

    #[test]
    fn exactly_once_per_reader_under_concurrency() {
        let (_g, mut src, rdr) = gate(1, 3);
        let n = STRESS_N + STRESS_N / 2;
        let producer = std::thread::spawn(move || {
            for ts in 0..n {
                src[0].add(Tuple::data(ts, ts as u64)).unwrap();
            }
            src[0].advance_clock(i64::MAX / 8);
        });
        let readers: Vec<_> = rdr
            .into_iter()
            .take(3)
            .map(|mut r| {
                std::thread::spawn(move || {
                    let mut got = Vec::with_capacity(n as usize);
                    let mut backoff = Backoff::active();
                    while got.len() < n as usize {
                        match r.get() {
                            Some(t) => {
                                got.push(t.payload);
                                backoff.reset();
                            }
                            None => backoff.snooze(),
                        }
                    }
                    got
                })
            })
            .collect();
        producer.join().unwrap();
        for h in readers {
            let got = h.join().unwrap();
            assert_eq!(got, (0..n as u64).collect::<Vec<_>>());
        }
    }
}
