//! Tuples, keys, control tuples and the mapping function f_μ (§2, §5, §7).
//!
//! A tuple carries metadata (the event-time timestamp τ plus, in STRETCH,
//! a *kind* discriminating regular data from control/dummy/flush tuples)
//! and a payload φ. Payloads are a generic parameter `P` so the hot paths
//! (e.g. the ScaleJoin benchmark's compact numeric tuples) pay no boxing.

use crate::time::EventTime;
use std::sync::Arc;

/// A key extracted by f_SK / f_MK. Keys are pre-hashed to 64 bits; the
/// workloads document their key extraction (e.g. interned word ids,
/// round-robin ScaleJoin slots).
pub type Key = u64;

/// Index of an operator instance (the j in o_j).
pub type InstanceId = usize;

/// Monotonically increasing epoch number (§5).
pub type Epoch = u64;

/// The mapping function f_μ: keys → responsible instance (§2.2).
///
/// A reconfiguration installs a new `Mapper` (f_μ*). `HashMod` is the
/// default key-by used by the paper's operators (`hash(k) % Π`); `Explicit`
/// supports load-balancing reconfigurations that move individual keys.
#[derive(Clone, Debug)]
pub enum Mapper {
    /// f_μ(k) = mix(k) % n over the instance list.
    HashMod { instances: Arc<Vec<InstanceId>> },
    /// Explicit key → instance map with a fallback HashMod for unseen keys.
    Explicit {
        map: Arc<std::collections::HashMap<Key, InstanceId>>,
        fallback: Arc<Vec<InstanceId>>,
    },
}

/// 64-bit finalizer (splitmix-style) so that small consecutive keys spread
/// uniformly over instances.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Multiply-shift range reduction (Lemire): map a mixed 64-bit hash onto
/// `0..n` with one widening multiply instead of the hardware-division
/// `%` — `map` sits on every routed tuple (§Perf). Uniform because the
/// hash is already finalized by [`mix64`].
#[inline]
fn range_reduce(hash: u64, n: usize) -> usize {
    debug_assert!(n > 0);
    ((hash as u128 * n as u128) >> 64) as usize
}

impl Mapper {
    /// Hash-mod mapper over instances `0..n`.
    pub fn hash_mod(n: usize) -> Self {
        Mapper::HashMod { instances: Arc::new((0..n).collect()) }
    }

    /// Hash-mod mapper over an explicit instance set (instances need not be
    /// contiguous: after decommissioning, ids come from the pool).
    pub fn over(instances: Vec<InstanceId>) -> Self {
        Mapper::HashMod { instances: Arc::new(instances) }
    }

    /// f_μ(k): the instance responsible for key `k`.
    #[inline]
    pub fn map(&self, k: Key) -> InstanceId {
        match self {
            Mapper::HashMod { instances } => instances[range_reduce(mix64(k), instances.len())],
            Mapper::Explicit { map, fallback } => match map.get(&k) {
                Some(&i) => i,
                None => fallback[range_reduce(mix64(k), fallback.len())],
            },
        }
    }

    /// The instance set 𝕆 this mapper routes to.
    pub fn instances(&self) -> Vec<InstanceId> {
        match self {
            Mapper::HashMod { instances } => instances.as_ref().clone(),
            Mapper::Explicit { map, fallback } => {
                let mut v: Vec<InstanceId> = fallback.as_ref().clone();
                v.extend(map.values().copied());
                v.sort_unstable();
                v.dedup();
                v
            }
        }
    }

    /// Parallelism degree Π implied by the mapper.
    pub fn degree(&self) -> usize {
        self.instances().len()
    }
}

/// Parameters of an elastic reconfiguration delivered through a control
/// tuple (Alg. 6): the next epoch id e*, the next instance set 𝕆*, and the
/// next mapping function f_μ*. γ is the control tuple's own timestamp.
#[derive(Clone, Debug)]
pub struct ReconfigSpec {
    pub epoch: Epoch,
    pub instances: Arc<Vec<InstanceId>>,
    pub mapper: Mapper,
}

/// Tuple kind: regular data, or one of STRETCH's special tuples.
#[derive(Clone, Debug)]
pub enum Kind {
    /// A regular data tuple.
    Data,
    /// Control tuple carrying reconfiguration parameters (§7, Alg. 5/6).
    Control(Arc<ReconfigSpec>),
    /// Heartbeat: advances watermarks when a source's rate drops to zero
    /// (plays the role of explicit watermarks, §2.3).
    Heartbeat,
    /// Flush: emitted on behalf of a removed source (§6) so its previously
    /// added tuples become ready. Not delivered to readers.
    Flush,
    /// Dummy: seeds the handles of a newly added source (§6). Not delivered.
    Dummy,
}

impl Kind {
    #[inline]
    pub fn is_data(&self) -> bool {
        matches!(self, Kind::Data)
    }
    #[inline]
    pub fn is_control(&self) -> bool {
        matches!(self, Kind::Control(_))
    }
}

/// A stream tuple: metadata (τ = `ts`, `kind`) + payload φ.
///
/// `input` tags which of the I logical input streams the tuple belongs to
/// (0-based); stateful operators with I > 1 (e.g. joins) maintain one window
/// instance per input per key (§2.1).
#[derive(Clone, Debug)]
pub struct Tuple<P> {
    pub ts: EventTime,
    pub kind: Kind,
    pub input: u8,
    /// Wall-clock ingestion stamp (µs since engine start), carried through
    /// operators for the §8 latency metric. 0 when untracked.
    pub ingest_us: u64,
    pub payload: P,
}

impl<P> Tuple<P> {
    #[inline]
    pub fn data(ts: EventTime, payload: P) -> Self {
        Tuple { ts, kind: Kind::Data, input: 0, ingest_us: 0, payload }
    }

    #[inline]
    pub fn data_on(ts: EventTime, input: u8, payload: P) -> Self {
        Tuple { ts, kind: Kind::Data, input, ingest_us: 0, payload }
    }

    #[inline]
    pub fn with_ingest(mut self, ingest_us: u64) -> Self {
        self.ingest_us = ingest_us;
        self
    }

    #[inline]
    pub fn with_input(mut self, input: u8) -> Self {
        self.input = input;
        self
    }
}

impl<P: Default> Tuple<P> {
    pub fn control(ts: EventTime, spec: ReconfigSpec) -> Self {
        Tuple { ts, kind: Kind::Control(Arc::new(spec)), input: 0, ingest_us: 0, payload: P::default() }
    }
    pub fn heartbeat(ts: EventTime) -> Self {
        Tuple { ts, kind: Kind::Heartbeat, input: 0, ingest_us: 0, payload: P::default() }
    }
    pub fn flush(ts: EventTime) -> Self {
        Tuple { ts, kind: Kind::Flush, input: 0, ingest_us: 0, payload: P::default() }
    }
    pub fn dummy(ts: EventTime) -> Self {
        Tuple { ts, kind: Kind::Dummy, input: 0, ingest_us: 0, payload: P::default() }
    }
}

/// Marker trait for payloads; blanket-implemented.
pub trait Payload: Clone + Send + Sync + 'static {}
impl<T: Clone + Send + Sync + 'static> Payload for T {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_mod_covers_all_instances() {
        let m = Mapper::hash_mod(7);
        let mut seen = [false; 7];
        for k in 0..10_000u64 {
            seen[m.map(k)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn hash_mod_is_balanced() {
        let m = Mapper::hash_mod(8);
        let mut counts = [0u32; 8];
        let n = 80_000u64;
        for k in 0..n {
            counts[m.map(k)] += 1;
        }
        let expect = n as f64 / 8.0;
        for c in counts {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "imbalance {dev}");
        }
    }

    #[test]
    fn range_reduce_covers_and_bounds() {
        for n in [1usize, 2, 3, 7, 64] {
            let mut seen = vec![false; n];
            for k in 0..20_000u64 {
                let i = range_reduce(mix64(k), n);
                assert!(i < n);
                seen[i] = true;
            }
            assert!(seen.iter().all(|&s| s), "n={n} not covered");
        }
    }

    #[test]
    fn mapper_is_deterministic() {
        let m = Mapper::hash_mod(5);
        for k in 0..100 {
            assert_eq!(m.map(k), m.map(k));
        }
    }

    #[test]
    fn over_non_contiguous_instances() {
        let m = Mapper::over(vec![2, 5, 9]);
        for k in 0..1000u64 {
            assert!([2, 5, 9].contains(&m.map(k)));
        }
        assert_eq!(m.degree(), 3);
        assert_eq!(m.instances(), vec![2, 5, 9]);
    }

    #[test]
    fn explicit_overrides_fallback() {
        let mut map = std::collections::HashMap::new();
        map.insert(42u64, 3usize);
        let m = Mapper::Explicit { map: Arc::new(map), fallback: Arc::new(vec![0, 1]) };
        assert_eq!(m.map(42), 3);
        for k in 0..100u64 {
            if k != 42 {
                assert!(m.map(k) <= 1);
            }
        }
    }

    #[test]
    fn control_tuples_flagged() {
        let spec = ReconfigSpec {
            epoch: 1,
            instances: Arc::new(vec![0, 1]),
            mapper: Mapper::hash_mod(2),
        };
        let t: Tuple<()> = Tuple::control(10, spec);
        assert!(t.kind.is_control());
        assert!(!t.kind.is_data());
        let d: Tuple<u32> = Tuple::data(5, 7);
        assert!(d.kind.is_data());
    }
}
