//! Schemas and dynamically-typed rows (the paper's parameter S, §2.1).
//!
//! Hot-path workloads use compact static payload structs; the schema layer
//! exists for the user-facing API (config-driven queries, the quickstart
//! example) and for egress formatting. A `Row` is validated against its
//! `Schema` at operator boundaries in debug builds.

use std::fmt;
use std::sync::Arc;

/// Field types supported by the dynamic layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FieldType {
    Int,
    Float,
    Str,
    Bool,
}

/// A dynamically-typed value (a φ[ℓ] sub-attribute).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(Arc<str>),
    Bool(bool),
}

impl Value {
    pub fn type_of(&self) -> FieldType {
        match self {
            Value::Int(_) => FieldType::Int,
            Value::Float(_) => FieldType::Float,
            Value::Str(_) => FieldType::Str,
            Value::Bool(_) => FieldType::Bool,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(Arc::from(v))
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// A named, ordered set of fields: the tuple schema S.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    fields: Arc<Vec<(String, FieldType)>>,
}

impl Schema {
    pub fn new(fields: Vec<(&str, FieldType)>) -> Self {
        Schema {
            fields: Arc::new(
                fields.into_iter().map(|(n, t)| (n.to_string(), t)).collect(),
            ),
        }
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }
    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn field(&self, i: usize) -> Option<(&str, FieldType)> {
        self.fields.get(i).map(|(n, t)| (n.as_str(), *t))
    }

    /// Index of a field by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fields.iter().position(|(n, _)| n == name)
    }

    /// Validate a row against this schema.
    pub fn validate(&self, row: &Row) -> Result<(), SchemaError> {
        if row.values.len() != self.fields.len() {
            return Err(SchemaError::Arity {
                expected: self.fields.len(),
                got: row.values.len(),
            });
        }
        for (i, ((name, ft), v)) in self.fields.iter().zip(row.values.iter()).enumerate() {
            if v.type_of() != *ft {
                return Err(SchemaError::Type {
                    field: name.clone(),
                    index: i,
                    expected: *ft,
                    got: v.type_of(),
                });
            }
        }
        Ok(())
    }

    /// Concatenate two schemas (used by joins whose S_O is the
    /// concatenation of the two input schemas, App. D).
    pub fn concat(&self, other: &Schema, l_prefix: &str, r_prefix: &str) -> Schema {
        let mut fields: Vec<(String, FieldType)> = Vec::new();
        for (n, t) in self.fields.iter() {
            fields.push((format!("{l_prefix}{n}"), *t));
        }
        for (n, t) in other.fields.iter() {
            fields.push((format!("{r_prefix}{n}"), *t));
        }
        Schema { fields: Arc::new(fields) }
    }
}

/// Schema validation errors.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum SchemaError {
    #[error("arity mismatch: schema has {expected} fields, row has {got}")]
    Arity { expected: usize, got: usize },
    #[error("type mismatch at field `{field}` (index {index}): expected {expected:?}, got {got:?}")]
    Type { field: String, index: usize, expected: FieldType, got: FieldType },
}

/// A dynamically-typed payload: the φ vector.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Row {
    pub values: Vec<Value>,
}

impl Row {
    pub fn new(values: Vec<Value>) -> Self {
        Row { values }
    }
    /// φ[ℓ] with the paper's 1-based indexing.
    pub fn phi(&self, l: usize) -> &Value {
        &self.values[l - 1]
    }
    pub fn get(&self, i: usize) -> Option<&Value> {
        self.values.get(i)
    }
}

impl fmt::Display for Row {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, v) in self.values.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v}")?;
        }
        write!(f, "]")
    }
}

/// Convenience macro for building rows: `row![1i64, 2.5, "x", true]`.
#[macro_export]
macro_rules! row {
    ($($v:expr),* $(,)?) => {
        $crate::schema::Row::new(vec![$($crate::schema::Value::from($v)),*])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tweet_schema() -> Schema {
        Schema::new(vec![("user", FieldType::Str), ("tweet", FieldType::Str)])
    }

    #[test]
    fn validate_ok() {
        let s = tweet_schema();
        let r = row!["alice", "hello #world"];
        assert!(s.validate(&r).is_ok());
    }

    #[test]
    fn validate_arity_error() {
        let s = tweet_schema();
        let r = row!["alice"];
        assert_eq!(
            s.validate(&r),
            Err(SchemaError::Arity { expected: 2, got: 1 })
        );
    }

    #[test]
    fn validate_type_error() {
        let s = tweet_schema();
        let r = row!["alice", 42i64];
        assert!(matches!(s.validate(&r), Err(SchemaError::Type { index: 1, .. })));
    }

    #[test]
    fn phi_is_one_based() {
        let r = row![10i64, 20i64];
        assert_eq!(r.phi(1), &Value::Int(10));
        assert_eq!(r.phi(2), &Value::Int(20));
    }

    #[test]
    fn concat_prefixes() {
        let l = Schema::new(vec![("id", FieldType::Str), ("price", FieldType::Int)]);
        let s = l.concat(&l, "l_", "r_");
        assert_eq!(s.len(), 4);
        assert_eq!(s.index_of("l_id"), Some(0));
        assert_eq!(s.index_of("r_price"), Some(3));
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::Int(3).as_float(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_int(), None);
        assert_eq!(Value::from("x").as_str(), Some("x"));
        assert_eq!(Value::from(true).as_bool(), Some(true));
    }
}
