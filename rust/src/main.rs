//! `stretch` — the launcher: run declarative jobs or config-driven
//! elastic join experiments, calibrate the cost model, or inspect the
//! runtime.
//!
//! ```sh
//! stretch calibrate
//! stretch run examples/configs/diamond.conf       # declarative job
//! stretch run --config job.conf --budget-ms 10    # CI smoke form
//! stretch run configs/scalejoin.toml              # classic Q3-Q6 shape
//! stretch serve examples/configs/server_two_jobs.conf   # multi-job server
//! stretch artifacts          # check the AOT kernel artifacts
//! stretch bench-diff BENCH_micro.baseline.json BENCH_micro.json
//! stretch lint rust/src      # concurrency-correctness analyzer (CI gate)
//! ```
//!
//! `run` dispatches on the config: a `[topology]` section makes it a
//! *job* (stages by name, edges, per-stage parallelism — built through
//! the operator registry and driven by `harness::run_job`, emitting
//! `BENCH_<job>.json`); otherwise it is the classic single-stage
//! ScaleJoin experiment shape.

use stretch::cli::{Cli, OrExit};
use stretch::config::{BatchTuning, Config};
use stretch::elastic::JoinCostModel;
use stretch::harness::{
    controller_from_config, run_elastic_join, run_job, serve_from_config, JoinRunConfig,
    TicketOutcome,
};
use stretch::metrics::{BenchReport, Json};
use stretch::sim::calibrate;
use stretch::workloads::RateSchedule;

fn cmd_calibrate() {
    let c = calibrate();
    println!("calibration (this machine, this build):");
    println!("  band comparisons : {:.1} M/s per thread", c.cmp_per_sec / 1e6);
    println!("  ESG round trip   : {:.3} µs/tuple (per-tuple add/get)", c.gate_tuple_s * 1e6);
    println!(
        "  ESG batched      : {:.3} µs/tuple ({:.1}× win, batch {})",
        c.gate_batch_tuple_s * 1e6,
        c.gate_tuple_s / c.gate_batch_tuple_s.max(1e-12),
        stretch::sim::calibrate::GATE_BATCH
    );
    println!("  SPSC hop         : {:.3} µs/tuple", c.queue_tuple_s * 1e6);
    println!("  merge-sort ingest: {:.3} µs/tuple", c.sort_tuple_s * 1e6);
}

fn cmd_artifacts() {
    if !stretch::runtime::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts`");
        std::process::exit(1);
    }
    let dir = stretch::runtime::artifacts_dir();
    println!("artifacts at {}:", dir.display());
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap_or_default();
    print!("{manifest}");
    match stretch::runtime::JoinKernel::load() {
        Ok(k) => println!("PJRT OK: platform = {}", k.platform()),
        Err(e) => {
            eprintln!("PJRT load failed: {e:#}");
            std::process::exit(1);
        }
    }
}

/// `run`: dispatch on the config shape.
fn cmd_run(path: &str, budget_ms: Option<u64>) {
    let cfg = Config::load(path).unwrap_or_else(|e| {
        eprintln!("config error: {e}");
        std::process::exit(1);
    });
    // Any `[topology]` or `[stage.*]` key makes this a job config —
    // dispatching on the whole prefix (not just `topology.stages`) means
    // a misspelled `stages` key reaches run_job's typed NoStages error
    // instead of silently running the classic experiment.
    let is_job = cfg
        .keys()
        .any(|k| k.starts_with("topology.") || k.starts_with("stage."));
    if is_job {
        cmd_run_job(&cfg, budget_ms);
    } else {
        cmd_run_join(&cfg, budget_ms);
    }
}

/// The declarative path: build + drive a `[topology]` job, emit
/// `BENCH_<job>.json`.
fn cmd_run_job(cfg: &Config, budget_ms: Option<u64>) {
    let outcome = run_job(cfg, budget_ms).unwrap_or_else(|e| {
        eprintln!("job error: {e}");
        std::process::exit(1);
    });
    let r = &outcome.result;
    println!("job `{}`: {} stages", outcome.name, outcome.stage_names.len());
    println!("\n  stage        operator        Π  reconfigs  backlog  batch");
    for (name, s) in outcome.stage_names.iter().zip(&r.stages) {
        let last = s.samples.last();
        println!(
            "  {:<12} {:<14} {:>2} {:>10} {:>8} {:>6}",
            name,
            s.name,
            last.map(|x| x.threads).unwrap_or(0),
            s.reconfigs.len(),
            last.map(|x| x.backlog).unwrap_or(0),
            last.map(|x| x.worker_batch).unwrap_or(0),
        );
    }
    println!(
        "\n  egress: {} tuples (dropped {}), e2e latency p50 {:.2} ms / mean {:.2} ms",
        r.egress_count,
        r.ingress_dropped,
        r.latency_p50_us as f64 / 1e3,
        r.latency_mean_us / 1e3
    );
    // per-reconfig latencies, straight off the handle's tickets (scripted
    // [schedule.*] steps and [elastic] controller decisions alike)
    if !outcome.tickets.is_empty() {
        println!("\n  reconfigs (measured via ReconfigTicket):");
        for t in &outcome.tickets {
            let stage = outcome
                .stage_names
                .get(t.stage())
                .map(String::as_str)
                .unwrap_or("?");
            let e = t.epoch().map(|v| v.to_string()).unwrap_or_else(|| "-".into());
            match t.outcome() {
                Some(TicketOutcome::Completed(ms)) => {
                    let verdict = if ms < 40.0 { " (< 40 ms)" } else { "" };
                    println!("    stage {stage:<12} epoch {e}: {ms:.2} ms{verdict}");
                }
                Some(TicketOutcome::Rejected(why)) => {
                    println!("    stage {stage:<12} epoch {e}: rejected ({why})");
                }
                Some(TicketOutcome::Abandoned) => {
                    println!("    stage {stage:<12} epoch {e}: abandoned (runtime shut down)");
                }
                None => {
                    println!(
                        "    stage {stage:<12} epoch {e}: unresolved (issued too close to EOS)"
                    );
                }
            }
        }
    }

    // fault recoveries, straight off the supervisor's RecoveryTickets
    // (only present when the config has a [faults] section)
    if !outcome.recoveries.is_empty() {
        println!("\n  recoveries (measured via RecoveryTicket):");
        for rt in &outcome.recoveries {
            let stage = outcome
                .stage_names
                .get(rt.stage())
                .map(String::as_str)
                .unwrap_or("?");
            match rt.mttr_ms() {
                Some(ms) => println!(
                    "    stage {stage:<12} worker {} ({:?}): healed in {ms:.2} ms",
                    rt.worker(),
                    rt.kind()
                ),
                None => println!(
                    "    stage {stage:<12} worker {} ({:?}): NOT healed",
                    rt.worker(),
                    rt.kind()
                ),
            }
        }
    }
    if outcome.degraded {
        println!("\n  job DEGRADED: the supervisor exhausted its escalation ladder");
    }

    // BENCH_<job>.json: the job's machine-readable perf record
    let slug: String = outcome
        .name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '-' { c } else { '-' })
        .collect();
    let mut rep = BenchReport::new(&slug);
    rep.set("kind", "job")
        .set("stages", outcome.stage_names.len())
        .set("egress_count", r.egress_count)
        .set("ingress_dropped", r.ingress_dropped)
        .set("latency_p50_us", r.latency_p50_us)
        .set("latency_mean_us", r.latency_mean_us);
    let stage_objs: Vec<Json> = outcome
        .stage_names
        .iter()
        .zip(&r.stages)
        .map(|(name, s)| {
            let last = s.samples.last();
            Json::obj(vec![
                ("name", Json::from(name.as_str())),
                ("operator", Json::from(s.name)),
                ("reconfigs", Json::from(s.reconfigs.len())),
                (
                    "reconfig_ms_max",
                    s.reconfigs
                        .iter()
                        .map(|&(_, ms)| ms)
                        .fold(f64::NAN, f64::max)
                        .into(),
                ),
                ("final_threads", Json::from(last.map(|x| x.threads).unwrap_or(0))),
                ("final_backlog", Json::from(last.map(|x| x.backlog).unwrap_or(0))),
                ("final_worker_batch", Json::from(last.map(|x| x.worker_batch).unwrap_or(0))),
            ])
        })
        .collect();
    rep.set("stage_stats", Json::Arr(stage_objs));
    // per-reconfig latencies sourced from the run's ReconfigTickets
    let ticket_objs: Vec<Json> = outcome
        .tickets
        .iter()
        .map(|t| {
            Json::obj(vec![
                (
                    "stage",
                    outcome
                        .stage_names
                        .get(t.stage())
                        .map(|s| Json::from(s.as_str()))
                        .unwrap_or(Json::Null),
                ),
                ("epoch", t.epoch().map(Json::from).unwrap_or(Json::Null)),
                ("ms", t.latency_ms().map(Json::from).unwrap_or(Json::Null)),
            ])
        })
        .collect();
    rep.set("reconfigs", Json::Arr(ticket_objs));
    // recovery record: `mttr_ms` (mean over healed faults) is an INFO
    // field by the bench-diff naming contract — recovery latency varies
    // with injected fault timing and must never gate the perf trajectory
    if !outcome.recoveries.is_empty() || outcome.degraded {
        let healed: Vec<f64> =
            outcome.recoveries.iter().filter_map(|rt| rt.mttr_ms()).collect();
        if !healed.is_empty() {
            rep.set("mttr_ms", healed.iter().sum::<f64>() / healed.len() as f64);
        }
        rep.set("degraded", outcome.degraded);
        let rec_objs: Vec<Json> = outcome
            .recoveries
            .iter()
            .map(|rt| {
                Json::obj(vec![
                    (
                        "stage",
                        outcome
                            .stage_names
                            .get(rt.stage())
                            .map(|s| Json::from(s.as_str()))
                            .unwrap_or(Json::Null),
                    ),
                    ("worker", Json::from(rt.worker())),
                    ("kind", Json::from(format!("{:?}", rt.kind()).to_lowercase())),
                    ("mttr_ms", rt.mttr_ms().map(Json::from).unwrap_or(Json::Null)),
                ])
            })
            .collect();
        rep.set("recoveries", Json::Arr(rec_objs));
    }
    match rep.write() {
        Ok(p) => println!("  json: {}", p.display()),
        Err(e) => eprintln!("  BENCH_{slug}.json write failed: {e}"),
    }
}

/// `serve`: run a multi-job `[server]`/`[job.<name>]` config — N jobs on
/// one shared runtime thread under one global core budget — print the
/// per-job outcomes and every cross-job rebalance the arbiter issued,
/// and emit `BENCH_server.json`.
fn cmd_serve(path: &str, budget_ms: Option<u64>) {
    let cfg = Config::load(path).unwrap_or_else(|e| {
        eprintln!("config error: {e}");
        std::process::exit(1);
    });
    let conf_dir = std::path::Path::new(path)
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .unwrap_or_else(|| std::path::Path::new("."));
    let out = serve_from_config(&cfg, conf_dir, budget_ms).unwrap_or_else(|e| {
        eprintln!("server error: {e}");
        std::process::exit(1);
    });
    println!(
        "server `{}`: {} job(s) under a {}-core budget",
        cfg.str_or("name", "server"),
        out.jobs.len(),
        out.budget
    );
    for (id, job) in &out.jobs {
        let r = &job.result;
        println!(
            "\n  {id} `{}`: {} stages, egress {} (dropped {}), e2e latency p50 {:.2} ms",
            job.name,
            job.stage_names.len(),
            r.egress_count,
            r.ingress_dropped,
            r.latency_p50_us as f64 / 1e3,
        );
        if !job.recoveries.is_empty() {
            let healed = job.recoveries.iter().filter(|rt| rt.mttr_ms().is_some()).count();
            println!("    recoveries: {healed}/{} healed", job.recoveries.len());
        }
        if job.degraded {
            println!("    job DEGRADED: the supervisor exhausted its escalation ladder");
        }
    }
    // every cross-job move the arbiter issued, with its measured epoch
    // reconfiguration latency — the §8.4 metric, fleet edition
    if !out.rebalances.is_empty() {
        println!("\n  cross-job rebalances (measured via ReconfigTicket):");
        for rb in &out.rebalances {
            let stage = out
                .jobs
                .iter()
                .find(|(id, _)| *id == rb.job)
                .and_then(|(_, j)| j.stage_names.get(rb.stage))
                .map(String::as_str)
                .unwrap_or("?");
            match rb.ticket.outcome() {
                Some(TicketOutcome::Completed(ms)) => {
                    let verdict = if ms < 40.0 { " (< 40 ms)" } else { "" };
                    println!("    {} stage {stage:<12}: {ms:.2} ms{verdict}", rb.job_name);
                }
                Some(TicketOutcome::Rejected(why)) => {
                    println!("    {} stage {stage:<12}: rejected ({why})", rb.job_name);
                }
                Some(TicketOutcome::Abandoned) => {
                    println!("    {} stage {stage:<12}: abandoned (job shut down)", rb.job_name);
                }
                None => {
                    println!("    {} stage {stage:<12}: unresolved", rb.job_name);
                }
            }
        }
    }

    // BENCH_server.json: the aggregate machine-readable record —
    // per-job throughput AND per-job reconfig latencies, plus the
    // cross-job rebalance trace
    let mut rep = BenchReport::new("server");
    rep.set("kind", "server").set("budget", out.budget).set("jobs_n", out.jobs.len());
    let job_objs: Vec<Json> = out
        .jobs
        .iter()
        .map(|(id, job)| {
            let r = &job.result;
            let ticket_objs: Vec<Json> = job
                .tickets
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        (
                            "stage",
                            job.stage_names
                                .get(t.stage())
                                .map(|s| Json::from(s.as_str()))
                                .unwrap_or(Json::Null),
                        ),
                        ("ms", t.latency_ms().map(Json::from).unwrap_or(Json::Null)),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("id", Json::from(id.to_string())),
                ("name", Json::from(job.name.as_str())),
                ("egress_count", Json::from(r.egress_count)),
                ("ingress_dropped", Json::from(r.ingress_dropped)),
                ("latency_p50_us", Json::from(r.latency_p50_us)),
                ("degraded", Json::from(job.degraded)),
                ("reconfigs", Json::Arr(ticket_objs)),
            ])
        })
        .collect();
    rep.set("jobs", Json::Arr(job_objs));
    let rb_objs: Vec<Json> = out
        .rebalances
        .iter()
        .map(|rb| {
            Json::obj(vec![
                ("job", Json::from(rb.job_name.as_str())),
                ("stage", Json::from(rb.stage)),
                ("ms", rb.ticket.latency_ms().map(Json::from).unwrap_or(Json::Null)),
            ])
        })
        .collect();
    rep.set("rebalances", Json::Arr(rb_objs));
    let done: Vec<f64> = out.rebalances.iter().filter_map(|rb| rb.ticket.latency_ms()).collect();
    if !done.is_empty() {
        rep.set("rebalance_ms_max", done.iter().fold(f64::NAN, |a, &b| a.max(b)));
    }
    match rep.write() {
        Ok(p) => println!("  json: {}", p.display()),
        Err(e) => eprintln!("  BENCH_server.json write failed: {e}"),
    }
}

/// `bench-diff`: compare two `BENCH_*.json` snapshots under a tolerance
/// factor and exit nonzero on regression — the CI perf gate
/// (`stretch bench-diff BENCH_micro.baseline.json BENCH_micro.json`).
///
/// `--gate-kinds` restricts which field kinds can fail the run, so CI
/// can apply different tolerances per kind: a loose 50× pass for noisy
/// timing fields and a tight 1.2× pass for the deterministic
/// allocs-per-tuple fields (`--tolerance 1.2 --gate-kinds alloc`).
fn cmd_bench_diff(baseline: &str, new: &str, tolerance: f64, gate_kinds: Option<&str>) {
    let kinds: Option<Vec<stretch::metrics::FieldKind>> = gate_kinds.map(|s| {
        s.split(',')
            .map(str::trim)
            .filter(|k| !k.is_empty())
            .map(|k| {
                stretch::metrics::FieldKind::from_name(k).unwrap_or_else(|| {
                    eprintln!(
                        "bench-diff error: unknown --gate-kinds entry `{k}` \
                         (known: throughput, latency, alloc, info)"
                    );
                    std::process::exit(2);
                })
            })
            .collect()
    });
    match stretch::metrics::diff_files_gated(baseline, new, tolerance, kinds.as_deref()) {
        Ok(d) => {
            println!("bench-diff {baseline} -> {new} (tolerance {tolerance}x)");
            println!("{d}");
            if d.is_regression() {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("bench-diff error: {e}");
            std::process::exit(2);
        }
    }
}

/// `lint`: run the in-tree concurrency-correctness analyzer
/// (`stretch::analysis`, rules L1–L6) over source paths. Exit status:
/// 0 clean, 1 findings, 2 I/O error — the blocking CI gate.
fn cmd_lint(paths: &[String], format: &str) {
    let paths: Vec<std::path::PathBuf> = if paths.is_empty() {
        vec![std::path::PathBuf::from("rust/src")]
    } else {
        paths.iter().map(std::path::PathBuf::from).collect()
    };
    let findings = match stretch::analysis::lint_paths(&paths) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("stretch lint: {e}");
            std::process::exit(2);
        }
    };
    match format {
        "json" => print!("{}", stretch::analysis::render_json(&findings)),
        "text" => print!("{}", stretch::analysis::render_text(&findings)),
        other => {
            eprintln!("stretch lint: unknown --format `{other}` (expected text|json)");
            std::process::exit(2);
        }
    }
    if !findings.is_empty() {
        std::process::exit(1);
    }
}

/// The classic config shape (no `[topology]`): a single-stage elastic
/// ScaleJoin experiment. `budget_ms` caps the wall-clock run by raising
/// `time_scale`, exactly like the job path — the flag means the same
/// thing on both.
fn cmd_run_join(cfg: &Config, budget_ms: Option<u64>) {
    let ws_ms = cfg.int_or("operator.ws_ms", 2_000);
    let n_keys = cfg.int_or("operator.keys", 64) as u64;
    let initial = cfg.int_or("engine.initial", 1) as usize;
    let max = cfg.int_or("engine.max", 4) as usize;
    let mut time_scale = cfg.float_or("run.time_scale", 2.0);
    let seed = cfg.int_or("run.seed", 7) as u64;
    let schedule = RateSchedule::from_config(cfg);
    let duration = schedule.duration_s();
    if let Some(ms) = budget_ms {
        time_scale = time_scale.max(duration as f64 * 1000.0 / ms.max(1) as f64);
    }

    // controller: none / reactive (default) / proactive, calibrated on
    // this box — same construction path as the declarative job runner
    let cal = calibrate();
    let model = JoinCostModel::new(cal.cmp_per_sec / max as f64, ws_ms as f64 / 1e3);
    let controller: Option<Box<dyn stretch::elastic::Controller>> =
        match cfg.str_or("elastic.controller", "reactive") {
            "none" => None,
            kind => Some(controller_from_config(cfg, kind, model)),
        };

    // `[batch]` section: data-plane batch sizes (§Perf)
    let batch = BatchTuning::from_config(cfg);
    println!(
        "running `{}`: WS={ws_ms}ms keys={n_keys} Π={initial}..{max} {}s ({}x compressed, batch {})",
        cfg.str_or("name", "experiment"),
        duration,
        time_scale,
        batch.worker
    );
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        n_keys,
        initial,
        max,
        schedule,
        time_scale,
        controller,
        controller_period_s: cfg.int_or("elastic.period_s", 2) as u32,
        seed,
        gate_capacity: cfg.int_or("engine.gate_capacity", 8192) as usize,
        worker_batch: batch.worker,
        ingress_batch: batch.ingress,
        manual_reconfigs: Vec::new(),
    });
    println!("\n  t  offered   served   cmp/s      lat(ms)  Π backlog");
    for s in &r.samples {
        println!(
            "{:>4} {:>8.0} {:>8.0} {:>10.2e} {:>8.1} {:>2} {:>7}",
            s.t_s,
            s.offered_tps,
            s.in_tps,
            s.cmp_per_s,
            s.latency_mean_us / 1e3,
            s.threads,
            s.backlog
        );
    }
    println!("\n{} results at the egress; reconfigurations:", r.egress_count);
    for (e, ms) in &r.reconfigs {
        println!("  epoch {e}: {ms:.2} ms");
    }
}

fn main() {
    let cli = Cli::new(
        "stretch",
        "STRETCH: virtual shared-nothing stream processing (paper reproduction)",
    )
    .opt("config", "config file for `run` (same as the positional path)", None)
    .opt("budget-ms", "cap the wall-clock run time of a job (CI smoke)", None)
    .opt("tolerance", "bench-diff tolerance factor before a field gates", Some("1.25"))
    .opt("gate-kinds", "bench-diff: only these field kinds gate (comma list)", None)
    .opt("format", "lint output format: text|json", Some("text"));
    let args = cli.parse().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.positional().first().map(|s| s.as_str()) {
        Some("calibrate") => cmd_calibrate(),
        Some("artifacts") => cmd_artifacts(),
        Some("bench-diff") => {
            let (b, n) = match (args.positional().get(1), args.positional().get(2)) {
                (Some(b), Some(n)) => (b.clone(), n.clone()),
                _ => {
                    eprintln!(
                        "usage: stretch bench-diff <baseline.json> <new.json> \
                         [--tolerance <factor>] [--gate-kinds <k1,k2,…>]"
                    );
                    std::process::exit(2);
                }
            };
            cmd_bench_diff(&b, &n, args.f64_or("tolerance", 1.25).or_exit(), args.get("gate-kinds"));
        }
        Some("lint") => {
            cmd_lint(&args.positional()[1..], args.str_or("format", "text"));
        }
        Some("run") => {
            let path = args
                .get("config")
                .map(str::to_string)
                .or_else(|| args.positional().get(1).cloned());
            match path {
                Some(p) => cmd_run(&p, args.u64_opt("budget-ms").or_exit()),
                None => {
                    eprintln!("usage: stretch run <job.conf>  (or --config <job.conf>)");
                    std::process::exit(2);
                }
            }
        }
        Some("serve") => {
            let path = args
                .get("config")
                .map(str::to_string)
                .or_else(|| args.positional().get(1).cloned());
            match path {
                Some(p) => cmd_serve(&p, args.u64_opt("budget-ms").or_exit()),
                None => {
                    eprintln!("usage: stretch serve <server.conf>  (or --config <server.conf>)");
                    std::process::exit(2);
                }
            }
        }
        _ => {
            println!("usage: stretch <command>\n");
            println!("  calibrate          measure this machine's cost model");
            println!("  artifacts          verify the AOT kernel artifacts + PJRT");
            println!("  run <config>       run a declarative job ([topology] config,");
            println!("                     see examples/configs/) or a classic elastic");
            println!("                     join experiment (configs/*.toml)");
            println!("  serve <config>     run a multi-job [server]/[job.*] config: N jobs");
            println!("                     on one runtime thread under one global core");
            println!("                     budget; emits BENCH_server.json");
            println!("  bench-diff <a> <b> compare two BENCH_*.json snapshots; exits 1");
            println!("                     when a throughput/latency/alloc field regresses");
            println!("  lint [paths…]      concurrency-correctness analyzer (rules L1-L6");
            println!("                     over rust/src by default); exits 1 on findings");
            println!("\noptions for run/serve: --config <path>, --budget-ms <ms> (CI smoke)");
            println!("options for bench-diff: --tolerance <factor> (default 1.25),");
            println!("                        --gate-kinds <throughput,latency,alloc,info>");
            println!("options for lint: --format <text|json> (default text)");
        }
    }
}
