//! `stretch` — the launcher: run config-driven elastic join experiments,
//! calibrate the cost model, or inspect the runtime.
//!
//! ```sh
//! stretch calibrate
//! stretch run configs/scalejoin.toml
//! stretch artifacts          # check the AOT kernel artifacts
//! ```

use stretch::cli::Cli;
use stretch::config::{BatchTuning, Config};
use stretch::elastic::{JoinCostModel, ProactiveController, ReactiveController, Thresholds};
use stretch::harness::{run_elastic_join, JoinRunConfig};
use stretch::sim::calibrate;
use stretch::workloads::RateSchedule;

fn cmd_calibrate() {
    let c = calibrate();
    println!("calibration (this machine, this build):");
    println!("  band comparisons : {:.1} M/s per thread", c.cmp_per_sec / 1e6);
    println!("  ESG round trip   : {:.3} µs/tuple (per-tuple add/get)", c.gate_tuple_s * 1e6);
    println!(
        "  ESG batched      : {:.3} µs/tuple ({:.1}× win, batch {})",
        c.gate_batch_tuple_s * 1e6,
        c.gate_tuple_s / c.gate_batch_tuple_s.max(1e-12),
        stretch::sim::calibrate::GATE_BATCH
    );
    println!("  SPSC hop         : {:.3} µs/tuple", c.queue_tuple_s * 1e6);
    println!("  merge-sort ingest: {:.3} µs/tuple", c.sort_tuple_s * 1e6);
}

fn cmd_artifacts() {
    if !stretch::runtime::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts`");
        std::process::exit(1);
    }
    let dir = stretch::runtime::artifacts_dir();
    println!("artifacts at {}:", dir.display());
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap_or_default();
    print!("{manifest}");
    match stretch::runtime::JoinKernel::load() {
        Ok(k) => println!("PJRT OK: platform = {}", k.platform()),
        Err(e) => {
            eprintln!("PJRT load failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cmd_run(path: &str) {
    let cfg = Config::load(path).unwrap_or_else(|e| {
        eprintln!("config error: {e}");
        std::process::exit(1);
    });
    let ws_ms = cfg.int_or("operator.ws_ms", 2_000);
    let n_keys = cfg.int_or("operator.keys", 64) as u64;
    let initial = cfg.int_or("engine.initial", 1) as usize;
    let max = cfg.int_or("engine.max", 4) as usize;
    let time_scale = cfg.float_or("run.time_scale", 2.0);
    let seed = cfg.int_or("run.seed", 7) as u64;

    // schedule: either constant or the Q5 random-phase stress profile
    let duration = cfg.int_or("run.duration_s", 30) as u32;
    let schedule = match cfg.str_or("run.schedule", "constant") {
        "q5" => RateSchedule::q5(
            seed,
            duration,
            cfg.float_or("run.min_rate", 500.0),
            cfg.float_or("run.max_rate", 4000.0),
            cfg.int_or("run.min_phase_s", 8) as u32,
            cfg.int_or("run.max_phase_s", 20) as u32,
        ),
        "step" => RateSchedule::step(
            duration,
            cfg.int_or("run.step_at_s", duration as i64 / 3) as u32,
            cfg.float_or("run.rate", 2000.0),
            cfg.float_or("run.step_rate", 4000.0),
        ),
        _ => RateSchedule::constant(duration, cfg.float_or("run.rate", 2000.0)),
    };

    // controller: none / reactive / proactive, calibrated on this box
    let cal = calibrate();
    let model = JoinCostModel::new(cal.cmp_per_sec / max as f64, ws_ms as f64 / 1e3);
    let controller: Option<Box<dyn stretch::elastic::Controller>> =
        match cfg.str_or("elastic.controller", "reactive") {
            "none" => None,
            "proactive" => Some(Box::new(ProactiveController::new(model))),
            _ => Some(Box::new(
                ReactiveController::new(
                    model,
                    Thresholds {
                        upper: cfg.float_or("elastic.upper", 0.90),
                        target: cfg.float_or("elastic.target", 0.70),
                        lower: cfg.float_or("elastic.lower", 0.45),
                    },
                )
                .with_cooldown(2),
            )),
        };

    // `[batch]` section: data-plane batch sizes (§Perf)
    let batch = BatchTuning::from_config(&cfg);
    println!(
        "running `{}`: WS={ws_ms}ms keys={n_keys} Π={initial}..{max} {}s ({}x compressed, batch {})",
        cfg.str_or("name", path),
        duration,
        time_scale,
        batch.worker
    );
    let r = run_elastic_join(JoinRunConfig {
        ws_ms,
        n_keys,
        initial,
        max,
        schedule,
        time_scale,
        controller,
        controller_period_s: cfg.int_or("elastic.period_s", 2) as u32,
        seed,
        gate_capacity: cfg.int_or("engine.gate_capacity", 8192) as usize,
        worker_batch: batch.worker,
        ingress_batch: batch.ingress,
        manual_reconfigs: Vec::new(),
    });
    println!("\n  t  offered   served   cmp/s      lat(ms)  Π backlog");
    for s in &r.samples {
        println!(
            "{:>4} {:>8.0} {:>8.0} {:>10.2e} {:>8.1} {:>2} {:>7}",
            s.t_s,
            s.offered_tps,
            s.in_tps,
            s.cmp_per_s,
            s.latency_mean_us / 1e3,
            s.threads,
            s.backlog
        );
    }
    println!("\n{} results at the egress; reconfigurations:", r.egress_count);
    for (e, ms) in &r.reconfigs {
        println!("  epoch {e}: {ms:.2} ms");
    }
}

fn main() {
    let cli = Cli::new(
        "stretch",
        "STRETCH: virtual shared-nothing stream processing (paper reproduction)",
    );
    let args = cli.parse().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match args.positional().first().map(|s| s.as_str()) {
        Some("calibrate") => cmd_calibrate(),
        Some("artifacts") => cmd_artifacts(),
        Some("run") => match args.positional().get(1) {
            Some(path) => cmd_run(path),
            None => {
                eprintln!("usage: stretch run <config.toml>");
                std::process::exit(2);
            }
        },
        _ => {
            println!("usage: stretch <command>\n");
            println!("  calibrate          measure this machine's cost model");
            println!("  artifacts          verify the AOT kernel artifacts + PJRT");
            println!("  run <config.toml>  run a config-driven elastic join experiment");
            println!("\nexperiment configs: see configs/*.toml; benches: cargo bench");
        }
    }
}
