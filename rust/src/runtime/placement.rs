//! Core/NUMA placement for the data plane (the "shared gates stay cheap
//! to share" prerequisite, PAPER.md §8).
//!
//! The engine is batched and false-sharing-free but — without this
//! module — placement-blind: worker threads, the job runtime thread and
//! every gate's slot/`Log` arrays land wherever the scheduler and
//! first-touch allocation happen to put them, so a reader group can sit
//! a socket away from the `ESG_out` it drains. Three pieces fix that:
//!
//! * [`CoreMap`] — the machine's topology (logical CPUs → sockets, SMT
//!   siblings), discovered from `/sys/devices/system/cpu` with a flat
//!   single-socket fallback when sysfs is absent (non-Linux, containers
//!   with a masked `/sys`).
//! * [`pin_current`] — a thin `sched_setaffinity` wrapper (no-op off
//!   Linux) so spawned threads self-pin; [`PinGuard`] is the RAII
//!   variant used to run first-touch initialization of gate memory on a
//!   core of the owning socket, restoring the caller's affinity after.
//! * [`PlacementPlan`] — assigns each stage's worker slots, its gate
//!   first-touch core and the job runtime thread to cores such that a
//!   stage's readers stay NUMA-local to its upstream's `ESG_out`
//!   whenever the socket has capacity. Explicit per-stage `cores`/
//!   `socket` config keys override the locality heuristic.
//!
//! Knobs: `[placement]` in job config ([`crate::config::PlacementConfig`])
//! plus per-stage `cores = [..]` / `socket = N` keys parsed into
//! [`crate::engine::job::JobSpec`].

use std::path::Path;

/// Words in the affinity mask: 16 × 64 = 1024 logical CPUs.
const MASK_WORDS: usize = 16;

#[cfg(target_os = "linux")]
extern "C" {
    fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
}

/// Pin the calling thread to one logical CPU. Returns whether the
/// kernel accepted the mask (always `false` off Linux, or for cores
/// outside the 1024-CPU mask or the process cpuset).
pub fn pin_current(core: usize) -> bool {
    if core >= MASK_WORDS * 64 {
        return false;
    }
    #[cfg(target_os = "linux")]
    {
        let mut mask = [0u64; MASK_WORDS];
        mask[core / 64] = 1u64 << (core % 64);
        // SAFETY: plain FFI into glibc's `sched_setaffinity` with pid 0
        // (the calling thread). `mask` is a live, initialized stack array
        // and `size_of_val` reports its exact byte length, so the kernel
        // reads only memory we own; the call writes nothing.
        unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
    }
    #[cfg(not(target_os = "linux"))]
    false
}

/// The calling thread's current affinity mask, `None` when unavailable.
fn current_affinity() -> Option<[u64; MASK_WORDS]> {
    #[cfg(target_os = "linux")]
    {
        let mut mask = [0u64; MASK_WORDS];
        // SAFETY: FFI into glibc's `sched_getaffinity` with pid 0 (the
        // calling thread). The kernel writes at most `size_of_val(&mask)`
        // bytes into `mask`, which is a live, exclusively-borrowed stack
        // array of exactly that size and is only read after rc == 0.
        let rc =
            unsafe { sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) };
        if rc == 0 {
            return Some(mask);
        }
    }
    None
}

/// Logical CPUs the calling thread may run on (empty when unknown —
/// non-Linux, or a kernel without affinity syscalls).
pub fn allowed_cores() -> Vec<usize> {
    match current_affinity() {
        Some(mask) => {
            (0..MASK_WORDS * 64).filter(|c| (mask[c / 64] >> (c % 64)) & 1 == 1).collect()
        }
        None => Vec::new(),
    }
}

/// RAII pin: restrict the current thread to `core`, restoring the
/// previous affinity mask on drop. Used to run first-touch allocation
/// of a stage's gate slot/`Log` arrays on a core of the owning socket
/// without leaking the mask to the rest of the build.
pub struct PinGuard {
    #[cfg_attr(not(target_os = "linux"), allow(dead_code))]
    saved: Option<[u64; MASK_WORDS]>,
}

impl PinGuard {
    pub fn pin(core: usize) -> PinGuard {
        let saved = current_affinity();
        pin_current(core);
        PinGuard { saved }
    }
}

impl Drop for PinGuard {
    fn drop(&mut self) {
        #[cfg(target_os = "linux")]
        if let Some(mask) = self.saved.take() {
            // SAFETY: same contract as `pin_current` — pid 0, a live stack
            // array of exactly the reported size, read-only to the kernel.
            // Restoring a mask captured by `sched_getaffinity` cannot fail
            // validation, and the result is irrelevant in a destructor.
            unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
        }
    }
}

/// One logical CPU.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Core {
    /// Kernel CPU id (the `N` of `/sys/devices/system/cpu/cpuN`).
    pub id: usize,
    /// Dense socket index in `0..CoreMap::sockets()` (kernel package
    /// ids need not be contiguous; they are renumbered in sorted order).
    pub socket: usize,
    /// First sibling of its SMT group — the "physical core" proxy the
    /// plan prefers before doubling up on hyper-threads.
    pub is_primary: bool,
}

/// The machine's CPU topology.
#[derive(Clone, Debug)]
pub struct CoreMap {
    cores: Vec<Core>,
    sockets: usize,
}

impl CoreMap {
    /// Discover the topology: sysfs on Linux, flat
    /// `available_parallelism` fallback elsewhere (or when `/sys` is
    /// masked, as in minimal containers).
    pub fn discover() -> CoreMap {
        #[cfg(target_os = "linux")]
        if let Some(m) = CoreMap::from_sysfs(Path::new("/sys/devices/system/cpu")) {
            return m;
        }
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        CoreMap::flat(n)
    }

    /// A uniform single-socket map of `n` physical cores (fallback and
    /// test helper).
    pub fn flat(n: usize) -> CoreMap {
        let n = n.max(1);
        CoreMap {
            cores: (0..n).map(|id| Core { id, socket: 0, is_primary: true }).collect(),
            sockets: 1,
        }
    }

    /// Parse a sysfs cpu tree rooted at `root` (`/sys/devices/system/cpu`
    /// in production; fixture snapshots in tests). `None` when the tree
    /// is absent or yields no parseable cpu.
    pub fn from_sysfs(root: &Path) -> Option<CoreMap> {
        let entries = std::fs::read_dir(root).ok()?;
        // (cpu id, kernel package id, first SMT sibling)
        let mut raw: Vec<(usize, usize, usize)> = Vec::new();
        for e in entries.flatten() {
            let name = e.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(id) = name.strip_prefix("cpu").and_then(|d| d.parse::<usize>().ok())
            else {
                continue; // cpufreq, cpuidle, possible, online, ...
            };
            let topo = e.path().join("topology");
            let Some(pkg) = read_usize(&topo.join("physical_package_id")) else {
                continue; // offline cpus export no topology
            };
            let first_sibling = read_trimmed(&topo.join("thread_siblings_list"))
                .and_then(|s| parse_cpu_list(&s))
                .and_then(|l| l.into_iter().min())
                .unwrap_or(id);
            raw.push((id, pkg, first_sibling));
        }
        if raw.is_empty() {
            return None;
        }
        raw.sort_unstable();
        // dense socket indices in kernel-package-id order
        let mut pkgs: Vec<usize> = raw.iter().map(|r| r.1).collect();
        pkgs.sort_unstable();
        pkgs.dedup();
        let cores = raw
            .into_iter()
            .map(|(id, pkg, first)| Core {
                id,
                socket: pkgs.binary_search(&pkg).unwrap(),
                is_primary: first == id,
            })
            .collect();
        Some(CoreMap { cores, sockets: pkgs.len() })
    }

    /// Number of logical CPUs.
    pub fn len(&self) -> usize {
        self.cores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    /// Number of sockets (≥ 1).
    pub fn sockets(&self) -> usize {
        self.sockets
    }

    /// All cores, sorted by kernel id.
    pub fn cores(&self) -> &[Core] {
        &self.cores
    }

    /// Kernel ids of the cores on `socket`, primaries first (so plans
    /// fill physical cores before hyper-thread siblings).
    pub fn cores_on(&self, socket: usize) -> Vec<usize> {
        let mut on: Vec<&Core> = self.cores.iter().filter(|c| c.socket == socket).collect();
        on.sort_by_key(|c| (!c.is_primary, c.id));
        on.iter().map(|c| c.id).collect()
    }

    /// Socket of kernel cpu `core`, `None` if the map has no such core.
    pub fn socket_of(&self, core: usize) -> Option<usize> {
        self.cores.iter().find(|c| c.id == core).map(|c| c.socket)
    }
}

fn read_trimmed(p: &Path) -> Option<String> {
    std::fs::read_to_string(p).ok().map(|s| s.trim().to_string())
}

fn read_usize(p: &Path) -> Option<usize> {
    read_trimmed(p)?.parse().ok()
}

/// Parse a sysfs cpu list: `"0-3"`, `"0,4"`, `"0,2-5,8"`.
fn parse_cpu_list(s: &str) -> Option<Vec<usize>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            let a: usize = a.trim().parse().ok()?;
            let b: usize = b.trim().parse().ok()?;
            if b < a {
                return None;
            }
            out.extend(a..=b);
        } else {
            out.push(part.parse().ok()?);
        }
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// What one stage asks of the placement planner, in DAG declaration
/// order (the same order `DagBuilder` spawns nodes).
#[derive(Clone, Debug, Default)]
pub struct StageRequest {
    pub name: String,
    /// Worker slots to place. Use the stage's `max`, not `initial`:
    /// pooled instances are spawned during the same build and inherit
    /// the build thread's mask, so they must self-pin too.
    pub workers: usize,
    /// Explicit kernel core ids from config — wins over everything.
    pub cores: Vec<usize>,
    /// Explicit socket from config — wins over the locality heuristic.
    pub socket: Option<usize>,
    /// Indices (into the request slice) of upstream stages.
    pub upstreams: Vec<usize>,
}

/// Where one stage landed.
#[derive(Clone, Debug)]
pub struct StagePlacement {
    /// Socket owning the stage's workers and gate memory.
    pub socket: usize,
    /// One kernel core id per worker slot (`len == workers`).
    pub worker_cores: Vec<usize>,
    /// Core to run first-touch initialization of the stage's gate
    /// slot/`Log` arrays on (a core of `socket`).
    pub touch_core: usize,
}

/// A full job-to-machine assignment.
#[derive(Clone, Debug)]
pub struct PlacementPlan {
    /// Per-stage placements, parallel to the request slice.
    pub stages: Vec<StagePlacement>,
    /// Core for the `JobHandle` runtime thread (feed/drain/sampling):
    /// the least-loaded socket's last core, away from the worker
    /// round-robin front.
    pub runtime_core: Option<usize>,
}

/// Validation failure against a concrete [`CoreMap`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PlacementError {
    UnknownCore { stage: String, core: usize, cores: usize },
    UnknownSocket { stage: String, socket: usize, sockets: usize },
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::UnknownCore { stage, core, cores } => write!(
                f,
                "stage `{stage}`: core {core} not in the machine's core map ({cores} cores)"
            ),
            PlacementError::UnknownSocket { stage, socket, sockets } => write!(
                f,
                "stage `{stage}`: socket {socket} out of range (machine has {sockets})"
            ),
        }
    }
}

impl std::error::Error for PlacementError {}

impl PlacementPlan {
    /// Assign every stage's worker slots (and the runtime thread) to
    /// cores. Preference order per stage: explicit `cores` → explicit
    /// `socket` → the first already-placed upstream's socket when it
    /// still has spare cores (readers drain that upstream's `ESG_out`,
    /// so this is the NUMA-locality invariant) → the least-loaded
    /// socket. Within a socket, cores are handed out round-robin,
    /// primaries first, wrapping once a socket oversubscribes.
    pub fn assign(
        map: &CoreMap,
        stages: &[StageRequest],
    ) -> Result<PlacementPlan, PlacementError> {
        let n_sock = map.sockets();
        let socket_cores: Vec<Vec<usize>> = (0..n_sock).map(|s| map.cores_on(s)).collect();
        let mut load = vec![0usize; n_sock];
        let mut cursor = vec![0usize; n_sock];
        let least = |load: &[usize]| (0..n_sock).min_by_key(|&s| (load[s], s)).unwrap_or(0);
        let mut out: Vec<StagePlacement> = Vec::with_capacity(stages.len());
        for (i, st) in stages.iter().enumerate() {
            for &c in &st.cores {
                if map.socket_of(c).is_none() {
                    return Err(PlacementError::UnknownCore {
                        stage: st.name.clone(),
                        core: c,
                        cores: map.len(),
                    });
                }
            }
            if let Some(s) = st.socket {
                if s >= n_sock {
                    return Err(PlacementError::UnknownSocket {
                        stage: st.name.clone(),
                        socket: s,
                        sockets: n_sock,
                    });
                }
            }
            let socket = if let Some(&c0) = st.cores.first() {
                map.socket_of(c0).unwrap()
            } else if let Some(s) = st.socket {
                s
            } else if let Some(up_sock) =
                st.upstreams.iter().filter(|&&u| u < i).map(|&u| out[u].socket).next()
            {
                if load[up_sock] + st.workers <= socket_cores[up_sock].len() {
                    up_sock
                } else {
                    least(&load)
                }
            } else {
                least(&load)
            };
            let worker_cores: Vec<usize> = if st.cores.is_empty() {
                let cs = &socket_cores[socket];
                (0..st.workers)
                    .map(|_| {
                        let c = cs[cursor[socket] % cs.len()];
                        cursor[socket] += 1;
                        c
                    })
                    .collect()
            } else {
                (0..st.workers).map(|k| st.cores[k % st.cores.len()]).collect()
            };
            load[socket] += st.workers;
            let touch_core = worker_cores.first().copied().unwrap_or(socket_cores[socket][0]);
            out.push(StagePlacement { socket, worker_cores, touch_core });
        }
        let rt_sock = least(&load);
        let runtime_core = socket_cores[rt_sock].last().copied();
        Ok(PlacementPlan { stages: out, runtime_core })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// Build a fixture `/sys/devices/system/cpu` snapshot.
    fn fixture(tag: &str, cpus: &[(usize, usize, &str)]) -> PathBuf {
        let root = std::env::temp_dir().join(format!("stretch_sysfs_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        for (id, pkg, siblings) in cpus {
            let topo = root.join(format!("cpu{id}")).join("topology");
            std::fs::create_dir_all(&topo).unwrap();
            std::fs::write(topo.join("physical_package_id"), format!("{pkg}\n")).unwrap();
            std::fs::write(topo.join("thread_siblings_list"), format!("{siblings}\n")).unwrap();
        }
        root
    }

    #[test]
    fn parses_single_socket_snapshot() {
        let root = fixture(
            "1s",
            &[(0, 0, "0"), (1, 0, "1"), (2, 0, "2"), (3, 0, "3")],
        );
        // decoy entries real sysfs also has
        std::fs::create_dir_all(root.join("cpufreq")).unwrap();
        std::fs::write(root.join("online"), "0-3\n").unwrap();
        let m = CoreMap::from_sysfs(&root).unwrap();
        std::fs::remove_dir_all(&root).ok();
        assert_eq!(m.len(), 4);
        assert_eq!(m.sockets(), 1);
        assert!(m.cores().iter().all(|c| c.socket == 0 && c.is_primary));
        assert_eq!(m.cores_on(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn parses_dual_socket_with_sparse_package_ids() {
        // kernel package ids 0 and 3 → dense sockets 0 and 1
        let root = fixture(
            "2s",
            &[(0, 0, "0"), (1, 0, "1"), (2, 3, "2"), (3, 3, "3")],
        );
        let m = CoreMap::from_sysfs(&root).unwrap();
        std::fs::remove_dir_all(&root).ok();
        assert_eq!(m.sockets(), 2);
        assert_eq!(m.socket_of(1), Some(0));
        assert_eq!(m.socket_of(2), Some(1));
        assert_eq!(m.cores_on(1), vec![2, 3]);
    }

    #[test]
    fn parses_smt_siblings_and_orders_primaries_first() {
        // 2 physical cores × 2 threads: (0,2) and (1,3) are sibling pairs
        let root = fixture(
            "smt",
            &[(0, 0, "0,2"), (1, 0, "1,3"), (2, 0, "0,2"), (3, 0, "1,3")],
        );
        let m = CoreMap::from_sysfs(&root).unwrap();
        std::fs::remove_dir_all(&root).ok();
        assert_eq!(m.len(), 4);
        let primary: Vec<bool> = m.cores().iter().map(|c| c.is_primary).collect();
        assert_eq!(primary, vec![true, true, false, false]);
        // physical cores handed out before hyper-thread siblings
        assert_eq!(m.cores_on(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn garbled_or_empty_tree_is_none_and_discover_still_works() {
        let root =
            std::env::temp_dir().join(format!("stretch_sysfs_empty_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("cpufreq")).unwrap();
        assert!(CoreMap::from_sysfs(&root).is_none());
        std::fs::remove_dir_all(&root).ok();
        assert!(CoreMap::from_sysfs(Path::new("/nonexistent/sysfs")).is_none());
        let m = CoreMap::discover();
        assert!(!m.is_empty());
        assert!(m.sockets() >= 1);
    }

    #[test]
    fn cpu_list_formats() {
        assert_eq!(parse_cpu_list("0-3"), Some(vec![0, 1, 2, 3]));
        assert_eq!(parse_cpu_list("0,4"), Some(vec![0, 4]));
        assert_eq!(parse_cpu_list("0,2-4,8"), Some(vec![0, 2, 3, 4, 8]));
        assert_eq!(parse_cpu_list(" 7 "), Some(vec![7]));
        assert_eq!(parse_cpu_list("3-1"), None);
        assert_eq!(parse_cpu_list("x"), None);
        assert_eq!(parse_cpu_list(""), None);
    }

    fn req(name: &str, workers: usize, ups: &[usize]) -> StageRequest {
        StageRequest {
            name: name.into(),
            workers,
            cores: Vec::new(),
            socket: None,
            upstreams: ups.to_vec(),
        }
    }

    fn dual_socket_map() -> CoreMap {
        CoreMap {
            cores: (0..8)
                .map(|id| Core { id, socket: id / 4, is_primary: true })
                .collect(),
            sockets: 2,
        }
    }

    #[test]
    fn readers_stay_local_to_upstream_when_capacity_allows() {
        let map = dual_socket_map();
        // diamond: src → (left, right) → join; 2 workers each
        let reqs = [
            req("src", 2, &[]),
            req("left", 2, &[0]),
            req("right", 2, &[0]),
            req("join", 2, &[1, 2]),
        ];
        let plan = PlacementPlan::assign(&map, &reqs).unwrap();
        // locality invariant: every stage with an upstream shares that
        // upstream's socket when the socket had room
        assert_eq!(plan.stages[1].socket, plan.stages[0].socket);
        // right no longer fits on socket 0 (src+left filled it) → spills
        assert_ne!(plan.stages[2].socket, plan.stages[0].socket);
        // join follows its first upstream (left, socket 0)? left's socket
        // is full, so it lands on the least-loaded one instead
        assert!(plan.stages[3].socket < map.sockets());
        for (p, r) in plan.stages.iter().zip(&reqs) {
            assert_eq!(p.worker_cores.len(), r.workers);
            for &c in &p.worker_cores {
                assert_eq!(map.socket_of(c), Some(p.socket));
            }
            assert_eq!(map.socket_of(p.touch_core), Some(p.socket));
        }
        assert!(plan.runtime_core.is_some());
    }

    #[test]
    fn single_socket_everything_lands_on_socket_zero() {
        let map = CoreMap::flat(2);
        let reqs = [req("a", 3, &[]), req("b", 3, &[0])];
        let plan = PlacementPlan::assign(&map, &reqs).unwrap();
        assert!(plan.stages.iter().all(|p| p.socket == 0));
        // oversubscription wraps round-robin instead of failing
        assert_eq!(plan.stages[0].worker_cores, vec![0, 1, 0]);
        assert_eq!(plan.runtime_core, Some(1));
    }

    #[test]
    fn explicit_cores_and_socket_override_locality() {
        let map = dual_socket_map();
        let mut a = req("a", 2, &[]);
        a.cores = vec![5, 6];
        let mut b = req("b", 1, &[0]);
        b.socket = Some(0);
        let plan = PlacementPlan::assign(&map, &[a, b]).unwrap();
        assert_eq!(plan.stages[0].socket, 1);
        assert_eq!(plan.stages[0].worker_cores, vec![5, 6]);
        assert_eq!(plan.stages[0].touch_core, 5);
        assert_eq!(plan.stages[1].socket, 0);
    }

    #[test]
    fn unknown_core_and_socket_are_typed_errors() {
        let map = CoreMap::flat(2);
        let mut a = req("a", 1, &[]);
        a.cores = vec![9];
        match PlacementPlan::assign(&map, &[a]).unwrap_err() {
            PlacementError::UnknownCore { stage, core, cores } => {
                assert_eq!((stage.as_str(), core, cores), ("a", 9, 2));
            }
            e => panic!("wrong error: {e}"),
        }
        let mut b = req("b", 1, &[]);
        b.socket = Some(1);
        assert!(matches!(
            PlacementPlan::assign(&map, &[b]).unwrap_err(),
            PlacementError::UnknownSocket { socket: 1, sockets: 1, .. }
        ));
    }

    #[test]
    fn pin_guard_restores_previous_affinity() {
        let before = allowed_cores();
        let Some(&core) = before.first() else {
            return; // affinity unavailable on this platform
        };
        {
            let _g = PinGuard::pin(core);
            assert_eq!(allowed_cores(), vec![core]);
        }
        assert_eq!(allowed_cores(), before);
    }

    #[test]
    fn pin_out_of_mask_is_rejected() {
        assert!(!pin_current(MASK_WORDS * 64));
    }
}
