//! Stub PJRT bridge (`--features pjrt` absent): same public surface as
//! the real `executable`/`offload` modules, no external dependencies.
//!
//! `artifacts_dir`/`artifacts_available` behave identically (they only
//! touch the filesystem); the loaders and kernels return
//! [`RuntimeError`] so callers take their documented fallback paths
//! (benches/examples skip the offload sweep, the CLI prints the error).

use std::fmt;
use std::path::{Path, PathBuf};

/// Probe batch size baked into the artifacts (see python/compile/model.py).
pub const BATCH: usize = 16;
/// Window tile variants baked into the artifacts, ascending.
pub const WINDOWS: [usize; 3] = [512, 2048, 8192];

/// Error carried by every stubbed runtime call.
#[derive(Debug, Clone)]
pub struct RuntimeError(pub &'static str);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

type Result<T> = std::result::Result<T, RuntimeError>;

const DISABLED: RuntimeError =
    RuntimeError("PJRT bridge compiled out (build with `--features pjrt` and vendored xla)");

/// Stub PJRT runtime; construction always fails.
pub struct PjrtRuntime {
    _priv: (),
}

/// Stub compiled module (never constructed).
pub struct LoadedExec {
    pub name: String,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Err(DISABLED)
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn load_hlo_text<P: AsRef<Path>>(&self, _path: P) -> Result<LoadedExec> {
        Err(DISABLED)
    }

    pub fn load_artifact(&self, _dir: &Path, _name: &str) -> Result<LoadedExec> {
        Err(DISABLED)
    }
}

/// Stub band-join kernel; `load` always fails, so the scalar predicate
/// loop (the measured winner on CPU) is used everywhere.
pub struct JoinKernel {
    _priv: (),
}

impl JoinKernel {
    pub fn load() -> Result<Self> {
        Err(DISABLED)
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn eval_mask(
        &mut self,
        _px: &[f32],
        _py: &[f32],
        _wa: &[f32],
        _wb: &[f32],
        _mask_out: &mut Vec<u8>,
    ) -> Result<()> {
        Err(DISABLED)
    }

    pub fn probe_indices(
        &mut self,
        _px: f32,
        _py: f32,
        _wa: &[f32],
        _wb: &[f32],
        _out: &mut Vec<u32>,
    ) -> Result<()> {
        Err(DISABLED)
    }
}

/// Stub thread-local kernel accessor: always `Err`.
pub fn with_thread_kernel<R>(_f: impl FnOnce(&mut JoinKernel) -> R) -> Result<R> {
    Err(DISABLED)
}

/// Locate the artifacts directory: $STRETCH_ARTIFACTS or ./artifacts
/// relative to the workspace root (same logic as the real module).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STRETCH_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cand = PathBuf::from("artifacts");
    if cand.exists() {
        return cand;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Whether the AOT artifacts have been built (`make artifacts`). True on
/// disk does not make the stub loadable — `JoinKernel::load` still
/// reports the feature as compiled out.
pub fn artifacts_available() -> bool {
    // The stub cannot execute artifacts even if present on disk: report
    // false so artifact-gated tests/benches skip instead of erroring.
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_loaders_fail_cleanly() {
        assert!(PjrtRuntime::cpu().is_err());
        assert!(JoinKernel::load().is_err());
        assert!(with_thread_kernel(|_| ()).is_err());
        assert!(!artifacts_available());
    }

    #[test]
    fn error_displays_hint() {
        let e = JoinKernel::load().unwrap_err();
        assert!(format!("{e:#}").contains("pjrt"));
    }
}
