//! The PJRT runtime bridge: load AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and execute
//! them from the rust hot path. Python never runs at request time.

pub mod executable;
pub mod offload;

pub use executable::{artifacts_available, artifacts_dir, LoadedExec, PjrtRuntime};
pub use offload::{with_thread_kernel, JoinKernel, BATCH, WINDOWS};
