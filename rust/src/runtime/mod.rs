//! Machine-facing runtime services: CPU/NUMA placement and the PJRT
//! offload bridge.
//!
//! [`placement`] owns the core-topology map, thread-affinity primitives
//! and the per-stage [`PlacementPlan`] that keeps reader groups
//! NUMA-local to the gates they drain — see its module docs.
//!
//! The rest of this module is the PJRT bridge: load AOT-compiled
//! JAX/Pallas artifacts (`artifacts/*.hlo.txt`, built once by
//! `make artifacts`) and execute them from the rust hot path. Python
//! never runs at request time.
//!
//! The real bridge needs the `xla` and `anyhow` crates, which this
//! offline container does not carry; it is therefore gated behind the
//! `pjrt` cargo feature. Without the feature a stub with the same public
//! surface compiles in: `artifacts_available()` always reports `false`
//! (even if artifacts exist on disk — the stub cannot execute them, and
//! `false` makes artifact-gated tests/benches skip cleanly), and every
//! loader returns [`RuntimeError`]; the engine falls back to the scalar
//! comparison loops (which the §Perf pass shows win on CPU anyway — the
//! offload is compile-only here).

pub mod placement;

pub use placement::{pin_current, CoreMap, PinGuard, PlacementPlan};

#[cfg(feature = "pjrt")]
pub mod executable;
#[cfg(feature = "pjrt")]
pub mod offload;

#[cfg(feature = "pjrt")]
pub use executable::{artifacts_available, artifacts_dir, LoadedExec, PjrtRuntime};
#[cfg(feature = "pjrt")]
pub use offload::{with_thread_kernel, JoinKernel, BATCH, WINDOWS};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{
    artifacts_available, artifacts_dir, with_thread_kernel, JoinKernel, LoadedExec, PjrtRuntime,
    RuntimeError, BATCH, WINDOWS,
};
