//! PJRT load-and-execute: HLO text → compiled executable → run.
//!
//! Follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (64-bit-id protos from jax ≥ 0.5
//! are rejected by xla_extension 0.5.1; the text parser reassigns ids).
//!
//! The underlying xla types hold raw pointers and are not `Send`; see
//! [`crate::runtime::offload`] for the thread-confined usage pattern.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU runtime: owns the client and the executables it compiled.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct LoadedExec {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO text file produced by `make artifacts`.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<LoadedExec> {
        let path = path.as_ref();
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "exec".into());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(LoadedExec { name, exe })
    }

    /// Load `<name>.hlo.txt` from an artifacts directory.
    pub fn load_artifact(&self, dir: &Path, name: &str) -> Result<LoadedExec> {
        self.load_hlo_text(dir.join(format!("{name}.hlo.txt")))
    }
}

impl LoadedExec {
    /// Execute with the given input literals; returns the flattened tuple
    /// of result literals (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let results = self.exe.execute::<xla::Literal>(args).context("execute")?;
        let lit = results[0][0].to_literal_sync().context("fetch result")?;
        lit.to_tuple().context("untuple result")
    }
}

/// Locate the artifacts directory: $STRETCH_ARTIFACTS or ./artifacts
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STRETCH_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // tests/benches run with CWD = workspace root
    let cand = PathBuf::from("artifacts");
    if cand.exists() {
        return cand;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Whether the AOT artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}
