//! Batched join-predicate offload: the L3 ↔ L1/L2 bridge.
//!
//! [`JoinKernel`] wraps the AOT-compiled band-join executables
//! (`artifacts/band_join_b{B}_w{W}.hlo.txt`): a probe batch is evaluated
//! against a stored-window tile in one PJRT call, returning the match
//! mask + per-probe counts computed by the Pallas kernel.
//!
//! xla handles are not `Send`, so each thread lazily builds its own
//! kernel instance ([`with_thread_kernel`]); the artifacts are compiled
//! once per thread at first use — never on the per-tuple path until warm.

use crate::runtime::executable::{artifacts_dir, LoadedExec, PjrtRuntime};
use anyhow::{Context, Result};
use std::cell::RefCell;

/// Probe batch size baked into the artifacts (see python/compile/model.py).
pub const BATCH: usize = 16;
/// Window tile variants baked into the artifacts, ascending.
pub const WINDOWS: [usize; 3] = [512, 2048, 8192];

/// The compiled band-join predicate variants.
pub struct JoinKernel {
    rt: PjrtRuntime,
    variants: Vec<(usize, LoadedExec)>, // (window size, exec)
    /// Reused padding buffers.
    px: Vec<f32>,
    py: Vec<f32>,
    wa: Vec<f32>,
    wb: Vec<f32>,
}

impl JoinKernel {
    /// Load every band-join variant from the artifacts directory.
    pub fn load() -> Result<Self> {
        let rt = PjrtRuntime::cpu()?;
        let dir = artifacts_dir();
        let mut variants = Vec::new();
        for w in WINDOWS {
            let exec = rt
                .load_artifact(&dir, &format!("band_join_b{BATCH}_w{w}"))
                .with_context(|| format!("band_join variant w={w} (run `make artifacts`)"))?;
            variants.push((w, exec));
        }
        Ok(JoinKernel {
            rt,
            variants,
            px: vec![f32::INFINITY; BATCH],
            py: vec![f32::INFINITY; BATCH],
            wa: Vec::new(),
            wb: Vec::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    /// Evaluate up to [`BATCH`] probes against a window of (a, b) columns.
    ///
    /// Returns the row-major mask (`probes.len() × window.len()`), probe-
    /// major. Window slots beyond `wa.len()` are padded with +inf (no
    /// match) inside the call; windows larger than the largest variant
    /// are evaluated in chunks.
    pub fn eval_mask(
        &mut self,
        px: &[f32],
        py: &[f32],
        wa: &[f32],
        wb: &[f32],
        mask_out: &mut Vec<u8>,
    ) -> Result<()> {
        assert_eq!(px.len(), py.len());
        assert!(px.len() <= BATCH, "probe batch larger than compiled BATCH");
        assert_eq!(wa.len(), wb.len());
        let b = px.len();
        let w = wa.len();
        mask_out.clear();
        mask_out.resize(b * w, 0);
        // pad probes with +inf (match nothing)
        self.px.iter_mut().for_each(|v| *v = f32::INFINITY);
        self.py.iter_mut().for_each(|v| *v = f32::INFINITY);
        self.px[..b].copy_from_slice(px);
        self.py[..b].copy_from_slice(py);

        let mut off = 0usize;
        while off < w {
            let remaining = w - off;
            // smallest variant covering the remainder (or the largest)
            let (vw, _) = *self
                .variants
                .iter()
                .find(|(vw, _)| *vw >= remaining)
                .unwrap_or(self.variants.last().unwrap());
            let chunk = remaining.min(vw);
            self.wa.clear();
            self.wa.extend_from_slice(&wa[off..off + chunk]);
            self.wa.resize(vw, f32::INFINITY);
            self.wb.clear();
            self.wb.extend_from_slice(&wb[off..off + chunk]);
            self.wb.resize(vw, f32::INFINITY);
            let exec = &self.variants.iter().find(|(x, _)| *x == vw).unwrap().1;
            let args = [
                xla::Literal::vec1(&self.px),
                xla::Literal::vec1(&self.py),
                xla::Literal::vec1(&self.wa),
                xla::Literal::vec1(&self.wb),
            ];
            let outs = exec.run(&args)?;
            // outs[0]: int8 mask (BATCH, vw); outs[1]: int32 counts (BATCH,)
            let flat: Vec<i8> = outs[0].to_vec().context("mask to_vec")?;
            for p in 0..b {
                let row = &flat[p * vw..p * vw + chunk];
                let dst = &mut mask_out[p * w + off..p * w + off + chunk];
                for (d, s) in dst.iter_mut().zip(row) {
                    *d = *s as u8;
                }
            }
            off += chunk;
        }
        Ok(())
    }

    /// Single-probe convenience: matching indices into the window.
    pub fn probe_indices(
        &mut self,
        px: f32,
        py: f32,
        wa: &[f32],
        wb: &[f32],
        out: &mut Vec<u32>,
    ) -> Result<()> {
        let mut mask = Vec::new();
        self.eval_mask(&[px], &[py], wa, wb, &mut mask)?;
        out.clear();
        for (i, &m) in mask.iter().enumerate() {
            if m != 0 {
                out.push(i as u32);
            }
        }
        Ok(())
    }
}

thread_local! {
    static THREAD_KERNEL: RefCell<Option<JoinKernel>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's lazily-constructed [`JoinKernel`].
/// Returns `Err` if the artifacts are missing or compilation fails.
pub fn with_thread_kernel<R>(f: impl FnOnce(&mut JoinKernel) -> R) -> Result<R> {
    THREAD_KERNEL.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(JoinKernel::load()?);
        }
        Ok(f(slot.as_mut().unwrap()))
    })
}
