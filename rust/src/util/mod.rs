//! Shared utilities: deterministic PRNG + samplers, backoff, SPSC queues.

pub mod backoff;
pub mod rng;
pub mod spsc;

pub use backoff::Backoff;
pub use rng::{Rng, Zipf};
