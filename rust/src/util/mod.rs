//! Shared utilities: deterministic PRNG + samplers, backoff, SPSC queues,
//! the run-buffer [`pool`], and the [`CachePadded`] false-sharing guard
//! used by the hot-path atomics (gate slots, queue indices).

pub mod backoff;
pub mod pool;
pub mod rng;
pub mod spsc;

pub use backoff::Backoff;
pub use pool::BufferPool;
pub use rng::{Rng, Zipf};

/// Pads and aligns `T` to 128 bytes so that two adjacent values (e.g.
/// per-source slots in a `Vec`, or a queue's head/tail indices) never
/// share a cache line. 128 rather than 64 because modern x86 prefetches
/// cache-line *pairs* (and Apple/ARM big cores use 128-byte lines), so
/// 64-byte padding still ping-pongs under the adjacent-line prefetcher.
#[derive(Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    #[inline]
    pub const fn new(value: T) -> Self {
        CachePadded { value }
    }

    /// Unwrap the padded value.
    #[inline]
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

#[cfg(test)]
mod tests {
    use super::CachePadded;

    #[test]
    fn cache_padded_is_line_pair_sized_and_aligned() {
        assert!(std::mem::align_of::<CachePadded<u64>>() >= 128);
        assert!(std::mem::size_of::<CachePadded<u64>>() >= 128);
        let v: Vec<CachePadded<u64>> = (0..4).map(CachePadded::new).collect();
        // adjacent elements land on distinct 128-byte lines
        let a = &*v[0] as *const u64 as usize;
        let b = &*v[1] as *const u64 as usize;
        assert!(b - a >= 128);
        assert_eq!(*v[3], 3);
    }
}
