//! Run-buffer pool: capacity-classed recycling for the `Vec<T>` runs
//! that carry tuples through the data plane (§Perf "memory discipline"
//! in the crate docs).
//!
//! The steady-state hot path never touches this pool: a worker's run
//! buffers circulate privately (fill → flush → drained-in-place →
//! refill), so the allocator is out of the loop entirely. The pool
//! serializes only *cold* transitions — worker eviction and re-growth
//! at an epoch switch, zombie-replay segment hand-back, burst decay —
//! which is why a plain `Mutex` per capacity class is the honest choice
//! over a lock-free stack: the locks are uncontended by construction,
//! and this file carries no `lint: lock-free` marker.
//!
//! Two disciplines, both enforced by tests (and exercised under Miri —
//! this module is on the nightly Miri list):
//!
//! * **capacity classes** — [`BufferPool::get`] only returns a buffer
//!   whose capacity already covers the request (classes are
//!   power-of-two buckets, takes search upward), so a recycled buffer
//!   never reallocates on first use;
//! * **shrink cap** — [`BufferPool::put`] clears the buffer (a recycled
//!   run can never leak stale tuples: payload drops happen at `put`,
//!   not at some later reuse) and shrinks any burst-inflated capacity
//!   back to the cap, so one traffic spike does not pin peak memory for
//!   the process lifetime. [`shrink_excess`] applies the same cap to
//!   scratch that stays caller-owned (worker batch buffers under a live
//!   `worker_batch` retune, SN staging rows, merge scratch).

use std::sync::Mutex;

/// Default capacity ceiling a buffer keeps through `put` (entries, not
/// bytes): covers the largest steady-state run in the tree (the merge's
/// `MERGE_RUN_MAX = 1024` scratch and any plausible `worker_batch`)
/// with headroom, while letting a 100k-entry burst buffer deflate.
pub const DEFAULT_SHRINK_CAP: usize = 4096;

/// Default retained buffers per capacity class; excess `put`s fall
/// through to the allocator so an eviction storm cannot hoard memory.
pub const DEFAULT_PER_CLASS: usize = 8;

/// A capacity-classed free list of `Vec<T>` run buffers. Shared by
/// value behind an `Arc` wherever one run lifecycle spans threads
/// (gate handles clone the same pool into every worker).
pub struct BufferPool<T> {
    /// `shelves[s]` holds buffers whose capacity `c` satisfies
    /// `2^s <= c < 2^(s+1)`; a `get` for `min_cap` starts at the
    /// ceiling class, so anything it finds already covers the request.
    shelves: Vec<Mutex<Vec<Vec<T>>>>,
    shrink_cap: usize,
    per_class: usize,
}

impl<T> BufferPool<T> {
    /// Pool with the default shrink cap and per-class retention.
    pub fn new() -> Self {
        Self::with_config(DEFAULT_SHRINK_CAP, DEFAULT_PER_CLASS)
    }

    /// Pool with an explicit shrink cap (entries) and per-class
    /// retention bound. `shrink_cap` is clamped to at least 1.
    pub fn with_config(shrink_cap: usize, per_class: usize) -> Self {
        let shrink_cap = shrink_cap.max(1);
        let classes = shrink_cap.next_power_of_two().trailing_zeros() as usize + 1;
        BufferPool {
            shelves: (0..classes).map(|_| Mutex::new(Vec::new())).collect(),
            shrink_cap,
            per_class,
        }
    }

    /// The capacity ceiling applied by [`put`](Self::put).
    pub fn shrink_cap(&self) -> usize {
        self.shrink_cap
    }

    /// Take a buffer with capacity at least `min_cap`, recycling a
    /// pooled one when a covering class has stock and falling back to
    /// a fresh allocation otherwise. The returned buffer is empty.
    pub fn get(&self, min_cap: usize) -> Vec<T> {
        let min_cap = min_cap.max(1);
        let start = min_cap.next_power_of_two().trailing_zeros() as usize;
        for shelf in self.shelves.iter().skip(start) {
            if let Some(buf) = shelf.lock().unwrap().pop() {
                debug_assert!(buf.capacity() >= min_cap && buf.is_empty());
                return buf;
            }
        }
        Vec::with_capacity(min_cap)
    }

    /// Return a buffer to the pool: clear it (dropping any residual
    /// payloads NOW, so a pooled buffer can never alias or resurrect a
    /// stale tuple), deflate burst capacity to the shrink cap, and
    /// shelve it unless its class is already at the retention bound
    /// (then the allocator takes it back).
    pub fn put(&self, mut buf: Vec<T>) {
        buf.clear();
        if buf.capacity() > self.shrink_cap {
            buf.shrink_to(self.shrink_cap);
        }
        if buf.capacity() == 0 {
            return;
        }
        let class = usize::BITS as usize - 1 - buf.capacity().leading_zeros() as usize;
        let class = class.min(self.shelves.len() - 1);
        let mut shelf = self.shelves[class].lock().unwrap();
        if shelf.len() < self.per_class {
            shelf.push(buf);
        }
    }

    /// Total buffers currently shelved (tests / memory accounting).
    pub fn pooled(&self) -> usize {
        self.shelves.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

impl<T> Default for BufferPool<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Apply a pool-style shrink cap to caller-owned scratch: if `buf`'s
/// capacity outgrew `cap` (a burst, or a `worker_batch` retune downward),
/// shrink it back — but never below its current length. Call this at the
/// natural empty point of the scratch's cycle; it is a capacity read
/// (two loads) in the common no-op case.
pub fn shrink_excess<T>(buf: &mut Vec<T>, cap: usize) {
    if buf.capacity() > cap {
        buf.shrink_to(cap.max(buf.len()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn get_returns_covering_capacity_and_recycles() {
        let pool: BufferPool<u64> = BufferPool::new();
        pool.put(Vec::with_capacity(1024));
        assert_eq!(pool.pooled(), 1);
        // a request the pooled buffer covers is served from the shelf
        let buf = pool.get(1000);
        assert!(buf.capacity() >= 1000);
        assert_eq!(pool.pooled(), 0);
        // a larger-class request falls back to a fresh allocation
        pool.put(buf);
        let big = pool.get(2048);
        assert!(big.capacity() >= 2048);
        assert_eq!(pool.pooled(), 1, "undersized buffer must stay shelved");
    }

    #[test]
    fn get_from_empty_pool_allocates_fresh() {
        let pool: BufferPool<u8> = BufferPool::new();
        let buf = pool.get(300);
        assert!(buf.capacity() >= 300 && buf.is_empty());
    }

    #[test]
    fn put_clears_and_applies_shrink_cap() {
        let pool: BufferPool<u32> = BufferPool::with_config(1024, 8);
        let mut burst: Vec<u32> = Vec::with_capacity(1 << 16);
        burst.extend(0..100);
        pool.put(burst);
        let back = pool.get(1);
        // the satellite invariant: capacity after a burst ≤ the cap
        assert!(back.capacity() <= 1024, "capacity {} > cap", back.capacity());
        assert!(back.is_empty());
    }

    #[test]
    fn per_class_retention_is_bounded() {
        let pool: BufferPool<u8> = BufferPool::with_config(4096, 3);
        for _ in 0..10 {
            pool.put(Vec::with_capacity(256));
        }
        assert_eq!(pool.pooled(), 3);
    }

    #[test]
    fn recycled_buffers_drop_stale_payloads_at_put() {
        let marker = Arc::new(());
        let pool: BufferPool<Arc<()>> = BufferPool::new();
        let mut buf = Vec::with_capacity(16);
        for _ in 0..5 {
            buf.push(marker.clone());
        }
        assert_eq!(Arc::strong_count(&marker), 6);
        pool.put(buf);
        // payloads died at put-time, not at some later reuse
        assert_eq!(Arc::strong_count(&marker), 1);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn zero_capacity_buffers_are_not_shelved() {
        let pool: BufferPool<u8> = BufferPool::new();
        pool.put(Vec::new());
        assert_eq!(pool.pooled(), 0);
    }

    /// The reconfiguration shape: worker threads hand buffers back on
    /// eviction while re-grown workers draw from the same pool. Vec
    /// ownership makes aliasing structurally impossible; this asserts
    /// the other half — nothing leaks across the hand-offs (every
    /// payload clone dies) and recycled buffers come back empty.
    #[test]
    fn cross_thread_recycling_neither_aliases_nor_leaks() {
        let marker = Arc::new(());
        let pool: Arc<BufferPool<Arc<()>>> = Arc::new(BufferPool::new());
        let threads: Vec<_> = (0..3)
            .map(|_| {
                let pool = pool.clone();
                let marker = marker.clone();
                std::thread::spawn(move || {
                    for _ in 0..20 {
                        let mut buf = pool.get(64);
                        assert!(buf.is_empty());
                        for _ in 0..8 {
                            buf.push(marker.clone());
                        }
                        pool.put(buf);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        drop(pool);
        assert_eq!(Arc::strong_count(&marker), 1);
    }
}
