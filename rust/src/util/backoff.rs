//! Exponential backoff for spinning threads.
//!
//! §7 of the paper: "When no tuple is retrieved ... exponential backoff
//! prevents the thread from creating contention on `ESG_in`." Pool
//! (disconnected) instances back off aggressively; active instances back
//! off lightly between empty polls.

use std::time::Duration;

/// Exponential backoff: spin-hint a few times, then yield, then sleep with
/// doubling duration up to `max_sleep`.
#[derive(Debug)]
pub struct Backoff {
    step: u32,
    max_sleep: Duration,
}

/// Spin steps before yielding to the OS scheduler.
const SPIN_LIMIT: u32 = 6;
/// Yield steps before sleeping.
const YIELD_LIMIT: u32 = 10;

impl Backoff {
    pub fn new(max_sleep: Duration) -> Self {
        Backoff { step: 0, max_sleep }
    }

    /// Backoff tuned for an active operator instance polling its input.
    pub fn active() -> Self {
        Backoff::new(Duration::from_micros(500))
    }

    /// Backoff tuned for a pooled (disconnected) instance: negligible
    /// contention, wakes up fast enough for sub-40ms reconfigurations.
    pub fn pooled() -> Self {
        Backoff::new(Duration::from_millis(2))
    }

    /// Record an unproductive poll and wait accordingly.
    pub fn snooze(&mut self) {
        if self.step < SPIN_LIMIT {
            for _ in 0..(1 << self.step) {
                std::hint::spin_loop();
            }
        } else if self.step < YIELD_LIMIT {
            std::thread::yield_now();
        } else {
            let exp = (self.step - YIELD_LIMIT).min(16);
            let sleep = Duration::from_micros(1u64 << exp).min(self.max_sleep);
            std::thread::sleep(sleep);
        }
        self.step = self.step.saturating_add(1);
    }

    /// Record a productive poll: reset to spinning.
    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }

    /// Whether the backoff has escalated to sleeping.
    pub fn is_sleeping(&self) -> bool {
        self.step >= YIELD_LIMIT
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn resets_to_spinning() {
        let mut b = Backoff::active();
        for _ in 0..20 {
            b.snooze();
        }
        assert!(b.is_sleeping());
        b.reset();
        assert!(!b.is_sleeping());
    }

    #[test]
    fn sleep_bounded_by_max() {
        let mut b = Backoff::new(Duration::from_micros(100));
        for _ in 0..40 {
            b.snooze();
        }
        // one more snooze at saturation must not exceed ~max_sleep (+ sched noise)
        let t0 = Instant::now();
        b.snooze();
        assert!(t0.elapsed() < Duration::from_millis(50));
    }

    #[test]
    fn early_steps_are_cheap() {
        let mut b = Backoff::active();
        let t0 = Instant::now();
        for _ in 0..SPIN_LIMIT {
            b.snooze();
        }
        assert!(t0.elapsed() < Duration::from_millis(10));
    }
}
