//! Deterministic PRNG and distribution samplers.
//!
//! The crates.io `rand` facade is unavailable offline, so we carry a small,
//! well-tested PRNG of our own: SplitMix64 for seeding and xoshiro256++ for
//! the main stream. Determinism matters: every workload generator, the
//! simulator, and the property-testing kit take explicit seeds so that any
//! failure reproduces bit-for-bit.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Small, fast, and statistically solid for simulation
/// and workload-generation purposes (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` using Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an exponential inter-arrival gap with the given mean.
    /// Used by Poisson-process workload generators.
    #[inline]
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Zipf(s) sampler over `{0, .., n-1}` using the rejection-inversion method
/// of Hörmann & Derflinger — O(1) per sample, no O(n) table.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    hx0: f64,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        assert!(s > 0.0 && (s - 1.0).abs() > 1e-9, "s=1 unsupported; use s=1.0001");
        let n = n as u64;
        let h = |x: f64| -> f64 { (x.powf(1.0 - s)) / (1.0 - s) };
        let h_x1 = h(1.5) - 1.0;
        let h_n = h(n as f64 + 0.5);
        let hx0 = h(0.5);
        Zipf { n, s, h_x1, h_n, hx0 }
    }

    fn h(&self, x: f64) -> f64 {
        x.powf(1.0 - self.s) / (1.0 - self.s)
    }

    fn h_inv(&self, x: f64) -> f64 {
        ((1.0 - self.s) * x).powf(1.0 / (1.0 - self.s))
    }

    /// Sample a rank in `[0, n)`; rank 0 is the most frequent item.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.hx0 + rng.f64() * (self.h_n - self.hx0);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0) as u64;
            let k = k.min(self.n);
            if u >= self.h(k as f64 + 0.5) - (k as f64).powf(-self.s) || k <= 1 {
                return k - 1;
            }
            // else reject and retry (rare)
            if self.h_x1 < 0.0 && k == 1 {
                return 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_differs_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exp(2.5)).sum();
        let mean = sum / n as f64;
        assert!((mean - 2.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Rng::new(5);
        let mut counts = vec![0u32; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[100]);
        // all samples in range is implied by indexing; head should dominate
        let head: u32 = counts[..10].iter().sum();
        let tail: u32 = counts[990..].iter().sum();
        assert!(head > tail * 10);
    }

    #[test]
    fn zipf_single_element() {
        let z = Zipf::new(1, 1.5);
        let mut r = Rng::new(17);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut r), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(29);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
