//! Bounded SPSC / MPSC queues used by the shared-nothing (SN) baseline.
//!
//! lint: lock-free — this file may not reference Mutex/RwLock/Condvar
//! (rule L5); the ring synchronizes through `head`/`tail` alone.
//!
//! §2.2: with SN parallelism each pair of connected instances exchanges
//! tuples over a *dedicated* queue. The SN baseline engine therefore pays
//! one enqueue per (tuple, downstream-responsible-instance) pair — the data
//! duplication overhead of Theorem 1 — whereas the VSN engine shares one
//! ESG among all instances.
//!
//! The queue is a classic ring buffer with cached head/tail indices
//! (Lamport queue with the producer/consumer caching optimization).
//! `head` and `tail` are [`CachePadded`] onto separate cache-line pairs
//! so producer and consumer never false-share, and the batch operations
//! ([`Producer::push_slice`], [`Consumer::pop_chunk`]) amortize the
//! remaining head/tail atomic traffic over whole runs of tuples.
//!
//! # Memory-ordering protocol (the pairings every site below cites)
//!
//! Single producer, single consumer; two index atomics, each with ONE
//! writer:
//!
//! * **tail publish** — the producer writes slots `[tail, tail+n)` then
//!   `tail.store(tail+n, Release)`; the consumer's
//!   `tail.load(Acquire)` pairs with it, making the slot writes visible
//!   before the index that covers them. This is the edge that hands a
//!   tuple across threads.
//! * **head reclaim** — the consumer reads slots out then
//!   `head.store(head+n, Release)`; the producer's
//!   `head.load(Acquire)` pairs with it, ensuring the consumer's reads
//!   completed before the producer may overwrite those slots.
//! * Each side loads its OWN index Relaxed — it is that index's only
//!   writer, so it always sees its latest value; no cross-thread edge
//!   is needed.
//! * **closed flag** — Release store / Acquire load; Acquire is
//!   stronger than this bool strictly needs (it is a latch carrying no
//!   payload), but it keeps `is_done()`'s closed-then-drained check
//!   ordered with the tail load that follows it.

use crate::util::CachePadded;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

struct Inner<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    cap: usize,
    head: CachePadded<AtomicUsize>, // next slot to pop
    tail: CachePadded<AtomicUsize>, // next slot to push
    closed: AtomicBool,
}

// SAFETY: `Inner` is shared by exactly one Producer and one Consumer.
// Slot `i` is written only by the producer while `head <= i < tail`
// excludes it from the consumer, and read only by the consumer after the
// producer's Release tail-publish made the write visible (protocol in
// the module docs). The `UnsafeCell`s are therefore never accessed from
// two threads at once, so sharing `Inner` is sound whenever `T: Send`.
unsafe impl<T: Send> Sync for Inner<T> {}
// SAFETY: moving `Inner` between threads moves owned `T`s (the queued
// elements) and atomics; both are `Send` when `T: Send`.
unsafe impl<T: Send> Send for Inner<T> {}

/// Producer handle (single producer).
pub struct Producer<T> {
    inner: Arc<Inner<T>>,
    head_cache: usize,
}

/// Consumer handle (single consumer).
pub struct Consumer<T> {
    inner: Arc<Inner<T>>,
    tail_cache: usize,
}

/// Error returned when pushing to a full or closed queue.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue full: backpressure — caller should retry (flow control).
    Full(T),
    /// Consumer dropped / channel closed.
    Closed(T),
}

/// Create a bounded SPSC queue with capacity `cap` (rounded up to a power
/// of two).
pub fn spsc<T>(cap: usize) -> (Producer<T>, Consumer<T>) {
    let cap = cap.max(2).next_power_of_two();
    let buf: Vec<UnsafeCell<MaybeUninit<T>>> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(Inner {
        buf: buf.into_boxed_slice(),
        cap,
        head: CachePadded::new(AtomicUsize::new(0)),
        tail: CachePadded::new(AtomicUsize::new(0)),
        closed: AtomicBool::new(false),
    });
    (
        Producer { inner: inner.clone(), head_cache: 0 },
        Consumer { inner, tail_cache: 0 },
    )
}

impl<T> Producer<T> {
    /// Attempt to push; `Err(Full)` signals backpressure.
    pub fn try_push(&mut self, v: T) -> Result<(), PushError<T>> {
        let inner = &*self.inner;
        // ORDERING: closed latch, Acquire paired with the Release store
        // in `close` (module docs).
        if inner.closed.load(Ordering::Acquire) {
            return Err(PushError::Closed(v));
        }
        // ORDERING: Relaxed — the producer is `tail`'s only writer, so
        // this is a self-read; no cross-thread edge needed.
        let tail = inner.tail.load(Ordering::Relaxed);
        if tail.wrapping_sub(self.head_cache) >= inner.cap {
            // ORDERING: head-reclaim edge — Acquire pairs with the
            // consumer's Release head publish in `try_pop`/`pop_chunk`,
            // so the consumer's slot reads happened-before we overwrite.
            self.head_cache = inner.head.load(Ordering::Acquire);
            if tail.wrapping_sub(self.head_cache) >= inner.cap {
                return Err(PushError::Full(v));
            }
        }
        // SAFETY: `tail & (cap-1)` is in bounds (cap is a power of two).
        // The full-check above proved `tail - head < cap`, so slot `tail`
        // is outside the consumer's live range `[head, tail)`: we are the
        // only thread touching it, and any previous occupant was already
        // moved out by `assume_init_read`. Writing a fresh value into the
        // `MaybeUninit` is sound and must not drop the old slot content.
        unsafe {
            (*inner.buf[tail & (inner.cap - 1)].get()).write(v);
        }
        // ORDERING: tail-publish edge — Release pairs with the consumer's
        // Acquire tail load; the slot write above becomes visible before
        // the index that covers it (module docs).
        inner.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Blocking push with spinning/yielding (used by generators that must
    /// respect backpressure). Returns `false` if the queue closed.
    pub fn push_blocking(&mut self, mut v: T) -> bool {
        let mut backoff = crate::util::backoff::Backoff::active();
        loop {
            match self.try_push(v) {
                Ok(()) => return true,
                Err(PushError::Closed(_)) => return false,
                Err(PushError::Full(back)) => {
                    v = back;
                    backoff.snooze();
                }
            }
        }
    }

    /// Free slots available to the producer right now (refreshes the
    /// cached head). Monotone until the next push: the consumer can only
    /// pop, so a subsequent [`push_slice`](Self::push_slice) of at most
    /// this many items is guaranteed to take them all.
    ///
    /// ORDERING: the `tail` self-read is Relaxed (single writer: us);
    /// the `head` refresh is the Acquire half of the head-reclaim edge
    /// (pairs with the consumer's Release head publish) so reclaimed
    /// slots are safe to overwrite.
    pub fn free(&mut self) -> usize {
        let inner = &*self.inner;
        let tail = inner.tail.load(Ordering::Relaxed);
        if tail.wrapping_sub(self.head_cache) >= inner.cap {
            self.head_cache = inner.head.load(Ordering::Acquire);
        }
        inner.cap - tail.wrapping_sub(self.head_cache)
    }

    /// Whether the channel was closed (by either end).
    pub fn is_closed(&self) -> bool {
        // ORDERING: closed latch, Acquire paired with `close`'s Release.
        self.inner.closed.load(Ordering::Acquire)
    }

    /// Batched push: move up to `max` items off the *front* of `items`
    /// into the queue with ONE tail publish, returning how many were
    /// taken. 0 can mean full, closed, or an empty `items` — callers that
    /// care distinguish via [`is_closed`](Self::is_closed)/[`free`](Self::free).
    ///
    /// lint: no-alloc — the batch hot path writes into preallocated ring
    /// slots and drains the caller's run in place.
    pub fn push_slice(&mut self, items: &mut Vec<T>, max: usize) -> usize {
        // ORDERING: closed latch, Acquire paired with `close`'s Release.
        if items.is_empty() || max == 0 || self.inner.closed.load(Ordering::Acquire) {
            return 0;
        }
        let n = self.free().min(items.len()).min(max);
        if n == 0 {
            return 0;
        }
        let inner = &*self.inner;
        // ORDERING: Relaxed self-read of `tail` (single writer: us).
        let tail = inner.tail.load(Ordering::Relaxed);
        let mask = inner.cap - 1;
        for (i, v) in items.drain(..n).enumerate() {
            // SAFETY: same argument as `try_push`, extended to a run:
            // `free()` proved slots `[tail, tail+n)` are outside the
            // consumer's live range, indices are masked into bounds, and
            // each target `MaybeUninit` holds no live value.
            unsafe {
                (*inner.buf[tail.wrapping_add(i) & mask].get()).write(v);
            }
        }
        // ORDERING: tail-publish edge — ONE Release covers the whole run
        // of slot writes above; pairs with the consumer's Acquire tail
        // load. This per-run (not per-tuple) publish is the batching win.
        inner.tail.store(tail.wrapping_add(n), Ordering::Release);
        n
    }

    /// Number of elements currently queued (approximate under concurrency).
    ///
    /// ORDERING: Relaxed on both indices — a monitoring snapshot with no
    /// associated slot access; the value is stale the moment it returns
    /// and synchronizes nothing.
    pub fn len(&self) -> usize {
        let t = self.inner.tail.load(Ordering::Relaxed);
        let h = self.inner.head.load(Ordering::Relaxed);
        t.wrapping_sub(h)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.cap
    }

    /// Close the channel: consumer will drain remaining items then see None.
    pub fn close(&self) {
        // ORDERING: Release pairs with the Acquire loads of `closed`;
        // everything pushed before closing is visible to a consumer that
        // observes the latch (drain-then-None contract).
        self.inner.closed.store(true, Ordering::Release);
    }
}

impl<T> Consumer<T> {
    /// Attempt to pop. `None` means currently empty (check `is_closed`).
    pub fn try_pop(&mut self) -> Option<T> {
        let inner = &*self.inner;
        // ORDERING: Relaxed self-read — the consumer is `head`'s only
        // writer.
        let head = inner.head.load(Ordering::Relaxed);
        if head == self.tail_cache {
            // ORDERING: tail-publish edge — Acquire pairs with the
            // producer's Release tail store, making the covered slot
            // writes visible before we read them below.
            self.tail_cache = inner.tail.load(Ordering::Acquire);
            if head == self.tail_cache {
                return None;
            }
        }
        // SAFETY: `head < tail_cache` (checked above), and the Acquire
        // tail load made the producer's write of slot `head` visible, so
        // the slot is initialized; the index is masked into bounds. We
        // are the only consumer, so moving the value out with
        // `assume_init_read` cannot race or double-read — the head
        // publish below retires the slot before the producer may reuse it.
        let v = unsafe { (*inner.buf[head & (inner.cap - 1)].get()).assume_init_read() };
        // ORDERING: head-reclaim edge — Release pairs with the producer's
        // Acquire head load; our slot read above happens-before the
        // producer's overwrite of this slot.
        inner.head.store(head.wrapping_add(1), Ordering::Release);
        Some(v)
    }

    /// Batched pop: append up to `max` queued items to `buf` with ONE
    /// head publish, returning how many were taken.
    ///
    /// lint: no-alloc — `reserve` on the caller's recycled scratch is a
    /// no-op in steady state (capacity persists across refills).
    pub fn pop_chunk(&mut self, buf: &mut Vec<T>, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        let inner = &*self.inner;
        // ORDERING: Relaxed self-read — we are `head`'s only writer.
        let head = inner.head.load(Ordering::Relaxed);
        if head == self.tail_cache {
            // ORDERING: tail-publish edge — Acquire pairs with the
            // producer's Release tail store (same as `try_pop`).
            self.tail_cache = inner.tail.load(Ordering::Acquire);
            if head == self.tail_cache {
                return 0;
            }
        }
        let n = self.tail_cache.wrapping_sub(head).min(max);
        let mask = inner.cap - 1;
        buf.reserve(n);
        for i in 0..n {
            // SAFETY: slots `[head, head+n)` are below the Acquire-loaded
            // tail, hence initialized and visible; indices masked into
            // bounds; single consumer, and the slots are not retired to
            // the producer until the head publish below — so each value
            // is moved out exactly once.
            buf.push(unsafe {
                (*inner.buf[head.wrapping_add(i) & mask].get()).assume_init_read()
            });
        }
        // ORDERING: head-reclaim edge — ONE Release retires the whole
        // run; pairs with the producer's Acquire head load.
        inner.head.store(head.wrapping_add(n), Ordering::Release);
        n
    }

    /// True when producer closed AND the queue is drained.
    pub fn is_done(&mut self) -> bool {
        // ORDERING: closed latch, Acquire paired with `close`'s Release —
        // and loaded BEFORE the emptiness probe: close-then-push is
        // impossible, so closed-and-then-empty really means end-of-stream.
        self.inner.closed.load(Ordering::Acquire) && self.try_peek_empty()
    }

    /// ORDERING: Relaxed self-read of `head`; Acquire tail refresh pairs
    /// with the producer's Release publish (tail-publish edge) so the
    /// emptiness verdict reflects every push that happened-before it.
    fn try_peek_empty(&mut self) -> bool {
        let inner = &*self.inner;
        let head = inner.head.load(Ordering::Relaxed);
        self.tail_cache = inner.tail.load(Ordering::Acquire);
        head == self.tail_cache
    }

    /// ORDERING: Relaxed on both indices — monitoring snapshot only,
    /// synchronizes nothing (same contract as `Producer::len`).
    pub fn len(&self) -> usize {
        let t = self.inner.tail.load(Ordering::Relaxed);
        let h = self.inner.head.load(Ordering::Relaxed);
        t.wrapping_sub(h)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        // ORDERING: Release pairs with the producer's Acquire `closed`
        // loads (same latch as `Producer::close`).
        self.inner.closed.store(true, Ordering::Release);
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.close();
        // Drain remaining initialized elements so they are dropped.
        while self.try_pop().is_some() {}
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Under Miri the threaded stress tests run on an interpreter ~3
    // orders of magnitude slower than native; a few hundred elements
    // still cross every wrap-around and cached-index refresh path.
    #[cfg(miri)]
    const STRESS_N: u64 = 300;
    #[cfg(not(miri))]
    const STRESS_N: u64 = 200_000;

    #[test]
    fn push_pop_roundtrip() {
        let (mut p, mut c) = spsc::<u32>(8);
        for i in 0..8 {
            p.try_push(i).unwrap();
        }
        assert!(matches!(p.try_push(99), Err(PushError::Full(99))));
        for i in 0..8 {
            assert_eq!(c.try_pop(), Some(i));
        }
        assert_eq!(c.try_pop(), None);
    }

    #[test]
    fn capacity_rounds_up() {
        let (p, _c) = spsc::<u8>(5);
        assert_eq!(p.capacity(), 8);
    }

    #[test]
    fn close_signals_consumer() {
        let (mut p, mut c) = spsc::<u32>(4);
        p.try_push(1).unwrap();
        p.close();
        assert!(!c.is_done()); // still has an element
        assert_eq!(c.try_pop(), Some(1));
        assert!(c.is_done());
    }

    #[test]
    fn push_after_close_fails() {
        let (mut p, c) = spsc::<u32>(4);
        c.close();
        assert!(matches!(p.try_push(1), Err(PushError::Closed(1))));
    }

    #[test]
    fn wraps_around() {
        let (mut p, mut c) = spsc::<u64>(4);
        for round in 0..100u64 {
            for i in 0..3 {
                p.try_push(round * 3 + i).unwrap();
            }
            for i in 0..3 {
                assert_eq!(c.try_pop(), Some(round * 3 + i));
            }
        }
    }

    #[test]
    fn concurrent_fifo_order() {
        let (mut p, mut c) = spsc::<u64>(64);
        let n = STRESS_N;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                assert!(p.push_blocking(i));
            }
        });
        let mut expected = 0u64;
        let mut backoff = crate::util::backoff::Backoff::active();
        while expected < n {
            match c.try_pop() {
                Some(v) => {
                    assert_eq!(v, expected);
                    expected += 1;
                    backoff.reset();
                }
                None => backoff.snooze(),
            }
        }
        producer.join().unwrap();
    }

    #[test]
    fn push_slice_pop_chunk_roundtrip() {
        let (mut p, mut c) = spsc::<u32>(8);
        let mut items: Vec<u32> = (0..12).collect();
        // only 8 fit; the pushed prefix is drained off `items`
        assert_eq!(p.push_slice(&mut items, usize::MAX), 8);
        assert_eq!(items, vec![8, 9, 10, 11]);
        assert_eq!(p.push_slice(&mut items, usize::MAX), 0); // full
        let mut out = Vec::new();
        assert_eq!(c.pop_chunk(&mut out, 5), 5);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        // freed space admits the remainder
        assert_eq!(p.push_slice(&mut items, usize::MAX), 4);
        assert!(items.is_empty());
        // the consumer's cached tail refreshes lazily: drain in chunks
        let mut got = 0;
        loop {
            let k = c.pop_chunk(&mut out, usize::MAX);
            if k == 0 {
                break;
            }
            got += k;
        }
        assert_eq!(got, 7);
        assert_eq!(out, (0..12).collect::<Vec<u32>>());
    }

    #[test]
    fn push_slice_respects_max_and_close() {
        let (mut p, mut c) = spsc::<u32>(8);
        let mut items: Vec<u32> = (0..6).collect();
        assert_eq!(p.push_slice(&mut items, 2), 2);
        assert_eq!(p.free(), 6);
        c.close();
        assert_eq!(p.push_slice(&mut items, usize::MAX), 0);
        assert!(p.is_closed());
        assert_eq!(items.len(), 4);
    }

    #[test]
    fn batched_concurrent_fifo_order() {
        let (mut p, mut c) = spsc::<u64>(64);
        let n = STRESS_N;
        let producer = std::thread::spawn(move || {
            let mut pending: Vec<u64> = Vec::new();
            let mut next = 0u64;
            let mut backoff = crate::util::backoff::Backoff::active();
            while next < n || !pending.is_empty() {
                while pending.len() < 17 && next < n {
                    pending.push(next);
                    next += 1;
                }
                if p.push_slice(&mut pending, usize::MAX) == 0 {
                    backoff.snooze();
                } else {
                    backoff.reset();
                }
            }
        });
        let mut expected = 0u64;
        let mut buf = Vec::new();
        let mut backoff = crate::util::backoff::Backoff::active();
        while expected < n {
            buf.clear();
            if c.pop_chunk(&mut buf, 23) == 0 {
                backoff.snooze();
                continue;
            }
            backoff.reset();
            for &v in &buf {
                assert_eq!(v, expected);
                expected += 1;
            }
        }
        producer.join().unwrap();
    }

    #[test]
    fn drops_remaining_elements() {
        // Arc payload lets us observe drops.
        let marker = Arc::new(());
        let (mut p, c) = spsc::<Arc<()>>(8);
        for _ in 0..5 {
            p.try_push(marker.clone()).unwrap();
        }
        assert_eq!(Arc::strong_count(&marker), 6);
        drop(c);
        drop(p);
        assert_eq!(Arc::strong_count(&marker), 1);
    }
}
