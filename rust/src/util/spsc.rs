//! Bounded SPSC / MPSC queues used by the shared-nothing (SN) baseline.
//!
//! §2.2: with SN parallelism each pair of connected instances exchanges
//! tuples over a *dedicated* queue. The SN baseline engine therefore pays
//! one enqueue per (tuple, downstream-responsible-instance) pair — the data
//! duplication overhead of Theorem 1 — whereas the VSN engine shares one
//! ESG among all instances.
//!
//! The queue is a classic ring buffer with cached head/tail indices
//! (Lamport queue with the producer/consumer caching optimization).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

struct Inner<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    cap: usize,
    head: AtomicUsize, // next slot to pop
    tail: AtomicUsize, // next slot to push
    closed: AtomicBool,
}

unsafe impl<T: Send> Sync for Inner<T> {}
unsafe impl<T: Send> Send for Inner<T> {}

/// Producer handle (single producer).
pub struct Producer<T> {
    inner: Arc<Inner<T>>,
    head_cache: usize,
}

/// Consumer handle (single consumer).
pub struct Consumer<T> {
    inner: Arc<Inner<T>>,
    tail_cache: usize,
}

/// Error returned when pushing to a full or closed queue.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue full: backpressure — caller should retry (flow control).
    Full(T),
    /// Consumer dropped / channel closed.
    Closed(T),
}

/// Create a bounded SPSC queue with capacity `cap` (rounded up to a power
/// of two).
pub fn spsc<T>(cap: usize) -> (Producer<T>, Consumer<T>) {
    let cap = cap.max(2).next_power_of_two();
    let buf: Vec<UnsafeCell<MaybeUninit<T>>> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(Inner {
        buf: buf.into_boxed_slice(),
        cap,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
    });
    (
        Producer { inner: inner.clone(), head_cache: 0 },
        Consumer { inner, tail_cache: 0 },
    )
}

impl<T> Producer<T> {
    /// Attempt to push; `Err(Full)` signals backpressure.
    pub fn try_push(&mut self, v: T) -> Result<(), PushError<T>> {
        let inner = &*self.inner;
        if inner.closed.load(Ordering::Acquire) {
            return Err(PushError::Closed(v));
        }
        let tail = inner.tail.load(Ordering::Relaxed);
        if tail.wrapping_sub(self.head_cache) >= inner.cap {
            self.head_cache = inner.head.load(Ordering::Acquire);
            if tail.wrapping_sub(self.head_cache) >= inner.cap {
                return Err(PushError::Full(v));
            }
        }
        unsafe {
            (*inner.buf[tail & (inner.cap - 1)].get()).write(v);
        }
        inner.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Blocking push with spinning/yielding (used by generators that must
    /// respect backpressure). Returns `false` if the queue closed.
    pub fn push_blocking(&mut self, mut v: T) -> bool {
        let mut backoff = crate::util::backoff::Backoff::active();
        loop {
            match self.try_push(v) {
                Ok(()) => return true,
                Err(PushError::Closed(_)) => return false,
                Err(PushError::Full(back)) => {
                    v = back;
                    backoff.snooze();
                }
            }
        }
    }

    /// Number of elements currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        let t = self.inner.tail.load(Ordering::Relaxed);
        let h = self.inner.head.load(Ordering::Relaxed);
        t.wrapping_sub(h)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.cap
    }

    /// Close the channel: consumer will drain remaining items then see None.
    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
    }
}

impl<T> Consumer<T> {
    /// Attempt to pop. `None` means currently empty (check `is_closed`).
    pub fn try_pop(&mut self) -> Option<T> {
        let inner = &*self.inner;
        let head = inner.head.load(Ordering::Relaxed);
        if head == self.tail_cache {
            self.tail_cache = inner.tail.load(Ordering::Acquire);
            if head == self.tail_cache {
                return None;
            }
        }
        let v = unsafe { (*inner.buf[head & (inner.cap - 1)].get()).assume_init_read() };
        inner.head.store(head.wrapping_add(1), Ordering::Release);
        Some(v)
    }

    /// True when producer closed AND the queue is drained.
    pub fn is_done(&mut self) -> bool {
        self.inner.closed.load(Ordering::Acquire) && self.try_peek_empty()
    }

    fn try_peek_empty(&mut self) -> bool {
        let inner = &*self.inner;
        let head = inner.head.load(Ordering::Relaxed);
        self.tail_cache = inner.tail.load(Ordering::Acquire);
        head == self.tail_cache
    }

    pub fn len(&self) -> usize {
        let t = self.inner.tail.load(Ordering::Relaxed);
        let h = self.inner.head.load(Ordering::Relaxed);
        t.wrapping_sub(h)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::Release);
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.close();
        // Drain remaining initialized elements so they are dropped.
        while self.try_pop().is_some() {}
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_roundtrip() {
        let (mut p, mut c) = spsc::<u32>(8);
        for i in 0..8 {
            p.try_push(i).unwrap();
        }
        assert!(matches!(p.try_push(99), Err(PushError::Full(99))));
        for i in 0..8 {
            assert_eq!(c.try_pop(), Some(i));
        }
        assert_eq!(c.try_pop(), None);
    }

    #[test]
    fn capacity_rounds_up() {
        let (p, _c) = spsc::<u8>(5);
        assert_eq!(p.capacity(), 8);
    }

    #[test]
    fn close_signals_consumer() {
        let (mut p, mut c) = spsc::<u32>(4);
        p.try_push(1).unwrap();
        p.close();
        assert!(!c.is_done()); // still has an element
        assert_eq!(c.try_pop(), Some(1));
        assert!(c.is_done());
    }

    #[test]
    fn push_after_close_fails() {
        let (mut p, c) = spsc::<u32>(4);
        c.close();
        assert!(matches!(p.try_push(1), Err(PushError::Closed(1))));
    }

    #[test]
    fn wraps_around() {
        let (mut p, mut c) = spsc::<u64>(4);
        for round in 0..100u64 {
            for i in 0..3 {
                p.try_push(round * 3 + i).unwrap();
            }
            for i in 0..3 {
                assert_eq!(c.try_pop(), Some(round * 3 + i));
            }
        }
    }

    #[test]
    fn concurrent_fifo_order() {
        let (mut p, mut c) = spsc::<u64>(64);
        let n = 200_000u64;
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                assert!(p.push_blocking(i));
            }
        });
        let mut expected = 0u64;
        let mut backoff = crate::util::backoff::Backoff::active();
        while expected < n {
            match c.try_pop() {
                Some(v) => {
                    assert_eq!(v, expected);
                    expected += 1;
                    backoff.reset();
                }
                None => backoff.snooze(),
            }
        }
        producer.join().unwrap();
    }

    #[test]
    fn drops_remaining_elements() {
        // Arc payload lets us observe drops.
        let marker = Arc::new(());
        let (mut p, c) = spsc::<Arc<()>>(8);
        for _ in 0..5 {
            p.try_push(marker.clone()).unwrap();
        }
        assert_eq!(Arc::strong_count(&marker), 6);
        drop(c);
        drop(p);
        assert_eq!(Arc::strong_count(&marker), 1);
    }
}
