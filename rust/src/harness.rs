//! Experiment harness: drive a live VSN ScaleJoin under a rate schedule
//! with a controller in the loop, sampling the §8 metrics once per tick.
//!
//! Used by the Q4-Q6 benches and the `elastic_scalejoin`/`e2e_pipeline`
//! examples. Wall-clock pacing is compressible (`time_scale`) so the
//! paper's 20-minute runs replay in seconds; event time always advances
//! at the schedule's nominal pace.

use crate::elastic::{Controller, Decision, Observation};
use crate::engine::{EgressDriver, VsnEngine, VsnOptions};
use crate::metrics::MetricsSnapshot;
use crate::time::EventTime;
use crate::tuple::{Mapper, Tuple};
use crate::workloads::rates::RateSchedule;
use crate::workloads::scalejoin_bench::{q3_operator, SjGen, SjPayload};
use std::time::{Duration, Instant};

/// Harness configuration.
pub struct JoinRunConfig {
    /// ScaleJoin window size (event-time ms).
    pub ws_ms: EventTime,
    /// Round-robin key count (paper: 1000).
    pub n_keys: u64,
    /// Initial / maximum parallelism (m, n).
    pub initial: usize,
    pub max: usize,
    /// The offered-rate schedule (event-time seconds).
    pub schedule: RateSchedule,
    /// Wall-time compression: 10.0 replays 10 event-seconds per wall-second.
    pub time_scale: f64,
    /// Optional elasticity controller.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    pub seed: u64,
    pub gate_capacity: usize,
    /// Scripted reconfigurations: (event second, new instance set) —
    /// issued directly, bypassing the controller (Q4 protocol timing).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for JoinRunConfig {
    fn default() -> Self {
        JoinRunConfig {
            ws_ms: 5_000,
            n_keys: 64,
            initial: 1,
            max: 4,
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            controller: None,
            controller_period_s: 1,
            seed: 7,
            gate_capacity: 1 << 13,
            manual_reconfigs: Vec::new(),
        }
    }
}

/// One per-event-second sample of the run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunSample {
    pub t_s: u32,
    pub offered_tps: f64,
    pub in_tps: f64,
    pub out_tps: f64,
    pub cmp_per_s: f64,
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
    pub threads: usize,
    pub backlog: u64,
    pub load_cv_pct: f64,
}

/// Result of a harness run.
pub struct RunResult {
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times.
    pub reconfigs: Vec<(u64, f64)>,
    /// Total data tuples drained at the egress.
    pub egress_count: u64,
}

/// Run a live, threaded VSN ScaleJoin experiment.
pub fn run_elastic_join(mut cfg: JoinRunConfig) -> RunResult {
    let def = q3_operator(cfg.ws_ms, cfg.n_keys);
    let (mut engine, mut ingress, mut readers) = VsnEngine::setup(
        def,
        VsnOptions {
            initial: cfg.initial,
            max: cfg.max,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: cfg.gate_capacity,
            ..Default::default()
        },
    );
    let control = engine.control.clone();
    let clock = engine.clock.clone();
    let metrics = engine.metrics.clone();
    let mut ing = ingress.remove(0);
    let mut egress = EgressDriver::new(readers.remove(0), clock.clone());
    let mut gen = SjGen::new(cfg.seed, 1.0);

    let duration_s = cfg.schedule.duration_s();
    let mut samples = Vec::with_capacity(duration_s as usize);
    let mut last_snap = MetricsSnapshot::default();
    let mut pending_event_tuples = 0.0f64;
    let mut event_ms_total: f64 = 0.0;
    let t0 = Instant::now();

    // wall tick: 20 ms of *wall* time per loop iteration
    let wall_tick = Duration::from_millis(20);
    let mut next_tick = t0;
    let mut next_sample_s: u32 = 1;
    let mut next_controller_s: u32 = cfg.controller_period_s;
    let mut manual = cfg.manual_reconfigs.clone();
    manual.sort_by_key(|&(at, _)| at);
    let mut next_manual = 0usize;
    let mut prev_loads: Vec<u64> = vec![0; cfg.max];

    loop {
        // how far event time should have progressed
        let wall_s = t0.elapsed().as_secs_f64();
        let event_s = wall_s * cfg.time_scale;
        // run slightly past the end so the final per-second sample lands
        if event_s >= duration_s as f64 + 0.1 {
            break;
        }
        let cur_rate = cfg.schedule.rate_at(event_s as u32);
        if event_s < duration_s as f64 {
            gen.set_rate(cur_rate);
            // feed the tuples that belong to this tick
            let tick_event_s = wall_tick.as_secs_f64() * cfg.time_scale;
            pending_event_tuples += cur_rate * tick_event_s;
            let n = pending_event_tuples.floor() as usize;
            pending_event_tuples -= n as f64;
            event_ms_total += tick_event_s * 1e3;
            for _ in 0..n {
                let mut t: Tuple<SjPayload> = gen.next();
                t.ingest_us = clock.now_us();
                ing.add(t);
            }
        }
        egress.poll();

        // per-event-second sampling
        while (next_sample_s as f64) <= event_s && next_sample_s <= duration_s {
            let snap = metrics.snapshot();
            let dt = 1.0 / cfg.time_scale; // wall seconds per event second
            let rates = snap.rates_since(&last_snap, dt);
            let epoch_cfg = engine.epoch_config();
            let active: Vec<usize> = epoch_cfg.instances.as_ref().clone();
            // per-interval load CV (Fig. 9 right): deltas, active set only
            let cv = {
                let deltas: Vec<f64> = active
                    .iter()
                    .map(|&i| {
                        let cur = metrics.instance_load(i);
                        let d = cur - prev_loads[i];
                        d as f64
                    })
                    .collect();
                for i in 0..cfg.max {
                    prev_loads[i] = metrics.instance_load(i);
                }
                let mean = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
                if deltas.len() < 2 || mean <= 0.0 {
                    0.0
                } else {
                    let var = deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
                        / deltas.len() as f64;
                    100.0 * var.sqrt() / mean
                }
            };
            samples.push(RunSample {
                t_s: next_sample_s,
                offered_tps: cfg.schedule.rate_at(next_sample_s - 1),
                // rates are per wall second; report per *event* second
                in_tps: rates.in_tps / cfg.time_scale / active.len().max(1) as f64,
                out_tps: rates.out_tps / cfg.time_scale,
                cmp_per_s: rates.cmp_per_s / cfg.time_scale,
                latency_p50_us: egress.latency_us.p50(),
                latency_mean_us: egress.latency_us.mean(),
                threads: active.len(),
                backlog: engine.esg_in.backlog(),
                load_cv_pct: cv,
            });
            last_snap = snap;
            egress.latency_us.reset();
            next_sample_s += 1;
        }

        // scripted reconfigurations (bypass the controller)
        while next_manual < manual.len() && (manual[next_manual].0 as f64) <= event_s {
            let set = manual[next_manual].1.clone();
            control.reconfigure(set.clone(), Mapper::over(set));
            next_manual += 1;
        }
        // controller tick
        if let Some(ctl) = cfg.controller.as_mut() {
            if (next_controller_s as f64) <= event_s {
                next_controller_s += cfg.controller_period_s;
                let epoch_cfg = engine.epoch_config();
                let active: Vec<usize> = epoch_cfg.instances.as_ref().clone();
                let obs = Observation {
                    in_rate: cur_rate,
                    cmp_per_s: samples.last().map(|s| s.cmp_per_s).unwrap_or(0.0),
                    backlog: engine.esg_in.backlog(),
                    dt: cfg.controller_period_s as f64,
                    active,
                    max: cfg.max,
                };
                if let Decision::Reconfigure(set) = ctl.tick(&obs) {
                    let mapper = Mapper::over(set.clone());
                    control.reconfigure(set, mapper);
                }
            }
        }

        next_tick += wall_tick;
        let now = Instant::now();
        if next_tick > now {
            std::thread::sleep(next_tick - now);
        } else {
            next_tick = now; // fell behind: don't try to catch up the wall
        }
    }

    // flush: end-of-stream heartbeat, drain remaining outputs briefly
    ing.heartbeat(event_ms_total as EventTime + cfg.ws_ms + 10_000);
    let drain_until = Instant::now() + Duration::from_millis(500);
    while Instant::now() < drain_until {
        if egress.poll() == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let reconfigs = control.completion_times();
    let egress_count = egress.count;
    engine.shutdown();
    RunResult { samples, reconfigs, egress_count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::{JoinCostModel, ReactiveController, Thresholds};

    #[test]
    fn harness_steady_run_produces_samples() {
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::constant(4, 500.0),
            time_scale: 4.0, // 4 event-seconds in ~1 wall-second
            initial: 2,
            max: 4,
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert_eq!(r.samples.len(), 4);
        assert!(r.egress_count > 0 || r.samples.iter().any(|s| s.cmp_per_s > 0.0));
        assert!(r.samples.iter().all(|s| s.threads == 2));
    }

    #[test]
    fn harness_controller_provisions_under_ramp() {
        // calibrate a model, then drive well past 1-thread capacity
        let model = JoinCostModel::new(5e5, 1.0); // deliberately small capacity
        let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(1);
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::step(6, 2, 200.0, 1500.0),
            time_scale: 3.0,
            initial: 1,
            max: 4,
            controller: Some(Box::new(ctl)),
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert!(!r.reconfigs.is_empty(), "controller should have reconfigured");
        assert!(r.samples.last().unwrap().threads > 1);
    }
}
