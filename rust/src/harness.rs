//! Experiment harness: drive a live VSN *pipeline* under a rate schedule
//! with per-stage controllers in the loop, sampling the §8 metrics once
//! per event second **per stage**.
//!
//! [`run_pipeline`] is the generic loop: it feeds a [`PacedSource`] into
//! stage 0, drains the last stage's egress, and per tick gives every
//! stage its scripted reconfigurations and controller decisions
//! independently. [`run_elastic_join`] — the Q3-Q6 entry point — is a
//! thin compatibility wrapper that builds a single-stage ScaleJoin
//! pipeline and reshapes the result.
//!
//! Wall-clock pacing is compressible (`time_scale`) so the paper's
//! 20-minute runs replay in seconds; event time always advances at the
//! schedule's nominal pace.

use crate::elastic::{Controller, Decision, Observation};
use crate::engine::pipeline::{Pipeline, PipelineBuilder};
use crate::engine::{EgressDriver, VsnOptions};
use crate::metrics::MetricsSnapshot;
use crate::time::EventTime;
use crate::tuple::{Mapper, Payload, Tuple};
use crate::workloads::nyse::{Trade, TradeStream};
use crate::workloads::rates::RateSchedule;
use crate::workloads::scalejoin_bench::{q3_operator, SjGen, SjPayload};
use crate::workloads::tweets::{Tweet, TweetGen};
use std::time::{Duration, Instant};

/// A generator the harness can pace against a [`RateSchedule`]: emits
/// ts-sorted tuples whose event time advances at ~`1000 / rate` ms each.
pub trait PacedSource<P>: Send {
    /// Adjust the nominal rate (tuples per event-second).
    fn set_rate(&mut self, _tps: f64) {}
    /// Next tuple (event time must not regress).
    fn next(&mut self) -> Tuple<P>;
}

impl PacedSource<SjPayload> for SjGen {
    fn set_rate(&mut self, tps: f64) {
        SjGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<SjPayload> {
        SjGen::next(self)
    }
}

impl PacedSource<Tweet> for TweetGen {
    fn set_rate(&mut self, tps: f64) {
        TweetGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Tweet> {
        TweetGen::next(self)
    }
}

impl PacedSource<Trade> for TradeStream {
    fn set_rate(&mut self, tps: f64) {
        TradeStream::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Trade> {
        TradeStream::next(self)
    }
}

/// Harness configuration (the Q3-Q6 single-stage ScaleJoin shape).
pub struct JoinRunConfig {
    /// ScaleJoin window size (event-time ms).
    pub ws_ms: EventTime,
    /// Round-robin key count (paper: 1000).
    pub n_keys: u64,
    /// Initial / maximum parallelism (m, n).
    pub initial: usize,
    pub max: usize,
    /// The offered-rate schedule (event-time seconds).
    pub schedule: RateSchedule,
    /// Wall-time compression: 10.0 replays 10 event-seconds per wall-second.
    pub time_scale: f64,
    /// Optional elasticity controller.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    pub seed: u64,
    pub gate_capacity: usize,
    /// Worker gate synchronization granularity (tuples per
    /// `get_batch`/`add_batch`) — the `[batch] worker` config knob.
    pub worker_batch: usize,
    /// Max run length per batched ingress add — the `[batch] ingress`
    /// config knob.
    pub ingress_batch: usize,
    /// Scripted reconfigurations: (event second, new instance set) —
    /// issued directly, bypassing the controller (Q4 protocol timing).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for JoinRunConfig {
    fn default() -> Self {
        JoinRunConfig {
            ws_ms: 5_000,
            n_keys: 64,
            initial: 1,
            max: 4,
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            controller: None,
            controller_period_s: 1,
            seed: 7,
            gate_capacity: 1 << 13,
            worker_batch: crate::engine::WORKER_BATCH,
            ingress_batch: 256,
            manual_reconfigs: Vec::new(),
        }
    }
}

/// One per-event-second sample of one stage.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunSample {
    pub t_s: u32,
    pub offered_tps: f64,
    pub in_tps: f64,
    pub out_tps: f64,
    pub cmp_per_s: f64,
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
    pub threads: usize,
    pub backlog: u64,
    pub load_cv_pct: f64,
}

/// Result of a single-stage harness run (the historical shape).
pub struct RunResult {
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times.
    pub reconfigs: Vec<(u64, f64)>,
    /// Total data tuples drained at the egress.
    pub egress_count: u64,
}

/// Per-stage runtime policy for a pipeline run.
pub struct StageRunConfig {
    /// Optional elasticity controller for this stage.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    /// Scripted reconfigurations: (event second, new instance set).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for StageRunConfig {
    fn default() -> Self {
        StageRunConfig { controller: None, controller_period_s: 1, manual_reconfigs: Vec::new() }
    }
}

/// Pipeline harness configuration.
pub struct PipelineRunConfig {
    pub schedule: RateSchedule,
    pub time_scale: f64,
    /// One entry per stage (missing trailing entries default).
    pub stages: Vec<StageRunConfig>,
    /// End-of-stream heartbeat horizon beyond the last event ms (flush
    /// windows; use ≥ the largest WS in the pipeline).
    pub flush_slack_ms: EventTime,
    /// Wall time to keep draining the egress after end-of-stream.
    pub drain: Duration,
    /// Max run length handed to the ingress per batched add — the
    /// `[batch] ingress` config knob (bounds gate burstiness).
    pub ingress_batch: usize,
}

impl Default for PipelineRunConfig {
    fn default() -> Self {
        PipelineRunConfig {
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            stages: Vec::new(),
            flush_slack_ms: 15_000,
            drain: Duration::from_millis(500),
            ingress_batch: 256,
        }
    }
}

/// Per-stage outcome of a pipeline run.
pub struct StageRunStats {
    pub name: &'static str,
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times of this stage.
    pub reconfigs: Vec<(u64, f64)>,
}

/// Result of a pipeline run.
pub struct PipelineRunResult {
    pub stages: Vec<StageRunStats>,
    /// Data tuples drained at the final egress.
    pub egress_count: u64,
    /// Whole-run end-to-end latency (ingest stamp at stage 0 → final
    /// egress) over every stamped output tuple.
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
}

/// Book-keeping the run loop carries per stage.
struct StageLoopState {
    cfg: StageRunConfig,
    last_snap: MetricsSnapshot,
    prev_loads: Vec<u64>,
    next_manual: usize,
    next_controller_s: u32,
    /// Arrival rate (t/event-s, de-duplicated across instances) of the
    /// latest sample — the controller's offered-load estimate for
    /// non-source stages.
    last_arrival_tps: f64,
    samples: Vec<RunSample>,
}

/// Drive a live, threaded VSN pipeline: pace `source` through stage 0
/// according to the schedule, drain the final egress, tick every stage's
/// manual/controller reconfigurations independently, and sample per-stage
/// metrics once per event second.
pub fn run_pipeline<In, Out>(
    mut pipeline: Pipeline<In, Out>,
    cfg: PipelineRunConfig,
    source: &mut dyn PacedSource<In>,
) -> PipelineRunResult
where
    In: Payload + Default,
    Out: Payload + Default,
{
    // A dropped-but-active ESG source would gate readiness forever, so
    // the loop only supports the single-upstream shape (upstreams = 1);
    // likewise a dropped-but-active egress reader would pin the final
    // gate's backlog at capacity and stall the last stage.
    assert_eq!(pipeline.ingress.len(), 1, "run_pipeline drives exactly one ingress source");
    assert_eq!(pipeline.egress.len(), 1, "run_pipeline drains exactly one egress reader");
    let clock = pipeline.clock.clone();
    let mut ing = pipeline.ingress.remove(0);
    let mut egress = EgressDriver::new(pipeline.egress.remove(0), clock.clone());

    let n_stages = pipeline.depth();
    assert!(
        cfg.stages.len() <= n_stages,
        "{} stage configs for a {}-stage pipeline — scripted reconfigs would be dropped",
        cfg.stages.len(),
        n_stages
    );
    let mut stage_cfgs: Vec<StageRunConfig> = cfg.stages.into_iter().collect();
    while stage_cfgs.len() < n_stages {
        stage_cfgs.push(StageRunConfig::default());
    }
    let mut loops: Vec<StageLoopState> = stage_cfgs
        .into_iter()
        .take(n_stages)
        .enumerate()
        .map(|(k, mut sc)| {
            sc.manual_reconfigs.sort_by_key(|&(at, _)| at);
            let period = sc.controller_period_s.max(1);
            StageLoopState {
                last_snap: MetricsSnapshot::default(),
                prev_loads: vec![0; pipeline.stages[k].max_parallelism()],
                next_manual: 0,
                next_controller_s: period,
                last_arrival_tps: 0.0,
                samples: Vec::new(),
                cfg: sc,
            }
        })
        .collect();

    let duration_s = cfg.schedule.duration_s();
    let mut pending_event_tuples = 0.0f64;
    let mut event_ms_total: f64 = 0.0;
    // per-tick feed run, handed to the gate via one batched add (§Perf)
    let mut feed_buf: Vec<Tuple<In>> = Vec::new();
    let t0 = Instant::now();

    // wall tick: 20 ms of *wall* time per loop iteration
    let wall_tick = Duration::from_millis(20);
    let mut next_tick = t0;
    let mut next_sample_s: u32 = 1;

    loop {
        // how far event time should have progressed
        let wall_s = t0.elapsed().as_secs_f64();
        let event_s = wall_s * cfg.time_scale;
        // run slightly past the end so the final per-second sample lands
        if event_s >= duration_s as f64 + 0.1 {
            break;
        }
        let cur_rate = cfg.schedule.rate_at(event_s as u32);
        if event_s < duration_s as f64 {
            source.set_rate(cur_rate);
            // feed the tuples that belong to this tick
            let tick_event_s = wall_tick.as_secs_f64() * cfg.time_scale;
            pending_event_tuples += cur_rate * tick_event_s;
            let n = pending_event_tuples.floor() as usize;
            pending_event_tuples -= n as f64;
            event_ms_total += tick_event_s * 1e3;
            debug_assert!(feed_buf.is_empty());
            let ingress_batch = cfg.ingress_batch.max(1);
            for _ in 0..n {
                let mut t = source.next();
                t.ingest_us = clock.now_us();
                feed_buf.push(t);
                if feed_buf.len() >= ingress_batch {
                    ing.add_batch(&mut feed_buf);
                }
            }
            ing.add_batch(&mut feed_buf);
        }
        egress.poll();

        // per-event-second sampling, every stage
        while (next_sample_s as f64) <= event_s && next_sample_s <= duration_s {
            for (k, st) in loops.iter_mut().enumerate() {
                let stage = &pipeline.stages[k];
                let metrics = stage.metrics();
                let snap = metrics.snapshot();
                let dt = 1.0 / cfg.time_scale; // wall seconds per event second
                let rates = snap.rates_since(&st.last_snap, dt);
                let active = stage.active_instances();
                // per-interval load CV (Fig. 9 right): deltas, active set only
                let cv = {
                    let deltas: Vec<f64> = active
                        .iter()
                        .map(|&i| (metrics.instance_load(i) - st.prev_loads[i]) as f64)
                        .collect();
                    for (i, p) in st.prev_loads.iter_mut().enumerate() {
                        *p = metrics.instance_load(i);
                    }
                    let mean = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
                    if deltas.len() < 2 || mean <= 0.0 {
                        0.0
                    } else {
                        let var = deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
                            / deltas.len() as f64;
                        100.0 * var.sqrt() / mean
                    }
                };
                // Every active instance reads (and counts) every gate
                // tuple, so the summed rate is m× the true arrival rate;
                // dividing by the active count recovers arrivals.
                let arrival_tps =
                    rates.in_tps / cfg.time_scale / active.len().max(1) as f64;
                st.last_arrival_tps = arrival_tps;
                st.samples.push(RunSample {
                    t_s: next_sample_s,
                    // stage 0 is offered the schedule; downstream stages
                    // are offered whatever their upstream emits
                    offered_tps: if k == 0 {
                        cfg.schedule.rate_at(next_sample_s - 1)
                    } else {
                        arrival_tps
                    },
                    // rates are per wall second; report per *event* second
                    in_tps: arrival_tps,
                    out_tps: rates.out_tps / cfg.time_scale,
                    cmp_per_s: rates.cmp_per_s / cfg.time_scale,
                    latency_p50_us: egress.latency_us.p50(),
                    latency_mean_us: egress.latency_us.mean(),
                    threads: active.len(),
                    backlog: stage.in_backlog(),
                    load_cv_pct: cv,
                });
                st.last_snap = snap;
            }
            // end-to-end latency is a property of the whole pipeline; the
            // per-second histogram resets once all stages sampled it
            egress.latency_us.reset();
            next_sample_s += 1;
        }

        // per-stage scripted reconfigurations (bypass the controllers)
        for (k, st) in loops.iter_mut().enumerate() {
            while st.next_manual < st.cfg.manual_reconfigs.len()
                && (st.cfg.manual_reconfigs[st.next_manual].0 as f64) <= event_s
            {
                let set = st.cfg.manual_reconfigs[st.next_manual].1.clone();
                pipeline.stages[k].reconfigure(set.clone(), Mapper::over(set));
                st.next_manual += 1;
            }
        }
        // per-stage controller ticks
        for (k, st) in loops.iter_mut().enumerate() {
            let period = st.cfg.controller_period_s.max(1);
            if let Some(ctl) = st.cfg.controller.as_mut() {
                if (st.next_controller_s as f64) <= event_s {
                    st.next_controller_s += period;
                    let stage = &mut pipeline.stages[k];
                    let active = stage.active_instances();
                    let obs = Observation {
                        in_rate: if k == 0 { cur_rate } else { st.last_arrival_tps },
                        cmp_per_s: st.samples.last().map(|s| s.cmp_per_s).unwrap_or(0.0),
                        backlog: stage.in_backlog(),
                        dt: period as f64,
                        active,
                        max: stage.max_parallelism(),
                    };
                    if let Decision::Reconfigure(set) = ctl.tick(&obs) {
                        let mapper = Mapper::over(set.clone());
                        stage.reconfigure(set, mapper);
                    }
                }
            }
        }

        next_tick += wall_tick;
        let now = Instant::now();
        if next_tick > now {
            std::thread::sleep(next_tick - now);
        } else {
            next_tick = now; // fell behind: don't try to catch up the wall
        }
    }

    // flush: end-of-stream heartbeat (workers forward it stage to stage),
    // then drain remaining outputs briefly
    ing.heartbeat(event_ms_total as EventTime + cfg.flush_slack_ms);
    let drain_until = Instant::now() + cfg.drain;
    while Instant::now() < drain_until {
        if egress.poll() == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let latency_p50_us = egress.latency_total_us.p50();
    let latency_mean_us = egress.latency_total_us.mean();
    let egress_count = egress.count;
    let stages = loops
        .into_iter()
        .enumerate()
        .map(|(k, st)| StageRunStats {
            name: pipeline.stages[k].name(),
            samples: st.samples,
            reconfigs: pipeline.stages[k].completion_times(),
        })
        .collect();
    pipeline.shutdown();
    PipelineRunResult { stages, egress_count, latency_p50_us, latency_mean_us }
}

/// Run a live, threaded VSN ScaleJoin experiment — the Q3-Q6 entry point,
/// now a thin wrapper over [`run_pipeline`] with a single-stage pipeline.
pub fn run_elastic_join(cfg: JoinRunConfig) -> RunResult {
    let def = q3_operator(cfg.ws_ms, cfg.n_keys);
    let pipeline = PipelineBuilder::new(
        def,
        VsnOptions {
            initial: cfg.initial,
            max: cfg.max,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: cfg.gate_capacity,
            worker_batch: cfg.worker_batch.max(1),
            ..Default::default()
        },
    )
    .build();
    let mut gen = SjGen::new(cfg.seed, 1.0);
    let pcfg = PipelineRunConfig {
        schedule: cfg.schedule,
        time_scale: cfg.time_scale,
        stages: vec![StageRunConfig {
            controller: cfg.controller,
            controller_period_s: cfg.controller_period_s,
            manual_reconfigs: cfg.manual_reconfigs,
        }],
        flush_slack_ms: cfg.ws_ms + 10_000,
        drain: Duration::from_millis(500),
        ingress_batch: cfg.ingress_batch.max(1),
    };
    let r = run_pipeline(pipeline, pcfg, &mut gen);
    let stage0 = r.stages.into_iter().next().expect("single-stage pipeline");
    RunResult { samples: stage0.samples, reconfigs: stage0.reconfigs, egress_count: r.egress_count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::{JoinCostModel, ReactiveController, Thresholds};
    use crate::workloads::nyse::NyseConfig;
    use crate::workloads::{hedge_join_op, trade_fanout_op};

    #[test]
    fn batch_tuning_reaches_engine_options() {
        let cfg = crate::config::Config::parse("[batch]\nworker = 32\nqueue = 16").unwrap();
        let t = crate::config::BatchTuning::from_config(&cfg);
        let v = VsnOptions::default().with_batch(&t);
        assert_eq!(v.worker_batch, 32);
        let s = crate::engine::SnOptions::default().with_batch(&t);
        assert_eq!(s.batch, 16);
    }

    #[test]
    fn harness_steady_run_produces_samples() {
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::constant(4, 500.0),
            time_scale: 4.0, // 4 event-seconds in ~1 wall-second
            initial: 2,
            max: 4,
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert_eq!(r.samples.len(), 4);
        assert!(r.egress_count > 0 || r.samples.iter().any(|s| s.cmp_per_s > 0.0));
        assert!(r.samples.iter().all(|s| s.threads == 2));
    }

    #[test]
    fn harness_controller_provisions_under_ramp() {
        // calibrate a model, then drive well past 1-thread capacity
        let model = JoinCostModel::new(5e5, 1.0); // deliberately small capacity
        let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(1);
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::step(6, 2, 200.0, 1500.0),
            time_scale: 3.0,
            initial: 1,
            max: 4,
            controller: Some(Box::new(ctl)),
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert!(!r.reconfigs.is_empty(), "controller should have reconfigured");
        assert!(r.samples.last().unwrap().threads > 1);
    }

    #[test]
    fn pipeline_harness_runs_two_stages_with_manual_reconfigs() {
        // NYSE fan-out → hedge join, reconfiguring EACH stage once
        let pipeline = PipelineBuilder::new(
            trade_fanout_op(64),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .stage(
            hedge_join_op(1_000, 32),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .build();
        let mut source = TradeStream::new(&NyseConfig::default(), 400.0);
        let r = run_pipeline(
            pipeline,
            PipelineRunConfig {
                schedule: RateSchedule::constant(4, 400.0),
                time_scale: 4.0,
                stages: vec![
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                ],
                flush_slack_ms: 5_000,
                drain: Duration::from_millis(500),
                ..Default::default()
            },
            &mut source,
        );
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages[0].samples.len(), 4);
        assert_eq!(r.stages[1].samples.len(), 4);
        // both stages completed their independent reconfigurations
        assert_eq!(r.stages[0].reconfigs.len(), 1, "stage 0 reconfig lost");
        assert_eq!(r.stages[1].reconfigs.len(), 1, "stage 1 reconfig lost");
        assert_eq!(r.stages[0].samples.last().unwrap().threads, 2);
        assert_eq!(r.stages[1].samples.last().unwrap().threads, 2);
        // data flowed through the shared gate into stage 2
        assert!(r.stages[1].samples.iter().any(|s| s.in_tps > 0.0));
    }
}
