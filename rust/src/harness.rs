//! Experiment harness: drive a live VSN *topology* (linear pipeline or
//! DAG) under a rate schedule with per-stage controllers in the loop,
//! sampling the §8 metrics once per event second **per stage**.
//!
//! [`run_pipeline`] is the generic loop: it paces a [`PacedSource`]
//! round-robin across every ingress wrapper (N ingress sources), drains
//! every egress reader (M sinks / readers — leaving one undrained would
//! pin its gate's backlog at capacity and stall the upstream stage), and
//! per tick gives every stage its scripted reconfigurations and
//! controller decisions independently; an optional topology-aware
//! [`DagController`] co-schedules all stages against a global core
//! budget. Degenerate topologies (no ingress, no egress) are typed
//! [`HarnessError`]s, not panics. [`run_elastic_join`] — the Q3-Q6 entry
//! point — is a thin compatibility wrapper that builds a single-stage
//! ScaleJoin pipeline and reshapes the result.
//!
//! Wall-clock pacing is compressible (`time_scale`) so the paper's
//! 20-minute runs replay in seconds; event time always advances at the
//! schedule's nominal pace.

use crate::config::{BatchTuning, Config};
use crate::elastic::{
    Controller, DagController, Decision, JoinCostModel, Observation, ProactiveController,
    ReactiveController, Thresholds,
};
use crate::engine::job::{JobError, JobSpec};
use crate::engine::pipeline::{Pipeline, PipelineBuilder};
use crate::engine::{EgressDriver, StretchIngress, VsnOptions};
use crate::metrics::MetricsSnapshot;
use crate::sim::calibrate;
use crate::time::EventTime;
use crate::tuple::{Mapper, Payload, Tuple};
use crate::workloads::nyse::{Trade, TradeStream};
use crate::workloads::rates::RateSchedule;
use crate::workloads::registry::{JobPayload, JobSource};
use crate::workloads::scalejoin_bench::{q3_operator, SjGen, SjPayload};
use crate::workloads::tweets::{Tweet, TweetGen};
use std::fmt;
use std::time::{Duration, Instant};

/// A generator the harness can pace against a [`RateSchedule`]: emits
/// ts-sorted tuples whose event time advances at ~`1000 / rate` ms each.
pub trait PacedSource<P>: Send {
    /// Adjust the nominal rate (tuples per event-second).
    fn set_rate(&mut self, _tps: f64) {}
    /// Next tuple (event time must not regress).
    fn next(&mut self) -> Tuple<P>;
}

impl PacedSource<SjPayload> for SjGen {
    fn set_rate(&mut self, tps: f64) {
        SjGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<SjPayload> {
        SjGen::next(self)
    }
}

impl PacedSource<Tweet> for TweetGen {
    fn set_rate(&mut self, tps: f64) {
        TweetGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Tweet> {
        TweetGen::next(self)
    }
}

impl PacedSource<Trade> for TradeStream {
    fn set_rate(&mut self, tps: f64) {
        TradeStream::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Trade> {
        TradeStream::next(self)
    }
}

impl PacedSource<JobPayload> for JobSource {
    fn set_rate(&mut self, tps: f64) {
        JobSource::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<JobPayload> {
        self.next_tuple()
    }
}

/// Harness configuration (the Q3-Q6 single-stage ScaleJoin shape).
pub struct JoinRunConfig {
    /// ScaleJoin window size (event-time ms).
    pub ws_ms: EventTime,
    /// Round-robin key count (paper: 1000).
    pub n_keys: u64,
    /// Initial / maximum parallelism (m, n).
    pub initial: usize,
    pub max: usize,
    /// The offered-rate schedule (event-time seconds).
    pub schedule: RateSchedule,
    /// Wall-time compression: 10.0 replays 10 event-seconds per wall-second.
    pub time_scale: f64,
    /// Optional elasticity controller.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    pub seed: u64,
    pub gate_capacity: usize,
    /// Worker gate synchronization granularity (tuples per
    /// `get_batch`/`add_batch`) — the `[batch] worker` config knob.
    pub worker_batch: usize,
    /// Max run length per batched ingress add — the `[batch] ingress`
    /// config knob.
    pub ingress_batch: usize,
    /// Scripted reconfigurations: (event second, new instance set) —
    /// issued directly, bypassing the controller (Q4 protocol timing).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for JoinRunConfig {
    fn default() -> Self {
        JoinRunConfig {
            ws_ms: 5_000,
            n_keys: 64,
            initial: 1,
            max: 4,
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            controller: None,
            controller_period_s: 1,
            seed: 7,
            gate_capacity: 1 << 13,
            worker_batch: crate::engine::WORKER_BATCH,
            ingress_batch: 256,
            manual_reconfigs: Vec::new(),
        }
    }
}

/// One per-event-second sample of one stage.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunSample {
    pub t_s: u32,
    pub offered_tps: f64,
    pub in_tps: f64,
    pub out_tps: f64,
    pub cmp_per_s: f64,
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
    pub threads: usize,
    pub backlog: u64,
    pub load_cv_pct: f64,
    /// Effective worker batch of the stage at sample time (moves when
    /// adaptive batch sizing is on).
    pub worker_batch: usize,
}

/// Result of a single-stage harness run (the historical shape).
pub struct RunResult {
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times.
    pub reconfigs: Vec<(u64, f64)>,
    /// Total data tuples drained at the egress.
    pub egress_count: u64,
}

/// Bounds of the adaptive worker-batch policy (the `[batch]`
/// `worker_min`/`worker_max` knobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdaptiveBatch {
    pub min: usize,
    pub max: usize,
}

impl From<&BatchTuning> for AdaptiveBatch {
    fn from(t: &BatchTuning) -> Self {
        AdaptiveBatch { min: t.worker_min, max: t.worker_max }
    }
}

/// Adaptive batch sizing policy (ROADMAP follow-up): derive a stage's
/// effective worker batch from its observed `in_backlog`. A cold stage
/// (little queued work) flushes small so tuples don't sit in `out_buf`
/// waiting for batch-mates (latency); a hot stage batches large so the
/// gate synchronization cost amortizes (throughput). `backlog / 4`
/// reaches the upper clamp once ~4 full batches are queued — past that
/// point a bigger batch no longer changes the arrival/service balance,
/// it only adds latency. Clamped to `[min, max]` from
/// [`BatchTuning`]; monotone in `backlog`.
pub fn adaptive_worker_batch(backlog: u64, bounds: AdaptiveBatch) -> usize {
    let lo = bounds.min.max(1);
    let hi = bounds.max.max(lo);
    ((backlog / 4).min(hi as u64) as usize).clamp(lo, hi)
}

/// Per-stage runtime policy for a pipeline run.
pub struct StageRunConfig {
    /// Optional elasticity controller for this stage.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    /// Scripted reconfigurations: (event second, new instance set).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
    /// When set, the stage's worker batch is re-derived from its
    /// `in_backlog` every controller tick via [`adaptive_worker_batch`].
    pub adaptive_batch: Option<AdaptiveBatch>,
}

impl Default for StageRunConfig {
    fn default() -> Self {
        StageRunConfig {
            controller: None,
            controller_period_s: 1,
            manual_reconfigs: Vec::new(),
            adaptive_batch: None,
        }
    }
}

/// Pipeline harness configuration.
pub struct PipelineRunConfig {
    pub schedule: RateSchedule,
    pub time_scale: f64,
    /// One entry per stage (missing trailing entries default).
    pub stages: Vec<StageRunConfig>,
    /// End-of-stream heartbeat horizon beyond the last event ms (flush
    /// windows; use ≥ the largest WS in the pipeline).
    pub flush_slack_ms: EventTime,
    /// Wall time to keep draining the egress after end-of-stream.
    pub drain: Duration,
    /// Max run length handed to the ingress per batched add — the
    /// `[batch] ingress` config knob (bounds gate burstiness).
    pub ingress_batch: usize,
    /// Optional topology-aware controller: co-schedules EVERY stage's
    /// parallelism against a global core budget from their `in_backlog`
    /// (takes priority over nothing — per-stage controllers still run;
    /// use one or the other per stage in practice).
    pub dag_controller: Option<DagController>,
    /// Tick period of the DAG controller in event-time seconds.
    pub dag_controller_period_s: u32,
}

impl Default for PipelineRunConfig {
    fn default() -> Self {
        PipelineRunConfig {
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            stages: Vec::new(),
            flush_slack_ms: 15_000,
            drain: Duration::from_millis(500),
            ingress_batch: 256,
            dag_controller: None,
            dag_controller_period_s: 1,
        }
    }
}

/// Typed configuration errors from [`run_pipeline`] — degenerate
/// topologies are reported, not asserted (no panic path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarnessError {
    /// The pipeline exposes no ingress wrapper to feed.
    NoIngress,
    /// The pipeline exposes no egress reader: the sink gates would fill
    /// to capacity and stall their stages with nobody draining them.
    NoEgress,
    /// More per-stage configs than stages — the extra scripted
    /// reconfigurations/controllers would be silently dropped.
    ExtraStageConfigs { given: usize, stages: usize },
}

impl fmt::Display for HarnessError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HarnessError::NoIngress => write!(f, "pipeline has no ingress source to drive"),
            HarnessError::NoEgress => write!(f, "pipeline has no egress reader to drain"),
            HarnessError::ExtraStageConfigs { given, stages } => write!(
                f,
                "{given} stage configs for a {stages}-stage pipeline — \
                 scripted reconfigs would be dropped"
            ),
        }
    }
}

impl std::error::Error for HarnessError {}

/// Per-stage outcome of a pipeline run.
pub struct StageRunStats {
    pub name: &'static str,
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times of this stage.
    pub reconfigs: Vec<(u64, f64)>,
}

/// Result of a pipeline run.
pub struct PipelineRunResult {
    pub stages: Vec<StageRunStats>,
    /// Data tuples drained at the final egress.
    pub egress_count: u64,
    /// Tuples the harness had to discard because their ingress wrapper's
    /// source slot was decommissioned mid-run (the wrapper leaves the
    /// feed rotation; 0 in healthy runs — nonzero means egress/latency
    /// stats cover only part of the offered stream).
    pub ingress_dropped: u64,
    /// Whole-run end-to-end latency (ingest stamp at stage 0 → final
    /// egress) over every stamped output tuple.
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
}

/// Book-keeping the run loop carries per stage.
struct StageLoopState {
    cfg: StageRunConfig,
    last_snap: MetricsSnapshot,
    prev_loads: Vec<u64>,
    next_manual: usize,
    next_controller_s: u32,
    /// Arrival rate (t/event-s, de-duplicated across instances) of the
    /// latest sample — the controller's offered-load estimate for
    /// non-source stages.
    last_arrival_tps: f64,
    samples: Vec<RunSample>,
}

/// Drive a live, threaded VSN topology: pace `source` round-robin
/// across every ingress wrapper, drain every egress reader, tick every
/// stage's manual/controller reconfigurations (and the optional global
/// [`DagController`]) independently, and sample per-stage metrics once
/// per event second.
///
/// Every ingress wrapper is fed every tick (an idle wrapper's gate clock
/// would hold back readiness) and every egress reader is drained (an
/// undrained reader would pin its gate's backlog at capacity and stall
/// the sink stage) — that is what makes N-ingress/M-egress DAG shapes
/// safe where the old single-path loop had to panic.
pub fn run_pipeline<In, Out>(
    mut pipeline: Pipeline<In, Out>,
    mut cfg: PipelineRunConfig,
    source: &mut dyn PacedSource<In>,
) -> Result<PipelineRunResult, HarnessError>
where
    In: Payload + Default,
    Out: Payload + Default,
{
    let clock = pipeline.clock.clone();
    let mut ings: Vec<StretchIngress<In>> = std::mem::take(&mut pipeline.ingress);
    let n_ing = ings.len();
    if n_ing == 0 {
        return Err(HarnessError::NoIngress);
    }
    if pipeline.egress.is_empty() {
        return Err(HarnessError::NoEgress);
    }
    let mut egress: Vec<EgressDriver<Tuple<Out>>> = std::mem::take(&mut pipeline.egress)
        .into_iter()
        .map(|r| EgressDriver::new(r, clock.clone()))
        .collect();
    // all drivers record into ONE histogram pair: end-to-end latency is
    // a property of the whole topology, whichever sink a tuple exits
    let (lat, lat_total) = (egress[0].latency_us.clone(), egress[0].latency_total_us.clone());
    for d in egress.iter_mut().skip(1) {
        d.latency_us = lat.clone();
        d.latency_total_us = lat_total.clone();
    }

    let n_stages = pipeline.depth();
    if cfg.stages.len() > n_stages {
        return Err(HarnessError::ExtraStageConfigs { given: cfg.stages.len(), stages: n_stages });
    }
    let mut stage_cfgs: Vec<StageRunConfig> = std::mem::take(&mut cfg.stages);
    while stage_cfgs.len() < n_stages {
        stage_cfgs.push(StageRunConfig::default());
    }
    let mut loops: Vec<StageLoopState> = stage_cfgs
        .into_iter()
        .take(n_stages)
        .enumerate()
        .map(|(k, mut sc)| {
            sc.manual_reconfigs.sort_by_key(|&(at, _)| at);
            let period = sc.controller_period_s.max(1);
            StageLoopState {
                last_snap: MetricsSnapshot::default(),
                prev_loads: vec![0; pipeline.stages[k].max_parallelism()],
                next_manual: 0,
                next_controller_s: period,
                last_arrival_tps: 0.0,
                samples: Vec::new(),
                cfg: sc,
            }
        })
        .collect();

    let duration_s = cfg.schedule.duration_s();
    let mut pending_event_tuples = 0.0f64;
    let mut event_ms_total: f64 = 0.0;
    // per-tick feed runs, one per ingress wrapper (round-robin split so
    // EVERY wrapper's gate clock advances every tick), each handed over
    // via one batched add (§Perf). A wrapper whose slot is decommissioned
    // under us (`Err(Inactive)`) leaves the rotation; its residual is
    // counted in `ingress_dropped`, never silently discarded.
    let mut feed_bufs: Vec<Vec<Tuple<In>>> = (0..n_ing).map(|_| Vec::new()).collect();
    let mut alive: Vec<bool> = vec![true; n_ing];
    let mut n_alive = n_ing;
    let mut ingress_dropped = 0u64;
    let mut rr = 0usize;
    let mut next_dag_ctl_s: u32 = cfg.dag_controller_period_s.max(1);
    let t0 = Instant::now();

    // wall tick: 20 ms of *wall* time per loop iteration
    let wall_tick = Duration::from_millis(20);
    let mut next_tick = t0;
    let mut next_sample_s: u32 = 1;

    loop {
        // how far event time should have progressed
        let wall_s = t0.elapsed().as_secs_f64();
        let event_s = wall_s * cfg.time_scale;
        // run slightly past the end so the final per-second sample lands
        if event_s >= duration_s as f64 + 0.1 {
            break;
        }
        let cur_rate = cfg.schedule.rate_at(event_s as u32);
        if event_s < duration_s as f64 {
            source.set_rate(cur_rate);
            // feed the tuples that belong to this tick
            let tick_event_s = wall_tick.as_secs_f64() * cfg.time_scale;
            pending_event_tuples += cur_rate * tick_event_s;
            let n = pending_event_tuples.floor() as usize;
            pending_event_tuples -= n as f64;
            event_ms_total += tick_event_s * 1e3;
            let ingress_batch = cfg.ingress_batch.max(1);
            for _ in 0..n {
                let mut t = source.next();
                t.ingest_us = clock.now_us();
                if n_alive == 0 {
                    ingress_dropped += 1; // every wrapper decommissioned
                    continue;
                }
                while !alive[rr] {
                    rr = (rr + 1) % n_ing;
                }
                feed_bufs[rr].push(t);
                if feed_bufs[rr].len() >= ingress_batch
                    && ings[rr].add_batch(&mut feed_bufs[rr]).is_err()
                {
                    // decommissioned mid-run: retire the wrapper from the
                    // rotation and account for the lost residual
                    ingress_dropped += feed_bufs[rr].len() as u64;
                    feed_bufs[rr].clear();
                    alive[rr] = false;
                    n_alive -= 1;
                }
                rr = (rr + 1) % n_ing;
            }
            for (i, buf) in feed_bufs.iter_mut().enumerate() {
                if alive[i] && ings[i].add_batch(buf).is_err() {
                    ingress_dropped += buf.len() as u64;
                    buf.clear();
                    alive[i] = false;
                    n_alive -= 1;
                }
            }
        }
        for d in egress.iter_mut() {
            d.poll();
        }

        // per-event-second sampling, every stage
        while (next_sample_s as f64) <= event_s && next_sample_s <= duration_s {
            for (k, st) in loops.iter_mut().enumerate() {
                let stage = &pipeline.stages[k];
                let metrics = stage.metrics();
                let snap = metrics.snapshot();
                let dt = 1.0 / cfg.time_scale; // wall seconds per event second
                let rates = snap.rates_since(&st.last_snap, dt);
                let active = stage.active_instances();
                // per-interval load CV (Fig. 9 right): deltas, active set only
                let cv = {
                    let deltas: Vec<f64> = active
                        .iter()
                        .map(|&i| (metrics.instance_load(i) - st.prev_loads[i]) as f64)
                        .collect();
                    for (i, p) in st.prev_loads.iter_mut().enumerate() {
                        *p = metrics.instance_load(i);
                    }
                    let mean = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
                    if deltas.len() < 2 || mean <= 0.0 {
                        0.0
                    } else {
                        let var = deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
                            / deltas.len() as f64;
                        100.0 * var.sqrt() / mean
                    }
                };
                // Every active instance reads (and counts) every gate
                // tuple, so the summed rate is m× the true arrival rate;
                // dividing by the active count recovers arrivals.
                let arrival_tps =
                    rates.in_tps / cfg.time_scale / active.len().max(1) as f64;
                st.last_arrival_tps = arrival_tps;
                st.samples.push(RunSample {
                    t_s: next_sample_s,
                    // With ONE ingress wrapper, stage 0 is offered the
                    // whole schedule. With several wrappers the harness
                    // cannot map wrappers to source stages (a DAG may
                    // have several), so every stage reports its measured
                    // arrival rate instead of a guessed split.
                    offered_tps: if k == 0 && n_ing == 1 {
                        cfg.schedule.rate_at(next_sample_s - 1)
                    } else {
                        arrival_tps
                    },
                    // rates are per wall second; report per *event* second
                    in_tps: arrival_tps,
                    out_tps: rates.out_tps / cfg.time_scale,
                    cmp_per_s: rates.cmp_per_s / cfg.time_scale,
                    latency_p50_us: lat.p50(),
                    latency_mean_us: lat.mean(),
                    threads: active.len(),
                    backlog: stage.in_backlog(),
                    load_cv_pct: cv,
                    worker_batch: stage.worker_batch(),
                });
                st.last_snap = snap;
            }
            // end-to-end latency is a property of the whole pipeline; the
            // per-second histogram resets once all stages sampled it
            lat.reset();
            next_sample_s += 1;
        }

        // per-stage scripted reconfigurations (bypass the controllers)
        for (k, st) in loops.iter_mut().enumerate() {
            while st.next_manual < st.cfg.manual_reconfigs.len()
                && (st.cfg.manual_reconfigs[st.next_manual].0 as f64) <= event_s
            {
                let set = st.cfg.manual_reconfigs[st.next_manual].1.clone();
                pipeline.stages[k].reconfigure(set.clone(), Mapper::over(set));
                st.next_manual += 1;
            }
        }
        // per-stage controller ticks (the tick also carries the adaptive
        // batch-sizing update, so it fires with or without a controller)
        for (k, st) in loops.iter_mut().enumerate() {
            let period = st.cfg.controller_period_s.max(1);
            if (st.next_controller_s as f64) > event_s {
                continue;
            }
            st.next_controller_s += period;
            let stage = &mut pipeline.stages[k];
            if let Some(bounds) = st.cfg.adaptive_batch {
                stage.set_worker_batch(adaptive_worker_batch(stage.in_backlog(), bounds));
            }
            if let Some(ctl) = st.cfg.controller.as_mut() {
                let active = stage.active_instances();
                let obs = Observation {
                    // the schedule rate only describes stage 0 when a
                    // single wrapper feeds it the whole stream; with
                    // several wrappers (possibly several source
                    // stages) use the measured arrival rate
                    in_rate: if k == 0 && n_ing == 1 {
                        cur_rate
                    } else {
                        st.last_arrival_tps
                    },
                    cmp_per_s: st.samples.last().map(|s| s.cmp_per_s).unwrap_or(0.0),
                    backlog: stage.in_backlog(),
                    dt: period as f64,
                    active,
                    max: stage.max_parallelism(),
                };
                if let Decision::Reconfigure(set) = ctl.tick(&obs) {
                    let mapper = Mapper::over(set.clone());
                    stage.reconfigure(set, mapper);
                }
            }
        }
        // global co-scheduling tick: one observation per stage, one
        // decision wave against the shared core budget
        if let Some(dc) = cfg.dag_controller.as_mut() {
            let period = cfg.dag_controller_period_s.max(1);
            if (next_dag_ctl_s as f64) <= event_s {
                next_dag_ctl_s += period;
                let obs: Vec<Observation> = loops
                    .iter()
                    .enumerate()
                    .map(|(k, st)| Observation {
                        in_rate: if k == 0 && n_ing == 1 {
                            cur_rate
                        } else {
                            st.last_arrival_tps
                        },
                        cmp_per_s: st.samples.last().map(|s| s.cmp_per_s).unwrap_or(0.0),
                        backlog: pipeline.stages[k].in_backlog(),
                        dt: period as f64,
                        active: pipeline.stages[k].active_instances(),
                        max: pipeline.stages[k].max_parallelism(),
                    })
                    .collect();
                for (k, d) in dc.tick(&obs).into_iter().enumerate() {
                    if let Decision::Reconfigure(set) = d {
                        let mapper = Mapper::over(set.clone());
                        pipeline.stages[k].reconfigure(set, mapper);
                    }
                }
            }
        }

        next_tick += wall_tick;
        let now = Instant::now();
        if next_tick > now {
            std::thread::sleep(next_tick - now);
        } else {
            next_tick = now; // fell behind: don't try to catch up the wall
        }
    }

    // flush: end-of-stream heartbeat on EVERY ingress wrapper (workers
    // forward it stage to stage; a silent wrapper would hold back every
    // downstream watermark), then drain remaining outputs briefly
    let horizon = event_ms_total as EventTime + cfg.flush_slack_ms;
    for (i, ing) in ings.iter_mut().enumerate() {
        if alive[i] {
            let _ = ing.heartbeat(horizon); // heartbeats carry no data
        }
    }
    let drain_until = Instant::now() + cfg.drain;
    while Instant::now() < drain_until {
        let mut polled = 0;
        for d in egress.iter_mut() {
            polled += d.poll();
        }
        if polled == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let latency_p50_us = lat_total.p50();
    let latency_mean_us = lat_total.mean();
    let egress_count = egress.iter().map(|d| d.count).sum();
    let stages = loops
        .into_iter()
        .enumerate()
        .map(|(k, st)| StageRunStats {
            name: pipeline.stages[k].name(),
            samples: st.samples,
            reconfigs: pipeline.stages[k].completion_times(),
        })
        .collect();
    pipeline.shutdown();
    Ok(PipelineRunResult {
        stages,
        egress_count,
        ingress_dropped,
        latency_p50_us,
        latency_mean_us,
    })
}

/// Run a live, threaded VSN ScaleJoin experiment — the Q3-Q6 entry point,
/// now a thin wrapper over [`run_pipeline`] with a single-stage pipeline.
pub fn run_elastic_join(cfg: JoinRunConfig) -> RunResult {
    let def = q3_operator(cfg.ws_ms, cfg.n_keys);
    let pipeline = PipelineBuilder::new(
        def,
        VsnOptions {
            initial: cfg.initial,
            max: cfg.max,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: cfg.gate_capacity,
            worker_batch: cfg.worker_batch.max(1),
            ..Default::default()
        },
    )
    .build();
    let mut gen = SjGen::new(cfg.seed, 1.0);
    let pcfg = PipelineRunConfig {
        schedule: cfg.schedule,
        time_scale: cfg.time_scale,
        stages: vec![StageRunConfig {
            controller: cfg.controller,
            controller_period_s: cfg.controller_period_s,
            manual_reconfigs: cfg.manual_reconfigs,
            adaptive_batch: None,
        }],
        flush_slack_ms: cfg.ws_ms + 10_000,
        drain: Duration::from_millis(500),
        ingress_batch: cfg.ingress_batch.max(1),
        ..Default::default()
    };
    // the builder above wires exactly one ingress and one egress, so the
    // typed degenerate-topology errors cannot occur here
    let r = run_pipeline(pipeline, pcfg, &mut gen)
        .expect("single-stage pipeline always has one ingress and one egress");
    let stage0 = r.stages.into_iter().next().expect("single-stage pipeline");
    RunResult { samples: stage0.samples, reconfigs: stage0.reconfigs, egress_count: r.egress_count }
}

/// Build a reactive ("reactive" or anything unrecognized, the classic
/// default) or proactive ("proactive") controller from the `[elastic]`
/// thresholds — the ONE construction path shared by the classic
/// experiment launcher and the per-stage declarative path, so the two
/// can never drift on thresholds or cooldown.
pub fn controller_from_config(
    cfg: &Config,
    kind: &str,
    model: JoinCostModel,
) -> Box<dyn Controller> {
    if kind == "proactive" {
        Box::new(ProactiveController::new(model))
    } else {
        Box::new(
            ReactiveController::new(
                model,
                Thresholds {
                    upper: cfg.float_or("elastic.upper", 0.90),
                    target: cfg.float_or("elastic.target", 0.70),
                    lower: cfg.float_or("elastic.lower", 0.45),
                },
            )
            .with_cooldown(2),
        )
    }
}

/// Expected value shape of a job config key ([`check_job_section_keys`]).
#[derive(Clone, Copy)]
enum KeyKind {
    Int,
    /// Accepts ints too (the usual numeric widening).
    Float,
    Str,
    Bool,
}

impl KeyKind {
    fn matches(self, v: &crate::config::ConfigValue) -> bool {
        use crate::config::ConfigValue as V;
        match self {
            KeyKind::Int => matches!(v, V::Int(_)),
            KeyKind::Float => matches!(v, V::Int(_) | V::Float(_)),
            KeyKind::Str => matches!(v, V::Str(_)),
            KeyKind::Bool => matches!(v, V::Bool(_)),
        }
    }
    fn name(self) -> &'static str {
        match self {
            KeyKind::Int => "an integer",
            KeyKind::Float => "a number",
            KeyKind::Str => "a string",
            KeyKind::Bool => "a bool",
        }
    }
}

/// Keys [`run_job`] consumes, per section, with their expected value
/// shapes — an unknown key OR a wrong-typed value under these sections
/// is a typo that would silently change the job, so both are rejected
/// (same contract as `JobSpec`'s `[topology]`/`[stage.*]` validation,
/// which covers those two prefixes itself). This table is the
/// authoritative list for the job path: keep it in sync with
/// [`RateSchedule::from_config`], [`JobSource::for_kind`],
/// [`BatchTuning::from_config`] and the `[elastic]` reads in [`run_job`]
/// (each of those carries a pointer back here).
const JOB_SECTION_KEYS: &[(&str, &[(&str, KeyKind)])] = &[
    (
        "run.",
        &[
            ("duration_s", KeyKind::Int),
            ("rate", KeyKind::Float),
            ("schedule", KeyKind::Str),
            ("seed", KeyKind::Int),
            ("min_rate", KeyKind::Float),
            ("max_rate", KeyKind::Float),
            ("min_phase_s", KeyKind::Int),
            ("max_phase_s", KeyKind::Int),
            ("step_at_s", KeyKind::Int),
            ("step_rate", KeyKind::Float),
            ("time_scale", KeyKind::Float),
            ("flush_slack_ms", KeyKind::Int),
            ("drain_ms", KeyKind::Int),
        ],
    ),
    (
        "elastic.",
        &[
            ("controller", KeyKind::Str),
            ("cores", KeyKind::Int),
            ("grow_backlog", KeyKind::Int),
            ("shrink_backlog", KeyKind::Int),
            ("cooldown_ticks", KeyKind::Int),
            ("period_s", KeyKind::Int),
            ("upper", KeyKind::Float),
            ("target", KeyKind::Float),
            ("lower", KeyKind::Float),
        ],
    ),
    (
        "source.",
        &[("symbols", KeyKind::Int), ("seed", KeyKind::Int), ("vocab", KeyKind::Int)],
    ),
    (
        "batch.",
        &[
            ("worker", KeyKind::Int),
            ("ingress", KeyKind::Int),
            ("queue", KeyKind::Int),
            ("adaptive", KeyKind::Bool),
            ("worker_min", KeyKind::Int),
            ("worker_max", KeyKind::Int),
        ],
    ),
];

/// Validate a job config's run-level sections: unknown sections, unknown
/// keys inside known sections, and wrong-typed values are all typed
/// errors — a declarative job must never silently run with defaults in
/// place of what the user wrote.
fn check_job_section_keys(cfg: &Config) -> Result<(), JobError> {
    'keys: for k in cfg.keys() {
        // `[topology]`/`[stage.*]` are JobSpec::from_config's territory;
        // the bare `name` key is the only free-form top-level one.
        if k == "name" || k.starts_with("topology.") || k.starts_with("stage.") {
            continue;
        }
        for (prefix, known) in JOB_SECTION_KEYS {
            if let Some(rest) = k.strip_prefix(prefix) {
                match known.iter().find(|(name, _)| *name == rest) {
                    None => {
                        return Err(JobError::BadValue {
                            key: k.to_string(),
                            msg: format!(
                                "unknown `[{}]` key (known: {})",
                                &prefix[..prefix.len() - 1],
                                known.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                            ),
                        })
                    }
                    Some((_, kind)) => {
                        let v = cfg.get(k).expect("keys() yields existing keys");
                        if !kind.matches(v) {
                            return Err(JobError::BadValue {
                                key: k.to_string(),
                                msg: format!("expected {}, got `{v}`", kind.name()),
                            });
                        }
                        continue 'keys;
                    }
                }
            }
        }
        // no known prefix matched: a misspelled section name would
        // silently drop the whole section — reject it by name
        return Err(JobError::BadValue {
            key: k.to_string(),
            msg: "unknown section/key for a job config (expected `name`, `[topology]`, \
                  `[stage.<name>]`, `[run]`, `[elastic]`, `[source]`, or `[batch]`)"
                .into(),
        });
    }
    Ok(())
}

/// Outcome of a declarative-job run ([`run_job`]).
pub struct JobRunOutcome {
    /// The config's `name` key.
    pub name: String,
    /// Config stage names aligned with `result.stages` indices.
    pub stage_names: Vec<String>,
    pub result: PipelineRunResult,
}

/// Run a config-declared job end to end: parse + validate the
/// [`JobSpec`], build the topology through the operator registry, pick
/// the paced generator matching the source stages' payload kind, wire
/// the `[elastic]` controller choice (`none` / `reactive` / `proactive`
/// per stage, or the global budgeted `dag` controller with
/// `elastic.cores`) and the `[batch]` adaptive batch sizing, then drive
/// everything through [`run_pipeline`] under the `[run]` rate schedule.
///
/// `budget_ms`, when given, caps the WALL-clock duration of the paced
/// phase by raising `time_scale` — the CI smoke knob (`stretch run
/// --config job.conf --budget-ms 10`).
pub fn run_job(cfg: &Config, budget_ms: Option<u64>) -> Result<JobRunOutcome, JobError> {
    check_job_section_keys(cfg)?;
    let spec = JobSpec::from_config(cfg)?;
    // resolve the generator BEFORE spawning anything — NoSource is a
    // pure config error and must not cost a topology spawn + teardown
    let mut source =
        JobSource::for_kind(spec.source_kind, cfg).ok_or(JobError::NoSource(spec.source_kind))?;
    let built = spec.build()?;
    let schedule = RateSchedule::from_config(cfg);
    let batch = BatchTuning::from_config(cfg);
    let n_stages = built.pipeline.depth();
    let adaptive = if batch.adaptive { Some(AdaptiveBatch::from(&batch)) } else { None };
    let period = cfg.int_or("elastic.period_s", 1).max(1) as u32;

    let mut dag_controller = None;
    let mut per_stage: Vec<Option<Box<dyn Controller>>> = (0..n_stages).map(|_| None).collect();
    match cfg.str_or("elastic.controller", "none") {
        "none" => {}
        "dag" => {
            dag_controller = Some(
                DagController::new(cfg.int_or("elastic.cores", 8).max(1) as usize)
                    .with_thresholds(
                        cfg.int_or("elastic.grow_backlog", 4096).max(1) as u64,
                        cfg.int_or("elastic.shrink_backlog", 64).max(0) as u64,
                    )
                    .with_cooldown(cfg.int_or("elastic.cooldown_ticks", 1).max(0) as u32),
            );
        }
        kind if kind == "reactive" || kind == "proactive" => {
            // per-stage controllers, each modelled on this machine's
            // calibrated costs and the stage's own window/parallelism
            let cal = calibrate();
            for (k, st) in spec.stages.iter().enumerate() {
                let model = JoinCostModel::new(
                    cal.cmp_per_sec / st.max.max(1) as f64,
                    st.params.ws_ms as f64 / 1e3,
                );
                per_stage[k] = Some(controller_from_config(cfg, kind, model));
            }
        }
        other => {
            return Err(JobError::BadValue {
                key: "elastic.controller".into(),
                msg: format!("unknown controller `{other}` (expected none/reactive/proactive/dag)"),
            })
        }
    }

    let stages: Vec<StageRunConfig> = per_stage
        .into_iter()
        .map(|controller| StageRunConfig {
            controller,
            controller_period_s: period,
            manual_reconfigs: Vec::new(),
            adaptive_batch: adaptive,
        })
        .collect();

    let max_ws = spec.stages.iter().map(|s| s.params.ws_ms).max().unwrap_or(1_000);
    let mut time_scale = cfg.float_or("run.time_scale", 1.0).max(1e-6);
    if let Some(ms) = budget_ms {
        time_scale = time_scale.max(schedule.duration_s() as f64 * 1000.0 / ms.max(1) as f64);
    }
    let pcfg = PipelineRunConfig {
        schedule,
        time_scale,
        stages,
        flush_slack_ms: cfg.int_or("run.flush_slack_ms", max_ws + 10_000),
        drain: Duration::from_millis(cfg.int_or("run.drain_ms", 500).max(0) as u64),
        ingress_batch: batch.ingress,
        dag_controller,
        dag_controller_period_s: period,
    };
    let result = run_pipeline(built.pipeline, pcfg, &mut source).map_err(JobError::Harness)?;
    Ok(JobRunOutcome { name: spec.name, stage_names: built.stage_names, result })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::{JoinCostModel, ReactiveController, Thresholds};
    use crate::workloads::nyse::NyseConfig;
    use crate::workloads::{hedge_join_op, trade_fanout_op};

    #[test]
    fn batch_tuning_reaches_engine_options() {
        let cfg = crate::config::Config::parse("[batch]\nworker = 32\nqueue = 16").unwrap();
        let t = crate::config::BatchTuning::from_config(&cfg);
        let v = VsnOptions::default().with_batch(&t);
        assert_eq!(v.worker_batch, 32);
        let s = crate::engine::SnOptions::default().with_batch(&t);
        assert_eq!(s.batch, 16);
    }

    #[test]
    fn adaptive_batch_policy_clamps_and_is_monotone() {
        let b = AdaptiveBatch { min: 16, max: 256 };
        assert_eq!(adaptive_worker_batch(0, b), 16, "cold stage flushes small");
        assert_eq!(adaptive_worker_batch(63, b), 16);
        assert_eq!(adaptive_worker_batch(256, b), 64);
        assert_eq!(adaptive_worker_batch(1 << 20, b), 256, "hot stage batches large");
        let mut last = 0;
        for backlog in [0u64, 10, 100, 1_000, 10_000, 100_000] {
            let v = adaptive_worker_batch(backlog, b);
            assert!(v >= last, "policy must be monotone in backlog");
            last = v;
        }
        // degenerate bounds can never stall a worker loop
        assert_eq!(adaptive_worker_batch(0, AdaptiveBatch { min: 0, max: 0 }), 1);
    }

    #[test]
    fn adaptive_batch_retunes_stages_from_backlog() {
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, worker_batch: 128, ..Default::default() },
        )
        .build();
        assert_eq!(pipeline.stages[0].worker_batch(), 128);
        let mut gen = SjGen::new(5, 1.0);
        let bounds = AdaptiveBatch { min: 8, max: 64 };
        let r = run_pipeline(
            pipeline,
            PipelineRunConfig {
                schedule: RateSchedule::constant(3, 400.0),
                time_scale: 3.0,
                stages: vec![StageRunConfig {
                    adaptive_batch: Some(bounds),
                    ..Default::default()
                }],
                ..Default::default()
            },
            &mut gen,
        )
        .unwrap();
        // the first controller tick fires after the first sample; every
        // later sample must reflect a batch re-derived inside the clamp
        // (the configured 128 sits outside it on purpose)
        let samples = &r.stages[0].samples;
        assert_eq!(samples.len(), 3);
        assert!(
            samples[1..].iter().all(|s| (8..=64).contains(&s.worker_batch)),
            "worker batch not re-derived: {:?}",
            samples.iter().map(|s| s.worker_batch).collect::<Vec<_>>()
        );
    }

    #[test]
    fn run_job_drives_a_declarative_two_stage_job() {
        let cfg = crate::config::Config::parse(
            r#"
name = "wc-smoke"
[topology]
stages = ["tok", "count"]
[stage.tok]
operator = "tweet-tokenize"
max = 2
[stage.count]
operator = "word-count"
inputs = ["tok"]
ws_ms = 500
max = 2
[run]
duration_s = 2
rate = 300
time_scale = 4
[batch]
adaptive = true
"#,
        )
        .unwrap();
        let out = run_job(&cfg, None).unwrap();
        assert_eq!(out.name, "wc-smoke");
        assert_eq!(out.stage_names, vec!["tok", "count"]);
        assert_eq!(out.result.stages.len(), 2);
        assert_eq!(out.result.stages[0].samples.len(), 2);
        assert!(
            out.result.egress_count > 0
                || out
                    .result
                    .stages
                    .iter()
                    .any(|s| s.samples.iter().any(|x| x.out_tps > 0.0)),
            "no data moved through the config-built pipeline"
        );
    }

    #[test]
    fn run_job_rejects_unknown_controller() {
        let cfg = crate::config::Config::parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"tweet-tokenize\"\n\
             [elastic]\ncontroller = \"warp\"",
        )
        .unwrap();
        match run_job(&cfg, None) {
            Err(JobError::BadValue { key, .. }) => assert_eq!(key, "elastic.controller"),
            other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn run_job_rejects_typod_section_keys() {
        const STAGES: &str = "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"tweet-tokenize\"\n";
        let bad_key = |body: &str| {
            let cfg = crate::config::Config::parse(&format!("{STAGES}{body}")).unwrap();
            match run_job(&cfg, None) {
                Err(JobError::BadValue { key, .. }) => key,
                other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
            }
        };
        // typo'd key inside a known section: must not silently become
        // the 30 s default schedule
        assert_eq!(bad_key("[run]\nduraton_s = 60"), "run.duraton_s");
        // typo'd SECTION name: must not silently drop the whole section
        assert_eq!(bad_key("[elastc]\ncontroller = \"dag\""), "elastc.controller");
        // right key, wrong value type: must not silently use the default
        assert_eq!(bad_key("[run]\nrate = \"fast\""), "run.rate");
        assert_eq!(bad_key("[run]\nduration_s = 2.5"), "run.duration_s");
        assert_eq!(bad_key("[batch]\nadaptive = 1"), "batch.adaptive");
        // numeric widening still allowed: an int where a float is expected
        let cfg = crate::config::Config::parse(&format!(
            "{STAGES}[run]\nduration_s = 1\nrate = 200\ntime_scale = 4"
        ))
        .unwrap();
        assert!(run_job(&cfg, None).is_ok(), "int-for-float must stay accepted");
    }

    #[test]
    fn harness_steady_run_produces_samples() {
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::constant(4, 500.0),
            time_scale: 4.0, // 4 event-seconds in ~1 wall-second
            initial: 2,
            max: 4,
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert_eq!(r.samples.len(), 4);
        assert!(r.egress_count > 0 || r.samples.iter().any(|s| s.cmp_per_s > 0.0));
        assert!(r.samples.iter().all(|s| s.threads == 2));
    }

    #[test]
    fn harness_controller_provisions_under_ramp() {
        // calibrate a model, then drive well past 1-thread capacity
        let model = JoinCostModel::new(5e5, 1.0); // deliberately small capacity
        let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(1);
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::step(6, 2, 200.0, 1500.0),
            time_scale: 3.0,
            initial: 1,
            max: 4,
            controller: Some(Box::new(ctl)),
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert!(!r.reconfigs.is_empty(), "controller should have reconfigured");
        assert!(r.samples.last().unwrap().threads > 1);
    }

    #[test]
    fn degenerate_topologies_are_typed_errors_not_panics() {
        // no egress reader: the sink gate would fill with nobody draining
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, egress_readers: 0, ..Default::default() },
        )
        .build();
        let mut gen = SjGen::new(1, 1.0);
        match run_pipeline(pipeline, PipelineRunConfig::default(), &mut gen) {
            Err(HarnessError::NoEgress) => {}
            other => panic!("expected NoEgress, got {:?}", other.map(|_| ()).err()),
        }
        // more stage configs than stages: scripted reconfigs would drop
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, ..Default::default() },
        )
        .build();
        let cfg = PipelineRunConfig {
            stages: vec![StageRunConfig::default(), StageRunConfig::default()],
            ..Default::default()
        };
        match run_pipeline(pipeline, cfg, &mut gen) {
            Err(HarnessError::ExtraStageConfigs { given: 2, stages: 1 }) => {}
            other => panic!("expected ExtraStageConfigs, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn pipeline_harness_runs_two_stages_with_manual_reconfigs() {
        // NYSE fan-out → hedge join, reconfiguring EACH stage once
        let pipeline = PipelineBuilder::new(
            trade_fanout_op(64),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .stage(
            hedge_join_op(1_000, 32),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .build();
        let mut source = TradeStream::new(&NyseConfig::default(), 400.0);
        let r = run_pipeline(
            pipeline,
            PipelineRunConfig {
                schedule: RateSchedule::constant(4, 400.0),
                time_scale: 4.0,
                stages: vec![
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                ],
                flush_slack_ms: 5_000,
                drain: Duration::from_millis(500),
                ..Default::default()
            },
            &mut source,
        )
        .unwrap();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages[0].samples.len(), 4);
        assert_eq!(r.stages[1].samples.len(), 4);
        // both stages completed their independent reconfigurations
        assert_eq!(r.stages[0].reconfigs.len(), 1, "stage 0 reconfig lost");
        assert_eq!(r.stages[1].reconfigs.len(), 1, "stage 1 reconfig lost");
        assert_eq!(r.stages[0].samples.last().unwrap().threads, 2);
        assert_eq!(r.stages[1].samples.last().unwrap().threads, 2);
        // data flowed through the shared gate into stage 2
        assert!(r.stages[1].samples.iter().any(|s| s.in_tps > 0.0));
    }
}
