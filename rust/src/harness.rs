//! Experiment harness: drive a live VSN *topology* (linear pipeline or
//! DAG) under a rate schedule with per-stage controllers in the loop,
//! sampling the §8 metrics once per event second **per stage**.
//!
//! [`run_pipeline`] is the generic loop: it paces a [`PacedSource`]
//! round-robin across every ingress wrapper (N ingress sources), drains
//! every egress reader (M sinks / readers — leaving one undrained would
//! pin its gate's backlog at capacity and stall the upstream stage), and
//! per tick gives every stage its scripted reconfigurations and
//! controller decisions independently; an optional topology-aware
//! [`DagController`] co-schedules all stages against a global core
//! budget. Degenerate topologies (no ingress, no egress) are typed
//! [`HarnessError`]s, not panics. [`run_elastic_join`] — the Q3-Q6 entry
//! point — is a thin compatibility wrapper that builds a single-stage
//! ScaleJoin pipeline and reshapes the result.
//!
//! Wall-clock pacing is compressible (`time_scale`) so the paper's
//! 20-minute runs replay in seconds; event time always advances at the
//! schedule's nominal pace.

use crate::elastic::{Controller, DagController, Decision, Observation};
use crate::engine::pipeline::{Pipeline, PipelineBuilder};
use crate::engine::{EgressDriver, StretchIngress, VsnOptions};
use crate::metrics::MetricsSnapshot;
use crate::time::EventTime;
use crate::tuple::{Mapper, Payload, Tuple};
use crate::workloads::nyse::{Trade, TradeStream};
use crate::workloads::rates::RateSchedule;
use crate::workloads::scalejoin_bench::{q3_operator, SjGen, SjPayload};
use crate::workloads::tweets::{Tweet, TweetGen};
use std::fmt;
use std::time::{Duration, Instant};

/// A generator the harness can pace against a [`RateSchedule`]: emits
/// ts-sorted tuples whose event time advances at ~`1000 / rate` ms each.
pub trait PacedSource<P>: Send {
    /// Adjust the nominal rate (tuples per event-second).
    fn set_rate(&mut self, _tps: f64) {}
    /// Next tuple (event time must not regress).
    fn next(&mut self) -> Tuple<P>;
}

impl PacedSource<SjPayload> for SjGen {
    fn set_rate(&mut self, tps: f64) {
        SjGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<SjPayload> {
        SjGen::next(self)
    }
}

impl PacedSource<Tweet> for TweetGen {
    fn set_rate(&mut self, tps: f64) {
        TweetGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Tweet> {
        TweetGen::next(self)
    }
}

impl PacedSource<Trade> for TradeStream {
    fn set_rate(&mut self, tps: f64) {
        TradeStream::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Trade> {
        TradeStream::next(self)
    }
}

/// Harness configuration (the Q3-Q6 single-stage ScaleJoin shape).
pub struct JoinRunConfig {
    /// ScaleJoin window size (event-time ms).
    pub ws_ms: EventTime,
    /// Round-robin key count (paper: 1000).
    pub n_keys: u64,
    /// Initial / maximum parallelism (m, n).
    pub initial: usize,
    pub max: usize,
    /// The offered-rate schedule (event-time seconds).
    pub schedule: RateSchedule,
    /// Wall-time compression: 10.0 replays 10 event-seconds per wall-second.
    pub time_scale: f64,
    /// Optional elasticity controller.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    pub seed: u64,
    pub gate_capacity: usize,
    /// Worker gate synchronization granularity (tuples per
    /// `get_batch`/`add_batch`) — the `[batch] worker` config knob.
    pub worker_batch: usize,
    /// Max run length per batched ingress add — the `[batch] ingress`
    /// config knob.
    pub ingress_batch: usize,
    /// Scripted reconfigurations: (event second, new instance set) —
    /// issued directly, bypassing the controller (Q4 protocol timing).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for JoinRunConfig {
    fn default() -> Self {
        JoinRunConfig {
            ws_ms: 5_000,
            n_keys: 64,
            initial: 1,
            max: 4,
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            controller: None,
            controller_period_s: 1,
            seed: 7,
            gate_capacity: 1 << 13,
            worker_batch: crate::engine::WORKER_BATCH,
            ingress_batch: 256,
            manual_reconfigs: Vec::new(),
        }
    }
}

/// One per-event-second sample of one stage.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunSample {
    pub t_s: u32,
    pub offered_tps: f64,
    pub in_tps: f64,
    pub out_tps: f64,
    pub cmp_per_s: f64,
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
    pub threads: usize,
    pub backlog: u64,
    pub load_cv_pct: f64,
}

/// Result of a single-stage harness run (the historical shape).
pub struct RunResult {
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times.
    pub reconfigs: Vec<(u64, f64)>,
    /// Total data tuples drained at the egress.
    pub egress_count: u64,
}

/// Per-stage runtime policy for a pipeline run.
pub struct StageRunConfig {
    /// Optional elasticity controller for this stage.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    /// Scripted reconfigurations: (event second, new instance set).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for StageRunConfig {
    fn default() -> Self {
        StageRunConfig { controller: None, controller_period_s: 1, manual_reconfigs: Vec::new() }
    }
}

/// Pipeline harness configuration.
pub struct PipelineRunConfig {
    pub schedule: RateSchedule,
    pub time_scale: f64,
    /// One entry per stage (missing trailing entries default).
    pub stages: Vec<StageRunConfig>,
    /// End-of-stream heartbeat horizon beyond the last event ms (flush
    /// windows; use ≥ the largest WS in the pipeline).
    pub flush_slack_ms: EventTime,
    /// Wall time to keep draining the egress after end-of-stream.
    pub drain: Duration,
    /// Max run length handed to the ingress per batched add — the
    /// `[batch] ingress` config knob (bounds gate burstiness).
    pub ingress_batch: usize,
    /// Optional topology-aware controller: co-schedules EVERY stage's
    /// parallelism against a global core budget from their `in_backlog`
    /// (takes priority over nothing — per-stage controllers still run;
    /// use one or the other per stage in practice).
    pub dag_controller: Option<DagController>,
    /// Tick period of the DAG controller in event-time seconds.
    pub dag_controller_period_s: u32,
}

impl Default for PipelineRunConfig {
    fn default() -> Self {
        PipelineRunConfig {
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            stages: Vec::new(),
            flush_slack_ms: 15_000,
            drain: Duration::from_millis(500),
            ingress_batch: 256,
            dag_controller: None,
            dag_controller_period_s: 1,
        }
    }
}

/// Typed configuration errors from [`run_pipeline`] — degenerate
/// topologies are reported, not asserted (no panic path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarnessError {
    /// The pipeline exposes no ingress wrapper to feed.
    NoIngress,
    /// The pipeline exposes no egress reader: the sink gates would fill
    /// to capacity and stall their stages with nobody draining them.
    NoEgress,
    /// More per-stage configs than stages — the extra scripted
    /// reconfigurations/controllers would be silently dropped.
    ExtraStageConfigs { given: usize, stages: usize },
}

impl fmt::Display for HarnessError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HarnessError::NoIngress => write!(f, "pipeline has no ingress source to drive"),
            HarnessError::NoEgress => write!(f, "pipeline has no egress reader to drain"),
            HarnessError::ExtraStageConfigs { given, stages } => write!(
                f,
                "{given} stage configs for a {stages}-stage pipeline — \
                 scripted reconfigs would be dropped"
            ),
        }
    }
}

impl std::error::Error for HarnessError {}

/// Per-stage outcome of a pipeline run.
pub struct StageRunStats {
    pub name: &'static str,
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times of this stage.
    pub reconfigs: Vec<(u64, f64)>,
}

/// Result of a pipeline run.
pub struct PipelineRunResult {
    pub stages: Vec<StageRunStats>,
    /// Data tuples drained at the final egress.
    pub egress_count: u64,
    /// Tuples the harness had to discard because their ingress wrapper's
    /// source slot was decommissioned mid-run (the wrapper leaves the
    /// feed rotation; 0 in healthy runs — nonzero means egress/latency
    /// stats cover only part of the offered stream).
    pub ingress_dropped: u64,
    /// Whole-run end-to-end latency (ingest stamp at stage 0 → final
    /// egress) over every stamped output tuple.
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
}

/// Book-keeping the run loop carries per stage.
struct StageLoopState {
    cfg: StageRunConfig,
    last_snap: MetricsSnapshot,
    prev_loads: Vec<u64>,
    next_manual: usize,
    next_controller_s: u32,
    /// Arrival rate (t/event-s, de-duplicated across instances) of the
    /// latest sample — the controller's offered-load estimate for
    /// non-source stages.
    last_arrival_tps: f64,
    samples: Vec<RunSample>,
}

/// Drive a live, threaded VSN topology: pace `source` round-robin
/// across every ingress wrapper, drain every egress reader, tick every
/// stage's manual/controller reconfigurations (and the optional global
/// [`DagController`]) independently, and sample per-stage metrics once
/// per event second.
///
/// Every ingress wrapper is fed every tick (an idle wrapper's gate clock
/// would hold back readiness) and every egress reader is drained (an
/// undrained reader would pin its gate's backlog at capacity and stall
/// the sink stage) — that is what makes N-ingress/M-egress DAG shapes
/// safe where the old single-path loop had to panic.
pub fn run_pipeline<In, Out>(
    mut pipeline: Pipeline<In, Out>,
    mut cfg: PipelineRunConfig,
    source: &mut dyn PacedSource<In>,
) -> Result<PipelineRunResult, HarnessError>
where
    In: Payload + Default,
    Out: Payload + Default,
{
    let clock = pipeline.clock.clone();
    let mut ings: Vec<StretchIngress<In>> = std::mem::take(&mut pipeline.ingress);
    let n_ing = ings.len();
    if n_ing == 0 {
        return Err(HarnessError::NoIngress);
    }
    if pipeline.egress.is_empty() {
        return Err(HarnessError::NoEgress);
    }
    let mut egress: Vec<EgressDriver<Tuple<Out>>> = std::mem::take(&mut pipeline.egress)
        .into_iter()
        .map(|r| EgressDriver::new(r, clock.clone()))
        .collect();
    // all drivers record into ONE histogram pair: end-to-end latency is
    // a property of the whole topology, whichever sink a tuple exits
    let (lat, lat_total) = (egress[0].latency_us.clone(), egress[0].latency_total_us.clone());
    for d in egress.iter_mut().skip(1) {
        d.latency_us = lat.clone();
        d.latency_total_us = lat_total.clone();
    }

    let n_stages = pipeline.depth();
    if cfg.stages.len() > n_stages {
        return Err(HarnessError::ExtraStageConfigs { given: cfg.stages.len(), stages: n_stages });
    }
    let mut stage_cfgs: Vec<StageRunConfig> = std::mem::take(&mut cfg.stages);
    while stage_cfgs.len() < n_stages {
        stage_cfgs.push(StageRunConfig::default());
    }
    let mut loops: Vec<StageLoopState> = stage_cfgs
        .into_iter()
        .take(n_stages)
        .enumerate()
        .map(|(k, mut sc)| {
            sc.manual_reconfigs.sort_by_key(|&(at, _)| at);
            let period = sc.controller_period_s.max(1);
            StageLoopState {
                last_snap: MetricsSnapshot::default(),
                prev_loads: vec![0; pipeline.stages[k].max_parallelism()],
                next_manual: 0,
                next_controller_s: period,
                last_arrival_tps: 0.0,
                samples: Vec::new(),
                cfg: sc,
            }
        })
        .collect();

    let duration_s = cfg.schedule.duration_s();
    let mut pending_event_tuples = 0.0f64;
    let mut event_ms_total: f64 = 0.0;
    // per-tick feed runs, one per ingress wrapper (round-robin split so
    // EVERY wrapper's gate clock advances every tick), each handed over
    // via one batched add (§Perf). A wrapper whose slot is decommissioned
    // under us (`Err(Inactive)`) leaves the rotation; its residual is
    // counted in `ingress_dropped`, never silently discarded.
    let mut feed_bufs: Vec<Vec<Tuple<In>>> = (0..n_ing).map(|_| Vec::new()).collect();
    let mut alive: Vec<bool> = vec![true; n_ing];
    let mut n_alive = n_ing;
    let mut ingress_dropped = 0u64;
    let mut rr = 0usize;
    let mut next_dag_ctl_s: u32 = cfg.dag_controller_period_s.max(1);
    let t0 = Instant::now();

    // wall tick: 20 ms of *wall* time per loop iteration
    let wall_tick = Duration::from_millis(20);
    let mut next_tick = t0;
    let mut next_sample_s: u32 = 1;

    loop {
        // how far event time should have progressed
        let wall_s = t0.elapsed().as_secs_f64();
        let event_s = wall_s * cfg.time_scale;
        // run slightly past the end so the final per-second sample lands
        if event_s >= duration_s as f64 + 0.1 {
            break;
        }
        let cur_rate = cfg.schedule.rate_at(event_s as u32);
        if event_s < duration_s as f64 {
            source.set_rate(cur_rate);
            // feed the tuples that belong to this tick
            let tick_event_s = wall_tick.as_secs_f64() * cfg.time_scale;
            pending_event_tuples += cur_rate * tick_event_s;
            let n = pending_event_tuples.floor() as usize;
            pending_event_tuples -= n as f64;
            event_ms_total += tick_event_s * 1e3;
            let ingress_batch = cfg.ingress_batch.max(1);
            for _ in 0..n {
                let mut t = source.next();
                t.ingest_us = clock.now_us();
                if n_alive == 0 {
                    ingress_dropped += 1; // every wrapper decommissioned
                    continue;
                }
                while !alive[rr] {
                    rr = (rr + 1) % n_ing;
                }
                feed_bufs[rr].push(t);
                if feed_bufs[rr].len() >= ingress_batch
                    && ings[rr].add_batch(&mut feed_bufs[rr]).is_err()
                {
                    // decommissioned mid-run: retire the wrapper from the
                    // rotation and account for the lost residual
                    ingress_dropped += feed_bufs[rr].len() as u64;
                    feed_bufs[rr].clear();
                    alive[rr] = false;
                    n_alive -= 1;
                }
                rr = (rr + 1) % n_ing;
            }
            for (i, buf) in feed_bufs.iter_mut().enumerate() {
                if alive[i] && ings[i].add_batch(buf).is_err() {
                    ingress_dropped += buf.len() as u64;
                    buf.clear();
                    alive[i] = false;
                    n_alive -= 1;
                }
            }
        }
        for d in egress.iter_mut() {
            d.poll();
        }

        // per-event-second sampling, every stage
        while (next_sample_s as f64) <= event_s && next_sample_s <= duration_s {
            for (k, st) in loops.iter_mut().enumerate() {
                let stage = &pipeline.stages[k];
                let metrics = stage.metrics();
                let snap = metrics.snapshot();
                let dt = 1.0 / cfg.time_scale; // wall seconds per event second
                let rates = snap.rates_since(&st.last_snap, dt);
                let active = stage.active_instances();
                // per-interval load CV (Fig. 9 right): deltas, active set only
                let cv = {
                    let deltas: Vec<f64> = active
                        .iter()
                        .map(|&i| (metrics.instance_load(i) - st.prev_loads[i]) as f64)
                        .collect();
                    for (i, p) in st.prev_loads.iter_mut().enumerate() {
                        *p = metrics.instance_load(i);
                    }
                    let mean = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
                    if deltas.len() < 2 || mean <= 0.0 {
                        0.0
                    } else {
                        let var = deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
                            / deltas.len() as f64;
                        100.0 * var.sqrt() / mean
                    }
                };
                // Every active instance reads (and counts) every gate
                // tuple, so the summed rate is m× the true arrival rate;
                // dividing by the active count recovers arrivals.
                let arrival_tps =
                    rates.in_tps / cfg.time_scale / active.len().max(1) as f64;
                st.last_arrival_tps = arrival_tps;
                st.samples.push(RunSample {
                    t_s: next_sample_s,
                    // With ONE ingress wrapper, stage 0 is offered the
                    // whole schedule. With several wrappers the harness
                    // cannot map wrappers to source stages (a DAG may
                    // have several), so every stage reports its measured
                    // arrival rate instead of a guessed split.
                    offered_tps: if k == 0 && n_ing == 1 {
                        cfg.schedule.rate_at(next_sample_s - 1)
                    } else {
                        arrival_tps
                    },
                    // rates are per wall second; report per *event* second
                    in_tps: arrival_tps,
                    out_tps: rates.out_tps / cfg.time_scale,
                    cmp_per_s: rates.cmp_per_s / cfg.time_scale,
                    latency_p50_us: lat.p50(),
                    latency_mean_us: lat.mean(),
                    threads: active.len(),
                    backlog: stage.in_backlog(),
                    load_cv_pct: cv,
                });
                st.last_snap = snap;
            }
            // end-to-end latency is a property of the whole pipeline; the
            // per-second histogram resets once all stages sampled it
            lat.reset();
            next_sample_s += 1;
        }

        // per-stage scripted reconfigurations (bypass the controllers)
        for (k, st) in loops.iter_mut().enumerate() {
            while st.next_manual < st.cfg.manual_reconfigs.len()
                && (st.cfg.manual_reconfigs[st.next_manual].0 as f64) <= event_s
            {
                let set = st.cfg.manual_reconfigs[st.next_manual].1.clone();
                pipeline.stages[k].reconfigure(set.clone(), Mapper::over(set));
                st.next_manual += 1;
            }
        }
        // per-stage controller ticks
        for (k, st) in loops.iter_mut().enumerate() {
            let period = st.cfg.controller_period_s.max(1);
            if let Some(ctl) = st.cfg.controller.as_mut() {
                if (st.next_controller_s as f64) <= event_s {
                    st.next_controller_s += period;
                    let stage = &mut pipeline.stages[k];
                    let active = stage.active_instances();
                    let obs = Observation {
                        // the schedule rate only describes stage 0 when a
                        // single wrapper feeds it the whole stream; with
                        // several wrappers (possibly several source
                        // stages) use the measured arrival rate
                        in_rate: if k == 0 && n_ing == 1 {
                            cur_rate
                        } else {
                            st.last_arrival_tps
                        },
                        cmp_per_s: st.samples.last().map(|s| s.cmp_per_s).unwrap_or(0.0),
                        backlog: stage.in_backlog(),
                        dt: period as f64,
                        active,
                        max: stage.max_parallelism(),
                    };
                    if let Decision::Reconfigure(set) = ctl.tick(&obs) {
                        let mapper = Mapper::over(set.clone());
                        stage.reconfigure(set, mapper);
                    }
                }
            }
        }
        // global co-scheduling tick: one observation per stage, one
        // decision wave against the shared core budget
        if let Some(dc) = cfg.dag_controller.as_mut() {
            let period = cfg.dag_controller_period_s.max(1);
            if (next_dag_ctl_s as f64) <= event_s {
                next_dag_ctl_s += period;
                let obs: Vec<Observation> = loops
                    .iter()
                    .enumerate()
                    .map(|(k, st)| Observation {
                        in_rate: if k == 0 && n_ing == 1 {
                            cur_rate
                        } else {
                            st.last_arrival_tps
                        },
                        cmp_per_s: st.samples.last().map(|s| s.cmp_per_s).unwrap_or(0.0),
                        backlog: pipeline.stages[k].in_backlog(),
                        dt: period as f64,
                        active: pipeline.stages[k].active_instances(),
                        max: pipeline.stages[k].max_parallelism(),
                    })
                    .collect();
                for (k, d) in dc.tick(&obs).into_iter().enumerate() {
                    if let Decision::Reconfigure(set) = d {
                        let mapper = Mapper::over(set.clone());
                        pipeline.stages[k].reconfigure(set, mapper);
                    }
                }
            }
        }

        next_tick += wall_tick;
        let now = Instant::now();
        if next_tick > now {
            std::thread::sleep(next_tick - now);
        } else {
            next_tick = now; // fell behind: don't try to catch up the wall
        }
    }

    // flush: end-of-stream heartbeat on EVERY ingress wrapper (workers
    // forward it stage to stage; a silent wrapper would hold back every
    // downstream watermark), then drain remaining outputs briefly
    let horizon = event_ms_total as EventTime + cfg.flush_slack_ms;
    for (i, ing) in ings.iter_mut().enumerate() {
        if alive[i] {
            let _ = ing.heartbeat(horizon); // heartbeats carry no data
        }
    }
    let drain_until = Instant::now() + cfg.drain;
    while Instant::now() < drain_until {
        let mut polled = 0;
        for d in egress.iter_mut() {
            polled += d.poll();
        }
        if polled == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    let latency_p50_us = lat_total.p50();
    let latency_mean_us = lat_total.mean();
    let egress_count = egress.iter().map(|d| d.count).sum();
    let stages = loops
        .into_iter()
        .enumerate()
        .map(|(k, st)| StageRunStats {
            name: pipeline.stages[k].name(),
            samples: st.samples,
            reconfigs: pipeline.stages[k].completion_times(),
        })
        .collect();
    pipeline.shutdown();
    Ok(PipelineRunResult {
        stages,
        egress_count,
        ingress_dropped,
        latency_p50_us,
        latency_mean_us,
    })
}

/// Run a live, threaded VSN ScaleJoin experiment — the Q3-Q6 entry point,
/// now a thin wrapper over [`run_pipeline`] with a single-stage pipeline.
pub fn run_elastic_join(cfg: JoinRunConfig) -> RunResult {
    let def = q3_operator(cfg.ws_ms, cfg.n_keys);
    let pipeline = PipelineBuilder::new(
        def,
        VsnOptions {
            initial: cfg.initial,
            max: cfg.max,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: cfg.gate_capacity,
            worker_batch: cfg.worker_batch.max(1),
            ..Default::default()
        },
    )
    .build();
    let mut gen = SjGen::new(cfg.seed, 1.0);
    let pcfg = PipelineRunConfig {
        schedule: cfg.schedule,
        time_scale: cfg.time_scale,
        stages: vec![StageRunConfig {
            controller: cfg.controller,
            controller_period_s: cfg.controller_period_s,
            manual_reconfigs: cfg.manual_reconfigs,
        }],
        flush_slack_ms: cfg.ws_ms + 10_000,
        drain: Duration::from_millis(500),
        ingress_batch: cfg.ingress_batch.max(1),
        ..Default::default()
    };
    // the builder above wires exactly one ingress and one egress, so the
    // typed degenerate-topology errors cannot occur here
    let r = run_pipeline(pipeline, pcfg, &mut gen)
        .expect("single-stage pipeline always has one ingress and one egress");
    let stage0 = r.stages.into_iter().next().expect("single-stage pipeline");
    RunResult { samples: stage0.samples, reconfigs: stage0.reconfigs, egress_count: r.egress_count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::{JoinCostModel, ReactiveController, Thresholds};
    use crate::workloads::nyse::NyseConfig;
    use crate::workloads::{hedge_join_op, trade_fanout_op};

    #[test]
    fn batch_tuning_reaches_engine_options() {
        let cfg = crate::config::Config::parse("[batch]\nworker = 32\nqueue = 16").unwrap();
        let t = crate::config::BatchTuning::from_config(&cfg);
        let v = VsnOptions::default().with_batch(&t);
        assert_eq!(v.worker_batch, 32);
        let s = crate::engine::SnOptions::default().with_batch(&t);
        assert_eq!(s.batch, 16);
    }

    #[test]
    fn harness_steady_run_produces_samples() {
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::constant(4, 500.0),
            time_scale: 4.0, // 4 event-seconds in ~1 wall-second
            initial: 2,
            max: 4,
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert_eq!(r.samples.len(), 4);
        assert!(r.egress_count > 0 || r.samples.iter().any(|s| s.cmp_per_s > 0.0));
        assert!(r.samples.iter().all(|s| s.threads == 2));
    }

    #[test]
    fn harness_controller_provisions_under_ramp() {
        // calibrate a model, then drive well past 1-thread capacity
        let model = JoinCostModel::new(5e5, 1.0); // deliberately small capacity
        let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(1);
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::step(6, 2, 200.0, 1500.0),
            time_scale: 3.0,
            initial: 1,
            max: 4,
            controller: Some(Box::new(ctl)),
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert!(!r.reconfigs.is_empty(), "controller should have reconfigured");
        assert!(r.samples.last().unwrap().threads > 1);
    }

    #[test]
    fn degenerate_topologies_are_typed_errors_not_panics() {
        // no egress reader: the sink gate would fill with nobody draining
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, egress_readers: 0, ..Default::default() },
        )
        .build();
        let mut gen = SjGen::new(1, 1.0);
        match run_pipeline(pipeline, PipelineRunConfig::default(), &mut gen) {
            Err(HarnessError::NoEgress) => {}
            other => panic!("expected NoEgress, got {:?}", other.map(|_| ()).err()),
        }
        // more stage configs than stages: scripted reconfigs would drop
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, ..Default::default() },
        )
        .build();
        let cfg = PipelineRunConfig {
            stages: vec![StageRunConfig::default(), StageRunConfig::default()],
            ..Default::default()
        };
        match run_pipeline(pipeline, cfg, &mut gen) {
            Err(HarnessError::ExtraStageConfigs { given: 2, stages: 1 }) => {}
            other => panic!("expected ExtraStageConfigs, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn pipeline_harness_runs_two_stages_with_manual_reconfigs() {
        // NYSE fan-out → hedge join, reconfiguring EACH stage once
        let pipeline = PipelineBuilder::new(
            trade_fanout_op(64),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .stage(
            hedge_join_op(1_000, 32),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .build();
        let mut source = TradeStream::new(&NyseConfig::default(), 400.0);
        let r = run_pipeline(
            pipeline,
            PipelineRunConfig {
                schedule: RateSchedule::constant(4, 400.0),
                time_scale: 4.0,
                stages: vec![
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                ],
                flush_slack_ms: 5_000,
                drain: Duration::from_millis(500),
                ..Default::default()
            },
            &mut source,
        )
        .unwrap();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages[0].samples.len(), 4);
        assert_eq!(r.stages[1].samples.len(), 4);
        // both stages completed their independent reconfigurations
        assert_eq!(r.stages[0].reconfigs.len(), 1, "stage 0 reconfig lost");
        assert_eq!(r.stages[1].reconfigs.len(), 1, "stage 1 reconfig lost");
        assert_eq!(r.stages[0].samples.last().unwrap().threads, 2);
        assert_eq!(r.stages[1].samples.last().unwrap().threads, 2);
        // data flowed through the shared gate into stage 2
        assert!(r.stages[1].samples.iter().any(|s| s.in_tps > 0.0));
    }
}
