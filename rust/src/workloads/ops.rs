//! Evaluation operator definitions (Appendix D).
//!
//! * Operator 2 — `A+` longest tweet per hashtag (the running example);
//! * Operator 5 — `A+` wordcount / paircount ([`count_per_key_op`] with
//!   the key functions from [`super::tweets`]);
//! * Operator 6 — the Q2 forwarding `O+` with I = 2 measuring the data
//!   sharing/sorting bottleneck.

use crate::operator::aggregate::{count_per_key_op, CountPerKey, FnAggLogic};
use crate::operator::map::{map_stage_op, MapLogic, MapStageLogic};
use crate::operator::state::WindowSet;
use crate::operator::{Ctx, OperatorDef, OperatorLogic, WindowType};
use crate::time::{WindowSpec, DELTA};
use crate::tuple::{Key, Payload, Tuple};
use crate::workloads::tweets::Tweet;

/// Operator 2: longest tweet (in chars) per hashtag per window.
pub fn longest_tweet_op(
    spec: WindowSpec,
) -> OperatorDef<FnAggLogic<Tweet, (Key, u64), u64>> {
    let logic = FnAggLogic::new(
        |t: &Tuple<Tweet>, keys: &mut Vec<Key>| super::tweets::hashtag_keys(t, keys),
        |w, t, _ctx| {
            if t.payload.chars as u64 > w.states[0] {
                w.states[0] = t.payload.chars as u64;
            }
        },
        |w, ctx| ctx.emit((w.key, w.states[0])),
    );
    OperatorDef::new("longest-tweet", spec, 1, WindowType::Multi, logic)
}

/// Operator 5 (wordcount flavour): count tweets per word per window.
pub fn wordcount_op(
    spec: WindowSpec,
) -> OperatorDef<CountPerKey<Tweet, impl Fn(&Tuple<Tweet>, &mut Vec<Key>) + Send + Sync>> {
    count_per_key_op("wordcount", spec, super::tweets::wordcount_keys)
}

/// Operator 5 (paircount flavour) with pair distance `bound`.
pub fn paircount_op(
    spec: WindowSpec,
    bound: usize,
) -> OperatorDef<CountPerKey<Tweet, impl Fn(&Tuple<Tweet>, &mut Vec<Key>) + Send + Sync>> {
    count_per_key_op("paircount", spec, super::tweets::paircount_keys(bound))
}

/// Operator 6: the Q2 forwarding `O+` (I = 2, WA = WS = δ, WT = single).
/// f_MK returns all n keys; f_μ is the identity, so instance j handles
/// key j and every instance forwards every tuple — the measured cost is
/// pure data sharing + sorting.
pub struct ForwardLogic<P> {
    pub n: u64,
    _marker: std::marker::PhantomData<fn(P)>,
}

impl<P: crate::tuple::Payload> OperatorLogic for ForwardLogic<P> {
    type In = P;
    type Out = P;
    type State = ();

    fn keys(&self, _t: &Tuple<P>, keys: &mut Vec<Key>) {
        keys.extend(0..self.n);
    }

    fn update(&self, _w: &mut WindowSet<()>, t: &Tuple<P>, ctx: &mut Ctx<'_, P>) {
        ctx.emit(t.payload.clone());
    }

    fn slide(&self, _w: &mut WindowSet<()>, _new_l: crate::time::EventTime) -> bool {
        true // keep the (stateless) window set; counters-free
    }

    fn has_output(&self) -> bool {
        false
    }

    fn keys_are_constant(&self) -> bool {
        true // f_MK = {0..n} for every tuple
    }
}

/// Build Operator 6 for parallelism degree `n`.
pub fn forward_op<P: crate::tuple::Payload>(n: usize) -> OperatorDef<ForwardLogic<P>> {
    OperatorDef::new(
        "forward",
        WindowSpec::new(DELTA, DELTA),
        2,
        WindowType::Single,
        ForwardLogic { n: n as u64, _marker: std::marker::PhantomData },
    )
}

/// Identity map for the registry's `forward` stage: emit every input
/// payload unchanged, τ preserved. Unlike [`ForwardLogic`] (Operator 6,
/// which deliberately re-emits per *instance* to measure the data
/// sharing/sorting bottleneck), this forwards each tuple exactly once —
/// the cheap stateless stage schedule demos scale up and down.
pub struct IdentityMap<P>(std::marker::PhantomData<fn(P) -> P>);

impl<P> Default for IdentityMap<P> {
    fn default() -> Self {
        IdentityMap(std::marker::PhantomData)
    }
}

impl<P: Payload> MapLogic for IdentityMap<P> {
    type In = P;
    type Out = P;

    fn flat_map(&self, t: &Tuple<P>, emit: &mut dyn FnMut(P)) {
        emit(t.payload.clone());
    }
}

/// Deploy the identity forward as an elastic Map stage (the registry's
/// `forward` operator; `lb_keys` synthetic routing keys, use ≫ max Π).
pub fn forward_stage_op<P: Payload>(lb_keys: u64) -> OperatorDef<MapStageLogic<IdentityMap<P>>> {
    map_stage_op("forward", IdentityMap::default(), lb_keys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OperatorMetrics;
    use crate::operator::state::SharedState;
    use crate::operator::OperatorCore;
    use crate::tuple::Mapper;

    #[test]
    fn forward_emits_per_instance() {
        // 2 instances: each forwards every tuple once
        let def = forward_op::<u32>(2);
        let shared = SharedState::new(4);
        let metrics = OperatorMetrics::new(2);
        let f_mu = Mapper::over(vec![0, 1]); // identity over 2 keys? HashMod ok
        let mut cores: Vec<_> = (0..2)
            .map(|i| OperatorCore::new(def.clone(), i, shared.clone(), metrics.clone()))
            .collect();
        let mut out = Vec::new();
        for ts in 1..=10i64 {
            let t = Tuple::data(ts, ts as u32);
            for c in cores.iter_mut() {
                let mut sink = |o: Tuple<u32>| out.push(o.payload);
                let mut ctx = Ctx::new(&mut sink);
                c.process(&t, &f_mu, &mut ctx);
            }
        }
        // each of the 10 tuples forwarded by each of 2 instances
        assert_eq!(out.len(), 20);
    }

    #[test]
    fn identity_forward_stage_emits_each_tuple_once_with_ts() {
        let def = forward_stage_op::<u32>(16);
        let mut core =
            OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out: Vec<(i64, u32)> = Vec::new();
        for ts in 1..=5i64 {
            let t = Tuple::data(ts, ts as u32 * 10);
            let mut sink = |o: Tuple<u32>| out.push((o.ts, o.payload));
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        assert_eq!(out, vec![(1, 10), (2, 20), (3, 30), (4, 40), (5, 50)]);
    }

    #[test]
    fn longest_tweet_op_emits_max() {
        use std::sync::Arc;
        let def = longest_tweet_op(WindowSpec::new(100, 100));
        let mut core =
            OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mk = |ts, tag: u32, chars| {
            Tuple::data(
                ts,
                Tweet {
                    user: 0,
                    words: Arc::new(vec![]),
                    hashtags: Arc::new(vec![tag]),
                    chars,
                },
            )
        };
        let mut out = Vec::new();
        for t in [mk(1, 7, 30), mk(2, 7, 55), mk(3, 7, 40), Tuple::heartbeat(500)] {
            let mut sink = |o: Tuple<(Key, u64)>| out.push(o.payload);
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        assert_eq!(out, vec![(7, 55)]);
    }
}
