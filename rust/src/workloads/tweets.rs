//! Synthetic tweet corpus (the Q1/Q2 workload substitute).
//!
//! The paper processes 4.3M real tweets (Oct 1-2, 2018). Q1's controlled
//! variable is the *duplication level* — how many keys (words / word
//! pairs / hashtags) a tuple yields — which this generator reproduces
//! exactly: Zipf-distributed vocabulary, configurable words-per-tweet and
//! hashtags-per-tweet, and the paircount distance bound B ∈ {3, 10, ∞}
//! (L/M/H duplication). See DESIGN.md §5 (substitutions).

use crate::operator::aggregate::{count_per_key_op, CountPerKey};
use crate::operator::map::{map_stage_op, MapLogic, MapStageLogic};
use crate::operator::OperatorDef;
use crate::time::WindowSpec;
use crate::tuple::{Key, Tuple};
use crate::util::{Rng, Zipf};
use std::sync::Arc;

/// A tweet payload: interned word ids + hashtag ids + length in chars.
#[derive(Clone, Debug, Default)]
pub struct Tweet {
    pub user: u32,
    pub words: Arc<Vec<u32>>,
    pub hashtags: Arc<Vec<u32>>,
    pub chars: u32,
}

/// Corpus generator parameters.
#[derive(Clone, Debug)]
pub struct TweetGenConfig {
    pub vocab: usize,
    pub hashtag_vocab: usize,
    pub zipf_s: f64,
    pub min_words: usize,
    pub max_words: usize,
    pub max_hashtags: usize,
    /// Mean inter-arrival gap in event-time ms.
    pub mean_gap_ms: f64,
    pub seed: u64,
}

impl Default for TweetGenConfig {
    fn default() -> Self {
        TweetGenConfig {
            vocab: 50_000,
            hashtag_vocab: 2_000,
            zipf_s: 1.1,
            min_words: 3,
            max_words: 18,
            max_hashtags: 3,
            mean_gap_ms: 1.0,
            seed: 0x7EE75,
        }
    }
}

pub struct TweetGen {
    cfg: TweetGenConfig,
    rng: Rng,
    words: Zipf,
    tags: Zipf,
    ts: i64,
}

impl TweetGen {
    pub fn new(cfg: TweetGenConfig) -> Self {
        TweetGen {
            rng: Rng::new(cfg.seed),
            words: Zipf::new(cfg.vocab, cfg.zipf_s),
            tags: Zipf::new(cfg.hashtag_vocab, cfg.zipf_s),
            ts: 0,
            cfg,
        }
    }

    /// Adjust the mean arrival rate (tweets per event-second) — used by
    /// the pipeline harness to replay rate schedules.
    pub fn set_rate(&mut self, tps: f64) {
        self.cfg.mean_gap_ms = (1000.0 / tps.max(1.0)).max(1e-6);
    }

    /// Next tweet tuple (timestamps strictly advance in expectation).
    pub fn next(&mut self) -> Tuple<Tweet> {
        self.ts += self.rng.exp(self.cfg.mean_gap_ms).round().max(0.0) as i64;
        let nw = self.rng.range(self.cfg.min_words, self.cfg.max_words + 1);
        let words: Vec<u32> = (0..nw).map(|_| self.words.sample(&mut self.rng) as u32).collect();
        let nh = self.rng.range(0, self.cfg.max_hashtags + 1);
        let hashtags: Vec<u32> =
            (0..nh).map(|_| self.tags.sample(&mut self.rng) as u32).collect();
        let chars = words.len() as u32 * 6 + self.rng.gen_range(20) as u32;
        Tuple::data(
            self.ts,
            Tweet {
                user: self.rng.next_u32() % 1_000_000,
                words: Arc::new(words),
                hashtags: Arc::new(hashtags),
                chars,
            },
        )
    }

    pub fn take(&mut self, n: usize) -> Vec<Tuple<Tweet>> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// f_MK for **wordcount** (Operator 5): one key per distinct word.
pub fn wordcount_keys(t: &Tuple<Tweet>, keys: &mut Vec<Key>) {
    let start = keys.len();
    for &w in t.payload.words.iter() {
        let k = w as Key;
        if !keys[start..].contains(&k) {
            keys.push(k);
        }
    }
}

// ---- the 2-stage wordcount pipeline (tokenize M → windowed count A+) --

/// Stage 1 of the pipeline wordcount: tokenize — one output tuple per
/// *distinct* word of the tweet, τ preserved. This is the Map `M` of
/// §2.1 deployed as an elastic VSN stage; downstream the words are plain
/// single-key tuples, so stage 2 is an ordinary key-by count.
pub struct Tokenize;

impl MapLogic for Tokenize {
    type In = Tweet;
    type Out = Key;

    fn flat_map(&self, t: &Tuple<Tweet>, emit: &mut dyn FnMut(Key)) {
        let ws = &t.payload.words;
        for (i, &w) in ws.iter().enumerate() {
            if !ws[..i].contains(&w) {
                emit(w as Key);
            }
        }
    }
}

/// Stage-1 operator: tokenize as an elastic Map stage (`lb_keys`
/// synthetic routing keys; use ≫ the stage's max parallelism).
pub fn tokenize_op(lb_keys: u64) -> OperatorDef<MapStageLogic<Tokenize>> {
    map_stage_op("tokenize", Tokenize, lb_keys)
}

/// Stage-2 operator: windowed count over the tokenized word stream (each
/// input tuple's payload IS its key).
pub fn word_count_stage_op(
    spec: WindowSpec,
) -> OperatorDef<CountPerKey<Key, impl Fn(&Tuple<Key>, &mut Vec<Key>) + Send + Sync>> {
    count_per_key_op("wordcount-stage", spec, |t: &Tuple<Key>, keys: &mut Vec<Key>| {
        keys.push(t.payload)
    })
}

/// f_MK for **paircount** (Operator 5): one key per distinct word pair
/// within distance `bound` (L: 3, M: 10, H: usize::MAX).
pub fn paircount_keys(bound: usize) -> impl Fn(&Tuple<Tweet>, &mut Vec<Key>) + Send + Sync {
    move |t, keys| {
        let ws = &t.payload.words;
        let start = keys.len();
        for i in 0..ws.len() {
            let hi = if bound == usize::MAX { ws.len() } else { (i + 1 + bound).min(ws.len()) };
            for j in (i + 1)..hi {
                let (a, b) = if ws[i] <= ws[j] { (ws[i], ws[j]) } else { (ws[j], ws[i]) };
                let k = ((a as u64) << 32) | b as u64;
                if !keys[start..].contains(&k) {
                    keys.push(k);
                }
            }
        }
    }
}

/// f_MK for the running example (Operator 2): one key per hashtag.
pub fn hashtag_keys(t: &Tuple<Tweet>, keys: &mut Vec<Key>) {
    let start = keys.len();
    for &h in t.payload.hashtags.iter() {
        let k = h as Key;
        if !keys[start..].contains(&k) {
            keys.push(k);
        }
    }
}

/// Average duplication factor (keys per tuple) of a key function over a
/// sample — the Q1 independent variable.
pub fn duplication_factor(
    tuples: &[Tuple<Tweet>],
    key_fn: impl Fn(&Tuple<Tweet>, &mut Vec<Key>),
) -> f64 {
    let mut keys = Vec::new();
    let mut total = 0usize;
    for t in tuples {
        keys.clear();
        key_fn(t, &mut keys);
        total += keys.len();
    }
    total as f64 / tuples.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_gen() -> TweetGen {
        TweetGen::new(TweetGenConfig {
            vocab: 500,
            hashtag_vocab: 50,
            seed: 42,
            ..Default::default()
        })
    }

    #[test]
    fn timestamps_nondecreasing() {
        let mut g = small_gen();
        let ts: Vec<i64> = g.take(1000).iter().map(|t| t.ts).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn deterministic_by_seed() {
        let a = small_gen().take(50);
        let b = small_gen().take(50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.payload.words, y.payload.words);
            assert_eq!(x.ts, y.ts);
        }
    }

    #[test]
    fn wordcount_keys_distinct() {
        let mut g = small_gen();
        let mut keys = Vec::new();
        for t in g.take(200) {
            keys.clear();
            wordcount_keys(&t, &mut keys);
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), keys.len(), "duplicate keys emitted");
            assert!(!keys.is_empty());
        }
    }

    #[test]
    fn tokenize_matches_wordcount_keys() {
        use crate::operator::map::MapLogic;
        let mut g = small_gen();
        for t in g.take(200) {
            let mut want = Vec::new();
            wordcount_keys(&t, &mut want);
            let mut got = Vec::new();
            Tokenize.flat_map(&t, &mut |k| got.push(k));
            assert_eq!(got, want, "tokenize must emit exactly f_MK's distinct words");
        }
    }

    #[test]
    fn paircount_duplication_ordering() {
        // L (B=3) < M (B=10) < H (B=∞), and all > wordcount
        let tuples = small_gen().take(500);
        let wc = duplication_factor(&tuples, wordcount_keys);
        let l = duplication_factor(&tuples, paircount_keys(3));
        let m = duplication_factor(&tuples, paircount_keys(10));
        let h = duplication_factor(&tuples, paircount_keys(usize::MAX));
        assert!(wc < l, "wc={wc} l={l}");
        assert!(l < m, "l={l} m={m}");
        assert!(m <= h, "m={m} h={h}");
    }

    #[test]
    fn pair_keys_are_order_invariant() {
        let t = Tuple::data(
            0,
            Tweet { user: 0, words: Arc::new(vec![7, 3]), hashtags: Arc::new(vec![]), chars: 0 },
        );
        let t2 = Tuple::data(
            0,
            Tweet { user: 0, words: Arc::new(vec![3, 7]), hashtags: Arc::new(vec![]), chars: 0 },
        );
        let mut k1 = Vec::new();
        let mut k2 = Vec::new();
        paircount_keys(10)(&t, &mut k1);
        paircount_keys(10)(&t2, &mut k2);
        assert_eq!(k1, k2);
    }

    #[test]
    fn zipf_head_dominates() {
        let tuples = small_gen().take(2000);
        let mut counts = std::collections::HashMap::new();
        for t in &tuples {
            for &w in t.payload.words.iter() {
                *counts.entry(w).or_insert(0u32) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap();
        let avg = counts.values().sum::<u32>() as f64 / counts.len() as f64;
        assert!(max as f64 > avg * 5.0, "vocabulary should be skewed");
    }
}
