//! Phased rate schedules (the Q5 stress workload, §8.5) and paced feeding.
//!
//! Q5: "several sequential phases in which data tuples are injected with
//! a constant rate, randomly chosen from [500, 8000] t/s. The length of
//! each phase is at least 100 and at most 300 seconds. The transition
//! between phases is an abrupt change."

use crate::util::Rng;

/// A piecewise-constant rate schedule.
#[derive(Clone, Debug)]
pub struct RateSchedule {
    /// (duration in seconds, rate in t/s)
    pub phases: Vec<(u32, f64)>,
}

impl RateSchedule {
    /// The Q5 schedule: random phases in [min_rate, max_rate], lengths in
    /// [min_len, max_len] seconds, totalling ~`total_s`.
    pub fn q5(seed: u64, total_s: u32, min_rate: f64, max_rate: f64, min_len: u32, max_len: u32) -> Self {
        let mut rng = Rng::new(seed);
        let mut phases = Vec::new();
        let mut acc = 0;
        while acc < total_s {
            let len = min_len + rng.gen_range((max_len - min_len + 1) as u64) as u32;
            let len = len.min(total_s - acc);
            let rate = min_rate + rng.f64() * (max_rate - min_rate);
            phases.push((len, rate));
            acc += len;
        }
        RateSchedule { phases }
    }

    /// Constant-rate schedule.
    pub fn constant(total_s: u32, rate: f64) -> Self {
        RateSchedule { phases: vec![(total_s, rate)] }
    }

    /// The Fig. 10 step: `lead_s` at `r0`, then the rest at `r1`.
    pub fn step(total_s: u32, lead_s: u32, r0: f64, r1: f64) -> Self {
        RateSchedule { phases: vec![(lead_s, r0), (total_s - lead_s, r1)] }
    }

    /// Total duration in seconds.
    pub fn duration_s(&self) -> u32 {
        self.phases.iter().map(|&(d, _)| d).sum()
    }

    /// Rate at second `s`.
    pub fn rate_at(&self, s: u32) -> f64 {
        let mut acc = 0;
        for &(d, r) in &self.phases {
            acc += d;
            if s < acc {
                return r;
            }
        }
        self.phases.last().map(|&(_, r)| r).unwrap_or(0.0)
    }

    /// Per-second rates over the whole schedule.
    pub fn per_second(&self) -> Vec<f64> {
        (0..self.duration_s()).map(|s| self.rate_at(s)).collect()
    }

    /// Build a schedule from a config's `[run]` section: `schedule` is
    /// `constant` (default), `step`, or `q5`, each with its own rate
    /// keys. Shared by the CLI's experiment and declarative-job paths.
    ///
    /// Adding a key here? Also register it in
    /// `harness::JOB_SECTION_KEYS`, or job configs using it will be
    /// rejected as typos.
    pub fn from_config(c: &crate::config::Config) -> Self {
        let duration = c.int_or("run.duration_s", 30).max(1) as u32;
        match c.str_or("run.schedule", "constant") {
            "q5" => RateSchedule::q5(
                c.int_or("run.seed", 7) as u64,
                duration,
                c.float_or("run.min_rate", 500.0),
                c.float_or("run.max_rate", 4000.0),
                c.int_or("run.min_phase_s", 8) as u32,
                c.int_or("run.max_phase_s", 20) as u32,
            ),
            "step" => RateSchedule::step(
                duration,
                (c.int_or("run.step_at_s", duration as i64 / 3) as u32).min(duration),
                c.float_or("run.rate", 2000.0),
                c.float_or("run.step_rate", 4000.0),
            ),
            _ => RateSchedule::constant(duration, c.float_or("run.rate", 2000.0)),
        }
    }
}

/// Parse timed steps `"<second> -> <value>"` (the arrow idiom shared
/// with `[topology] edges`) into (second, value) pairs sorted by second.
/// Used by the `[schedule.<stage>]` scale/rate steps.
pub fn parse_steps(items: &[String]) -> Result<Vec<(u32, f64)>, String> {
    let mut out = Vec::with_capacity(items.len());
    for it in items {
        let (at, val) = it
            .split_once("->")
            .ok_or_else(|| format!("expected `<second> -> <value>`, got `{it}`"))?;
        let at: u32 = at
            .trim()
            .parse()
            .map_err(|_| format!("`{it}`: the part before `->` must be an event second"))?;
        let val: f64 = val
            .trim()
            .parse()
            .map_err(|_| format!("`{it}`: the part after `->` must be a number"))?;
        if !val.is_finite() {
            return Err(format!("`{it}`: value must be finite"));
        }
        out.push((at, val));
    }
    out.sort_by_key(|&(at, _)| at);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_steps_sorts_and_rejects_garbage() {
        let ok = parse_steps(&["10 -> 2000".into(), "3 -> 500.5".into()]).unwrap();
        assert_eq!(ok, vec![(3, 500.5), (10, 2000.0)]);
        assert!(parse_steps(&["10: 2000".into()]).is_err(), "missing arrow");
        assert!(parse_steps(&["x -> 2000".into()]).is_err(), "bad second");
        assert!(parse_steps(&["1 -> fast".into()]).is_err(), "bad value");
    }

    #[test]
    fn q5_phase_bounds() {
        let s = RateSchedule::q5(7, 1200, 500.0, 8000.0, 100, 300);
        assert!(s.duration_s() >= 1200);
        for (i, &(d, r)) in s.phases.iter().enumerate() {
            assert!((500.0..=8000.0).contains(&r));
            // all but the (possibly clipped) last phase respect min length
            if i + 1 < s.phases.len() {
                assert!((100..=300).contains(&d), "phase {i} len {d}");
            }
        }
        // abrupt changes: consecutive rates differ
        for w in s.phases.windows(2) {
            assert!((w[0].1 - w[1].1).abs() > 1e-9);
        }
    }

    #[test]
    fn rate_at_piecewise() {
        let s = RateSchedule::step(100, 40, 1000.0, 4000.0);
        assert_eq!(s.rate_at(0), 1000.0);
        assert_eq!(s.rate_at(39), 1000.0);
        assert_eq!(s.rate_at(40), 4000.0);
        assert_eq!(s.rate_at(99), 4000.0);
        assert_eq!(s.rate_at(200), 4000.0); // clamps to last
    }

    #[test]
    fn per_second_length() {
        let s = RateSchedule::constant(30, 100.0);
        assert_eq!(s.per_second().len(), 30);
        assert!(s.per_second().iter().all(|&r| r == 100.0));
    }
}
