//! Evaluation workloads (§8): generators, operator definitions, and the
//! baselines, one module per experiment family.
//!
//! * [`tweets`] — synthetic tweet corpus + wordcount/paircount key
//!   functions (Q1, Q2) and the 2-stage tokenize → count pipeline ops;
//! * [`scalejoin_bench`] — the §8.3 band-join streams, the 1T baseline,
//!   and the PJRT offload adapter (Q3-Q5);
//! * [`nyse`] — the synthetic NYSE trade trace + hedge predicate (Q6),
//!   the 2-stage fan-out → band-join pipeline ops, and the diamond-DAG
//!   ops (filter → L-leg ∥ R-leg → hedge join, Q7);
//! * [`rates`] — phased rate schedules (Q5) and rate steps (Q4);
//! * [`ops`] — the Appendix-D operator definitions;
//! * [`registry`] — the declarative layer's operator registry: names →
//!   [`crate::operator::OperatorDef`] constructors over the common
//!   [`registry::JobPayload`] enum, plus the paced [`registry::JobSource`]
//!   generators (consumed by [`crate::engine::job`]).

pub mod nyse;
pub mod ops;
pub mod rates;
pub mod registry;
pub mod scalejoin_bench;
pub mod tweets;

pub use nyse::{
    hedge_diamond_oracle, hedge_join_op, left_leg_op, right_leg_op, trade_fanout_op,
    trade_filter_op, TradeStream,
};
pub use ops::{forward_op, longest_tweet_op, paircount_op, wordcount_op};
pub use rates::RateSchedule;
pub use registry::{JobPayload, JobSource, PayloadKind};
pub use tweets::{tokenize_op, word_count_stage_op};
