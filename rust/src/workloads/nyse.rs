//! Synthetic NYSE trade trace (the Q6 workload substitute).
//!
//! The paper uses six hours of NYSE/NASDAQ trades (2018-07-30, NYSE FTP),
//! restricted to the 10 biggest companies, with rates oscillating between
//! 0 and 8000 t/s. What Q6 exercises is (a) the hedge self-join predicate
//! and (b) the controller's response to abrupt, bursty rate changes — both
//! reproduced here: a U-shaped intraday rate envelope with superimposed
//! bursts and lulls, and per-symbol price random walks around the
//! previous-day average. See DESIGN.md §5.

use crate::operator::join::{scalejoin_op, Either, JoinPredicate, ScaleJoinLogic};
use crate::operator::map::{map_stage_op, MapLogic, MapStageLogic};
use crate::operator::OperatorDef;
use crate::time::EventTime;
use crate::tuple::Tuple;
use crate::util::Rng;

/// A trade ⟨τ, [id, TradePrice, AveragePrice]⟩ (prices in cents).
#[derive(Clone, Copy, Debug, Default)]
pub struct Trade {
    pub id: u16,
    pub price: i32,
    pub avg: i32,
}

/// Hedge join output ⟨l_id, l_price, r_id, r_price⟩.
#[derive(Clone, Copy, Debug, Default)]
pub struct HedgeOut {
    pub l_id: u16,
    pub l_price: i32,
    pub r_id: u16,
    pub r_price: i32,
}

/// Normalized distance ND_t = (price - avg) / avg.
#[inline]
pub fn nd(t: &Trade) -> f64 {
    (t.price - t.avg) as f64 / t.avg as f64
}

/// The §8.6 hedge predicate: distinct companies whose normalized
/// distances sit in the negative-correlation band ND_l/ND_r ∈
/// [-1.05, -0.95].
pub struct HedgePredicate;

impl JoinPredicate for HedgePredicate {
    type L = Trade;
    type R = Trade;
    type Out = HedgeOut;

    #[inline]
    fn matches(&self, l: &Trade, r: &Trade) -> bool {
        if l.id == r.id {
            return false;
        }
        let (a, b) = (nd(l), nd(r));
        if b == 0.0 {
            return false;
        }
        let ratio = a / b;
        (-1.05..=-0.95).contains(&ratio)
    }

    #[inline]
    fn combine(&self, l: &Trade, r: &Trade) -> HedgeOut {
        HedgeOut { l_id: l.id, l_price: l.price, r_id: r.id, r_price: r.price }
    }
}

// ---- the 2-stage hedge pipeline (self-join fan-out M → band join J+) --

/// Stage 1 of the Q6 pipeline: the self-join fan-out Map. Every trade is
/// materialized once per join side (`Either::L` then `Either::R`, τ
/// preserved) — what the monolithic benches did by hand at the ingress
/// now runs as an elastic stage of its own. Trades whose previous-day
/// average is zero can never satisfy the hedge predicate and are dropped
/// here (cheap early filtering).
pub struct TradeFanout;

impl MapLogic for TradeFanout {
    type In = Trade;
    type Out = Either<Trade, Trade>;

    fn flat_map(&self, t: &Tuple<Trade>, emit: &mut dyn FnMut(Either<Trade, Trade>)) {
        if t.payload.avg == 0 {
            return;
        }
        emit(Either::L(t.payload));
        emit(Either::R(t.payload));
    }
}

/// Stage-1 operator: trade fan-out as an elastic Map stage.
pub fn trade_fanout_op(lb_keys: u64) -> OperatorDef<MapStageLogic<TradeFanout>> {
    map_stage_op("trade-fanout", TradeFanout, lb_keys)
}

// ---- the diamond DAG (filter → L-leg ∥ R-leg → hedge join) -----------
//
// The true-DAG flavour of the Q6 pipeline: instead of one Map stage
// materializing both join sides, the filtered trade stream FANS OUT to
// two independent Map stages — one per join side — whose outputs FAN IN
// to the hedge `J+`'s shared ESG_in. Per-branch elasticity is the point:
// the two legs scale independently (e.g. asymmetric per-side costs).

/// Diamond source stage: drop trades whose previous-day average is zero
/// (they can never satisfy the hedge predicate) and forward the rest.
pub struct TradeFilter;

impl MapLogic for TradeFilter {
    type In = Trade;
    type Out = Trade;

    fn flat_map(&self, t: &Tuple<Trade>, emit: &mut dyn FnMut(Trade)) {
        if t.payload.avg != 0 {
            emit(t.payload);
        }
    }
}

/// Diamond branch: materialize the LEFT join side of each trade.
pub struct LeftLeg;

impl MapLogic for LeftLeg {
    type In = Trade;
    type Out = Either<Trade, Trade>;

    fn flat_map(&self, t: &Tuple<Trade>, emit: &mut dyn FnMut(Either<Trade, Trade>)) {
        emit(Either::L(t.payload));
    }
}

/// Diamond branch: materialize the RIGHT join side of each trade.
pub struct RightLeg;

impl MapLogic for RightLeg {
    type In = Trade;
    type Out = Either<Trade, Trade>;

    fn flat_map(&self, t: &Tuple<Trade>, emit: &mut dyn FnMut(Either<Trade, Trade>)) {
        emit(Either::R(t.payload));
    }
}

/// Diamond source stage (filter) as an elastic Map stage.
pub fn trade_filter_op(lb_keys: u64) -> OperatorDef<MapStageLogic<TradeFilter>> {
    map_stage_op("trade-filter", TradeFilter, lb_keys)
}

/// Diamond left branch as an elastic Map stage.
pub fn left_leg_op(lb_keys: u64) -> OperatorDef<MapStageLogic<LeftLeg>> {
    map_stage_op("left-leg", LeftLeg, lb_keys)
}

/// Diamond right branch as an elastic Map stage.
pub fn right_leg_op(lb_keys: u64) -> OperatorDef<MapStageLogic<RightLeg>> {
    map_stage_op("right-leg", RightLeg, lb_keys)
}

/// Sequential reference for the diamond: every ordered trade pair
/// (l, r), l ≠ r, within the strict WS band, tested with the hedge
/// predicate — exactly the match set the fan-out → fan-in → `J+`
/// topology produces (both sides of every trade reach the join).
pub fn hedge_diamond_oracle(trades: &[Tuple<Trade>], ws_ms: EventTime) -> Vec<HedgeOut> {
    let p = HedgePredicate;
    let mut out = Vec::new();
    for (i, a) in trades.iter().enumerate() {
        for (j, b) in trades.iter().enumerate() {
            if i == j || a.payload.avg == 0 || b.payload.avg == 0 {
                continue;
            }
            if (a.ts - b.ts).abs() >= ws_ms {
                continue;
            }
            if p.matches(&a.payload, &b.payload) {
                out.push(p.combine(&a.payload, &b.payload));
            }
        }
    }
    out
}

/// Stage-2 operator: the hedge band self-join over the fanned-out stream
/// (WS in event-time ms; the paper uses 30 s).
pub fn hedge_join_op(
    ws_ms: EventTime,
    n_keys: u64,
) -> OperatorDef<ScaleJoinLogic<HedgePredicate>> {
    scalejoin_op("hedge", ws_ms, HedgePredicate, n_keys)
}

/// Trace generator configuration.
#[derive(Clone, Debug)]
pub struct NyseConfig {
    pub symbols: usize,
    /// Trace duration in event-time seconds.
    pub duration_s: u32,
    /// Peak rate (t/s) at open/close.
    pub peak_rate: f64,
    /// Midday floor rate (t/s).
    pub floor_rate: f64,
    /// Probability per second of an abrupt burst / lull.
    pub burst_prob: f64,
    pub seed: u64,
}

impl Default for NyseConfig {
    fn default() -> Self {
        NyseConfig {
            symbols: 10,
            duration_s: 600,
            peak_rate: 8000.0,
            floor_rate: 200.0,
            burst_prob: 0.05,
            seed: 0x4E595345, // "NYSE"
        }
    }
}

/// Generates a full trace as (rate profile, tuples).
pub struct NyseGen {
    cfg: NyseConfig,
    rng: Rng,
    prices: Vec<i32>,
    avgs: Vec<i32>,
}

/// One mean-reverting random-walk step of symbol `sym`'s price.
#[inline]
fn walk_price(rng: &mut Rng, prices: &mut [i32], avgs: &[i32], sym: usize) -> i32 {
    let drift = (avgs[sym] - prices[sym]) / 50;
    let noise = rng.gen_range(41) as i32 - 20;
    prices[sym] = (prices[sym] + drift + noise).max(avgs[sym] / 2);
    prices[sym]
}

impl NyseGen {
    pub fn new(cfg: NyseConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let avgs: Vec<i32> =
            (0..cfg.symbols).map(|_| 2_000 + rng.gen_range(48_000) as i32).collect();
        let prices = avgs.clone();
        NyseGen { cfg, rng, prices, avgs }
    }

    /// Intraday U-shaped envelope with bursts: rate (t/s) at second `s`.
    pub fn rate_at(&mut self, s: u32) -> f64 {
        let frac = s as f64 / self.cfg.duration_s as f64;
        // U shape: high at both ends
        let u = 4.0 * (frac - 0.5) * (frac - 0.5); // 1 at edges, 0 midday
        let base = self.cfg.floor_rate + u * (self.cfg.peak_rate - self.cfg.floor_rate);
        if self.rng.chance(self.cfg.burst_prob) {
            // abrupt burst or lull
            if self.rng.chance(0.5) {
                self.cfg.peak_rate * self.rng.f32_range(0.6, 1.0) as f64
            } else {
                self.cfg.floor_rate * self.rng.f32_range(0.0, 0.5) as f64
            }
        } else {
            base * self.rng.f32_range(0.8, 1.2) as f64
        }
    }

    /// Generate the trace: per-second rates + the trade tuples. Trades are
    /// emitted with millisecond timestamps spread uniformly in the second.
    pub fn generate(&mut self) -> (Vec<f64>, Vec<Tuple<Trade>>) {
        let mut rates = Vec::with_capacity(self.cfg.duration_s as usize);
        let mut tuples = Vec::new();
        for s in 0..self.cfg.duration_s {
            let rate = self.rate_at(s);
            rates.push(rate);
            let n = rate.round() as usize;
            let mut offs: Vec<i64> = (0..n).map(|_| self.rng.gen_range(1000) as i64).collect();
            offs.sort_unstable();
            for off in offs {
                let sym = self.rng.gen_range(self.cfg.symbols as u64) as usize;
                let price = walk_price(&mut self.rng, &mut self.prices, &self.avgs, sym);
                tuples.push(Tuple::data(
                    s as EventTime * 1000 + off,
                    Trade { id: sym as u16, price, avg: self.avgs[sym] },
                ));
            }
        }
        (rates, tuples)
    }
}

/// Incremental, rate-paced trade source (the pipeline-harness flavour of
/// [`NyseGen`]): same per-symbol random walks, but event time advances by
/// `1000 / rate` ms in expectation per tuple so a driver can replay any
/// [`crate::workloads::rates::RateSchedule`] against it.
pub struct TradeStream {
    rng: Rng,
    prices: Vec<i32>,
    avgs: Vec<i32>,
    ts: EventTime,
    frac: f64,
    pub rate_tps: f64,
}

impl TradeStream {
    pub fn new(cfg: &NyseConfig, rate_tps: f64) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let avgs: Vec<i32> =
            (0..cfg.symbols).map(|_| 2_000 + rng.gen_range(48_000) as i32).collect();
        let prices = avgs.clone();
        TradeStream { rng, prices, avgs, ts: 0, frac: 0.0, rate_tps: rate_tps.max(1.0) }
    }

    pub fn set_rate(&mut self, rate_tps: f64) {
        self.rate_tps = rate_tps.max(1.0);
    }

    pub fn next(&mut self) -> Tuple<Trade> {
        self.frac += 1000.0 / self.rate_tps;
        let step = self.frac.floor();
        self.frac -= step;
        self.ts += step as EventTime;
        let sym = self.rng.gen_range(self.avgs.len() as u64) as usize;
        let price = walk_price(&mut self.rng, &mut self.prices, &self.avgs, sym);
        Tuple::data(self.ts, Trade { id: sym as u16, price, avg: self.avgs[sym] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> NyseGen {
        NyseGen::new(NyseConfig {
            duration_s: 60,
            peak_rate: 800.0,
            floor_rate: 50.0,
            ..Default::default()
        })
    }

    #[test]
    fn trace_sorted_and_rates_bounded() {
        let (rates, tuples) = small().generate();
        assert_eq!(rates.len(), 60);
        assert!(tuples.windows(2).all(|w| w[0].ts <= w[1].ts));
        assert!(rates.iter().all(|&r| (0.0..=1000.0).contains(&r)));
    }

    #[test]
    fn u_shape_visible() {
        let (rates, _) = small().generate();
        let edge = (rates[0] + rates[59]) / 2.0;
        let mid: f64 = rates[25..35].iter().sum::<f64>() / 10.0;
        assert!(edge > mid, "edges {edge} should exceed midday {mid}");
    }

    #[test]
    fn prices_track_avg() {
        let (_, tuples) = small().generate();
        for t in &tuples {
            let ndv = nd(&t.payload).abs();
            assert!(ndv < 0.6, "price drifted too far: nd={ndv}");
        }
    }

    #[test]
    fn hedge_predicate_semantics() {
        let p = HedgePredicate;
        let l = Trade { id: 1, price: 105, avg: 100 }; // nd = 0.05
        let r = Trade { id: 2, price: 95, avg: 100 }; // nd = -0.05 → ratio -1
        assert!(p.matches(&l, &r));
        let same = Trade { id: 1, price: 95, avg: 100 };
        assert!(!p.matches(&l, &same), "same symbol must not match");
        let off = Trade { id: 3, price: 80, avg: 100 }; // ratio -0.25
        assert!(!p.matches(&l, &off));
        let possame = Trade { id: 4, price: 105, avg: 100 }; // ratio +1
        assert!(!p.matches(&l, &possame));
    }

    #[test]
    fn fanout_emits_both_sides_with_same_ts() {
        let t = Tuple::data(42, Trade { id: 3, price: 105, avg: 100 });
        let mut out = Vec::new();
        TradeFanout.flat_map(&t, &mut |e| out.push(e));
        assert_eq!(out.len(), 2);
        assert!(matches!(out[0], Either::L(l) if l.id == 3));
        assert!(matches!(out[1], Either::R(r) if r.id == 3));
        // zero-average trades are dropped (predicate can never match)
        let bad = Tuple::data(43, Trade { id: 1, price: 5, avg: 0 });
        let mut out2 = Vec::new();
        TradeFanout.flat_map(&bad, &mut |e| out2.push(e));
        assert!(out2.is_empty());
    }

    #[test]
    fn diamond_legs_materialize_one_side_each() {
        let t = Tuple::data(42, Trade { id: 3, price: 105, avg: 100 });
        let (mut l_out, mut r_out) = (Vec::new(), Vec::new());
        LeftLeg.flat_map(&t, &mut |e| l_out.push(e));
        RightLeg.flat_map(&t, &mut |e| r_out.push(e));
        assert!(matches!(l_out[..], [Either::L(x)] if x.id == 3));
        assert!(matches!(r_out[..], [Either::R(x)] if x.id == 3));
        // the filter stage drops zero-average trades; the legs pass all
        let bad = Tuple::data(43, Trade { id: 1, price: 5, avg: 0 });
        let mut f_out = Vec::new();
        TradeFilter.flat_map(&bad, &mut |e| f_out.push(e));
        assert!(f_out.is_empty());
        TradeFilter.flat_map(&t, &mut |e| f_out.push(e));
        assert_eq!(f_out.len(), 1);
    }

    #[test]
    fn diamond_oracle_counts_both_orientations_within_strict_window() {
        let a = Tuple::data(0, Trade { id: 1, price: 105, avg: 100 }); // nd = 0.05
        let b = Tuple::data(10, Trade { id: 2, price: 95, avg: 100 }); // nd = -0.05
        // both (La, Rb) and (Lb, Ra) hit the band (ratio −1 each way)
        assert_eq!(hedge_diamond_oracle(&[a.clone(), b.clone()], 100).len(), 2);
        // strict window: |Δts| ≥ WS never matches
        assert_eq!(hedge_diamond_oracle(&[a, b], 10).len(), 0);
    }

    #[test]
    fn trade_stream_paces_event_time() {
        let cfg = NyseConfig::default();
        let mut s = TradeStream::new(&cfg, 1000.0);
        let ts0 = s.next().ts;
        let mut last = ts0;
        for _ in 0..2000 {
            let t = s.next();
            assert!(t.ts >= last, "stream must stay ts-sorted");
            assert!((t.payload.id as usize) < cfg.symbols);
            assert!(nd(&t.payload).abs() < 0.6);
            last = t.ts;
        }
        // 2000 tuples at 1000 t/s ≈ 2000 ms of event time
        assert!((1600..2400).contains(&(last - ts0)), "dt={}", last - ts0);
    }

    #[test]
    fn deterministic() {
        let (r1, t1) = small().generate();
        let (r2, t2) = small().generate();
        assert_eq!(r1, r2);
        assert_eq!(t1.len(), t2.len());
    }
}
