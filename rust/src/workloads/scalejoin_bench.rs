//! The §8.3 ScaleJoin benchmark workload (Q3-Q5): two streams joined by
//! the band predicate, plus the optimized single-thread baseline (1T) and
//! the PJRT-offload predicate adapter.
//!
//! L schema ⟨τ, [x: int, y: float]⟩, R schema ⟨τ, [a: int, b: float,
//! c: double, d: bool]⟩; x, y, a, b uniform in [1, 10 000] → one output
//! per ~250k comparisons on average.

use crate::operator::join::{scalejoin_op, BatchMatcher, Either, JoinPredicate, StoredWindow};
use crate::operator::OperatorDef;
use crate::time::EventTime;
use crate::tuple::Tuple;
use crate::util::Rng;
use std::collections::VecDeque;

/// Left tuple payload ⟨x, y⟩.
#[derive(Clone, Copy, Debug, Default)]
pub struct LTuple {
    pub x: i32,
    pub y: f32,
}

/// Right tuple payload ⟨a, b, c, d⟩.
#[derive(Clone, Copy, Debug, Default)]
pub struct RTuple {
    pub a: i32,
    pub b: f32,
    pub c: f64,
    pub d: bool,
}

/// Join output: the concatenated payloads.
#[derive(Clone, Copy, Debug, Default)]
pub struct SjOut {
    pub x: i32,
    pub y: f32,
    pub a: i32,
    pub b: f32,
}

/// The §8.3 band predicate.
pub struct BandPredicate;

impl JoinPredicate for BandPredicate {
    type L = LTuple;
    type R = RTuple;
    type Out = SjOut;

    #[inline]
    fn matches(&self, l: &LTuple, r: &RTuple) -> bool {
        (r.a - 10 <= l.x && l.x <= r.a + 10) && (r.b - 10.0 <= l.y && l.y <= r.b + 10.0)
    }

    #[inline]
    fn combine(&self, l: &LTuple, r: &RTuple) -> SjOut {
        SjOut { x: l.x, y: l.y, a: r.a, b: r.b }
    }
}

pub type SjPayload = Either<LTuple, RTuple>;

/// Workload generator: alternating L/R tuples at a given event-time rate.
pub struct SjGen {
    rng: Rng,
    ts: EventTime,
    /// event-time microstep accumulator for rates above 1 t/ms
    frac: f64,
    pub rate_tps: f64,
}

impl SjGen {
    pub fn new(seed: u64, rate_tps: f64) -> Self {
        SjGen { rng: Rng::new(seed), ts: 0, frac: 0.0, rate_tps }
    }

    pub fn set_rate(&mut self, rate_tps: f64) {
        self.rate_tps = rate_tps.max(1.0);
    }

    /// Next tuple; event time advances by 1000/rate ms in expectation.
    pub fn next(&mut self) -> Tuple<SjPayload> {
        self.frac += 1000.0 / self.rate_tps;
        let step = self.frac.floor();
        self.frac -= step;
        self.ts += step as EventTime;
        let v1 = 1 + self.rng.gen_range(10_000) as i32;
        let v2 = 1.0 + self.rng.gen_range(10_000) as f32;
        if self.rng.chance(0.5) {
            Tuple::data_on(self.ts, 0, Either::L(LTuple { x: v1, y: v2 }))
        } else {
            Tuple::data_on(
                self.ts,
                1,
                Either::R(RTuple { a: v1, b: v2, c: v1 as f64 * 0.5, d: v1 % 2 == 0 }),
            )
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Tuple<SjPayload>> {
        (0..n).map(|_| self.next()).collect()
    }
}

/// Build the Q3 ScaleJoin operator: WA = δ, WS given, 1000 keys (paper).
pub fn q3_operator(
    ws: EventTime,
    n_keys: u64,
) -> OperatorDef<crate::operator::join::ScaleJoinLogic<BandPredicate>> {
    scalejoin_op("scalejoin", ws, BandPredicate, n_keys)
}

/// The optimized single-threaded baseline **1T** (§8.3): devotes every
/// cycle to the analysis — two ring windows, direct compare, no gates,
/// no counters, no routing.
pub struct OneT {
    ws: EventTime,
    l_win: VecDeque<(EventTime, LTuple)>,
    r_win: VecDeque<(EventTime, RTuple)>,
    pub comparisons: u64,
    pub matches: u64,
}

impl OneT {
    pub fn new(ws: EventTime) -> Self {
        OneT { ws, l_win: VecDeque::new(), r_win: VecDeque::new(), comparisons: 0, matches: 0 }
    }

    #[inline]
    pub fn process(&mut self, t: &Tuple<SjPayload>) {
        let cutoff = t.ts - self.ws + 1;
        match &t.payload {
            Either::L(l) => {
                while self.r_win.front().map(|&(ts, _)| ts < cutoff).unwrap_or(false) {
                    self.r_win.pop_front();
                }
                self.comparisons += self.r_win.len() as u64;
                for &(_, r) in &self.r_win {
                    if (r.a - 10 <= l.x && l.x <= r.a + 10)
                        && (r.b - 10.0 <= l.y && l.y <= r.b + 10.0)
                    {
                        self.matches += 1;
                    }
                }
                self.l_win.push_back((t.ts, *l));
            }
            Either::R(r) => {
                while self.l_win.front().map(|&(ts, _)| ts < cutoff).unwrap_or(false) {
                    self.l_win.pop_front();
                }
                self.comparisons += self.l_win.len() as u64;
                for &(_, l) in &self.l_win {
                    if (r.a - 10 <= l.x && l.x <= r.a + 10)
                        && (r.b - 10.0 <= l.y && l.y <= r.b + 10.0)
                    {
                        self.matches += 1;
                    }
                }
                self.r_win.push_back((t.ts, *r));
            }
        }
    }

    pub fn window_len(&self) -> usize {
        self.l_win.len() + self.r_win.len()
    }
}

/// PJRT-offload adapter: evaluates the band predicate through the
/// AOT-compiled Pallas kernel (thread-local PJRT instances).
pub struct KernelMatcher {
    /// reusable column buffers (behind a refcell-free &mut in probe —
    /// BatchMatcher takes &self, so buffers live in a thread local).
    _priv: (),
}

impl KernelMatcher {
    pub fn new() -> Self {
        KernelMatcher { _priv: () }
    }
}

impl Default for KernelMatcher {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    static COLS: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

fn kernel_probe(px: f32, py: f32, wa: &[f32], wb: &[f32], out: &mut Vec<u32>) {
    crate::runtime::with_thread_kernel(|k| {
        k.probe_indices(px, py, wa, wb, out).expect("kernel probe")
    })
    .expect("offload kernel unavailable (run `make artifacts`)");
}

impl BatchMatcher<BandPredicate> for KernelMatcher {
    fn probe_l(&self, probe: &LTuple, stored: &StoredWindow<RTuple>, out: &mut Vec<u32>) {
        COLS.with(|cols| {
            let (wa, wb) = &mut *cols.borrow_mut();
            wa.clear();
            wb.clear();
            for r in stored.payload.iter() {
                wa.push(r.a as f32);
                wb.push(r.b);
            }
            kernel_probe(probe.x as f32, probe.y, wa, wb, out);
        });
    }
    fn probe_r(&self, probe: &RTuple, stored: &StoredWindow<LTuple>, out: &mut Vec<u32>) {
        COLS.with(|cols| {
            let (wa, wb) = &mut *cols.borrow_mut();
            wa.clear();
            wb.clear();
            for l in stored.payload.iter() {
                wa.push(l.x as f32);
                wb.push(l.y);
            }
            kernel_probe(probe.a as f32, probe.b, wa, wb, out);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selectivity_near_paper() {
        // one match per ~250k comparisons (x,y,a,b uniform in [1,1e4], ±10)
        let mut gen = SjGen::new(1, 1000.0);
        let mut j = OneT::new(60_000);
        for t in gen.take(40_000) {
            j.process(&t);
        }
        assert!(j.comparisons > 1_000_000);
        let sel = j.comparisons as f64 / j.matches.max(1) as f64;
        assert!(
            (80_000.0..800_000.0).contains(&sel),
            "selectivity {sel} should be near 250k"
        );
    }

    #[test]
    fn onet_window_bounded_by_ws() {
        let mut gen = SjGen::new(2, 1000.0); // 1 tuple/ms
        let mut j = OneT::new(1000); // 1 s window
        for t in gen.take(10_000) {
            j.process(&t);
        }
        // ~1000 tuples fit the window (both streams combined)
        assert!(j.window_len() < 1500, "window grew to {}", j.window_len());
    }

    #[test]
    fn rate_controls_event_time() {
        let mut gen = SjGen::new(3, 2000.0);
        let ts0 = gen.next().ts;
        let tuples = gen.take(2000);
        let dt = tuples.last().unwrap().ts - ts0;
        // 2000 tuples at 2000 t/s ≈ 1000 ms of event time
        assert!((800..1200).contains(&dt), "dt={dt}");
    }

    #[test]
    fn generator_alternates_streams() {
        let mut gen = SjGen::new(4, 1000.0);
        let tuples = gen.take(1000);
        let l = tuples.iter().filter(|t| t.input == 0).count();
        assert!((300..700).contains(&l), "L/R balance off: {l}");
    }
}
