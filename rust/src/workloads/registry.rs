//! Operator registry for the declarative JobSpec layer
//! ([`crate::engine::job`]).
//!
//! A config file names its stages' operators (`tweet-tokenize`,
//! `trade-filter`, `hedge-join`, …); this module resolves those names to
//! [`OperatorDef`] constructors over ONE common payload enum,
//! [`JobPayload`], so a whole declarative topology is monomorphic — every
//! stage is an `OperatorLogic<In = JobPayload, Out = JobPayload>` and the
//! [`DagBuilder`] needs no per-job generics.
//!
//! The bridge is [`DynOp`]: it wraps any typed operator whose In/Out
//! payloads implement [`JobConvert`] and re-types tuples at the stage
//! boundary (one payload clone per delegated `keys`/`update` call —
//! payloads are small or `Arc`-backed, and the trait's `&self` methods
//! leave nowhere thread-safe to cache the retyped tuple between calls;
//! the perf-sensitive benches keep using the typed builders directly).
//! Variant mismatches cannot occur at runtime:
//! [`crate::engine::job::JobSpec`] type-checks every edge against the
//! registry's declared [`PayloadKind`]s before anything is built.

use crate::config::Config;
use crate::engine::dag::{DagBuilder, NodeHandle};
use crate::engine::vsn::VsnOptions;
use crate::operator::join::Either;
use crate::operator::state::WindowSet;
use crate::operator::{Ctx, OperatorDef, OperatorLogic};
use crate::time::{EventTime, WindowSpec};
use crate::tuple::{Key, Tuple};
use crate::workloads::nyse::{
    hedge_join_op, left_leg_op, right_leg_op, trade_fanout_op, trade_filter_op, HedgeOut,
    NyseConfig, Trade, TradeStream,
};
use crate::workloads::ops::{forward_stage_op, paircount_op};
use crate::workloads::tweets::{tokenize_op, word_count_stage_op, Tweet, TweetGen, TweetGenConfig};
use std::fmt;
use std::sync::{Arc, Mutex};

/// The payload *kind* an operator consumes/produces — the registry's
/// type system: [`crate::engine::job::JobSpec`] checks every edge's
/// upstream output kind against the consumer's input kind and rejects
/// mismatches with a typed error before any gate exists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadKind {
    /// [`Trade`] — NYSE trade tuples.
    Trade,
    /// [`Either<Trade, Trade>`] — a trade materialized on one join side.
    TradePair,
    /// [`Tweet`] — the synthetic tweet corpus.
    Tweet,
    /// [`Key`] — a single interned word id.
    Word,
    /// `(Key, u64)` — a windowed per-key count.
    WordCount,
    /// [`HedgeOut`] — a hedge join match.
    Hedge,
}

impl fmt::Display for PayloadKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PayloadKind::Trade => "trade",
            PayloadKind::TradePair => "trade-pair",
            PayloadKind::Tweet => "tweet",
            PayloadKind::Word => "word",
            PayloadKind::WordCount => "word-count",
            PayloadKind::Hedge => "hedge",
        })
    }
}

/// The common payload enum every declarative stage speaks — one variant
/// per [`PayloadKind`].
#[derive(Clone, Debug)]
pub enum JobPayload {
    Trade(Trade),
    TradePair(Either<Trade, Trade>),
    Tweet(Tweet),
    Word(Key),
    WordCount((Key, u64)),
    Hedge(HedgeOut),
}

impl Default for JobPayload {
    fn default() -> Self {
        JobPayload::Word(0)
    }
}

impl JobPayload {
    pub fn kind(&self) -> PayloadKind {
        match self {
            JobPayload::Trade(_) => PayloadKind::Trade,
            JobPayload::TradePair(_) => PayloadKind::TradePair,
            JobPayload::Tweet(_) => PayloadKind::Tweet,
            JobPayload::Word(_) => PayloadKind::Word,
            JobPayload::WordCount(_) => PayloadKind::WordCount,
            JobPayload::Hedge(_) => PayloadKind::Hedge,
        }
    }
}

/// A typed payload that maps to/from one [`JobPayload`] variant.
/// `from_job` panics on a variant mismatch — unreachable for topologies
/// that passed [`crate::engine::job::JobSpec`] validation, which is the
/// only construction path.
pub trait JobConvert: Clone + Send + Sync + Default + 'static {
    const KIND: PayloadKind;
    fn into_job(self) -> JobPayload;
    fn from_job(p: JobPayload) -> Self;
}

#[cold]
fn variant_mismatch(want: PayloadKind, got: &JobPayload) -> ! {
    panic!(
        "JobPayload variant mismatch: stage expected `{want}`, got `{}` \
         (JobSpec edge type-checking should have rejected this topology)",
        got.kind()
    )
}

macro_rules! job_convert {
    ($ty:ty, $kind:ident) => {
        impl JobConvert for $ty {
            const KIND: PayloadKind = PayloadKind::$kind;
            fn into_job(self) -> JobPayload {
                JobPayload::$kind(self)
            }
            fn from_job(p: JobPayload) -> Self {
                match p {
                    JobPayload::$kind(v) => v,
                    other => variant_mismatch(Self::KIND, &other),
                }
            }
        }
    };
}

job_convert!(Trade, Trade);
job_convert!(Either<Trade, Trade>, TradePair);
job_convert!(Tweet, Tweet);
job_convert!(Key, Word);
job_convert!((Key, u64), WordCount);
job_convert!(HedgeOut, Hedge);

/// Re-type a whole tuple into the job's common payload (metadata — τ,
/// kind, input tag, ingest stamp — is preserved verbatim).
pub fn into_job_tuple<P: JobConvert>(t: Tuple<P>) -> Tuple<JobPayload> {
    Tuple {
        ts: t.ts,
        kind: t.kind,
        input: t.input,
        ingest_us: t.ingest_us,
        payload: t.payload.into_job(),
    }
}

fn retype<P: JobConvert>(t: &Tuple<JobPayload>) -> Tuple<P> {
    Tuple {
        ts: t.ts,
        kind: t.kind.clone(),
        input: t.input,
        ingest_us: t.ingest_us,
        payload: P::from_job(t.payload.clone()),
    }
}

/// Adapter deploying a typed [`OperatorLogic`] as a
/// `JobPayload → JobPayload` stage: inputs are re-typed per call, inner
/// emissions are staged through a private [`Ctx`] and re-wrapped into
/// the outer one (timestamps, ingest stamps and comparison counts all
/// carried over), so operator semantics are bit-identical to the typed
/// deployment.
pub struct DynOp<L: OperatorLogic> {
    inner: Arc<L>,
}

impl<L> DynOp<L>
where
    L: OperatorLogic,
    L::In: JobConvert,
    L::Out: JobConvert,
{
    /// Run `f` against an inner `Ctx`, then replay its staged emissions
    /// and comparison count into the outer context.
    ///
    /// §Perf memory discipline audit: this bridge allocates — one
    /// `staged` Vec per update/output call, one payload clone per
    /// `retype` — which is inherent to erasing the operator type behind
    /// `JobPayload`, and deliberately exempt from the steady-state
    /// allocs-per-tuple contract: declarative jobs trade the bridge cost
    /// for monomorphic deployment ergonomics, while the measured hot
    /// paths (gate, worker, fan-out) stay typed. What the bridge does
    /// NOT do is duplicate per downstream edge — each tuple is re-typed
    /// once per call and the DAG replicates runs at the gate, clone
    /// N−1 / move-last (see [`crate::engine::sn::SnIngress::forward`]).
    fn bridged(&self, ctx: &mut Ctx<'_, JobPayload>, f: impl FnOnce(&L, &mut Ctx<'_, L::Out>)) {
        let mut staged: Vec<Tuple<L::Out>> = Vec::new();
        let comparisons = {
            let mut sink = |o: Tuple<L::Out>| staged.push(o);
            let mut inner = Ctx::new(&mut sink);
            inner.win_right = ctx.win_right;
            inner.ingest_us = ctx.ingest_us;
            f(&self.inner, &mut inner);
            inner.flush();
            inner.comparisons
        };
        if comparisons > 0 {
            ctx.record_comparisons(comparisons);
        }
        for o in staged {
            ctx.emit_at(o.ts, o.payload.into_job());
        }
    }
}

impl<L> OperatorLogic for DynOp<L>
where
    L: OperatorLogic,
    L::In: JobConvert,
    L::Out: JobConvert,
{
    type In = JobPayload;
    type Out = JobPayload;
    type State = L::State;

    fn keys(&self, t: &Tuple<JobPayload>, keys: &mut Vec<Key>) {
        self.inner.keys(&retype::<L::In>(t), keys);
    }

    fn update(
        &self,
        w: &mut WindowSet<L::State>,
        t: &Tuple<JobPayload>,
        ctx: &mut Ctx<'_, JobPayload>,
    ) {
        let t_in = retype::<L::In>(t);
        self.bridged(ctx, |inner, ictx| inner.update(w, &t_in, ictx));
    }

    fn output(&self, w: &WindowSet<L::State>, ctx: &mut Ctx<'_, JobPayload>) {
        self.bridged(ctx, |inner, ictx| inner.output(w, ictx));
    }

    fn slide(&self, w: &mut WindowSet<L::State>, new_l: EventTime) -> bool {
        self.inner.slide(w, new_l)
    }

    fn has_output(&self) -> bool {
        self.inner.has_output()
    }

    fn keys_are_constant(&self) -> bool {
        self.inner.keys_are_constant()
    }
}

/// Wrap a typed operator definition into its `JobPayload` deployment
/// (geometry, input count, window type and name are preserved).
pub fn wrap_op<L>(def: OperatorDef<L>) -> OperatorDef<DynOp<L>>
where
    L: OperatorLogic,
    L::In: JobConvert,
    L::Out: JobConvert,
{
    OperatorDef {
        spec: def.spec,
        inputs: def.inputs,
        wt: def.wt,
        logic: Arc::new(DynOp { inner: def.logic }),
        name: def.name,
    }
}

/// Per-stage operator parameters a config's `[stage.<name>]` section may
/// override (each constructor reads the subset it needs).
#[derive(Clone, Copy, Debug)]
pub struct StageParams {
    /// Window size WS in event-time ms (joins, aggregates).
    pub ws_ms: EventTime,
    /// Window advance WA in event-time ms (defaults to WS: tumbling).
    pub wa_ms: EventTime,
    /// Synthetic load-balancing key count of Map stages (≫ max Π).
    pub lb_keys: u64,
    /// Round-robin key count of ScaleJoin stages.
    pub n_keys: u64,
    /// Word-pair distance bound B of `pair-count` (Q1's L/M/H
    /// duplication levels: 3 / 10 / large).
    pub pair_bound: usize,
}

impl Default for StageParams {
    fn default() -> Self {
        StageParams { ws_ms: 1_000, wa_ms: 1_000, lb_keys: 64, n_keys: 32, pair_bound: 10 }
    }
}

type MakeFn = fn(
    &StageParams,
    &mut DagBuilder<JobPayload>,
    VsnOptions,
    &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload>;

/// One named operator the declarative layer can instantiate.
pub struct OperatorEntry {
    pub name: &'static str,
    /// Payload kind consumed (edge type checking). `None` marks a
    /// payload-polymorphic operator (`forward`) that adapts to whatever
    /// its upstream produces — [`crate::engine::job::JobSpec`] resolves
    /// the concrete kind per topology, so such an operator cannot be a
    /// source stage.
    pub input: Option<PayloadKind>,
    /// Payload kind produced; `None` = same as the resolved input kind.
    pub output: Option<PayloadKind>,
    pub about: &'static str,
    make: MakeFn,
}

impl OperatorEntry {
    /// Declare this operator as a DAG node (a source node when `ups` is
    /// empty).
    pub fn instantiate(
        &self,
        p: &StageParams,
        b: &mut DagBuilder<JobPayload>,
        opts: VsnOptions,
        ups: &[NodeHandle<JobPayload>],
    ) -> NodeHandle<JobPayload> {
        (self.make)(p, b, opts, ups)
    }
}

impl fmt::Debug for OperatorEntry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OperatorEntry")
            .field("name", &self.name)
            .field("input", &self.input)
            .field("output", &self.output)
            .finish()
    }
}

fn add_node<L>(
    b: &mut DagBuilder<JobPayload>,
    def: OperatorDef<L>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload>
where
    L: OperatorLogic<In = JobPayload, Out = JobPayload>,
{
    if ups.is_empty() {
        b.source(def, opts)
    } else {
        b.node(def, opts, ups)
    }
}

fn make_trade_filter(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(b, wrap_op(trade_filter_op(p.lb_keys)), opts, ups)
}

fn make_trade_fanout(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(b, wrap_op(trade_fanout_op(p.lb_keys)), opts, ups)
}

fn make_left_leg(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(b, wrap_op(left_leg_op(p.lb_keys)), opts, ups)
}

fn make_right_leg(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(b, wrap_op(right_leg_op(p.lb_keys)), opts, ups)
}

fn make_hedge_join(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(b, wrap_op(hedge_join_op(p.ws_ms, p.n_keys)), opts, ups)
}

fn make_tweet_tokenize(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(b, wrap_op(tokenize_op(p.lb_keys)), opts, ups)
}

fn make_word_count(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    // WindowSpec::new(advance, size)
    add_node(b, wrap_op(word_count_stage_op(WindowSpec::new(p.wa_ms, p.ws_ms))), opts, ups)
}

fn make_forward(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    // natively JobPayload → JobPayload: no DynOp re-typing needed, the
    // identity forwards whatever variant flows through
    add_node(b, forward_stage_op::<JobPayload>(p.lb_keys), opts, ups)
}

fn make_pair_count(
    p: &StageParams,
    b: &mut DagBuilder<JobPayload>,
    opts: VsnOptions,
    ups: &[NodeHandle<JobPayload>],
) -> NodeHandle<JobPayload> {
    add_node(
        b,
        wrap_op(paircount_op(WindowSpec::new(p.wa_ms, p.ws_ms), p.pair_bound)),
        opts,
        ups,
    )
}

/// Every operator a job config can name.
pub const OPERATORS: &[OperatorEntry] = &[
    OperatorEntry {
        name: "trade-filter",
        input: Some(PayloadKind::Trade),
        output: Some(PayloadKind::Trade),
        about: "drop trades whose previous-day average is zero",
        make: make_trade_filter,
    },
    OperatorEntry {
        name: "trade-fanout",
        input: Some(PayloadKind::Trade),
        output: Some(PayloadKind::TradePair),
        about: "materialize both join sides of every trade (self-join fan-out)",
        make: make_trade_fanout,
    },
    OperatorEntry {
        name: "left-leg",
        input: Some(PayloadKind::Trade),
        output: Some(PayloadKind::TradePair),
        about: "materialize the LEFT join side (diamond branch)",
        make: make_left_leg,
    },
    OperatorEntry {
        name: "right-leg",
        input: Some(PayloadKind::Trade),
        output: Some(PayloadKind::TradePair),
        about: "materialize the RIGHT join side (diamond branch)",
        make: make_right_leg,
    },
    OperatorEntry {
        name: "hedge-join",
        input: Some(PayloadKind::TradePair),
        output: Some(PayloadKind::Hedge),
        about: "hedge band self-join (WS = ws_ms, keys = keys)",
        make: make_hedge_join,
    },
    OperatorEntry {
        name: "tweet-tokenize",
        input: Some(PayloadKind::Tweet),
        output: Some(PayloadKind::Word),
        about: "one output per distinct word of the tweet",
        make: make_tweet_tokenize,
    },
    OperatorEntry {
        name: "word-count",
        input: Some(PayloadKind::Word),
        output: Some(PayloadKind::WordCount),
        about: "windowed count per word (WS = ws_ms, WA = wa_ms)",
        make: make_word_count,
    },
    OperatorEntry {
        name: "forward",
        input: None,
        output: None,
        about: "forward every tuple unchanged (payload-polymorphic; \
                cheap stateless stage for schedule demos)",
        make: make_forward,
    },
    OperatorEntry {
        name: "pair-count",
        input: Some(PayloadKind::Tweet),
        output: Some(PayloadKind::WordCount),
        about: "windowed count per word pair within distance pair_bound \
                (WS = ws_ms, WA = wa_ms)",
        make: make_pair_count,
    },
];

/// Look an operator up in the *static* table by its registry name
/// (closure-registered operators resolve through [`resolve`]).
pub fn lookup(name: &str) -> Option<&'static OperatorEntry> {
    OPERATORS.iter().find(|e| e.name == name)
}

/// Type-erased constructor of a closure-registered operator.
type DynMake = Arc<
    dyn Fn(
            &StageParams,
            &mut DagBuilder<JobPayload>,
            VsnOptions,
            &[NodeHandle<JobPayload>],
        ) -> NodeHandle<JobPayload>
        + Send
        + Sync,
>;

struct DynOperator {
    name: &'static str,
    make: DynMake,
}

/// Process-wide table of closure-registered operators
/// ([`OperatorRegistry::register_fn`]).
static DYN_OPERATORS: Mutex<Vec<DynOperator>> = Mutex::new(Vec::new());

/// Why a dynamic registration was refused.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// The name is already taken — by the static [`OPERATORS`] table or
    /// by an earlier registration. Names resolve process-wide, so a
    /// silent override would change every job config using the name.
    DuplicateName(String),
    /// Operator names must be non-empty `[A-Za-z0-9_-]` — they are
    /// referenced from `[stage.<name>] operator = "..."` config values
    /// and become stage/metric labels.
    BadName(String),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::DuplicateName(n) => {
                write!(f, "operator `{n}` is already registered")
            }
            RegistryError::BadName(n) => {
                write!(f, "operator name `{n}` must be non-empty [A-Za-z0-9_-]")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// The process-wide registration face of the operator registry: the
/// escape hatch that lets the config/declarative path name *user
/// closures*, not just the static [`OPERATORS`] table — the declarative
/// twin of the typed path's `OperatorDef::from_fn`.
pub struct OperatorRegistry;

impl OperatorRegistry {
    /// Register `f` as a named flat-map operator over [`JobPayload`]:
    /// after this, any job config may declare
    /// `operator = "<name>"` and [`resolve`] will instantiate the
    /// closure as an ordinary Map stage (stateless, timestamp-preserving,
    /// load-balanced over the stage's `lb_keys`).
    ///
    /// A closure operator is payload-*polymorphic*, exactly like the
    /// static `forward` entry: it adapts to whatever kind its upstream
    /// produces, must emit the same kind it consumes, and therefore
    /// cannot be a source stage ([`crate::engine::job::JobSpec`] rejects
    /// that as `PolymorphicSource`).
    ///
    /// The name is claimed forever (one small leak per *successful*
    /// registration — operator names thread through `&'static str`
    /// stage and metric labels); duplicates and malformed names are
    /// refused with a typed [`RegistryError`].
    pub fn register_fn<F>(name: &str, f: F) -> Result<(), RegistryError>
    where
        F: Fn(&Tuple<JobPayload>, &mut dyn FnMut(JobPayload)) + Send + Sync + 'static,
    {
        if name.is_empty()
            || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Err(RegistryError::BadName(name.to_string()));
        }
        let mut reg = DYN_OPERATORS.lock().unwrap();
        if lookup(name).is_some() || reg.iter().any(|d| d.name == name) {
            return Err(RegistryError::DuplicateName(name.to_string()));
        }
        let name: &'static str = Box::leak(name.to_string().into_boxed_str());
        let f = Arc::new(f);
        let make: DynMake = Arc::new(move |p, b, opts, ups| {
            let g = Arc::clone(&f);
            let def = OperatorDef::from_fn(
                name,
                p.lb_keys.max(1),
                move |t: &Tuple<JobPayload>, emit: &mut dyn FnMut(JobPayload)| g(t, emit),
            );
            add_node(b, def, opts, ups)
        });
        reg.push(DynOperator { name, make });
        Ok(())
    }
}

enum ResolvedMake {
    Static(MakeFn),
    Dynamic(DynMake),
}

/// A registry name resolved to something the declarative layer can
/// type-check and instantiate — either a static [`OPERATORS`] entry or
/// a closure registered through [`OperatorRegistry::register_fn`].
pub struct ResolvedOperator {
    input: Option<PayloadKind>,
    output: Option<PayloadKind>,
    make: ResolvedMake,
}

impl ResolvedOperator {
    /// Payload kind consumed (`None` = polymorphic, resolved per
    /// topology — see [`OperatorEntry::input`]).
    pub fn input(&self) -> Option<PayloadKind> {
        self.input
    }

    /// Payload kind produced; `None` = same as the resolved input kind.
    pub fn output(&self) -> Option<PayloadKind> {
        self.output
    }

    /// Declare this operator as a DAG node (a source node when `ups` is
    /// empty) — same contract as [`OperatorEntry::instantiate`].
    pub fn instantiate(
        &self,
        p: &StageParams,
        b: &mut DagBuilder<JobPayload>,
        opts: VsnOptions,
        ups: &[NodeHandle<JobPayload>],
    ) -> NodeHandle<JobPayload> {
        match &self.make {
            ResolvedMake::Static(f) => f(p, b, opts, ups),
            ResolvedMake::Dynamic(f) => f(p, b, opts, ups),
        }
    }
}

/// Resolve an operator name: the static table first, then dynamic
/// registrations. This is the lookup the declarative layer goes
/// through, so closure-registered operators work everywhere a config
/// can name an operator.
pub fn resolve(name: &str) -> Option<ResolvedOperator> {
    if let Some(e) = lookup(name) {
        return Some(ResolvedOperator {
            input: e.input,
            output: e.output,
            make: ResolvedMake::Static(e.make),
        });
    }
    let reg = DYN_OPERATORS.lock().unwrap();
    reg.iter().find(|d| d.name == name).map(|d| ResolvedOperator {
        // closure operators adapt to their upstream's kind (the
        // `forward` contract)
        input: None,
        output: None,
        make: ResolvedMake::Dynamic(Arc::clone(&d.make)),
    })
}

/// Every operator name a job config can currently reference: the static
/// table in declaration order, then closure registrations in
/// registration order (error messages quote this list).
pub fn known_operators() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = OPERATORS.iter().map(|e| e.name).collect();
    names.extend(DYN_OPERATORS.lock().unwrap().iter().map(|d| d.name));
    names
}

/// A rate-paceable external source producing [`JobPayload`] tuples — the
/// harness-facing generator of a declarative job (selected by the source
/// stages' input kind).
pub enum JobSource {
    Trades(TradeStream),
    Tweets(TweetGen),
}

impl JobSource {
    /// The generator for source stages consuming `kind`, parameterized by
    /// the config's `[source]` section. `None` when no generator produces
    /// that payload kind.
    ///
    /// Adding a `[source]` key here? Also register it in
    /// `harness::JOB_SECTION_KEYS`, or job configs using it will be
    /// rejected as typos.
    pub fn for_kind(kind: PayloadKind, cfg: &Config) -> Option<JobSource> {
        match kind {
            PayloadKind::Trade => Some(JobSource::Trades(TradeStream::new(
                &NyseConfig {
                    symbols: cfg.int_or("source.symbols", 10).max(1) as usize,
                    seed: cfg.int_or("source.seed", 0x4E59_5345) as u64,
                    ..Default::default()
                },
                1_000.0,
            ))),
            PayloadKind::Tweet => Some(JobSource::Tweets(TweetGen::new(TweetGenConfig {
                vocab: cfg.int_or("source.vocab", 3_000).max(1) as usize,
                seed: cfg.int_or("source.seed", 0x7EE75) as u64,
                ..Default::default()
            }))),
            _ => None,
        }
    }

    pub fn kind(&self) -> PayloadKind {
        match self {
            JobSource::Trades(_) => PayloadKind::Trade,
            JobSource::Tweets(_) => PayloadKind::Tweet,
        }
    }

    pub fn set_rate(&mut self, tps: f64) {
        match self {
            JobSource::Trades(s) => s.set_rate(tps),
            JobSource::Tweets(s) => s.set_rate(tps),
        }
    }

    pub fn next_tuple(&mut self) -> Tuple<JobPayload> {
        match self {
            JobSource::Trades(s) => into_job_tuple(s.next()),
            JobSource::Tweets(s) => into_job_tuple(s.next()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OperatorMetrics;
    use crate::operator::state::SharedState;
    use crate::operator::OperatorCore;
    use crate::tuple::Mapper;

    #[test]
    fn registry_names_resolve_and_kinds_are_consistent() {
        for e in OPERATORS {
            assert_eq!(lookup(e.name).unwrap().name, e.name);
        }
        assert!(lookup("no-such-op").is_none());
        let j = lookup("hedge-join").unwrap();
        assert_eq!((j.input, j.output), (Some(PayloadKind::TradePair), Some(PayloadKind::Hedge)));
        // forward is the one payload-polymorphic entry: kind resolved per
        // topology by JobSpec
        let f = lookup("forward").unwrap();
        assert_eq!((f.input, f.output), (None, None));
        let p = lookup("pair-count").unwrap();
        assert_eq!((p.input, p.output), (Some(PayloadKind::Tweet), Some(PayloadKind::WordCount)));
    }

    #[test]
    fn register_fn_claims_a_name_and_resolves_polymorphic() {
        let pass = |t: &Tuple<JobPayload>, emit: &mut dyn FnMut(JobPayload)| {
            emit(t.payload.clone())
        };
        OperatorRegistry::register_fn("test-dyn-passthrough", pass).unwrap();
        // duplicates — static or dynamic — and malformed names are refused
        assert_eq!(
            OperatorRegistry::register_fn("forward", pass),
            Err(RegistryError::DuplicateName("forward".into()))
        );
        assert_eq!(
            OperatorRegistry::register_fn("test-dyn-passthrough", pass),
            Err(RegistryError::DuplicateName("test-dyn-passthrough".into()))
        );
        assert_eq!(
            OperatorRegistry::register_fn("bad name!", pass),
            Err(RegistryError::BadName("bad name!".into()))
        );
        // resolves like `forward`: payload-polymorphic
        let r = resolve("test-dyn-passthrough").unwrap();
        assert_eq!((r.input(), r.output()), (None, None));
        // static names resolve through the same path, kinds intact
        let j = resolve("hedge-join").unwrap();
        assert_eq!(
            (j.input(), j.output()),
            (Some(PayloadKind::TradePair), Some(PayloadKind::Hedge))
        );
        assert!(resolve("no-such-op").is_none());
        assert!(known_operators().contains(&"test-dyn-passthrough"));
        assert!(known_operators().contains(&"hedge-join"));
    }

    #[test]
    fn pair_count_counts_pairs_within_the_bound() {
        use crate::workloads::tweets::paircount_keys;
        let def = wrap_op(crate::workloads::ops::paircount_op(WindowSpec::new(100, 100), 10));
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let tweet = Tweet {
            user: 0,
            words: Arc::new(vec![3, 7, 9]),
            hashtags: Arc::new(vec![]),
            chars: 18,
        };
        let t = into_job_tuple(Tuple::data(1, tweet.clone()));
        let done = into_job_tuple(Tuple::<Tweet>::heartbeat(500));
        let mut out: Vec<(Key, u64)> = Vec::new();
        for tup in [t, done] {
            let mut sink = |o: Tuple<JobPayload>| match o.payload {
                JobPayload::WordCount(c) => out.push(c),
                other => panic!("pair-count must emit word counts, got {other:?}"),
            };
            let mut ctx = Ctx::new(&mut sink);
            core.process(&tup, &f_mu, &mut ctx);
        }
        // 3 distinct words → 3 pairs, each counted once in window [0,100)
        let mut want = Vec::new();
        paircount_keys(10)(&Tuple::data(1, tweet), &mut want);
        out.sort_unstable();
        let mut want: Vec<(Key, u64)> = want.into_iter().map(|k| (k, 1)).collect();
        want.sort_unstable();
        assert_eq!(out, want);
    }

    #[test]
    fn job_convert_round_trips_every_variant() {
        let t = Trade { id: 3, price: 105, avg: 100 };
        assert_eq!(Trade::from_job(t.into_job()).id, 3);
        let w: Key = 42;
        assert_eq!(Key::from_job(w.into_job()), 42);
        let c: (Key, u64) = (7, 9);
        assert_eq!(<(Key, u64)>::from_job(c.into_job()), (7, 9));
        let h = HedgeOut { l_id: 1, l_price: 2, r_id: 3, r_price: 4 };
        assert_eq!(HedgeOut::from_job(h.into_job()).r_price, 4);
        assert_eq!(JobPayload::default().kind(), PayloadKind::Word);
    }

    #[test]
    #[should_panic(expected = "variant mismatch")]
    fn job_convert_mismatch_panics_with_kinds() {
        let _ = Trade::from_job(JobPayload::Word(1));
    }

    #[test]
    fn dyn_op_preserves_map_semantics_through_the_core() {
        // wrapped trade-filter ≡ typed trade-filter on the same input
        let def = wrap_op(trade_filter_op(8));
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out: Vec<(i64, PayloadKind)> = Vec::new();
        for (ts, avg) in [(1i64, 100), (2, 0), (3, 50)] {
            let t = into_job_tuple(Tuple::data(ts, Trade { id: 1, price: 10, avg }));
            let mut sink = |o: Tuple<JobPayload>| out.push((o.ts, o.payload.kind()));
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        // the avg == 0 trade is dropped, τ preserved, output kind Trade
        assert_eq!(out, vec![(1, PayloadKind::Trade), (3, PayloadKind::Trade)]);
    }

    #[test]
    fn job_source_selection_matches_kinds() {
        let cfg = Config::parse("[source]\nsymbols = 4").unwrap();
        let mut s = JobSource::for_kind(PayloadKind::Trade, &cfg).unwrap();
        assert_eq!(s.kind(), PayloadKind::Trade);
        let t = s.next_tuple();
        assert_eq!(t.payload.kind(), PayloadKind::Trade);
        assert!(JobSource::for_kind(PayloadKind::Hedge, &cfg).is_none());
        let mut s = JobSource::for_kind(PayloadKind::Tweet, &cfg).unwrap();
        assert_eq!(s.next_tuple().payload.kind(), PayloadKind::Tweet);
    }
}
