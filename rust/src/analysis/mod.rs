//! `stretch lint` — the in-tree concurrency-correctness analyzer.
//!
//! STRETCH's exactly-once / ready-order guarantees are carried by a few
//! hundred hand-placed atomic-ordering sites and `unsafe` blocks in the
//! lock-free data plane. The compiler checks none of the *arguments*
//! for those sites; this module does. It is a lightweight, std-only
//! static analyzer (no rustc plumbing, no external crates):
//! [`lexer`] tokenizes a file precisely enough that keywords inside
//! strings or comments can never confuse a rule, and [`rules`] checks
//! the repo's concurrency + memory-discipline invariants L1–L6 (SAFETY
//! comments on `unsafe`, ORDERING justifications on data-plane atomics,
//! no ad-hoc sleeping/spinning, cache-padded slot arrays,
//! lock-free-marker enforcement, no allocation in `lint: no-alloc`
//! hot fns — see [`rules`] for the full table).
//!
//! Run it as `stretch lint [--format text|json] [paths…]` (default path
//! `rust/src`); exit status 0 = clean, 1 = findings, 2 = I/O error. CI
//! runs it as a blocking gate, and a self-test pins the committed tree
//! to zero findings — a PR that adds an unjustified atomic op fails in
//! both places.

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, Finding};

use crate::metrics::Json;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Lint files and/or directory trees (directories are walked
/// recursively for `*.rs`, skipping `target/` and dot-dirs). Findings
/// come back sorted by (file, line, rule).
pub fn lint_paths(paths: &[PathBuf]) -> io::Result<Vec<Finding>> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        collect_rs_files(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut out = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        out.extend(lint_source(&f.to_string_lossy(), &src));
    }
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(out)
}

fn collect_rs_files(p: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(p)?;
    if meta.is_file() {
        // explicit file arguments are linted even without a .rs suffix
        out.push(p.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        fs::read_dir(p)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for e in entries {
        let name = e.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if e.is_dir() {
            if name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&e, out)?;
        } else if name.ends_with(".rs") {
            out.push(e);
        }
    }
    Ok(())
}

/// Human-readable report: one `file:line: [rule] message` per finding
/// plus a summary line.
pub fn render_text(findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
    }
    if findings.is_empty() {
        s.push_str("stretch lint: clean\n");
    } else {
        let files: std::collections::BTreeSet<&str> =
            findings.iter().map(|f| f.file.as_str()).collect();
        s.push_str(&format!(
            "stretch lint: {} finding(s) in {} file(s)\n",
            findings.len(),
            files.len()
        ));
    }
    s
}

/// Machine-readable report. Schema (stable, pinned by a test):
///
/// ```json
/// {"tool": "stretch-lint", "version": 1, "count": N,
///  "findings": [{"file": "...", "line": 12, "rule": "...", "message": "..."}]}
/// ```
pub fn render_json(findings: &[Finding]) -> String {
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::from(f.file.as_str())),
                ("line", Json::from(f.line as u64)),
                ("rule", Json::from(f.rule)),
                ("message", Json::from(f.message.as_str())),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("tool", Json::from("stretch-lint")),
        ("version", Json::from(1u64)),
        ("count", Json::from(findings.len())),
        ("findings", Json::Arr(items)),
    ]);
    format!("{doc}\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::parse_json;

    fn sample_findings() -> Vec<Finding> {
        lint_source(
            "rust/src/scalegate/bad.rs",
            "fn f(x: &AtomicU64, p: *mut u8) {\n    x.store(1, Ordering::Release);\n    unsafe { p.write(0) }\n}",
        )
    }

    #[test]
    fn json_output_matches_schema_and_round_trips() {
        let f = sample_findings();
        assert!(!f.is_empty());
        let doc = parse_json(&render_json(&f)).expect("render_json must emit valid JSON");
        let Json::Obj(kvs) = doc else { panic!("top level must be an object") };
        let get = |k: &str| kvs.iter().find(|(n, _)| n == k).map(|(_, v)| v);
        assert_eq!(get("tool"), Some(&Json::Str("stretch-lint".into())));
        assert_eq!(get("version"), Some(&Json::Num(1.0)));
        assert_eq!(get("count"), Some(&Json::Num(f.len() as f64)));
        let Some(Json::Arr(items)) = get("findings") else { panic!("findings must be an array") };
        assert_eq!(items.len(), f.len());
        for (item, expect) in items.iter().zip(&f) {
            let Json::Obj(kv) = item else { panic!("finding must be an object") };
            let g = |k: &str| kv.iter().find(|(n, _)| n == k).map(|(_, v)| v);
            assert_eq!(g("file"), Some(&Json::Str(expect.file.clone())));
            assert_eq!(g("line"), Some(&Json::Num(expect.line as f64)));
            assert_eq!(g("rule"), Some(&Json::Str(expect.rule.to_string())));
            assert!(matches!(g("message"), Some(Json::Str(_))));
        }
    }

    #[test]
    fn json_escapes_special_characters() {
        let f = vec![Finding {
            file: "a\\b.rs".into(),
            line: 1,
            rule: rules::RULE_SLEEP,
            message: "quote \" and\nnewline".into(),
        }];
        // must still parse — escaping is the emitter's job
        assert!(parse_json(&render_json(&f)).is_ok());
    }

    #[test]
    fn text_output_names_file_line_rule() {
        let f = sample_findings();
        let txt = render_text(&f);
        assert!(txt.contains("rust/src/scalegate/bad.rs:2:"));
        assert!(txt.contains("[ordering-comment]"));
        assert!(txt.contains("[safety-comment]"));
        assert!(txt.contains("finding(s)"));
        assert!(render_text(&[]).contains("clean"));
    }

    /// The keystone self-test: the committed tree has zero findings.
    /// Every new `unsafe` block or data-plane atomic op added without a
    /// SAFETY/ORDERING argument fails this test (and the CI lint gate).
    #[test]
    fn committed_tree_is_clean() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
        let findings = lint_paths(&[root]).expect("lint walk failed");
        assert!(
            findings.is_empty(),
            "committed tree must lint clean:\n{}",
            render_text(&findings)
        );
    }

    #[test]
    fn lint_paths_reports_missing_path_as_io_error() {
        let missing = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("no/such/dir");
        assert!(lint_paths(&[missing]).is_err());
    }
}
