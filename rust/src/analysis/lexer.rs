//! Minimal Rust lexer for the in-tree concurrency analyzer.
//!
//! Tokenizes just enough of the language to make the [`super::rules`]
//! checks reliable at the token level instead of the fragile line level:
//!
//! * comments are **retained** as tokens (the rules read `// SAFETY:` /
//!   `// ORDERING:` justifications out of them), with nested `/* */`
//!   handled;
//! * string / raw-string / byte-string / char literals are classified,
//!   so `"unsafe"` inside a literal or a doc example can never trigger a
//!   rule;
//! * `'a` lifetimes are distinguished from `'x'` char literals;
//! * every token carries its 1-based source line for reporting.
//!
//! This is deliberately NOT a general Rust lexer — no macro expansion,
//! no token trees, no float-suffix pedantry — but it is exact for the
//! constructs the rules inspect.

/// Token class. `Ident` covers keywords too — the rules match on text.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `Ordering`, `Vec`, …).
    Ident,
    /// Single punctuation character (`::` arrives as two `:` tokens).
    Punct,
    /// `// …` comment, text includes the slashes (`///` and `//!` too).
    LineComment,
    /// `/* … */` comment (nested), text includes the delimiters.
    BlockComment,
    /// String literal: `"…"`, `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`.
    Str,
    /// Char or byte-char literal: `'x'`, `'\n'`, `b'x'`.
    Char,
    /// Lifetime: `'a`, `'_`, `'static`.
    Lifetime,
    /// Numeric literal (integer or float, suffixes included).
    Num,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    /// Is this token a comment (line or block)?
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }

    /// Is this an identifier with exactly this text?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this a punctuation token with exactly this char?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a token stream. Never fails: unterminated literals or
/// comments simply run to end-of-input (the analyzer lints real files
/// that rustc already accepted, so recovery precision is not critical).
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut toks: Vec<Tok> = Vec::new();

    let collect = |b: &[char], lo: usize, hi: usize| -> String { b[lo..hi].iter().collect() };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (covers `///` docs and `//!` inner docs)
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let lo = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::LineComment, text: collect(&b, lo, i), line });
            continue;
        }
        // block comment, nested
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let lo = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::BlockComment,
                text: collect(&b, lo, i),
                line: start_line,
            });
            continue;
        }
        // raw / byte string prefixes: r"…", r#"…"#, b"…", br"…", br#"…"#
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            let mut is_raw = c == 'r';
            if c == 'b' && j < n && b[j] == 'r' {
                is_raw = true;
                j += 1;
            }
            if is_raw && j < n && (b[j] == '"' || b[j] == '#') {
                // raw string: count hashes, then scan for `"` + hashes
                let lo = i;
                let start_line = line;
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    j += 1;
                    'scan: while j < n {
                        if b[j] == '\n' {
                            line += 1;
                            j += 1;
                        } else if b[j] == '"' {
                            let mut k = j + 1;
                            let mut seen = 0usize;
                            while k < n && seen < hashes && b[k] == '#' {
                                seen += 1;
                                k += 1;
                            }
                            if seen == hashes {
                                j = k;
                                break 'scan;
                            }
                            j += 1;
                        } else {
                            j += 1;
                        }
                    }
                    toks.push(Tok {
                        kind: TokKind::Str,
                        text: collect(&b, lo, j),
                        line: start_line,
                    });
                    i = j;
                    continue;
                }
                // `r#ident` (raw identifier) — fall through to ident below
            }
            if c == 'b' && i + 1 < n && b[i + 1] == '"' {
                // byte string: same scanner as a plain string
                let lo = i;
                let start_line = line;
                i += 1; // position on the opening quote
                i = scan_quoted(&b, i, '"', &mut line);
                toks.push(Tok { kind: TokKind::Str, text: collect(&b, lo, i), line: start_line });
                continue;
            }
            if c == 'b' && i + 1 < n && b[i + 1] == '\'' {
                let lo = i;
                i += 1;
                i = scan_quoted(&b, i, '\'', &mut line);
                toks.push(Tok { kind: TokKind::Char, text: collect(&b, lo, i), line });
                continue;
            }
            // plain identifier starting with r/b
        }
        // plain string
        if c == '"' {
            let lo = i;
            let start_line = line;
            i = scan_quoted(&b, i, '"', &mut line);
            toks.push(Tok { kind: TokKind::Str, text: collect(&b, lo, i), line: start_line });
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            // `'a`, `'_`, `'static` (no closing quote) are lifetimes;
            // `'x'`, `'\n'` are chars. Disambiguate by lookahead: an
            // ident char NOT followed by `'` starts a lifetime.
            let is_lifetime = i + 1 < n
                && is_ident_start(b[i + 1])
                && !(i + 2 < n && b[i + 2] == '\'');
            if is_lifetime {
                let lo = i;
                i += 1;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                toks.push(Tok { kind: TokKind::Lifetime, text: collect(&b, lo, i), line });
            } else {
                let lo = i;
                i = scan_quoted(&b, i, '\'', &mut line);
                toks.push(Tok { kind: TokKind::Char, text: collect(&b, lo, i), line });
            }
            continue;
        }
        // number
        if c.is_ascii_digit() {
            let lo = i;
            i += 1;
            while i < n
                && (is_ident_cont(b[i])
                    // decimal point only when followed by a digit, so
                    // `0..len` lexes as Num(0) `.` `.` Ident(len)
                    || (b[i] == '.' && i + 1 < n && b[i + 1].is_ascii_digit()))
            {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Num, text: collect(&b, lo, i), line });
            continue;
        }
        // identifier / keyword
        if is_ident_start(c) {
            let lo = i;
            i += 1;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: collect(&b, lo, i), line });
            continue;
        }
        // single punctuation char
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    toks
}

/// Scan a quoted literal starting at the opening quote `b[i] == quote`.
/// Returns the index one past the closing quote, honoring `\` escapes
/// and counting newlines into `line`.
fn scan_quoted(b: &[char], mut i: usize, quote: char, line: &mut u32) -> usize {
    let n = b.len();
    debug_assert!(b[i] == quote);
    i += 1;
    while i < n {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            c if c == quote => return i + 1,
            _ => i += 1,
        }
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_punct() {
        let t = kinds("let x = a::b;");
        assert_eq!(
            t,
            vec![
                (TokKind::Ident, "let".into()),
                (TokKind::Ident, "x".into()),
                (TokKind::Punct, "=".into()),
                (TokKind::Ident, "a".into()),
                (TokKind::Punct, ":".into()),
                (TokKind::Punct, ":".into()),
                (TokKind::Ident, "b".into()),
                (TokKind::Punct, ";".into()),
            ]
        );
    }

    #[test]
    fn keyword_inside_string_is_a_str_token() {
        let t = lex(r#"let s = "unsafe { Ordering::SeqCst }";"#);
        assert!(t.iter().all(|t| !t.is_ident("unsafe")));
        assert!(t.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn keyword_inside_comment_is_a_comment_token() {
        let t = lex("// unsafe here is fine\nlet x = 1;");
        assert_eq!(t[0].kind, TokKind::LineComment);
        assert!(t[1..].iter().all(|t| !t.is_ident("unsafe")));
    }

    #[test]
    fn nested_block_comment() {
        let t = lex("/* outer /* inner unsafe */ still comment */ fn f() {}");
        assert_eq!(t[0].kind, TokKind::BlockComment);
        assert!(t[0].text.contains("inner unsafe"));
        assert!(t[1].is_ident("fn"));
    }

    #[test]
    fn raw_string_with_hashes_and_quotes() {
        let t = lex(r##"let s = r#"contains "quotes" and unsafe"#; next"##);
        let s = t.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert!(s.text.contains("quotes"));
        assert!(t.iter().any(|t| t.is_ident("next")));
        assert!(t.iter().all(|t| !t.is_ident("unsafe")));
    }

    #[test]
    fn lifetime_vs_char() {
        let t = kinds("fn f<'a>(x: &'a u8) { let c = 'x'; let nl = '\\n'; }");
        assert!(t.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'a"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Char && s == "'x'"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Char && s == "'\\n'"));
    }

    #[test]
    fn static_lifetime_and_underscore() {
        let t = kinds("&'static str; &'_ u8");
        assert!(t.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'static"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'_"));
    }

    #[test]
    fn line_numbers_track_newlines_and_multiline_literals() {
        let src = "a\nb \"multi\nline\" c\n/* block\ncomment */ d";
        let t = lex(src);
        let find = |name: &str| t.iter().find(|t| t.is_ident(name)).unwrap().line;
        assert_eq!(find("a"), 1);
        assert_eq!(find("b"), 2);
        assert_eq!(find("c"), 3); // string swallowed one newline
        assert_eq!(find("d"), 5); // block comment swallowed another
    }

    #[test]
    fn numbers_do_not_eat_range_dots() {
        let t = kinds("for i in 0..10 {}");
        assert!(t.iter().any(|(k, s)| *k == TokKind::Num && s == "0"));
        assert!(t.iter().any(|(k, s)| *k == TokKind::Num && s == "10"));
        assert_eq!(t.iter().filter(|(k, s)| *k == TokKind::Punct && s == ".").count(), 2);
    }

    #[test]
    fn byte_and_raw_byte_strings() {
        let t = lex(r#"let a = b"bytes"; let c = b'x'; let d = br"raw";"#);
        assert_eq!(t.iter().filter(|t| t.kind == TokKind::Str).count(), 2);
        assert_eq!(t.iter().filter(|t| t.kind == TokKind::Char).count(), 1);
    }

    #[test]
    fn escaped_quote_in_string() {
        let t = lex(r#"let s = "he said \"unsafe\""; done"#);
        assert!(t.iter().any(|t| t.is_ident("done")));
        assert!(t.iter().all(|t| !t.is_ident("unsafe")));
    }
}
