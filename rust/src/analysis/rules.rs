//! The rule engine behind `stretch lint`: repo-specific concurrency
//! invariants checked per file over the [`super::lexer`] token stream.
//!
//! | rule id            | invariant                                              |
//! |--------------------|--------------------------------------------------------|
//! | `safety-comment`   | L1: every `unsafe` (block/fn/impl) is immediately      |
//! |                    | preceded by a `// SAFETY:` argument                    |
//! | `ordering-comment` | L2: every atomic load/store/RMW/fence in the data-plane|
//! |                    | modules carries an `// ORDERING:` justification on the |
//! |                    | statement or its enclosing fn's doc comment            |
//! | `seqcst`           | L2b: bare `Ordering::SeqCst` is "justify-or-weaken" —  |
//! |                    | the justification must name SeqCst explicitly          |
//! | `sleep`            | L3: no `thread::sleep` / `spin_loop` / `yield_now`     |
//! |                    | outside `util::backoff`                                |
//! | `cache-padded`     | L4: shared per-slot arrays in `scalegate/` wrap their  |
//! |                    | elements in `CachePadded`                              |
//! | `lock-free`        | L5: no `Mutex`/`RwLock`/`Condvar` in files declaring a |
//! |                    | `//! lint: lock-free` marker                           |
//! | `alloc`            | L6: no `Vec::new`/`with_capacity`/`collect`/`Box::new`/|
//! |                    | `to_vec` in fns marked `lint: no-alloc`                |
//!
//! **Scope.** `#[cfg(test)]` / `#[test]` items are skipped by every rule
//! (tests may sleep, take locks, and poke atomics freely). L2 applies
//! only to the data-plane set named by the audit: `scalegate/`,
//! `util/spsc.rs`, `engine/{vsn,barrier,epoch,sn}.rs`, and `metrics/`.
//! L4 applies inside `scalegate/`; L5 only where the marker is declared.
//! L6 applies to any fn whose doc block carries a `lint: no-alloc`
//! marker (the repo marks the `scalegate/` merge path and the
//! `util/spsc.rs` batch hot fns); it keeps the allocation-free
//! steady-state contract of §Perf "memory discipline" honest — scratch
//! in those fns must come from the caller or the run-buffer pool, never
//! the allocator. `reserve` is deliberately NOT banned: on recycled
//! capacity it is a no-op, and banning it would force waivers onto
//! every batch-append site.
//!
//! **Waivers.** A finding is suppressed by a comment on the same
//! statement containing `lint: allow(<rule-id>) — <reason>`; the reason
//! is part of the contract (a bare waiver reads as a TODO in review).
//!
//! A justification "on the statement" means: in a comment token lexically
//! attached to the statement — above it (between the previous `;`/`{`/`}`
//! and the site), inside it (multi-line statements work), or trailing on
//! the terminator's line. "On the enclosing fn" means in the comment
//! block that documents the fn (doc comments and attributes scanned as
//! one header region).

use super::lexer::{lex, Tok, TokKind};
use std::collections::BTreeSet;

/// Rule L1 — `unsafe` without `// SAFETY:`.
pub const RULE_SAFETY: &str = "safety-comment";
/// Rule L2 — data-plane atomic op without `// ORDERING:`.
pub const RULE_ORDERING: &str = "ordering-comment";
/// Rule L2b — `Ordering::SeqCst` whose justification doesn't name it.
pub const RULE_SEQCST: &str = "seqcst";
/// Rule L3 — blocking/spin primitive outside `util::backoff`.
pub const RULE_SLEEP: &str = "sleep";
/// Rule L4 — un-padded shared slot array in `scalegate/`.
pub const RULE_CACHE_PADDED: &str = "cache-padded";
/// Rule L5 — lock type in a `//! lint: lock-free` file.
pub const RULE_LOCK_FREE: &str = "lock-free";
/// Rule L6 — allocating call in a fn marked `lint: no-alloc`.
pub const RULE_ALLOC: &str = "alloc";

/// One analyzer finding. `file` is the path as given (normalized to
/// `/` separators), `line` is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// Lint one file's source. `path` decides rule scope (see module docs);
/// it is not read from disk — callers pass fixtures directly in tests.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let path = path.replace('\\', "/");
    let toks = lex(src);
    let skip = test_skip_mask(&toks);
    let fns = fn_spans(&toks);
    let mut out = Vec::new();

    check_safety(&path, &toks, &skip, &mut out);
    if in_dataplane(&path) {
        check_ordering(&path, &toks, &skip, &fns, &mut out);
    }
    if !path.ends_with("util/backoff.rs") {
        check_sleep(&path, &toks, &skip, &fns, &mut out);
    }
    if path.contains("scalegate/") {
        check_cache_padded(&path, &toks, &skip, &mut out);
    }
    check_lock_free(&path, &toks, &skip, &fns, &mut out);
    check_alloc(&path, &toks, &skip, &fns, &mut out);

    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// L2's file scope: the lock-free data plane named by the audit.
fn in_dataplane(path: &str) -> bool {
    path.contains("scalegate/")
        || path.contains("metrics/")
        || path.ends_with("util/spsc.rs")
        || path.ends_with("engine/vsn.rs")
        || path.ends_with("engine/barrier.rs")
        || path.ends_with("engine/epoch.rs")
        || path.ends_with("engine/sn.rs")
}

// ---------------------------------------------------------------------
// shared token-walking infrastructure
// ---------------------------------------------------------------------

/// Mark every token belonging to a `#[test]` / `#[cfg(test)]`-gated item
/// (attributes included) so rules can skip test code wholesale.
fn test_skip_mask(toks: &[Tok]) -> Vec<bool> {
    let mut skip = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !(toks[i].is_punct('#') && i + 1 < toks.len() && toks[i + 1].is_punct('[')) {
            i += 1;
            continue;
        }
        let attr_start = i;
        // collect the attribute's identifiers up to the matching `]`
        let mut idents: Vec<&str> = Vec::new();
        let mut depth = 1usize;
        let mut j = i + 2;
        while j < toks.len() && depth > 0 {
            if toks[j].is_punct('[') {
                depth += 1;
            } else if toks[j].is_punct(']') {
                depth -= 1;
            } else if toks[j].kind == TokKind::Ident {
                idents.push(&toks[j].text);
            }
            j += 1;
        }
        // `#[test]`, or a `cfg(..)` whose predicate mentions `test`
        // without negation; `cfg_attr` and `cfg(not(test))` stay live.
        let is_test = matches!(idents.first(), Some(&"test"))
            || (matches!(idents.first(), Some(&"cfg"))
                && idents.iter().any(|s| *s == "test")
                && !idents.iter().any(|s| *s == "not"));
        if !is_test {
            i = j;
            continue;
        }
        // swallow any further attributes on the same item
        let mut k = j;
        while k + 1 < toks.len() && toks[k].is_punct('#') && toks[k + 1].is_punct('[') {
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if toks[k].is_punct('[') {
                    d += 1;
                } else if toks[k].is_punct(']') {
                    d -= 1;
                }
                k += 1;
            }
        }
        // the item itself: ends at a top-level `;` or the matching `}`
        // of its first `{`
        let mut end = k;
        while end < toks.len() {
            if toks[end].is_punct(';') {
                break;
            }
            if toks[end].is_punct('{') {
                let mut d = 1usize;
                let mut m = end + 1;
                while m < toks.len() && d > 0 {
                    if toks[m].is_punct('{') {
                        d += 1;
                    } else if toks[m].is_punct('}') {
                        d -= 1;
                    }
                    m += 1;
                }
                end = m.saturating_sub(1);
                break;
            }
            end += 1;
        }
        let end = (end + 1).min(toks.len());
        for s in skip.iter_mut().take(end).skip(attr_start) {
            *s = true;
        }
        i = end;
    }
    skip
}

/// A `fn` item: its body token range and the comment blob documenting it
/// (the header region preceding `fn` plus comments inside the signature).
struct FnSpan {
    body_start: usize,
    body_end: usize,
    doc: String,
}

fn fn_spans(toks: &[Tok]) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for i in 0..toks.len() {
        if !toks[i].is_ident("fn") {
            continue;
        }
        let mut doc = String::new();
        // backward over visibility/qualifiers/attributes to the previous
        // item boundary, harvesting the doc-comment block
        let mut j = i;
        let mut steps = 0;
        while j > 0 && steps < 64 {
            j -= 1;
            steps += 1;
            let t = &toks[j];
            if t.is_comment() {
                doc.push_str(&t.text);
                doc.push('\n');
            } else if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
                break;
            }
        }
        // forward across the signature to the body `{` (trait method
        // declarations end at `;` and have no span)
        let mut k = i + 1;
        let mut body = None;
        while k < toks.len() {
            let t = &toks[k];
            if t.is_comment() {
                doc.push_str(&t.text);
                doc.push('\n');
                k += 1;
                continue;
            }
            if t.is_punct(';') {
                break;
            }
            if t.is_punct('{') {
                body = Some(k);
                break;
            }
            k += 1;
        }
        let Some(bs) = body else { continue };
        let mut d = 1usize;
        let mut m = bs + 1;
        while m < toks.len() && d > 0 {
            if toks[m].is_punct('{') {
                d += 1;
            } else if toks[m].is_punct('}') {
                d -= 1;
            }
            m += 1;
        }
        spans.push(FnSpan { body_start: bs, body_end: m, doc });
    }
    spans
}

/// Doc blob of the innermost-declared fn whose body contains `site`
/// (empty when the site is outside any fn body).
fn enclosing_fn_doc<'a>(fns: &'a [FnSpan], site: usize) -> &'a str {
    fns.iter()
        .filter(|f| f.body_start < site && site < f.body_end)
        .max_by_key(|f| f.body_start)
        .map(|f| f.doc.as_str())
        .unwrap_or("")
}

/// All comment text lexically attached to the statement containing
/// `site`: comments above it back to the previous `;`/`{`/`}`, comments
/// inside the (possibly multi-line) statement, and trailing comments on
/// the terminator's line.
fn stmt_comment_blob(toks: &[Tok], site: usize) -> String {
    let mut blob = String::new();
    let mut j = site;
    let mut steps = 0;
    while j > 0 && steps < 96 {
        j -= 1;
        steps += 1;
        let t = &toks[j];
        if t.is_comment() {
            blob.push_str(&t.text);
            blob.push('\n');
        } else if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            break;
        }
    }
    let mut k = site + 1;
    let mut steps = 0;
    while k < toks.len() && steps < 96 {
        let t = &toks[k];
        if t.is_comment() {
            blob.push_str(&t.text);
            blob.push('\n');
            k += 1;
            steps += 1;
            continue;
        }
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            let term_line = t.line;
            let mut m = k + 1;
            while m < toks.len() && toks[m].is_comment() && toks[m].line == term_line {
                blob.push_str(&toks[m].text);
                blob.push('\n');
                m += 1;
            }
            break;
        }
        k += 1;
        steps += 1;
    }
    blob
}

/// `lint: allow(<rule>)` waiver anywhere in the statement's comments.
fn waived(blob: &str, rule: &str) -> bool {
    blob.contains(&format!("lint: allow({rule})"))
}

/// Previous non-comment token index, if any.
fn prev_code(toks: &[Tok], i: usize) -> Option<usize> {
    (0..i).rev().find(|&j| !toks[j].is_comment())
}

/// Next non-comment token index, if any.
fn next_code(toks: &[Tok], i: usize) -> Option<usize> {
    (i..toks.len()).find(|&j| !toks[j].is_comment())
}

// ---------------------------------------------------------------------
// L1: SAFETY comments on `unsafe`
// ---------------------------------------------------------------------

fn check_safety(path: &str, toks: &[Tok], skip: &[bool], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if skip[i] || !t.is_ident("unsafe") {
            continue;
        }
        let blob = stmt_comment_blob(toks, i);
        if blob.contains("SAFETY:") || waived(&blob, RULE_SAFETY) {
            continue;
        }
        out.push(Finding {
            file: path.to_string(),
            line: t.line,
            rule: RULE_SAFETY,
            message: "`unsafe` without an immediately-preceding `// SAFETY:` argument"
                .to_string(),
        });
    }
}

// ---------------------------------------------------------------------
// L2: ORDERING comments on data-plane atomic ops
// ---------------------------------------------------------------------

const ATOMIC_ORDERINGS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

fn check_ordering(
    path: &str,
    toks: &[Tok],
    skip: &[bool],
    fns: &[FnSpan],
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if skip[i] || !toks[i].is_ident("Ordering") {
            continue;
        }
        // match `Ordering :: <variant>` through any interleaved comments
        let Some(c1) = next_code(toks, i + 1) else { continue };
        let Some(c2) = next_code(toks, c1 + 1) else { continue };
        let Some(v) = next_code(toks, c2 + 1) else { continue };
        if !(toks[c1].is_punct(':') && toks[c2].is_punct(':')) {
            continue;
        }
        let variant = toks[v].text.as_str();
        if toks[v].kind != TokKind::Ident || !ATOMIC_ORDERINGS.contains(&variant) {
            continue;
        }
        let blob = stmt_comment_blob(toks, i);
        let fn_doc = enclosing_fn_doc(fns, i);
        let has_ordering = blob.contains("ORDERING:") || fn_doc.contains("ORDERING:");
        if variant == "SeqCst" {
            let names_seqcst = (blob.contains("ORDERING:") && blob.contains("SeqCst"))
                || (fn_doc.contains("ORDERING:") && fn_doc.contains("SeqCst"));
            if !names_seqcst && !waived(&blob, RULE_SEQCST) && !waived(fn_doc, RULE_SEQCST) {
                out.push(Finding {
                    file: path.to_string(),
                    line: toks[i].line,
                    rule: RULE_SEQCST,
                    message: "bare `Ordering::SeqCst`: justify-or-weaken — the `// ORDERING:` \
                              argument must say why no weaker ordering suffices (naming SeqCst), \
                              or the site should be downgraded"
                        .to_string(),
                });
            }
        } else if !has_ordering && !waived(&blob, RULE_ORDERING) && !waived(fn_doc, RULE_ORDERING)
        {
            out.push(Finding {
                file: path.to_string(),
                line: toks[i].line,
                rule: RULE_ORDERING,
                message: format!(
                    "atomic op with `Ordering::{variant}` lacks an `// ORDERING:` justification \
                     on the statement or its enclosing fn"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// L3: no sleeping / spinning outside util::backoff
// ---------------------------------------------------------------------

fn check_sleep(path: &str, toks: &[Tok], skip: &[bool], fns: &[FnSpan], out: &mut Vec<Finding>) {
    for (i, t) in toks.iter().enumerate() {
        if skip[i] || t.kind != TokKind::Ident {
            continue;
        }
        let hit = match t.text.as_str() {
            // only the `thread::sleep` path-call form (a method named
            // `sleep` on some future type should not trip this)
            "sleep" => {
                let p1 = prev_code(toks, i);
                let p2 = p1.and_then(|j| prev_code(toks, j));
                let p3 = p2.and_then(|j| prev_code(toks, j));
                matches!((p1, p2, p3), (Some(a), Some(b), Some(c))
                    if toks[a].is_punct(':') && toks[b].is_punct(':') && toks[c].is_ident("thread"))
            }
            "spin_loop" | "yield_now" => true,
            _ => false,
        };
        if !hit {
            continue;
        }
        let blob = stmt_comment_blob(toks, i);
        if waived(&blob, RULE_SLEEP) || waived(enclosing_fn_doc(fns, i), RULE_SLEEP) {
            continue;
        }
        out.push(Finding {
            file: path.to_string(),
            line: t.line,
            rule: RULE_SLEEP,
            message: format!(
                "`{}` outside util::backoff — hot paths use `Backoff` (waive deliberate \
                 wall-clock waits with `lint: allow(sleep) — <reason>`)",
                t.text
            ),
        });
    }
}

// ---------------------------------------------------------------------
// L4: per-slot arrays in scalegate/ must be CachePadded
// ---------------------------------------------------------------------

fn check_cache_padded(path: &str, toks: &[Tok], skip: &[bool], out: &mut Vec<Finding>) {
    // pass A: structs declared in this file that contain atomic fields
    let mut atomic_structs: BTreeSet<String> = BTreeSet::new();
    for i in 0..toks.len() {
        if skip[i] || !toks[i].is_ident("struct") {
            continue;
        }
        let Some(ni) = next_code(toks, i + 1) else { continue };
        if toks[ni].kind != TokKind::Ident {
            continue;
        }
        let name = toks[ni].text.clone();
        // find the field list: `{`/`(` at generic-angle depth 0
        let mut j = ni + 1;
        let mut angle = 0i32;
        let mut open: Option<(char, char, usize)> = None;
        while j < toks.len() {
            let t = &toks[j];
            if t.is_punct('<') {
                angle += 1;
            } else if t.is_punct('>') {
                angle -= 1;
            } else if angle == 0 && t.is_punct('{') {
                open = Some(('{', '}', j));
                break;
            } else if angle == 0 && t.is_punct('(') {
                open = Some(('(', ')', j));
                break;
            } else if angle == 0 && t.is_punct(';') {
                break;
            }
            j += 1;
        }
        let Some((o, c, fs)) = open else { continue };
        let mut d = 1usize;
        let mut m = fs + 1;
        let mut has_atomic = false;
        while m < toks.len() && d > 0 {
            let t = &toks[m];
            if t.is_punct(o) {
                d += 1;
            } else if t.is_punct(c) {
                d -= 1;
            } else if t.kind == TokKind::Ident && t.text.starts_with("Atomic") {
                has_atomic = true;
            }
            m += 1;
        }
        if has_atomic {
            atomic_structs.insert(name);
        }
    }
    // pass B: Vec<…> whose first type ident is atomic-bearing and not
    // CachePadded
    for i in 0..toks.len() {
        if skip[i] || !toks[i].is_ident("Vec") {
            continue;
        }
        let Some(lt) = next_code(toks, i + 1) else { continue };
        if !toks[lt].is_punct('<') {
            continue;
        }
        let Some(inner_i) = (lt + 1..toks.len()).find(|&j| toks[j].kind == TokKind::Ident)
        else {
            continue;
        };
        let inner = toks[inner_i].text.as_str();
        if inner == "CachePadded" {
            continue;
        }
        if !(inner.starts_with("Atomic") || atomic_structs.contains(inner)) {
            continue;
        }
        let blob = stmt_comment_blob(toks, i);
        if waived(&blob, RULE_CACHE_PADDED) {
            continue;
        }
        out.push(Finding {
            file: path.to_string(),
            line: toks[i].line,
            rule: RULE_CACHE_PADDED,
            message: format!(
                "shared per-slot array `Vec<{inner}>` in scalegate/ must wrap its elements in \
                 `CachePadded` (adjacent slots false-share otherwise)"
            ),
        });
    }
}

// ---------------------------------------------------------------------
// L5: lock types banned in `//! lint: lock-free` files
// ---------------------------------------------------------------------

fn check_lock_free(path: &str, toks: &[Tok], skip: &[bool], fns: &[FnSpan], out: &mut Vec<Finding>) {
    let marked = toks.iter().any(|t| {
        t.kind == TokKind::LineComment
            && t.text.starts_with("//!")
            && t.text.contains("lint: lock-free")
    });
    if !marked {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if skip[i] || t.kind != TokKind::Ident {
            continue;
        }
        if !matches!(t.text.as_str(), "Mutex" | "RwLock" | "Condvar") {
            continue;
        }
        let blob = stmt_comment_blob(toks, i);
        if waived(&blob, RULE_LOCK_FREE) || waived(enclosing_fn_doc(fns, i), RULE_LOCK_FREE) {
            continue;
        }
        out.push(Finding {
            file: path.to_string(),
            line: t.line,
            rule: RULE_LOCK_FREE,
            message: format!(
                "`{}` referenced in a file declaring `//! lint: lock-free`",
                t.text
            ),
        });
    }
}

// ---------------------------------------------------------------------
// L6: allocating calls banned in `lint: no-alloc` fns
// ---------------------------------------------------------------------

fn check_alloc(path: &str, toks: &[Tok], skip: &[bool], fns: &[FnSpan], out: &mut Vec<Finding>) {
    for f in fns {
        if !f.doc.contains("lint: no-alloc") {
            continue;
        }
        for i in f.body_start..f.body_end.min(toks.len()) {
            let t = &toks[i];
            if skip[i] || t.kind != TokKind::Ident {
                continue;
            }
            let what = match t.text.as_str() {
                "collect" | "to_vec" | "with_capacity" => t.text.clone(),
                // `new` only as `Vec::new` / `Box::new` — a constructor
                // named `new` on a non-allocating type must not trip
                "new" => {
                    let p1 = prev_code(toks, i);
                    let p2 = p1.and_then(|j| prev_code(toks, j));
                    let p3 = p2.and_then(|j| prev_code(toks, j));
                    let owner = match (p1, p2, p3) {
                        (Some(a), Some(b), Some(c))
                            if toks[a].is_punct(':') && toks[b].is_punct(':') =>
                        {
                            toks[c].text.as_str()
                        }
                        _ => continue,
                    };
                    if !matches!(owner, "Vec" | "Box") {
                        continue;
                    }
                    format!("{owner}::new")
                }
                _ => continue,
            };
            let blob = stmt_comment_blob(toks, i);
            if waived(&blob, RULE_ALLOC) || waived(&f.doc, RULE_ALLOC) {
                continue;
            }
            out.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: RULE_ALLOC,
                message: format!(
                    "`{what}` allocates inside a `lint: no-alloc` fn — draw scratch from the \
                     caller or the run-buffer pool (waive deliberate cold-path allocation with \
                     `lint: allow(alloc) — <reason>`)"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ----- L1 -----

    #[test]
    fn l1_unsafe_without_safety_flags() {
        let src = "fn f(p: *mut u8) { unsafe { p.write(0) } }";
        let f = lint_source("rust/src/foo.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_SAFETY]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn l1_safety_comment_above_statement_passes() {
        let src = "fn f(p: *mut u8) {\n    // SAFETY: p is valid for writes by contract.\n    unsafe { p.write(0) }\n}";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    #[test]
    fn l1_safety_on_multiline_let_statement_passes() {
        let src = "fn f(p: *const u32) -> u32 {\n    // SAFETY: index masked to capacity, slot initialized by the writer.\n    let v = unsafe {\n        p.add(1)\n            .read()\n    };\n    v\n}";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    #[test]
    fn l1_unsafe_impl_each_needs_its_own_safety() {
        let src = "struct X;\n// SAFETY: X owns no thread-affine state.\nunsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        let f = lint_source("rust/src/foo.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_SAFETY]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn l1_unsafe_inside_string_or_comment_is_ignored() {
        let src = "fn f() {\n    let s = \"unsafe { boom }\";\n    // this mentions unsafe but is a comment\n    let r = r#\"also unsafe here\"#;\n    let _ = (s, r);\n}";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    #[test]
    fn l1_cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *mut u8) { unsafe { p.write(0) } }\n}";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    #[test]
    fn l1_waiver_suppresses() {
        let src = "fn f(p: *mut u8) {\n    // lint: allow(safety-comment) — fixture for the doc example\n    unsafe { p.write(0) }\n}";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    // ----- L2 -----

    #[test]
    fn l2_bare_atomic_in_dataplane_flags() {
        let src = "fn f(x: &AtomicU64) { x.store(1, Ordering::Release); }";
        let f = lint_source("rust/src/scalegate/x.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ORDERING]);
    }

    #[test]
    fn l2_out_of_scope_file_is_not_checked() {
        let src = "fn f(x: &AtomicU64) { x.store(1, Ordering::Release); }";
        assert!(lint_source("rust/src/harness/handle.rs", src).is_empty());
    }

    #[test]
    fn l2_statement_comment_justifies() {
        let src = "fn f(x: &AtomicU64) {\n    // ORDERING: Release publish pairs with the reader's Acquire in `get`.\n    x.store(1, Ordering::Release);\n}";
        assert!(lint_source("rust/src/scalegate/x.rs", src).is_empty());
    }

    #[test]
    fn l2_trailing_comment_on_terminator_line_justifies() {
        let src = "fn f(x: &AtomicU64) {\n    x.store(1, Ordering::Release); // ORDERING: pairs with Acquire in `get`\n}";
        assert!(lint_source("rust/src/util/spsc.rs", src).is_empty());
    }

    #[test]
    fn l2_enclosing_fn_doc_justifies_all_sites() {
        let src = "/// Bump statistics counters.\n///\n/// ORDERING: Relaxed — pure statistics, no synchronization implied.\nfn bump(a: &AtomicU64, b: &AtomicU64) {\n    a.fetch_add(1, Ordering::Relaxed);\n    b.fetch_add(1, Ordering::Relaxed);\n}";
        assert!(lint_source("rust/src/metrics/mod.rs", src).is_empty());
    }

    #[test]
    fn l2_multiline_statement_both_orderings_covered() {
        let src = "fn f(s: &AtomicU8) {\n    // ORDERING: AcqRel on success pairs with state() Acquire; Relaxed on\n    // failure — the loser retries with fresh loads.\n    let _ = s\n        .compare_exchange(0, 1, Ordering::AcqRel, Ordering::Relaxed);\n}";
        assert!(lint_source("rust/src/engine/vsn.rs", src).is_empty());
    }

    #[test]
    fn l2_multiline_statement_unjustified_flags_both() {
        let src = "fn f(s: &AtomicU8) {\n    let _ = s\n        .compare_exchange(0, 1, Ordering::AcqRel, Ordering::Relaxed);\n}";
        let f = lint_source("rust/src/engine/vsn.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ORDERING, RULE_ORDERING]);
    }

    #[test]
    fn l2_seqcst_needs_named_justification() {
        let bad = "fn f(x: &AtomicU64) {\n    // ORDERING: publish\n    x.store(1, Ordering::SeqCst);\n}";
        let f = lint_source("rust/src/scalegate/x.rs", bad);
        assert_eq!(rules_of(&f), vec![RULE_SEQCST]);

        let good = "fn f(x: &AtomicU64) {\n    // ORDERING: SeqCst — the flag participates in a Dekker-style store/load\n    // pattern with `other`; Acquire/Release does not order the two stores.\n    x.store(1, Ordering::SeqCst);\n}";
        assert!(lint_source("rust/src/scalegate/x.rs", good).is_empty());
    }

    #[test]
    fn l2_ordering_in_string_is_ignored() {
        let src = "fn f() { let s = \"Ordering::Relaxed\"; let _ = s; }";
        assert!(lint_source("rust/src/scalegate/x.rs", src).is_empty());
    }

    #[test]
    fn l2_cmp_ordering_is_not_an_atomic_site() {
        let src = "fn f(a: u32, b: u32) -> Ordering { a.cmp(&b) }\nfn g() -> Ordering { Ordering::Less }";
        assert!(lint_source("rust/src/scalegate/x.rs", src).is_empty());
    }

    // ----- L3 -----

    #[test]
    fn l3_thread_sleep_flags() {
        let src = "fn f() { std::thread::sleep(std::time::Duration::from_millis(1)); }";
        let f = lint_source("rust/src/engine/vsn.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_SLEEP]);
    }

    #[test]
    fn l3_spin_loop_and_yield_now_flag() {
        let src = "fn f() { std::hint::spin_loop(); std::thread::yield_now(); }";
        let f = lint_source("rust/src/foo.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_SLEEP, RULE_SLEEP]);
    }

    #[test]
    fn l3_backoff_module_is_exempt() {
        let src = "fn f() { std::hint::spin_loop(); std::thread::sleep(d); }";
        assert!(lint_source("rust/src/util/backoff.rs", src).is_empty());
    }

    #[test]
    fn l3_waiver_with_reason_suppresses() {
        let src = "fn f(d: Duration) {\n    // lint: allow(sleep) — wall-clock pacing of the runtime tick, not a wait\n    std::thread::sleep(d);\n}";
        assert!(lint_source("rust/src/harness/handle.rs", src).is_empty());
    }

    #[test]
    fn l3_method_named_sleep_is_not_flagged() {
        let src = "fn f(w: &Widget) { w.sleep(); }";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    #[test]
    fn l3_test_code_may_sleep() {
        let src = "#[test]\nfn waits() { std::thread::sleep(std::time::Duration::from_millis(1)); }";
        assert!(lint_source("rust/src/foo.rs", src).is_empty());
    }

    // ----- L4 -----

    #[test]
    fn l4_unpadded_atomic_vec_in_scalegate_flags() {
        let src = "struct Gate {\n    cursors: Vec<AtomicU64>,\n}";
        let f = lint_source("rust/src/scalegate/x.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_CACHE_PADDED]);
    }

    #[test]
    fn l4_padded_vec_passes() {
        let src = "struct Slot { cursor: AtomicU64 }\nstruct Gate {\n    slots: Vec<CachePadded<Slot>>,\n}";
        assert!(lint_source("rust/src/scalegate/x.rs", src).is_empty());
    }

    #[test]
    fn l4_vec_of_atomic_bearing_struct_flags() {
        let src = "struct Slot { active: AtomicBool, cursor: AtomicU64 }\nstruct Gate { slots: Vec<Slot> }";
        let f = lint_source("rust/src/scalegate/x.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_CACHE_PADDED]);
    }

    #[test]
    fn l4_outside_scalegate_not_checked() {
        let src = "struct Gate { cursors: Vec<AtomicU64> }";
        assert!(lint_source("rust/src/engine/vsn.rs", src).is_empty());
    }

    #[test]
    fn l4_plain_data_vec_passes() {
        let src = "struct Seg { buf: Vec<UnsafeCell<MaybeUninit<u8>>> }";
        assert!(lint_source("rust/src/scalegate/x.rs", src).is_empty());
    }

    // ----- L5 -----

    #[test]
    fn l5_lock_in_marked_file_flags() {
        let src = "//! The ring. lint: lock-free\nuse std::sync::Mutex;\n";
        let f = lint_source("rust/src/util/spsc.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_LOCK_FREE]);
    }

    #[test]
    fn l5_unmarked_file_may_lock() {
        let src = "use std::sync::{Mutex, RwLock};\n";
        assert!(lint_source("rust/src/scalegate/esg.rs", src).is_empty());
    }

    #[test]
    fn l5_test_mod_in_marked_file_may_lock() {
        let src = "//! lint: lock-free\n#[cfg(test)]\nmod tests {\n    use std::sync::Mutex;\n}";
        assert!(lint_source("rust/src/util/spsc.rs", src).is_empty());
    }

    // ----- L6 -----

    #[test]
    fn l6_marked_fn_with_vec_new_flags() {
        let src = "/// Hot path.\n/// lint: no-alloc — steady state must not touch the allocator.\nfn f() -> Vec<u8> {\n    let v: Vec<u8> = Vec::new();\n    v\n}";
        let f = lint_source("rust/src/scalegate/esg.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ALLOC]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn l6_unmarked_fn_allocates_freely() {
        let src = "fn f() -> Vec<u8> { let mut v = Vec::with_capacity(8); v.push(1); v }";
        assert!(lint_source("rust/src/scalegate/esg.rs", src).is_empty());
    }

    #[test]
    fn l6_collect_to_vec_and_with_capacity_flag() {
        let src = "// lint: no-alloc\nfn f(s: &[u8]) {\n    let a: Vec<u8> = s.iter().copied().collect();\n    let b = s.to_vec();\n    let c: Vec<u8> = Vec::with_capacity(4);\n    let _ = (a, b, c);\n}";
        let f = lint_source("rust/src/util/spsc.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ALLOC, RULE_ALLOC, RULE_ALLOC]);
    }

    #[test]
    fn l6_box_new_flags_but_other_constructors_pass() {
        let src = "// lint: no-alloc\nfn f() {\n    let b = Box::new(1u8);\n    let k = Backoff::new();\n    let _ = (b, k);\n}";
        let f = lint_source("rust/src/scalegate/esg.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ALLOC]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn l6_reserve_is_deliberately_allowed() {
        let src = "// lint: no-alloc — recycled capacity makes reserve a no-op\nfn f(buf: &mut Vec<u8>, n: usize) {\n    buf.reserve(n);\n    buf.push(0);\n}";
        assert!(lint_source("rust/src/util/spsc.rs", src).is_empty());
    }

    #[test]
    fn l6_statement_waiver_suppresses() {
        let src = "// lint: no-alloc\nfn f() {\n    // lint: allow(alloc) — cold start: the pool is empty exactly once\n    let v: Vec<u8> = Vec::with_capacity(8);\n    let _ = v;\n}";
        assert!(lint_source("rust/src/scalegate/esg.rs", src).is_empty());
    }

    #[test]
    fn l6_applies_outside_the_dataplane_too() {
        let src = "// lint: no-alloc\nfn f() { let v: Vec<u8> = Vec::new(); let _ = v; }";
        let f = lint_source("rust/src/harness/handle.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ALLOC]);
    }

    #[test]
    fn l6_test_code_is_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    // lint: no-alloc\n    fn f() { let v: Vec<u8> = Vec::new(); let _ = v; }\n}";
        assert!(lint_source("rust/src/scalegate/esg.rs", src).is_empty());
    }

    // ----- cross-cutting -----

    #[test]
    fn findings_are_sorted_by_line() {
        let src = "fn f(x: &AtomicU64, p: *mut u8) {\n    x.store(1, Ordering::Release);\n    unsafe { p.write(0) }\n}";
        let f = lint_source("rust/src/scalegate/x.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_ORDERING, RULE_SAFETY]);
        assert!(f[0].line < f[1].line);
    }

    #[test]
    fn cfg_not_test_is_still_linted() {
        let src = "#[cfg(not(test))]\nfn f(p: *mut u8) { unsafe { p.write(0) } }";
        let f = lint_source("rust/src/foo.rs", src);
        assert_eq!(rules_of(&f), vec![RULE_SAFETY]);
    }
}
