//! Mini property-testing kit (proptest is unavailable offline).
//!
//! `check` runs a property over many deterministically-seeded random cases;
//! on failure it retries with the same case seed to confirm, then reports
//! the seed so the case reproduces exactly:
//!
//! ```text
//! property failed: case seed = 0x6e2a..., add `TestCase::replay(seed)` to debug
//! ```
//!
//! Generators are just closures over [`crate::util::Rng`]; helpers below
//! cover the common shapes (sorted timestamp streams, key sets, rate
//! schedules).

use crate::util::Rng;

/// A single randomized test case with its own seeded RNG.
pub struct TestCase {
    pub seed: u64,
    pub rng: Rng,
}

impl TestCase {
    pub fn replay(seed: u64) -> Self {
        TestCase { seed, rng: Rng::new(seed) }
    }
}

/// Run `prop` over `cases` deterministic random cases. Panics (with the
/// case seed) on the first failure. The master seed can be overridden via
/// the `STRETCH_PROP_SEED` env var; case count via `STRETCH_PROP_CASES`.
pub fn check<F: FnMut(&mut TestCase)>(name: &str, cases: usize, mut prop: F) {
    let master = std::env::var("STRETCH_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5354_5245_5443_4821); // "STRETCH!"
    let cases = std::env::var("STRETCH_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(cases);
    let mut seeder = Rng::new(master);
    for i in 0..cases {
        let seed = seeder.next_u64();
        let mut tc = TestCase::replay(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut tc)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed on case {i}/{cases} (seed {seed:#x}):\n  {msg}\n\
                 reproduce with TestCase::replay({seed:#x}) or STRETCH_PROP_SEED"
            );
        }
    }
}

/// Generate a sorted timestamp stream: `n` timestamps starting at `start`
/// with gaps in `[0, max_gap]` (duplicates allowed — the algorithms must
/// handle ties).
pub fn sorted_timestamps(rng: &mut Rng, n: usize, start: i64, max_gap: i64) -> Vec<i64> {
    let mut ts = start;
    (0..n)
        .map(|_| {
            ts += rng.gen_range(max_gap as u64 + 1) as i64;
            ts
        })
        .collect()
}

/// Generate a set of `n` distinct keys in a wide space.
pub fn keys(rng: &mut Rng, n: usize) -> Vec<u64> {
    let mut ks = std::collections::BTreeSet::new();
    while ks.len() < n {
        ks.insert(rng.next_u64() >> 16);
    }
    ks.into_iter().collect()
}

/// Pick a random subset of at least `min` elements.
pub fn subset<T: Clone>(rng: &mut Rng, xs: &[T], min: usize) -> Vec<T> {
    assert!(min <= xs.len());
    let k = rng.range(min, xs.len() + 1);
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    idx.sort_unstable();
    idx.into_iter().map(|i| xs[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 50, |tc| {
            let v = tc.rng.gen_range(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn check_reports_seed_on_failure() {
        check("failing", 50, |tc| {
            // fails on roughly half the cases
            assert!(tc.rng.f64() < 0.5, "coin came up tails");
        });
    }

    #[test]
    fn sorted_timestamps_are_sorted() {
        check("ts sorted", 20, |tc| {
            let n = tc.rng.range(1, 200);
            let ts = sorted_timestamps(&mut tc.rng, n, 0, 5);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        });
    }

    #[test]
    fn keys_distinct() {
        let mut rng = Rng::new(1);
        let ks = keys(&mut rng, 100);
        assert_eq!(ks.len(), 100);
        let set: std::collections::BTreeSet<_> = ks.iter().collect();
        assert_eq!(set.len(), 100);
    }

    #[test]
    fn subset_respects_min() {
        check("subset", 30, |tc| {
            let xs: Vec<u32> = (0..20).collect();
            let s = subset(&mut tc.rng, &xs, 3);
            assert!(s.len() >= 3 && s.len() <= 20);
            // all elements from xs, in order
            assert!(s.windows(2).all(|w| w[0] < w[1]));
        });
    }
}
