//! Watermark tracking (§2.3, Definitions 2 and 3).
//!
//! Two mechanisms coexist, exactly as in the paper:
//!
//! * **Implicit watermarks** — when an instance's physical input streams are
//!   timestamp-sorted, tuples are merge-sorted and fed once *ready*
//!   (Def. 3); the instance watermark then advances to each ready tuple's
//!   timestamp. The VSN path gets this for free from the ScaleGate; the SN
//!   baseline uses [`MergeSorter`] per instance.
//! * **Explicit watermarks** — heartbeat tuples carry timestamps that bound
//!   future tuples, covering sources whose rate drops to zero.

use crate::time::{EventTime, TIME_MIN};
use crate::tuple::{Kind, Tuple};
use std::collections::BinaryHeap;

/// Per-instance watermark state W (Def. 2): the earliest event time any
/// future tuple processed by this instance can carry.
#[derive(Clone, Debug)]
pub struct Watermark {
    w: EventTime,
}

impl Default for Watermark {
    fn default() -> Self {
        Watermark { w: TIME_MIN }
    }
}

impl Watermark {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current watermark value.
    #[inline]
    pub fn get(&self) -> EventTime {
        self.w
    }

    /// Update from a ready tuple's timestamp; watermarks never regress.
    /// Returns `true` if the watermark strictly increased (the trigger
    /// condition of Alg. 4 L17 is `W > W̄ ∧ W > γ`).
    #[inline]
    pub fn update(&mut self, ts: EventTime) -> bool {
        if ts > self.w {
            self.w = ts;
            true
        } else {
            false
        }
    }
}

/// Tracks the minimum of the latest watermarks across I input channels —
/// the multi-input combination rule for explicit watermarks (§2.3) and the
/// readiness bound of Def. 3 for merge-sorting.
#[derive(Clone, Debug)]
pub struct MultiInputWatermark {
    latest: Vec<EventTime>,
}

impl MultiInputWatermark {
    pub fn new(inputs: usize) -> Self {
        assert!(inputs > 0);
        MultiInputWatermark { latest: vec![TIME_MIN; inputs] }
    }

    /// Record a watermark/timestamp observation from channel `i`; returns
    /// the combined (min) watermark after the update.
    pub fn observe(&mut self, i: usize, ts: EventTime) -> EventTime {
        debug_assert!(
            ts >= self.latest[i],
            "channel {i} watermark regressed: {ts} < {}",
            self.latest[i]
        );
        self.latest[i] = self.latest[i].max(ts);
        self.combined()
    }

    /// min_i(latest_i): tuples with ts <= this are *ready* (Def. 3).
    pub fn combined(&self) -> EventTime {
        *self.latest.iter().min().expect("at least one input")
    }

    pub fn channel(&self, i: usize) -> EventTime {
        self.latest[i]
    }

    pub fn inputs(&self) -> usize {
        self.latest.len()
    }
}

/// An entry in the merge heap: (ts, channel, seq) with a total order so
/// that equal timestamps break ties deterministically by channel then
/// arrival order (needed for deterministic SN ≡ VSN comparisons).
#[derive(Debug)]
struct HeapEntry<P> {
    ts: EventTime,
    channel: usize,
    seq: u64,
    tuple: Tuple<P>,
}

impl<P> PartialEq for HeapEntry<P> {
    fn eq(&self, other: &Self) -> bool {
        (self.ts, self.channel, self.seq) == (other.ts, other.channel, other.seq)
    }
}
impl<P> Eq for HeapEntry<P> {}
impl<P> PartialOrd for HeapEntry<P> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<P> Ord for HeapEntry<P> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        (other.ts, other.channel, other.seq).cmp(&(self.ts, self.channel, self.seq))
    }
}

/// Merge-sorts I timestamp-sorted channels and releases tuples once ready
/// (Def. 3). This is what each SN operator instance runs over its dedicated
/// input queues (§8: "in SN setups input tuples are merge-sorted by both
/// o+_j and d_j instances").
pub struct MergeSorter<P> {
    heap: BinaryHeap<HeapEntry<P>>,
    wm: MultiInputWatermark,
    seq: u64,
}

impl<P> MergeSorter<P> {
    pub fn new(channels: usize) -> Self {
        MergeSorter {
            heap: BinaryHeap::new(),
            wm: MultiInputWatermark::new(channels),
            seq: 0,
        }
    }

    /// Offer a tuple from `channel`. Heartbeats/flushes advance the channel
    /// watermark without being queued for delivery.
    pub fn offer(&mut self, channel: usize, t: Tuple<P>) {
        self.wm.observe(channel, t.ts);
        match t.kind {
            Kind::Heartbeat | Kind::Flush | Kind::Dummy => {}
            _ => {
                self.heap.push(HeapEntry { ts: t.ts, channel, seq: self.seq, tuple: t });
                self.seq += 1;
            }
        }
    }

    /// Pop the earliest *ready* tuple, if any (ts <= min over channels of
    /// the latest observed ts).
    pub fn pop_ready(&mut self) -> Option<Tuple<P>> {
        let bound = self.wm.combined();
        if self.heap.peek().map(|e| e.ts <= bound).unwrap_or(false) {
            Some(self.heap.pop().unwrap().tuple)
        } else {
            None
        }
    }

    /// Number of buffered (not yet ready or not yet popped) tuples.
    pub fn buffered(&self) -> usize {
        self.heap.len()
    }

    pub fn watermark(&self) -> EventTime {
        self.wm.combined()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watermark_never_regresses() {
        let mut w = Watermark::new();
        assert!(w.update(10));
        assert!(!w.update(5));
        assert_eq!(w.get(), 10);
        assert!(w.update(11));
    }

    #[test]
    fn multi_input_min_rule() {
        let mut m = MultiInputWatermark::new(3);
        m.observe(0, 10);
        m.observe(1, 20);
        assert_eq!(m.combined(), TIME_MIN); // channel 2 silent
        m.observe(2, 5);
        assert_eq!(m.combined(), 5);
        m.observe(2, 30);
        assert_eq!(m.combined(), 10);
    }

    #[test]
    fn merge_sorter_releases_only_ready() {
        let mut ms: MergeSorter<u32> = MergeSorter::new(2);
        ms.offer(0, Tuple::data(5, 1));
        ms.offer(0, Tuple::data(9, 2));
        // channel 1 silent: nothing ready
        assert!(ms.pop_ready().is_none());
        ms.offer(1, Tuple::data(7, 3));
        // ready bound = min(9, 7) = 7 → release ts 5 and 7
        assert_eq!(ms.pop_ready().unwrap().ts, 5);
        assert_eq!(ms.pop_ready().unwrap().ts, 7);
        assert!(ms.pop_ready().is_none()); // ts 9 > bound
        ms.offer(1, Tuple::data(20, 4));
        assert_eq!(ms.pop_ready().unwrap().ts, 9);
    }

    #[test]
    fn merge_sorter_output_is_sorted() {
        let mut ms: MergeSorter<u32> = MergeSorter::new(2);
        let a = [1, 4, 6, 8, 12];
        let b = [2, 3, 9, 10, 15];
        for &ts in &a {
            ms.offer(0, Tuple::data(ts, 0));
        }
        for &ts in &b {
            ms.offer(1, Tuple::data(ts, 1));
        }
        let mut out = Vec::new();
        while let Some(t) = ms.pop_ready() {
            out.push(t.ts);
        }
        // ready bound is min(12, 15) = 12
        assert_eq!(out, vec![1, 2, 3, 4, 6, 8, 9, 10, 12]);
        let mut sorted = out.clone();
        sorted.sort();
        assert_eq!(out, sorted);
    }

    #[test]
    fn heartbeats_advance_without_delivery() {
        let mut ms: MergeSorter<u32> = MergeSorter::new(2);
        ms.offer(0, Tuple::data(5, 1));
        ms.offer(1, Tuple::heartbeat(100));
        let t = ms.pop_ready().unwrap();
        assert_eq!(t.ts, 5);
        assert!(ms.pop_ready().is_none());
        assert_eq!(ms.watermark(), 5); // min(5, 100)
    }

    #[test]
    fn ties_break_by_channel_then_seq() {
        let mut ms: MergeSorter<u32> = MergeSorter::new(2);
        ms.offer(1, Tuple::data(5, 10));
        ms.offer(0, Tuple::data(5, 20));
        ms.offer(0, Tuple::data(5, 21));
        ms.offer(0, Tuple::heartbeat(6));
        ms.offer(1, Tuple::heartbeat(6));
        let order: Vec<u32> = std::iter::from_fn(|| ms.pop_ready()).map(|t| t.payload).collect();
        assert_eq!(order, vec![20, 21, 10]); // channel 0 first, then arrival order
    }
}
