//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Records microsecond-scale values with ~4% relative precision using
//! log2 major buckets × 16 linear minor buckets. Lock-free recording via
//! relaxed atomics; merging/reading happens off the hot path.
//!
//! ORDERING: every atomic in this file is Relaxed — the cells are pure
//! statistics, read by samplers that act on the values alone. A reader
//! racing a writer may see `count`/`sum`/bucket totals from slightly
//! different instants; that skew is inherent to sampling a live system
//! and no correctness decision hangs off it.

use std::sync::atomic::{AtomicU64, Ordering};

const MINOR_BITS: u32 = 4;
const MINOR: usize = 1 << MINOR_BITS; // 16
const MAJORS: usize = 40; // covers up to ~2^40 us
const BUCKETS: usize = MAJORS * MINOR;

/// Concurrent histogram of non-negative u64 samples.
pub struct Histogram {
    buckets: Box<[AtomicU64; BUCKETS]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[inline]
fn bucket_of(v: u64) -> usize {
    if v < MINOR as u64 {
        return v as usize;
    }
    let major = 63 - v.leading_zeros(); // floor(log2 v), >= MINOR_BITS
    let minor = ((v >> (major - MINOR_BITS)) & (MINOR as u64 - 1)) as usize;
    let idx = ((major - MINOR_BITS + 1) as usize) * MINOR + minor;
    idx.min(BUCKETS - 1)
}

/// Representative (lower-bound) value of a bucket.
fn bucket_value(idx: usize) -> u64 {
    if idx < MINOR {
        return idx as u64;
    }
    let major = (idx / MINOR - 1) as u32 + MINOR_BITS;
    let minor = (idx % MINOR) as u64;
    (1u64 << major) | (minor << (major - MINOR_BITS))
}

impl Histogram {
    pub fn new() -> Self {
        // Avoid large stack array: build on the heap.
        let v: Vec<AtomicU64> = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        let buckets: Box<[AtomicU64; BUCKETS]> =
            v.into_boxed_slice().try_into().map_err(|_| ()).unwrap();
        Histogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample (e.g. latency in microseconds).
    ///
    /// ORDERING: Relaxed — statistics cells (see the module docs).
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// ORDERING: Relaxed — monitoring read (see the module docs).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// ORDERING: Relaxed — monitoring read (see the module docs).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// ORDERING: Relaxed — monitoring read (see the module docs).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile in [0, 1].
    ///
    /// ORDERING: Relaxed — monitoring scan (see the module docs).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for i in 0..BUCKETS {
            acc += self.buckets[i].load(Ordering::Relaxed);
            if acc >= target {
                return bucket_value(i);
            }
        }
        self.max()
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Reset all counters (between experiment phases).
    ///
    /// ORDERING: Relaxed — statistics reset; in-flight `record`s may land
    /// on either side of it, as with any sampler (see the module docs).
    pub fn reset(&self) {
        for b in self.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Snapshot (count, mean, p50, p99, max).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count(),
            mean: self.mean(),
            p50: self.p50(),
            p99: self.p99(),
            max: self.max(),
        }
    }
}

/// A point-in-time summary of a histogram.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSnapshot {
    pub count: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
    pub max: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_exact() {
        let h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.max(), 15);
    }

    #[test]
    fn mean_exact() {
        let h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_within_precision() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.07, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.07, "p99={p99}");
    }

    #[test]
    fn bucket_roundtrip_monotone() {
        let mut values: Vec<u64> = Vec::new();
        for exp in 0..32 {
            for off in [0u64, 1, 3] {
                values.push((1u64 << exp) + off);
            }
        }
        values.sort_unstable();
        let mut last = 0;
        for v in values {
            let b = bucket_of(v);
            assert!(b >= last, "non-monotone at {v}");
            last = b;
            let rep = bucket_value(b);
            assert!(rep <= v, "rep {rep} > v {v}");
            // relative error bound ~ 1/16
            if v >= 16 {
                assert!((v - rep) as f64 / v as f64 <= 1.0 / 8.0, "v={v} rep={rep}");
            }
        }
    }

    #[test]
    fn reset_clears() {
        let h = Histogram::new();
        h.record(5);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.p99(), 0);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record(t * 10_000 + i);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }
}
