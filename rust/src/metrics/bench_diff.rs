//! Compare two `BENCH_*.json` snapshots — the `stretch bench-diff`
//! subcommand and the CI perf gate.
//!
//! A committed baseline snapshot plus this comparator turn the repo's
//! perf trajectory into an *enforced* contract: CI re-runs the micro
//! bench and fails the pipeline when a throughput field fell (or a
//! latency field rose) beyond a tolerance factor, the same way bit-rot
//! already fails the build. Std-only: a small recursive-descent JSON
//! parser into [`Json`] (serde is unavailable offline), then a top-level
//! field-by-field comparison.
//!
//! Classification is by key name, matching the repo's report idiom:
//! keys starting with `allocs_per_tuple` / `bytes_per_tuple` are
//! allocation-discipline fields (lower is better, deterministic — see
//! below), keys ending in `_tps` / `_per_s` are throughputs (higher is
//! better), keys containing `p50` / `p99` / `latency` are latencies
//! (lower is better); everything else is informational and never gates.
//! Fields missing from either side, non-numeric fields, and fields
//! whose baseline is ≤ 0 (a skipped or degenerate measurement) are
//! skipped — EXCEPT alloc fields, where a zero baseline is the whole
//! point of the contract and still gates.
//!
//! Because allocation counts are deterministic where tuples/s on a
//! shared 1-core CI runner are not, alloc fields support a much tighter
//! tolerance than timing fields (CI: 1.2× vs 50×). The `gate_kinds`
//! filter exists for exactly that split: one invocation gates timing
//! kinds at the wide factor, a second gates only `alloc` at the tight
//! one (`stretch bench-diff … --tolerance 1.2 --gate-kinds alloc`).

use super::bench_json::Json;
use std::fmt;

/// JSON parse errors with a byte offset (good enough to locate a typo in
/// a hand-edited baseline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { at: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected `{}`", b as char))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected `{lit}`"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte `{}`", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok());
                            match hex.and_then(char::from_u32) {
                                // surrogate pairs are not worth the code:
                                // bench reports never emit them
                                Some(c) => {
                                    s.push(c);
                                    self.pos += 4;
                                }
                                None => return self.err("bad \\u escape"),
                            }
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // multi-byte UTF-8 sequences pass through verbatim
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xc0) == 0x80 {
                        self.pos += 1;
                    }
                    match std::str::from_utf8(&self.bytes[start..self.pos]) {
                        Ok(frag) => s.push_str(frag),
                        Err(_) => return self.err("invalid UTF-8 in string"),
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii slice");
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err(format!("bad number `{text}`")),
        }
    }
}

/// Parse one JSON document (must consume the whole input).
pub fn parse_json(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage after JSON value");
    }
    Ok(v)
}

/// How a compared field gates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FieldKind {
    /// Higher is better (`*_tps`, `*_per_s`): regressed when
    /// `new < baseline / tolerance`.
    Throughput,
    /// Lower is better (`*p50*`, `*p99*`, `*latency*`): regressed when
    /// `new > baseline * tolerance`.
    Latency,
    /// Allocation discipline (`allocs_per_tuple*`, `bytes_per_tuple*`):
    /// lower is better, deterministic, gated with an absolute noise
    /// floor ([`ALLOC_GATE_FLOOR`]) so a ≈0 baseline still gates —
    /// regressed when `new > baseline * tolerance + floor`.
    Alloc,
    /// Neither — reported for context, never gates.
    Info,
}

impl FieldKind {
    /// Parse a CLI kind name (`--gate-kinds throughput,latency,alloc`).
    pub fn from_name(name: &str) -> Option<FieldKind> {
        match name {
            "throughput" => Some(FieldKind::Throughput),
            "latency" => Some(FieldKind::Latency),
            "alloc" => Some(FieldKind::Alloc),
            "info" => Some(FieldKind::Info),
            _ => None,
        }
    }
}

/// Absolute slack added to every alloc-field gate: steady-state counts
/// hover near zero, so a pure ratio would gate on (0.0001 → 0.0002)
/// noise. 0.01 allocs (or bytes) per tuple matches the bench's own
/// `allocs_per_tuple < 0.01` assertion bar — anything under it is
/// allocation-free for the contract's purposes.
pub const ALLOC_GATE_FLOOR: f64 = 0.01;

/// Classify a report key by the repo's naming idiom. The canonical
/// gated alloc fields START with the metric name
/// (`allocs_per_tuple_batched_gate`); prefixed variants like
/// `diamond_allocs_per_tuple` stay informational — the diamond path is
/// threaded, so its counts carry scheduler-dependent stragglers the
/// deterministic single-thread gate must not inherit.
pub fn classify(key: &str) -> FieldKind {
    if key.starts_with("allocs_per_tuple") || key.starts_with("bytes_per_tuple") {
        FieldKind::Alloc
    } else if key.ends_with("_tps") || key.ends_with("_per_s") {
        FieldKind::Throughput
    } else if key.contains("p50") || key.contains("p99") || key.contains("latency") {
        FieldKind::Latency
    } else {
        FieldKind::Info
    }
}

/// One compared top-level field.
#[derive(Clone, Debug)]
pub struct FieldDiff {
    pub key: String,
    pub baseline: f64,
    pub new: f64,
    /// `new / baseline` (for latency a ratio > 1 means slower).
    pub ratio: f64,
    pub kind: FieldKind,
    pub regressed: bool,
}

/// Outcome of comparing two reports.
#[derive(Clone, Debug, Default)]
pub struct DiffReport {
    /// Every numeric top-level field present in BOTH reports, in the
    /// baseline's field order.
    pub fields: Vec<FieldDiff>,
    /// Gated fields (throughput/latency) with a positive baseline that
    /// moved beyond the tolerance factor.
    pub regressions: usize,
}

impl DiffReport {
    pub fn is_regression(&self) -> bool {
        self.regressions > 0
    }
}

impl fmt::Display for DiffReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for d in &self.fields {
            let tag = match (d.kind, d.regressed) {
                (_, true) => "REGRESSED",
                (FieldKind::Info, _) => "info",
                _ => "ok",
            };
            writeln!(
                f,
                "{:<28} {:>16.3} -> {:>16.3}  ({:>7.3}x)  {}",
                d.key, d.baseline, d.new, d.ratio, tag
            )?;
        }
        write!(f, "{} field(s) compared, {} regression(s)", self.fields.len(), self.regressions)
    }
}

fn numeric_fields(doc: &Json) -> Vec<(String, f64)> {
    match doc {
        Json::Obj(kvs) => kvs
            .iter()
            .filter_map(|(k, v)| match v {
                Json::Num(x) => Some((k.clone(), *x)),
                _ => None,
            })
            .collect(),
        _ => Vec::new(),
    }
}

/// Compare two parsed reports under a tolerance *factor* (1.25 = allow
/// 25% drift before gating; CI on shared runners uses a much wider
/// factor for timing kinds). Fields whose baseline is ≤ 0 never gate —
/// a zero baseline marks a skipped/degenerate measurement, not a perf
/// contract — except [`FieldKind::Alloc`], where ≈0 baselines are the
/// contract and the absolute [`ALLOC_GATE_FLOOR`] absorbs the noise.
pub fn compare(baseline: &Json, new: &Json, tolerance: f64) -> DiffReport {
    compare_gated(baseline, new, tolerance, None)
}

/// [`compare`] restricted to gating only the listed kinds: fields of
/// other kinds are still compared and reported, but never count as
/// regressions. `None` gates every kind. This is how CI applies a tight
/// tolerance to deterministic alloc fields without flaking on noisy
/// timing fields (module docs).
pub fn compare_gated(
    baseline: &Json,
    new: &Json,
    tolerance: f64,
    gate_kinds: Option<&[FieldKind]>,
) -> DiffReport {
    let tol = tolerance.max(1.0);
    let new_fields = numeric_fields(new);
    let mut out = DiffReport::default();
    for (key, base) in numeric_fields(baseline) {
        let Some(&(_, cur)) = new_fields.iter().find(|(k, _)| *k == key) else { continue };
        let kind = classify(&key);
        let moved = match kind {
            FieldKind::Throughput => base > 0.0 && cur < base / tol,
            FieldKind::Latency => base > 0.0 && cur > base * tol,
            FieldKind::Alloc => base >= 0.0 && cur > base * tol + ALLOC_GATE_FLOOR,
            FieldKind::Info => false,
        };
        let gate_ok = match gate_kinds {
            None => true,
            Some(ks) => ks.contains(&kind),
        };
        let regressed = moved && gate_ok;
        if regressed {
            out.regressions += 1;
        }
        let ratio = if base != 0.0 { cur / base } else { f64::NAN };
        out.fields.push(FieldDiff { key, baseline: base, new: cur, ratio, kind, regressed });
    }
    out
}

/// Errors from [`diff_files`].
#[derive(Debug)]
pub enum DiffError {
    Io(String, std::io::Error),
    Parse(String, ParseError),
}

impl fmt::Display for DiffError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DiffError::Io(path, e) => write!(f, "{path}: {e}"),
            DiffError::Parse(path, e) => write!(f, "{path}: {e}"),
        }
    }
}

impl std::error::Error for DiffError {}

/// Load, parse and compare two report files.
pub fn diff_files(baseline: &str, new: &str, tolerance: f64) -> Result<DiffReport, DiffError> {
    diff_files_gated(baseline, new, tolerance, None)
}

/// [`diff_files`] with a [`compare_gated`] kind filter — the engine
/// behind `stretch bench-diff --gate-kinds …`.
pub fn diff_files_gated(
    baseline: &str,
    new: &str,
    tolerance: f64,
    gate_kinds: Option<&[FieldKind]>,
) -> Result<DiffReport, DiffError> {
    let load = |path: &str| -> Result<Json, DiffError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| DiffError::Io(path.to_string(), e))?;
        parse_json(&text).map_err(|e| DiffError::Parse(path.to_string(), e))
    };
    Ok(compare_gated(&load(baseline)?, &load(new)?, tolerance, gate_kinds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parser_round_trips_the_report_format() {
        // exactly what BenchReport::render emits
        let text = "{\n  \"bench\": \"micro\",\n  \"esg_per_tuple_tps\": 4200000,\n  \
                    \"sweep\": [{\"batch\":16,\"us\":0.25},{\"batch\":64,\"us\":0.1}],\n  \
                    \"ok\": true,\n  \"skipped\": null,\n  \"note\": \"a\\\"b\\u0041\"\n}\n";
        let v = parse_json(text).unwrap();
        let Json::Obj(kvs) = &v else { panic!("expected object") };
        assert_eq!(kvs.len(), 6);
        assert_eq!(kvs[0], ("bench".into(), Json::Str("micro".into())));
        assert_eq!(kvs[1].1, Json::Num(4_200_000.0));
        assert_eq!(kvs[5].1, Json::Str("a\"bA".into()));
        // Display → parse is the identity on the value
        assert_eq!(parse_json(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn parser_rejects_malformed_input() {
        for bad in ["", "{", "{\"a\" 1}", "[1,]", "nul", "{\"a\":1} x", "\"\\q\""] {
            assert!(parse_json(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn classification_follows_key_names() {
        assert_eq!(classify("esg_batched_tps"), FieldKind::Throughput);
        assert_eq!(classify("cmp_per_s"), FieldKind::Throughput);
        assert_eq!(classify("latency_p50_us"), FieldKind::Latency);
        assert_eq!(classify("latency_mean_us"), FieldKind::Latency);
        assert_eq!(classify("budget_ms"), FieldKind::Info);
        assert_eq!(classify("esg_batch_size"), FieldKind::Info);
        // recovery latency is an observability record, not a perf
        // contract: chaos timing varies run to run and must never gate
        assert_eq!(classify("mttr_ms"), FieldKind::Info);
    }

    #[test]
    fn mttr_is_informational_and_never_gates() {
        let base = parse_json(r#"{"a_tps": 1000, "mttr_ms": 5}"#).unwrap();
        let worse = parse_json(r#"{"a_tps": 1000, "mttr_ms": 500}"#).unwrap();
        let d = compare(&base, &worse, 1.25);
        assert!(!d.is_regression(), "{d}");
        assert!(d.fields.iter().any(|f| f.key == "mttr_ms" && f.kind == FieldKind::Info));
    }

    #[test]
    fn throughput_drop_and_latency_rise_both_gate() {
        let base = parse_json(r#"{"a_tps": 1000, "latency_p99_us": 100, "budget_ms": 10}"#)
            .unwrap();
        // throughput halved AND p99 doubled: both beyond a 1.25 factor
        let worse = parse_json(r#"{"a_tps": 500, "latency_p99_us": 200, "budget_ms": 99}"#)
            .unwrap();
        let d = compare(&base, &worse, 1.25);
        assert_eq!(d.regressions, 2, "{d}");
        assert!(d.is_regression());
        // the info field moved 10x but never gates
        assert!(d.fields.iter().any(|f| f.key == "budget_ms" && !f.regressed));
        // same numbers pass under a wide CI factor
        assert!(!compare(&base, &worse, 50.0).is_regression());
        // improvements never gate
        let better = parse_json(r#"{"a_tps": 2000, "latency_p99_us": 50}"#).unwrap();
        assert!(!compare(&base, &better, 1.25).is_regression());
    }

    #[test]
    fn zero_baselines_and_missing_fields_are_skipped() {
        let base = parse_json(r#"{"a_tps": 0, "b_tps": 100, "mode": "x"}"#).unwrap();
        let new = parse_json(r#"{"a_tps": 0, "c_tps": 1}"#).unwrap();
        let d = compare(&base, &new, 1.25);
        // only a_tps is shared and numeric; zero baseline never gates
        assert_eq!(d.fields.len(), 1);
        assert_eq!(d.regressions, 0);
    }

    #[test]
    fn alloc_fields_classify_by_prefix_only() {
        assert_eq!(classify("allocs_per_tuple_batched_gate"), FieldKind::Alloc);
        assert_eq!(classify("bytes_per_tuple_batched_gate"), FieldKind::Alloc);
        // prefixed variants (threaded paths, scheduler noise) stay Info
        assert_eq!(classify("diamond_allocs_per_tuple"), FieldKind::Info);
        assert_eq!(classify("diamond_bytes_per_tuple"), FieldKind::Info);
    }

    #[test]
    fn alloc_fields_gate_with_floor_even_on_zero_baseline() {
        let base = parse_json(r#"{"allocs_per_tuple_batched_gate": 0.0}"#).unwrap();
        // under the absolute floor: allocation-free for the contract
        let under = parse_json(r#"{"allocs_per_tuple_batched_gate": 0.005}"#).unwrap();
        assert!(!compare(&base, &under, 1.2).is_regression());
        // over the floor: the zero baseline STILL gates (unlike tps)
        let over = parse_json(r#"{"allocs_per_tuple_batched_gate": 0.02}"#).unwrap();
        let d = compare(&base, &over, 1.2);
        assert!(d.is_regression(), "{d}");
        // a real nonzero baseline gates on factor + floor together
        let base2 = parse_json(r#"{"allocs_per_tuple_batched_gate": 0.002}"#).unwrap();
        let leak = parse_json(r#"{"allocs_per_tuple_batched_gate": 0.5}"#).unwrap();
        assert!(compare(&base2, &leak, 1.2).is_regression());
        // improvements never gate
        let zero = parse_json(r#"{"allocs_per_tuple_batched_gate": 0.0}"#).unwrap();
        assert!(!compare(&base2, &zero, 1.2).is_regression());
    }

    #[test]
    fn gate_kinds_filter_restricts_what_counts_as_regression() {
        let base =
            parse_json(r#"{"a_tps": 1000, "allocs_per_tuple_batched_gate": 0.0}"#).unwrap();
        // tps halved (regression at 1.2×) AND allocs leaked past the floor
        let worse =
            parse_json(r#"{"a_tps": 500, "allocs_per_tuple_batched_gate": 0.5}"#).unwrap();
        // alloc-only invocation ignores the noisy tps drop…
        let d = compare_gated(&base, &worse, 1.2, Some(&[FieldKind::Alloc]));
        assert_eq!(d.regressions, 1, "{d}");
        let alloc = d
            .fields
            .iter()
            .find(|f| f.key == "allocs_per_tuple_batched_gate")
            .unwrap();
        assert!(alloc.regressed);
        assert!(d.fields.iter().any(|f| f.key == "a_tps" && !f.regressed));
        // …while the unfiltered invocation gates both
        assert_eq!(compare_gated(&base, &worse, 1.2, None).regressions, 2);
        // and a filter naming no moved kind gates nothing
        assert!(!compare_gated(&base, &worse, 1.2, Some(&[FieldKind::Latency]))
            .is_regression());
    }

    #[test]
    fn field_kind_from_name_parses_cli_names() {
        assert_eq!(FieldKind::from_name("throughput"), Some(FieldKind::Throughput));
        assert_eq!(FieldKind::from_name("latency"), Some(FieldKind::Latency));
        assert_eq!(FieldKind::from_name("alloc"), Some(FieldKind::Alloc));
        assert_eq!(FieldKind::from_name("info"), Some(FieldKind::Info));
        assert_eq!(FieldKind::from_name("allocs"), None);
    }

    #[test]
    fn diff_files_round_trip() {
        let dir = std::env::temp_dir().join(format!("stretch_bd_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("base.json");
        let b = dir.join("new.json");
        std::fs::write(&a, "{\n  \"x_tps\": 100\n}\n").unwrap();
        std::fs::write(&b, "{\n  \"x_tps\": 10\n}\n").unwrap();
        let d = diff_files(a.to_str().unwrap(), b.to_str().unwrap(), 1.25).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(d.is_regression());
        assert!(diff_files("/nonexistent.json", "/nonexistent.json", 1.25).is_err());
    }
}
