//! CSV/console reporting for benchmark harnesses.
//!
//! Every figure-regenerating bench writes a CSV under `results/` with the
//! same series the paper plots, so the curves can be re-plotted directly.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// Simple CSV writer with a fixed header.
pub struct CsvWriter {
    w: BufWriter<File>,
    path: PathBuf,
    cols: usize,
}

impl CsvWriter {
    /// Create `path` (parent dirs included) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(&path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, path, cols: header.len() })
    }

    /// Write one row of display-formatted values.
    pub fn row(&mut self, values: &[&dyn std::fmt::Display]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "row arity != header arity");
        let mut first = true;
        for v in values {
            if !first {
                write!(self.w, ",")?;
            }
            write!(self.w, "{v}")?;
            first = false;
        }
        writeln!(self.w)?;
        Ok(())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Macro to write a CSV row from heterogeneous values.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($v:expr),* $(,)?) => {
        $w.row(&[$(&$v as &dyn std::fmt::Display),*]).expect("csv write")
    };
}

/// Console table printer for bench summaries (paper-style rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, values: &[String]) {
        assert_eq!(values.len(), self.headers.len());
        self.rows.push(values.to_vec());
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("stretch_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&[&1, &"x"]).unwrap();
            w.row(&[&2.5, &"y"]).unwrap();
            w.flush().unwrap();
        }
        let s = std::fs::read_to_string(&path).unwrap();
        assert_eq!(s, "a,b\n1,x\n2.5,y\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn csv_arity_checked() {
        let dir = std::env::temp_dir().join(format!("stretch_csv2_{}", std::process::id()));
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&[&1]);
    }

    #[test]
    fn table_renders_padded() {
        let mut t = Table::new(&["name", "val"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("| name   | val |"));
        assert!(r.contains("| longer | 22  |"));
    }
}
