//! Machine-readable benchmark reports: every `benches/bench_*.rs` writes
//! a `BENCH_<name>.json` next to its human output so the repo's perf
//! trajectory is a diffable record (throughput, p50/p99 latency,
//! reconfiguration times) rather than scrollback. Std-only JSON emitter
//! (serde is unavailable offline).

use std::fmt;
use std::io::Write;
use std::path::PathBuf;

/// A JSON value (the subset bench reports need).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Finite numbers only; NaN/∞ serialize as `null`.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<V: Into<Json>> From<Vec<V>> for Json {
    fn from(v: Vec<V>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn escape(s: &str, out: &mut fmt::Formatter<'_>) -> fmt::Result {
    out.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => out.write_str("\\\"")?,
            '\\' => out.write_str("\\\\")?,
            '\n' => out.write_str("\\n")?,
            '\r' => out.write_str("\\r")?,
            '\t' => out.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(out, "\\u{:04x}", c as u32)?,
            c => write!(out, "{c}")?,
        }
    }
    out.write_str("\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) if !v.is_finite() => f.write_str("null"),
            Json::Num(v) => {
                if *v == v.trunc() && v.abs() < 9.0e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => escape(s, f),
            Json::Arr(xs) => {
                f.write_str("[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(kvs) => {
                f.write_str("{")?;
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    escape(k, f)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Builder for one bench's `BENCH_<name>.json`.
pub struct BenchReport {
    name: String,
    fields: Vec<(String, Json)>,
}

impl BenchReport {
    /// `name` is the suffix: `BenchReport::new("micro")` →
    /// `BENCH_micro.json`.
    pub fn new(name: &str) -> Self {
        BenchReport {
            name: name.to_string(),
            fields: vec![("bench".to_string(), Json::Str(name.to_string()))],
        }
    }

    /// Set a top-level field (insertion order preserved).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        self.fields.push((key.to_string(), value.into()));
        self
    }

    /// Serialize the report (pretty enough to diff: one field per line).
    pub fn render(&self) -> String {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            s.push_str(&format!("  {}: {}", Json::Str(k.clone()), v));
            if i + 1 < self.fields.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("}\n");
        s
    }

    /// Write `BENCH_<name>.json` into the current directory (the repo
    /// root when run via cargo) and return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(std::path::Path::new("."))
    }

    /// Write `BENCH_<name>.json` into `dir` and return the path.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.render().as_bytes())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_scalars_arrays_objects() {
        let j = Json::obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::from("x\"y")),
            ("c", Json::from(vec![1u64, 2, 3])),
            ("d", Json::Null),
            ("e", Json::from(f64::NAN)),
        ]);
        assert_eq!(j.to_string(), r#"{"a":1.5,"b":"x\"y","c":[1,2,3],"d":null,"e":null}"#);
    }

    #[test]
    fn integral_floats_render_without_fraction() {
        assert_eq!(Json::from(250_000.0f64).to_string(), "250000");
        assert_eq!(Json::from(0.25f64).to_string(), "0.25");
    }

    #[test]
    fn report_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join(format!("stretch_bj_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = BenchReport::new("unit");
        r.set("tput_tps", 123.0)
            .set("levels", Json::Arr(vec![Json::obj(vec![("p50_us", Json::from(7u64))])]));
        let path = r.write_to(&dir).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(s.contains("\"bench\": \"unit\""));
        assert!(s.contains("\"tput_tps\": 123"));
        assert!(s.starts_with("{\n") && s.ends_with("}\n"));
    }
}
