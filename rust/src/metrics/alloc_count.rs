//! A counting `#[global_allocator]` wrapper for allocation-discipline
//! measurement (std-only; bench/example wiring only).
//!
//! [`CountingAlloc`] forwards every call to [`System`] and bumps two
//! process-global Relaxed counters. Rust permits exactly one
//! `#[global_allocator]` per binary, so the *type* lives here in the
//! library while the static is declared only by the binaries that
//! measure (`benches/bench_micro.rs`, `examples/quickstart.rs`):
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: stretch::metrics::CountingAlloc =
//!     stretch::metrics::CountingAlloc;
//! ```
//!
//! [`alloc_snapshot`] reads the counters; the delta of two snapshots
//! bounds the allocator traffic of the code between them. Allocation
//! counts — unlike tuples/s — are deterministic on a noisy shared
//! runner, which is what lets `bench_micro` assert the steady-state
//! `allocs_per_tuple ≈ 0` contract tightly (§Perf memory discipline)
//! and lets `stretch bench-diff` gate the recorded fields at a 1.2×
//! tolerance where timing fields need 50×. In a binary that does not
//! install the wrapper the counters simply stay at zero.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// Forwarding allocator that counts allocation calls and requested
/// bytes. Zero-sized; all state is in module-level counters.
pub struct CountingAlloc;

/// Counter snapshot: allocation calls and bytes requested so far
/// (process-wide, all threads).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct AllocSnapshot {
    /// `alloc` + `alloc_zeroed` + `realloc` calls observed.
    pub allocs: u64,
    /// Bytes requested by those calls (requested, not resident).
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Counters accumulated since an earlier snapshot (saturating, so a
    /// snapshot pair from mismatched sources cannot underflow).
    pub fn delta(self, since: AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs.saturating_sub(since.allocs),
            bytes: self.bytes.saturating_sub(since.bytes),
        }
    }
}

/// Read the counters.
///
/// ORDERING: Relaxed — pure statistics; a snapshot implies no
/// synchronization with the allocation sites it counts. The measurement
/// protocol is snapshot-delta around a region the caller has already
/// quiesced (or accepts cross-thread noise for).
pub fn alloc_snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Ordering::Relaxed),
        bytes: BYTES.load(Ordering::Relaxed),
    }
}

// SAFETY: a pure forwarding wrapper — every method delegates to
// `System` under the caller's own `GlobalAlloc` contract and keeps no
// allocator state of its own (the counters never feed back into any
// allocation decision), so `System`'s correctness carries over intact.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds the `GlobalAlloc` contract for `alloc`;
    // the wrapper forwards it to `System` unchanged.
    // ORDERING: Relaxed counter bumps — statistics only (see
    // `alloc_snapshot`), synchronizing nothing.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: forwarding the caller's contract unchanged to System.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: caller upholds the `GlobalAlloc` contract for `dealloc`
    // (ptr/layout come from this allocator); forwarded unchanged.
    // Frees are not counted: the discipline metric is allocator
    // *acquisition* traffic.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: forwarding the caller's contract unchanged to System.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: caller upholds the `GlobalAlloc` contract for
    // `alloc_zeroed`; forwarded unchanged.
    // ORDERING: Relaxed counter bumps — statistics only.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: forwarding the caller's contract unchanged to System.
        unsafe { System.alloc_zeroed(layout) }
    }

    // SAFETY: caller upholds the `GlobalAlloc` contract for `realloc`
    // (ptr/layout/new_size valid per its docs); forwarded unchanged.
    // Counted as one allocation of `new_size` bytes — a realloc may
    // move, which is exactly the traffic the discipline metric tracks.
    // ORDERING: Relaxed counter bumps — statistics only.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        // SAFETY: forwarding the caller's contract unchanged to System.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_is_saturating_and_directional() {
        let a = AllocSnapshot { allocs: 10, bytes: 100 };
        let b = AllocSnapshot { allocs: 14, bytes: 164 };
        assert_eq!(b.delta(a), AllocSnapshot { allocs: 4, bytes: 64 });
        assert_eq!(a.delta(b), AllocSnapshot { allocs: 0, bytes: 0 });
    }

    #[test]
    fn wrapper_counts_and_forwards() {
        // the wrapper is NOT installed as the test binary's global
        // allocator; drive it directly
        let a = CountingAlloc;
        let layout = Layout::from_size_align(64, 8).unwrap();
        let before = alloc_snapshot();
        let p = unsafe { a.alloc(layout) };
        assert!(!p.is_null());
        unsafe { a.dealloc(p, layout) };
        let z = unsafe { a.alloc_zeroed(layout) };
        assert!(!z.is_null());
        // zeroed memory really is zeroed (the forward worked)
        assert!((0..64).all(|i| unsafe { *z.add(i) } == 0));
        unsafe { a.dealloc(z, layout) };
        let d = alloc_snapshot().delta(before);
        // ≥: the counters are process-global and tests run in parallel
        assert!(d.allocs >= 2, "allocs delta {}", d.allocs);
        assert!(d.bytes >= 128, "bytes delta {}", d.bytes);
    }
}
