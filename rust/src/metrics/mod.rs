//! Metrics subsystem (§8's measurement methodology).
//!
//! Tracks the paper's four metrics: input rate (t/s), throughput
//! (t/s or comparisons/s for joins), per-output latency (difference
//! between an output tuple's emission and the latest contributing input,
//! §8), and reconfiguration time. Plus per-thread load for the coefficient
//! of variation reported in Fig. 9.

pub mod alloc_count;
pub mod bench_diff;
pub mod bench_json;
pub mod histogram;
pub mod reporter;

pub use alloc_count::{alloc_snapshot, AllocSnapshot, CountingAlloc};
pub use bench_diff::{diff_files, diff_files_gated, parse_json, DiffReport, FieldDiff, FieldKind};
pub use bench_json::{BenchReport, Json};
pub use histogram::{HistSnapshot, Histogram};
pub use reporter::CsvWriter;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Shared counters for one operator (all instances record into it).
///
/// ORDERING: every counter here is Relaxed on both sides — pure
/// statistics. Readers (the harness sampler, end-of-run reports) act on
/// the values themselves; no other data is published through them, and
/// cross-counter skew within one snapshot is inherent to sampling a
/// live system anyway.
pub struct OperatorMetrics {
    /// Data tuples consumed from the input.
    pub tuples_in: AtomicU64,
    /// Output tuples produced.
    pub tuples_out: AtomicU64,
    /// Join comparisons executed (the paper's join throughput metric).
    pub comparisons: AtomicU64,
    /// Latency histogram, microseconds.
    pub latency_us: Histogram,
    /// Per-instance tuples processed (for load CV, Fig. 9 right).
    per_instance: Vec<AtomicU64>,
}

impl OperatorMetrics {
    pub fn new(max_instances: usize) -> Arc<Self> {
        Arc::new(OperatorMetrics {
            tuples_in: AtomicU64::new(0),
            tuples_out: AtomicU64::new(0),
            comparisons: AtomicU64::new(0),
            latency_us: Histogram::new(),
            per_instance: (0..max_instances).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    /// ORDERING: Relaxed — statistics counters (see the struct docs).
    #[inline]
    pub fn record_in(&self, instance: usize) {
        self.tuples_in.fetch_add(1, Ordering::Relaxed);
        if instance < self.per_instance.len() {
            self.per_instance[instance].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// ORDERING: Relaxed — statistics counter (see the struct docs).
    #[inline]
    pub fn record_out(&self, n: u64) {
        self.tuples_out.fetch_add(n, Ordering::Relaxed);
    }

    /// ORDERING: Relaxed — statistics counter (see the struct docs).
    #[inline]
    pub fn record_comparisons(&self, n: u64) {
        self.comparisons.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_latency_us(&self, us: u64) {
        self.latency_us.record(us);
    }

    /// Coefficient of variation (%) of per-instance processed counts,
    /// restricted to the currently active instance set.
    ///
    /// ORDERING: Relaxed — monitoring snapshot of statistics counters.
    pub fn load_cv_percent(&self, active: &[usize]) -> f64 {
        let loads: Vec<f64> = active
            .iter()
            .filter_map(|&i| self.per_instance.get(i))
            .map(|c| c.load(Ordering::Relaxed) as f64)
            .collect();
        if loads.len() < 2 {
            return 0.0;
        }
        let mean = loads.iter().sum::<f64>() / loads.len() as f64;
        if mean == 0.0 {
            return 0.0;
        }
        let var = loads.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / loads.len() as f64;
        100.0 * var.sqrt() / mean
    }

    /// ORDERING: Relaxed — monitoring read of a statistics counter.
    pub fn instance_load(&self, i: usize) -> u64 {
        self.per_instance[i].load(Ordering::Relaxed)
    }

    /// ORDERING: Relaxed — statistics reset between sampling phases;
    /// in-flight bumps may land on either side, as with any sampler.
    pub fn reset_instance_loads(&self) {
        for c in &self.per_instance {
            c.store(0, Ordering::Relaxed);
        }
    }

    /// ORDERING: Relaxed — monitoring snapshot (see the struct docs).
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            tuples_in: self.tuples_in.load(Ordering::Relaxed),
            tuples_out: self.tuples_out.load(Ordering::Relaxed),
            comparisons: self.comparisons.load(Ordering::Relaxed),
            latency: self.latency_us.snapshot(),
        }
    }
}

/// Point-in-time operator metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    pub tuples_in: u64,
    pub tuples_out: u64,
    pub comparisons: u64,
    pub latency: HistSnapshot,
}

impl MetricsSnapshot {
    /// Rates between two snapshots over `dt` seconds.
    pub fn rates_since(&self, earlier: &MetricsSnapshot, dt_s: f64) -> Rates {
        let d = dt_s.max(1e-9);
        Rates {
            in_tps: (self.tuples_in - earlier.tuples_in) as f64 / d,
            out_tps: (self.tuples_out - earlier.tuples_out) as f64 / d,
            cmp_per_s: (self.comparisons - earlier.comparisons) as f64 / d,
        }
    }
}

/// Throughput rates derived from snapshots.
#[derive(Clone, Copy, Debug, Default)]
pub struct Rates {
    pub in_tps: f64,
    pub out_tps: f64,
    pub cmp_per_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = OperatorMetrics::new(4);
        m.record_in(0);
        m.record_in(1);
        m.record_out(3);
        m.record_comparisons(100);
        let s = m.snapshot();
        assert_eq!(s.tuples_in, 2);
        assert_eq!(s.tuples_out, 3);
        assert_eq!(s.comparisons, 100);
    }

    #[test]
    fn cv_zero_when_balanced() {
        let m = OperatorMetrics::new(4);
        for i in 0..4 {
            for _ in 0..100 {
                m.record_in(i);
            }
        }
        assert!(m.load_cv_percent(&[0, 1, 2, 3]) < 1e-9);
    }

    #[test]
    fn cv_detects_imbalance() {
        let m = OperatorMetrics::new(2);
        for _ in 0..100 {
            m.record_in(0);
        }
        for _ in 0..50 {
            m.record_in(1);
        }
        let cv = m.load_cv_percent(&[0, 1]);
        assert!(cv > 30.0, "cv={cv}");
    }

    #[test]
    fn cv_restricted_to_active() {
        let m = OperatorMetrics::new(3);
        for _ in 0..100 {
            m.record_in(0);
        }
        for _ in 0..100 {
            m.record_in(1);
        }
        // instance 2 idle but not active: CV over {0,1} is 0
        assert!(m.load_cv_percent(&[0, 1]) < 1e-9);
        assert!(m.load_cv_percent(&[0, 1, 2]) > 10.0);
    }

    #[test]
    fn rates_between_snapshots() {
        let m = OperatorMetrics::new(1);
        let s0 = m.snapshot();
        for _ in 0..500 {
            m.record_in(0);
        }
        m.record_comparisons(2000);
        let s1 = m.snapshot();
        let r = s1.rates_since(&s0, 2.0);
        assert!((r.in_tps - 250.0).abs() < 1e-9);
        assert!((r.cmp_per_s - 1000.0).abs() < 1e-9);
    }
}
