//! The live-job runtime: [`Job::launch`] is the ONE way a running
//! topology is owned.
//!
//! STRETCH's headline is *instantaneous* elasticity — sub-40 ms
//! reconfigurations with no state transfer (§1, §6) — and the elasticity
//! literature (Röger & Mayer's survey, PAPERS.md) frames that as a
//! *mechanism* the engine provides to an external *policy* through a
//! runtime interface. This module is that interface. `launch` moves the
//! data plane — the paced feed, the egress drain and the per-event-second
//! metrics sampling — onto a background runtime thread, and hands back a
//! [`JobHandle`]: the live control surface.
//!
//! * [`JobCtl::scale`] / [`JobCtl::scale_to`] issue a reconfiguration and
//!   return a [`ReconfigTicket`] that resolves to the *measured* reconfig
//!   latency — the paper's <40 ms claim as a first-class observable;
//! * [`JobCtl::set_rate`] overrides the offered rate from now on;
//! * [`JobCtl::set_worker_batch`] retunes a stage's data-plane batching;
//! * [`JobCtl::sample`] returns a [`JobMetrics`] snapshot (per-stage
//!   backlog / parallelism / throughput / latency);
//! * [`JobCtl::await_quiesce`] blocks until the feed has ended and the
//!   egress has gone quiet;
//! * [`JobHandle::shutdown`] stops the topology and returns the
//!   [`JobRunOutcome`] (per-stage samples, reconfig times, tickets).
//!
//! Everything that *decides* — rate schedules beyond the launch plan,
//! scripted reconfigurations, the `elastic` controllers — lives outside,
//! as [`crate::harness::policy`] clients of this surface.
//! [`crate::harness::run_pipeline`] and [`crate::harness::run_job`] are
//! themselves thin clients: launch, drive policies, await quiesce,
//! shut down.

use super::{HarnessError, PacedSource, PipelineRunResult, RunSample, StageRunStats};
use crate::engine::pipeline::Pipeline;
use crate::engine::{EgressDriver, EngineClock, StretchIngress};
use crate::metrics::{Histogram, MetricsSnapshot};
use crate::time::EventTime;
use crate::tuple::{Epoch, InstanceId, Mapper, Payload, Tuple};
use crate::workloads::rates::RateSchedule;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Lifecycle of a launched job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum JobPhase {
    /// The paced feed is running (schedule not yet exhausted).
    Running,
    /// End-of-stream heartbeats sent; in-flight outputs still draining.
    Draining,
    /// Feed done and the egress has gone quiet — results are stable.
    /// The runtime keeps draining the egress and serving commands until
    /// [`JobHandle::shutdown`].
    Quiesced,
    /// The runtime thread has exited.
    Stopped,
}

/// Default deadline of [`JobCtl::await_quiesce`] — generous (a healthy
/// drain is sub-second), but finite: a wedged drain returns instead of
/// hanging the caller forever.
pub const QUIESCE_CAP: Duration = Duration::from_secs(120);

/// The drain never went quiet within the deadline
/// ([`JobCtl::await_quiesce_timeout`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuiesceTimeout {
    /// How long the caller waited.
    pub waited: Duration,
    /// The job's lifecycle phase at the deadline.
    pub phase: JobPhase,
}

impl fmt::Display for QuiesceTimeout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job did not quiesce within {:?} (phase {:?})", self.waited, self.phase)
    }
}

impl std::error::Error for QuiesceTimeout {}

/// Replay a fixed, ts-sorted corpus through the paced feed: `next` pops
/// the front, [`PacedSource::exhausted`] flips once the corpus is
/// consumed, and the runtime then cuts straight to end-of-stream — every
/// tuple is fed exactly once. This is the exact-equivalence harness mode
/// (the oracle tests feed a corpus, not a generator).
pub struct ReplaySource<P: Payload> {
    tuples: VecDeque<Tuple<P>>,
}

impl<P: Payload> ReplaySource<P> {
    pub fn new(tuples: Vec<Tuple<P>>) -> Self {
        ReplaySource { tuples: tuples.into() }
    }
}

impl<P: Payload> PacedSource<P> for ReplaySource<P> {
    fn next(&mut self) -> Tuple<P> {
        self.tuples.pop_front().expect("ReplaySource drained past exhaustion")
    }
    fn exhausted(&self) -> bool {
        self.tuples.is_empty()
    }
}

/// Why the runtime refused a reconfiguration without attempting it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Issued after end-of-stream: no watermark will ever pass the
    /// control tuple, so the epoch switch could never complete.
    AfterEos,
    /// The target instance set contains a crashed worker's slot — dead
    /// slots are terminal for the run and can never rejoin an epoch.
    DeadInstance,
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::AfterEos => write!(f, "issued after end-of-stream"),
            RejectReason::DeadInstance => write!(f, "target set contains a dead instance"),
        }
    }
}

/// Terminal state of a [`ReconfigTicket`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TicketOutcome {
    /// The reconfiguration completed: measured issue→barrier wall ms.
    Completed(f64),
    /// Refused up front, with the typed reason.
    Rejected(RejectReason),
    /// The runtime shut down before the reconfiguration completed.
    Abandoned,
}

#[derive(Default)]
struct TicketInner {
    epoch: Option<Epoch>,
    outcome: Option<TicketOutcome>,
}

struct TicketState {
    inner: Mutex<TicketInner>,
    cv: Condvar,
}

/// A pending reconfiguration issued through a [`JobCtl`]. Resolves to the
/// measured reconfiguration latency (issue → completion barrier, wall ms)
/// once every instance of the stage has switched epochs — the §8.4
/// reconfiguration-time metric as a per-call observable.
#[derive(Clone)]
pub struct ReconfigTicket {
    stage: usize,
    state: Arc<TicketState>,
}

impl ReconfigTicket {
    fn new(stage: usize) -> Self {
        ReconfigTicket {
            stage,
            state: Arc::new(TicketState {
                inner: Mutex::new(TicketInner::default()),
                cv: Condvar::new(),
            }),
        }
    }

    /// Stage index this reconfiguration targets.
    pub fn stage(&self) -> usize {
        self.stage
    }

    /// Epoch id, once the runtime has issued the control tuple.
    pub fn epoch(&self) -> Option<Epoch> {
        self.state.inner.lock().unwrap().epoch
    }

    /// Measured reconfiguration latency, once complete (non-blocking).
    pub fn latency_ms(&self) -> Option<f64> {
        match self.state.inner.lock().unwrap().outcome {
            Some(TicketOutcome::Completed(ms)) => Some(ms),
            _ => None,
        }
    }

    /// The terminal outcome, once there is one (non-blocking).
    pub fn outcome(&self) -> Option<TicketOutcome> {
        self.state.inner.lock().unwrap().outcome
    }

    /// Block until the ticket reaches a terminal outcome or `timeout`
    /// elapses (`None` = still pending at the deadline).
    pub fn wait_outcome(&self, timeout: Duration) -> Option<TicketOutcome> {
        let deadline = Instant::now() + timeout;
        let mut g = self.state.inner.lock().unwrap();
        loop {
            if let Some(o) = g.outcome {
                return Some(o);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self.state.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
    }

    /// Block until the reconfiguration completes, is rejected/abandoned,
    /// or `timeout` elapses. Returns the measured latency in ms; `None`
    /// for every non-completed outcome (see [`Self::wait_outcome`] for
    /// the typed version).
    pub fn wait(&self, timeout: Duration) -> Option<f64> {
        match self.wait_outcome(timeout) {
            Some(TicketOutcome::Completed(ms)) => Some(ms),
            _ => None,
        }
    }

    fn issue(&self, epoch: Epoch) {
        self.state.inner.lock().unwrap().epoch = Some(epoch);
    }

    fn finish(&self, o: TicketOutcome) {
        let mut g = self.state.inner.lock().unwrap();
        if g.outcome.is_none() {
            g.outcome = Some(o);
        }
        self.state.cv.notify_all();
    }

    pub(crate) fn resolve(&self, ms: f64) {
        self.finish(TicketOutcome::Completed(ms));
    }

    pub(crate) fn reject(&self, why: RejectReason) {
        self.finish(TicketOutcome::Rejected(why));
    }

    pub(crate) fn kill(&self) {
        self.finish(TicketOutcome::Abandoned);
    }
}

impl fmt::Debug for ReconfigTicket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let g = self.state.inner.lock().unwrap();
        f.debug_struct("ReconfigTicket")
            .field("stage", &self.stage)
            .field("epoch", &g.epoch)
            .field("outcome", &g.outcome)
            .finish()
    }
}

/// Supervision view of one stage, detector-classified every runtime
/// tick from the engine's [`crate::engine::WorkerHealth`] slab.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StageHealth {
    /// Crashed instance ids (terminal — healing evicts them from the
    /// epoch via reconfiguration).
    pub dead: Vec<InstanceId>,
    /// Instances whose progress epoch has not advanced for
    /// [`LaunchConfig::stall_after_ms`] while the stage's backlog is
    /// nonzero (or with an injected stall in effect). Self-recovering:
    /// the next processed batch clears the mark.
    pub stalled: Vec<InstanceId>,
}

impl StageHealth {
    /// No dead and no stalled workers.
    pub fn is_healthy(&self) -> bool {
        self.dead.is_empty() && self.stalled.is_empty()
    }
}

/// Live view of one stage (refreshed every runtime tick, ~20 ms).
#[derive(Clone, Debug)]
pub struct StageMetrics {
    /// Operator name.
    pub name: &'static str,
    /// Currently active instance ids (𝕆).
    pub active: Vec<InstanceId>,
    /// Maximum parallelism n (pool included).
    pub max: usize,
    /// Pending backlog on the stage's ESG_in.
    pub backlog: u64,
    /// Current effective worker batch.
    pub worker_batch: usize,
    /// Dead/stalled classification of this stage's workers.
    pub health: StageHealth,
    /// Latest per-event-second sample ([`RunSample::default`] before the
    /// first event second completes).
    pub last: RunSample,
}

/// A point-in-time observation of the whole job — what policies consume.
#[derive(Clone, Debug)]
pub struct JobMetrics {
    /// Current event-time position in seconds (computed live at
    /// [`JobCtl::sample`] time).
    pub event_s: f64,
    /// Scheduled feed duration in event seconds.
    pub duration_s: u32,
    /// Offered rate currently applied to the feed (t/event-s).
    pub offered_tps: f64,
    /// Number of ingress wrappers the topology launched with.
    pub ingress: usize,
    /// Tuples handed to the feed so far.
    pub fed: u64,
    /// Data tuples drained at the egress so far.
    pub egress_count: u64,
    /// Tuples dropped because their ingress slot was decommissioned.
    pub ingress_dropped: u64,
    /// Lifecycle phase at the last runtime tick.
    pub phase: JobPhase,
    /// One entry per stage, upstream first.
    pub stages: Vec<StageMetrics>,
}

/// Launch-time plan of a job run — only the *data-plane* knobs: how the
/// feed is paced and flushed. Policy (controllers, scripted steps) stays
/// outside, driven through the handle.
#[derive(Clone)]
pub struct LaunchConfig {
    /// Job name (reports, `BENCH_<name>.json`).
    pub name: String,
    /// Per-stage display names; when the length does not match the
    /// topology depth, operator names are used.
    pub stage_names: Vec<String>,
    /// Offered-rate plan for the paced feed. [`JobCtl::set_rate`]
    /// overrides it from the moment it is called.
    pub schedule: RateSchedule,
    /// Wall-time compression: 10.0 replays 10 event-seconds per
    /// wall-second.
    pub time_scale: f64,
    /// End-of-stream heartbeat horizon beyond the last event ms (flush
    /// windows; use ≥ the largest WS in the topology).
    pub flush_slack_ms: EventTime,
    /// Wall time to keep draining the egress after end-of-stream before
    /// declaring the job quiesced (extended while output still arrives,
    /// up to `drain_cap`).
    pub drain: Duration,
    /// Hard ceiling on the post-EOS drain window: a sink that trickles
    /// output forever (or a wedged stage) can otherwise extend the drain
    /// indefinitely and [`JobCtl::await_quiesce`] would never return.
    pub drain_cap: Duration,
    /// Stall detector window: a worker whose progress epoch has not
    /// advanced for this long while its stage's backlog is nonzero is
    /// classified [`crate::engine::WorkerState::Stalled`].
    pub stall_after_ms: u64,
    /// Max run length per batched ingress add (`[batch] ingress`).
    pub ingress_batch: usize,
    /// Keep every drained egress tuple for [`JobHandle::take_egress`]
    /// (exact-output tests); off by default — benches only need counts.
    pub capture_egress: bool,
    /// Pin the job runtime thread (feed + drain + sampling) to this core.
    /// Set by the placement plan so the drain stays NUMA-local to the
    /// sink gates; `None` leaves the thread floating.
    pub pin_core: Option<usize>,
}

impl Default for LaunchConfig {
    fn default() -> Self {
        LaunchConfig {
            name: "job".into(),
            stage_names: Vec::new(),
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            flush_slack_ms: 15_000,
            drain: Duration::from_millis(500),
            drain_cap: Duration::from_secs(30),
            stall_after_ms: 250,
            ingress_batch: 256,
            capture_egress: false,
            pin_core: None,
        }
    }
}

/// Commands the handle sends to the runtime thread.
enum Cmd {
    Scale { stage: usize, target: ScaleTarget, ticket: ReconfigTicket },
    SetWorkerBatch { stage: usize, n: usize },
    SetRate(f64),
    InjectFault { stage: usize, worker: InstanceId, fault: crate::engine::InjectedFault },
}

enum ScaleTarget {
    /// Resize to this many instances (pool semantics, §7).
    Count(usize),
    /// Install exactly this instance set.
    Set(Vec<InstanceId>),
}

/// State shared between the handle and whichever driver (per-job thread
/// or the multi-job server loop) paces the runtime.
pub(crate) struct RtShared {
    cmds: Mutex<VecDeque<Cmd>>,
    metrics: Mutex<JobMetrics>,
    phase: Mutex<JobPhase>,
    phase_cv: Condvar,
    stop: AtomicBool,
    /// Every ticket ever issued through the handle, issue order.
    tickets: Mutex<Vec<ReconfigTicket>>,
    /// Final statistics, published exactly once by
    /// [`JobTicker::finalize`]; [`JobHandle::shutdown`] takes them.
    fin: Mutex<Option<RtFinal>>,
}

impl RtShared {
    /// Ask the driver to stop the runtime (idempotent).
    pub(crate) fn request_stop(&self) {
        self.stop.store(true, Ordering::Release);
    }

    /// Whether a stop has been requested.
    pub(crate) fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }
}

fn set_phase(shared: &RtShared, p: JobPhase) {
    let mut g = shared.phase.lock().unwrap();
    if *g < p {
        *g = p;
        shared.phase_cv.notify_all();
    }
}

/// The payload-type-erased control surface of a live job. Cloneable and
/// `&self` throughout, so policies, tests and user code can all hold one.
#[derive(Clone)]
pub struct JobCtl {
    shared: Arc<RtShared>,
    t0: Instant,
    time_scale: f64,
    /// Per-stage maximum parallelism (validates scale targets before
    /// they reach the runtime thread).
    maxes: Arc<Vec<usize>>,
}

impl JobCtl {
    /// Number of stages.
    pub fn depth(&self) -> usize {
        self.maxes.len()
    }

    fn push_scale(&self, stage: usize, target: ScaleTarget) -> ReconfigTicket {
        assert!(stage < self.depth(), "stage {stage} out of range ({} stages)", self.depth());
        let ticket = ReconfigTicket::new(stage);
        self.shared.tickets.lock().unwrap().push(ticket.clone());
        self.shared
            .cmds
            .lock()
            .unwrap()
            .push_back(Cmd::Scale { stage, target, ticket: ticket.clone() });
        ticket
    }

    /// Scale `stage` to `n` active instances (keep existing ids, grow
    /// from the lowest pool ids, shrink from the highest; `n` clamps to
    /// the stage's pool). The ticket resolves to the measured
    /// reconfiguration latency. A reconfiguration reaching the runtime
    /// after end-of-stream could never complete (no watermark advances
    /// past it), so it is rejected and its ticket fails fast
    /// ([`ReconfigTicket::wait`] returns `None` without timing out).
    pub fn scale(&self, stage: usize, n: usize) -> ReconfigTicket {
        self.push_scale(stage, ScaleTarget::Count(n.max(1)))
    }

    /// Reconfigure `stage` to exactly this instance set. Every id must
    /// address one of the stage's own instance slots (`< max`) — on a
    /// shared DAG gate an out-of-range id would address another stage's
    /// slots, so it is a caller error, rejected here.
    pub fn scale_to(&self, stage: usize, set: Vec<InstanceId>) -> ReconfigTicket {
        assert!(!set.is_empty(), "instance set must be non-empty");
        assert!(stage < self.depth(), "stage {stage} out of range ({} stages)", self.depth());
        let max = self.maxes[stage];
        assert!(
            set.iter().all(|&i| i < max),
            "instance set {set:?} exceeds stage {stage}'s pool (max parallelism {max})"
        );
        self.push_scale(stage, ScaleTarget::Set(set))
    }

    /// Override the offered feed rate (t/event-s) from now on.
    pub fn set_rate(&self, tps: f64) {
        self.shared.cmds.lock().unwrap().push_back(Cmd::SetRate(tps.max(0.0)));
    }

    /// Retune `stage`'s worker batch (live, no reconfiguration).
    pub fn set_worker_batch(&self, stage: usize, n: usize) {
        assert!(stage < self.depth(), "stage {stage} out of range ({} stages)", self.depth());
        self.shared.cmds.lock().unwrap().push_back(Cmd::SetWorkerBatch { stage, n });
    }

    /// Arm a fault into one worker slot of `stage` (chaos testing); the
    /// worker applies it at its next batch boundary. Out-of-range worker
    /// ids are ignored by the runtime.
    pub fn inject_fault(
        &self,
        stage: usize,
        worker: InstanceId,
        fault: crate::engine::InjectedFault,
    ) {
        assert!(stage < self.depth(), "stage {stage} out of range ({} stages)", self.depth());
        self.shared.cmds.lock().unwrap().push_back(Cmd::InjectFault { stage, worker, fault });
    }

    /// Snapshot the job's metrics. Per-stage fields are at most one
    /// runtime tick (~20 ms) old; `event_s` is computed live.
    pub fn sample(&self) -> JobMetrics {
        let mut m = self.shared.metrics.lock().unwrap().clone();
        m.event_s = self.t0.elapsed().as_secs_f64() * self.time_scale;
        m
    }

    /// Current lifecycle phase.
    pub fn phase(&self) -> JobPhase {
        *self.shared.phase.lock().unwrap()
    }

    /// Whether the job has quiesced (feed ended, egress quiet).
    pub fn quiesced(&self) -> bool {
        self.phase() >= JobPhase::Quiesced
    }

    /// Block until the job quiesces (or the runtime stops), bounded by a
    /// generous default deadline ([`QUIESCE_CAP`]): a wedged drain makes
    /// this return — late, but never hung. Use
    /// [`Self::await_quiesce_timeout`] to observe the timeout as a typed
    /// error and pick your own deadline.
    pub fn await_quiesce(&self) {
        let _ = self.await_quiesce_timeout(QUIESCE_CAP);
    }

    /// Block until the job quiesces, the runtime stops, or `timeout`
    /// elapses — the deadline-bounded quiesce wait.
    pub fn await_quiesce_timeout(&self, timeout: Duration) -> Result<(), QuiesceTimeout> {
        let deadline = Instant::now() + timeout;
        let mut g = self.shared.phase.lock().unwrap();
        while *g < JobPhase::Quiesced {
            let now = Instant::now();
            if now >= deadline {
                return Err(QuiesceTimeout { waited: timeout, phase: *g });
            }
            let (ng, _) = self.shared.phase_cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
        Ok(())
    }

    /// Every reconfiguration ticket issued through this handle so far.
    pub fn tickets(&self) -> Vec<ReconfigTicket> {
        self.shared.tickets.lock().unwrap().clone()
    }

    /// A control surface with no runtime behind it — commands queue
    /// forever. Lets policy unit tests observe what a policy *issues*.
    #[cfg(test)]
    pub(crate) fn detached(n_stages: usize) -> JobCtl {
        JobCtl {
            shared: Arc::new(RtShared {
                cmds: Mutex::new(VecDeque::new()),
                metrics: Mutex::new(JobMetrics {
                    event_s: 0.0,
                    duration_s: 0,
                    offered_tps: 0.0,
                    ingress: 1,
                    fed: 0,
                    egress_count: 0,
                    ingress_dropped: 0,
                    phase: JobPhase::Running,
                    stages: Vec::new(),
                }),
                phase: Mutex::new(JobPhase::Running),
                phase_cv: Condvar::new(),
                stop: AtomicBool::new(false),
                tickets: Mutex::new(Vec::new()),
                fin: Mutex::new(None),
            }),
            t0: Instant::now(),
            time_scale: 1.0,
            maxes: Arc::new(vec![8; n_stages]),
        }
    }
}

/// Outcome of a finished job run ([`JobHandle::shutdown`]). Cloneable so
/// the handle can cache it — a second `shutdown` (e.g. a server stop
/// racing a user stop) returns the same outcome instead of panicking.
#[derive(Clone)]
pub struct JobRunOutcome {
    /// The job's name ([`LaunchConfig::name`] / the config's `name` key).
    pub name: String,
    /// Display stage names aligned with `result.stages` indices.
    pub stage_names: Vec<String>,
    pub result: PipelineRunResult,
    /// Every reconfiguration issued through the handle (scripted-,
    /// policy- or user-driven), with its measured latency once resolved —
    /// the source for `BENCH_<job>.json`'s per-reconfig latencies.
    pub tickets: Vec<ReconfigTicket>,
    /// Every fault recovery a supervisor drove during the run, with its
    /// measured detection→healed latency (MTTR) — empty unless a
    /// [`super::policy::SupervisorPolicy`] was attached ([`super::run_job`]
    /// fills this from its [`super::policy::RecoveryLog`] after quiesce).
    pub recoveries: Vec<super::policy::RecoveryTicket>,
    /// Whether the supervisor exhausted its escalation ladder on some
    /// fault and marked the job degraded (results are best-effort).
    pub degraded: bool,
}

/// A built topology plus its paced source and launch plan — call
/// [`Job::launch`] to start it and receive the [`JobHandle`].
pub struct Job<In: Payload + Default, Out: Payload + Default> {
    pub pipeline: Pipeline<In, Out>,
    pub source: Box<dyn PacedSource<In>>,
    pub cfg: LaunchConfig,
}

impl<In: Payload + Default, Out: Payload + Default> Job<In, Out> {
    pub fn new(pipeline: Pipeline<In, Out>, source: impl PacedSource<In> + 'static) -> Self {
        Job { pipeline, source: Box::new(source), cfg: LaunchConfig::default() }
    }

    pub fn with_config(mut self, cfg: LaunchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Start the job: validate the topology shape, move the data plane
    /// (feed, drain, sampling) onto a dedicated runtime thread, and
    /// return the live handle. Degenerate topologies are typed errors,
    /// before any runtime thread exists.
    pub fn launch(self) -> Result<JobHandle<Out>, HarnessError> {
        let (handle, mut rt) = self.launch_parts()?;
        let name = handle.name.clone();
        let pin = rt.cfg.pin_core;
        let thread = std::thread::Builder::new()
            .name(format!("job-{name}"))
            .spawn(move || {
                if let Some(core) = pin {
                    crate::runtime::placement::pin_current(core);
                }
                drive_runtime(&mut rt);
            })
            .expect("spawn job runtime thread");
        *handle.thread.lock().unwrap() = Some(thread);
        Ok(handle)
    }

    /// Validate and assemble the job WITHOUT spawning anything: the
    /// handle plus the not-yet-driven [`JobRuntime`]. [`Job::launch`]
    /// pairs the runtime with a dedicated thread; the multi-job
    /// [`crate::harness::server::JobServer`] registers it with its
    /// shared ticker loop instead.
    pub(crate) fn launch_parts(self) -> Result<(JobHandle<Out>, JobRuntime<In, Out>), HarnessError> {
        let Job { pipeline, source, mut cfg } = self;
        if pipeline.ingress.is_empty() {
            return Err(HarnessError::NoIngress);
        }
        if pipeline.egress.is_empty() {
            return Err(HarnessError::NoEgress);
        }
        // a zero/negative compression factor would freeze event time and
        // make the job unquiesceable — clamp it for the runtime AND the
        // handle's live event_s computation alike
        cfg.time_scale = cfg.time_scale.max(1e-9);
        let n_stages = pipeline.depth();
        let name = cfg.name.clone();
        let stage_names: Vec<String> = if cfg.stage_names.len() == n_stages {
            cfg.stage_names.clone()
        } else {
            pipeline.stages.iter().map(|s| s.name().to_string()).collect()
        };
        let init_stages: Vec<StageMetrics> = pipeline
            .stages
            .iter()
            .map(|s| StageMetrics {
                name: s.name(),
                active: s.active_instances(),
                max: s.max_parallelism(),
                backlog: 0,
                worker_batch: s.worker_batch(),
                health: StageHealth::default(),
                last: RunSample::default(),
            })
            .collect();
        let shared = Arc::new(RtShared {
            cmds: Mutex::new(VecDeque::new()),
            metrics: Mutex::new(JobMetrics {
                event_s: 0.0,
                duration_s: cfg.schedule.duration_s(),
                offered_tps: cfg.schedule.rate_at(0),
                ingress: pipeline.ingress.len(),
                fed: 0,
                egress_count: 0,
                ingress_dropped: 0,
                phase: JobPhase::Running,
                stages: init_stages,
            }),
            phase: Mutex::new(JobPhase::Running),
            phase_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            tickets: Mutex::new(Vec::new()),
            fin: Mutex::new(None),
        });
        let captured: Arc<Mutex<Vec<Tuple<Out>>>> = Arc::new(Mutex::new(Vec::new()));
        let capture = cfg.capture_egress.then(|| captured.clone());
        let maxes: Arc<Vec<usize>> =
            Arc::new(pipeline.stages.iter().map(|s| s.max_parallelism()).collect());
        let t0 = Instant::now();
        let ctl = JobCtl { shared: shared.clone(), t0, time_scale: cfg.time_scale, maxes };
        let rt = JobRuntime::new(pipeline, source, cfg, shared, capture, t0);
        let handle = JobHandle {
            ctl,
            name,
            stage_names,
            captured,
            thread: Mutex::new(None),
            outcome: Mutex::new(None),
        };
        Ok((handle, rt))
    }
}

/// Owner of a launched job: the [`JobCtl`] control surface (via `Deref`)
/// plus the typed egress capture and the final [`JobRunOutcome`].
pub struct JobHandle<Out: Payload + Default> {
    ctl: JobCtl,
    name: String,
    stage_names: Vec<String>,
    captured: Arc<Mutex<Vec<Tuple<Out>>>>,
    /// The dedicated driver thread ([`Job::launch`]); stays `None` when
    /// a server loop drives the runtime instead.
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Cached outcome: [`Self::shutdown`] is idempotent — the first call
    /// builds it, every later call returns the cached clone.
    outcome: Mutex<Option<JobRunOutcome>>,
}

impl<Out: Payload + Default> std::ops::Deref for JobHandle<Out> {
    type Target = JobCtl;
    fn deref(&self) -> &JobCtl {
        &self.ctl
    }
}

impl<Out: Payload + Default> JobHandle<Out> {
    /// A detachable clone of the control surface (policies, other
    /// threads).
    pub fn ctl(&self) -> JobCtl {
        self.ctl.clone()
    }

    /// Display stage names, aligned with stage indices.
    pub fn stage_names(&self) -> &[String] {
        &self.stage_names
    }

    /// Drain the captured egress tuples accumulated so far (only
    /// populated when launched with [`LaunchConfig::capture_egress`]).
    pub fn take_egress(&self) -> Vec<Tuple<Out>> {
        std::mem::take(&mut *self.captured.lock().unwrap())
    }

    /// Stop the runtime, shut every stage down (upstream first) and
    /// return the run's outcome. Shutting down before
    /// [`JobCtl::await_quiesce`] abandons in-flight tuples.
    ///
    /// Idempotent: the outcome is cached on the first call, and every
    /// later call — including a concurrent one racing the first (a
    /// server stop racing a user stop) — returns the cached clone
    /// instead of double-joining the runtime.
    pub fn shutdown(&self) -> JobRunOutcome {
        // the cache lock is held across the whole teardown: a second
        // caller blocks here until the first finishes, then takes the
        // cached branch
        let mut cached = self.outcome.lock().unwrap();
        if let Some(out) = cached.as_ref() {
            return out.clone();
        }
        self.ctl.shared.request_stop();
        match self.thread.lock().unwrap().take() {
            Some(t) => {
                t.join().unwrap_or_else(|_| panic!("job runtime thread panicked"));
            }
            None => {
                // server-driven: the server loop finalizes the runtime
                // on its next pass — wait for the Stopped phase it
                // publishes (bounded: a vanished driver must not hang
                // the caller forever)
                let deadline = Instant::now() + QUIESCE_CAP;
                let mut g = self.ctl.shared.phase.lock().unwrap();
                while *g < JobPhase::Stopped {
                    let now = Instant::now();
                    assert!(
                        now < deadline,
                        "job runtime was never finalized (server loop gone?)"
                    );
                    let (ng, _) =
                        self.ctl.shared.phase_cv.wait_timeout(g, deadline - now).unwrap();
                    g = ng;
                }
            }
        }
        let fin = self
            .ctl
            .shared
            .fin
            .lock()
            .unwrap()
            .take()
            .expect("runtime finalized without publishing final statistics");
        let out = JobRunOutcome {
            name: self.name.clone(),
            stage_names: self.stage_names.clone(),
            result: PipelineRunResult {
                stages: fin.stages,
                egress_count: fin.egress_count,
                ingress_dropped: fin.ingress_dropped,
                latency_p50_us: fin.latency_p50_us,
                latency_mean_us: fin.latency_mean_us,
            },
            tickets: self.ctl.tickets(),
            recoveries: Vec::new(),
            degraded: false,
        };
        *cached = Some(out.clone());
        out
    }
}

impl<Out: Payload + Default> Drop for JobHandle<Out> {
    fn drop(&mut self) {
        if self.outcome.get_mut().unwrap().is_some() {
            return; // already shut down and cached
        }
        self.ctl.shared.request_stop();
        if let Some(t) = self.thread.get_mut().unwrap().take() {
            let _ = t.join();
        }
        // a server-driven runtime (thread = None) is finalized by the
        // server loop itself — nothing to join here
    }
}

/// Final statistics the runtime publishes (via [`RtShared::fin`]) when
/// its driver finalizes it.
struct RtFinal {
    stages: Vec<StageRunStats>,
    egress_count: u64,
    ingress_dropped: u64,
    latency_p50_us: u64,
    latency_mean_us: f64,
}

/// Per-stage sampling bookkeeping local to the runtime thread.
struct StageTrack {
    last_snap: MetricsSnapshot,
    prev_loads: Vec<u64>,
    samples: Vec<RunSample>,
}

/// Resolve every pending ticket whose reconfiguration has completed
/// (matched by epoch against the stage's recorded completion times) —
/// called once per runtime tick and once more at finalize.
fn resolve_completed(
    pending: &mut Vec<(usize, Epoch, ReconfigTicket)>,
    stages: &[Box<dyn crate::engine::pipeline::StageHandle>],
) {
    pending.retain(|(stage, epoch, ticket)| {
        match stages[*stage].completion_times().iter().find(|(e, _)| e == epoch) {
            Some(&(_, ms)) => {
                ticket.resolve(ms);
                false
            }
            None => true,
        }
    });
}

/// Ensures waiters wake even if the driving thread panics: dropping the
/// guard forces the job's phase to `Stopped`. Every driver (the per-job
/// thread and the server loop) arms one per runtime it drives.
pub(crate) struct StopGuard(Arc<RtShared>);

impl StopGuard {
    pub(crate) fn new(shared: Arc<RtShared>) -> Self {
        StopGuard(shared)
    }
}

impl Drop for StopGuard {
    fn drop(&mut self) {
        set_phase(&self.0, JobPhase::Stopped);
    }
}

/// One wall tick of the shared runtime cadence. Both drivers — the
/// per-job thread ([`drive_runtime`]) and the multi-job server loop —
/// pace [`JobTicker::tick`] at this interval, and the feed derives its
/// per-tick tuple quantum from it.
pub(crate) const RUNTIME_TICK: Duration = Duration::from_millis(20);

/// The payload-type-erased drive contract of one launched job: what a
/// driver needs to pace the data plane without knowing the tuple types.
/// [`Job::launch`] drives one ticker on a dedicated thread; the
/// [`crate::harness::server::JobServer`] loop interleaves many.
pub(crate) trait JobTicker: Send {
    /// One pass of the drive loop: feed, drain, sample, serve commands.
    fn tick(&mut self);
    /// Whether a stop has been requested through the handle/server.
    fn stop_requested(&self) -> bool;
    /// End-of-run accounting: kill unresolved tickets, shut the pipeline
    /// down and publish the final statistics to the shared state
    /// (idempotent — a second call is a no-op).
    fn finalize(&mut self);
    /// The shared state (drivers arm a [`StopGuard`] on it).
    fn shared(&self) -> Arc<RtShared>;
}

/// Per-job driver: pace [`JobTicker::tick`] at the shared wall cadence
/// until a stop is requested, then finalize. This is the whole body of
/// the per-job runtime thread; the server loop implements the same
/// contract over many runtimes at once.
pub(crate) fn drive_runtime(rt: &mut dyn JobTicker) {
    let _guard = StopGuard::new(rt.shared());
    let mut next_tick = Instant::now();
    while !rt.stop_requested() {
        rt.tick();
        next_tick += RUNTIME_TICK;
        let now = Instant::now();
        if next_tick > now {
            // lint: allow(sleep) — wall-clock pacing of the runtime tick
            // (feed/sample cadence), not a data-plane wait: nothing can
            // arrive earlier than the next scheduled tick.
            std::thread::sleep(next_tick - now);
        } else {
            next_tick = now; // fell behind: don't try to catch up the wall
        }
    }
    rt.finalize();
}

/// The data plane of one launched job, factored as an explicit state
/// machine — [`Self::tick`] is one pass of the old per-job runtime loop
/// (pace the source round-robin across every ingress wrapper, drain
/// every egress reader, sample per-stage metrics once per event second,
/// serve the handle's commands), with the stop check and wall pacing
/// hoisted into the driver so ONE thread can interleave many jobs.
/// Every *decision* (controllers, scripted reconfigs, adaptive batching)
/// still arrives as a [`Cmd`] through the handle.
pub(crate) struct JobRuntime<In: Payload + Default, Out: Payload + Default> {
    pipeline: Pipeline<In, Out>,
    source: Box<dyn PacedSource<In>>,
    cfg: LaunchConfig,
    shared: Arc<RtShared>,
    capture: Option<Arc<Mutex<Vec<Tuple<Out>>>>>,
    t0: Instant,
    clock: EngineClock,
    ings: Vec<StretchIngress<In>>,
    n_ing: usize,
    egress: Vec<EgressDriver<Tuple<Out>>>,
    // all egress drivers record into ONE histogram pair: end-to-end
    // latency is a property of the whole topology, whichever sink a
    // tuple exits
    lat: Arc<Histogram>,
    lat_total: Arc<Histogram>,
    tracks: Vec<StageTrack>,
    duration_s: u32,
    pending_event_tuples: f64,
    event_ms_total: f64,
    // per-tick feed runs, one per ingress wrapper (round-robin split so
    // EVERY wrapper's gate clock advances every tick), each handed over
    // via one batched add (§Perf). A wrapper whose slot is decommissioned
    // under us (`Err(Inactive)`) leaves the rotation; its residual is
    // counted in `ingress_dropped`, never silently discarded.
    feed_bufs: Vec<Vec<Tuple<In>>>,
    alive: Vec<bool>,
    n_alive: usize,
    ingress_dropped: u64,
    fed: u64,
    max_fed_ts: EventTime,
    rr: usize,
    rate_override: Option<f64>,
    // event second the current rate override took effect
    override_from_s: u32,
    pending_tickets: Vec<(usize, Epoch, ReconfigTicket)>,
    next_sample_s: u32,
    eos: bool,
    quiesce_at: Option<Instant>,
    drain_deadline: Option<Instant>,
    // extend the drain while output still arrives, in `quiet` increments
    quiet: Duration,
    stall_after_us: u64,
    finalized: bool,
}

impl<In: Payload + Default, Out: Payload + Default> JobRuntime<In, Out> {
    fn new(
        mut pipeline: Pipeline<In, Out>,
        source: Box<dyn PacedSource<In>>,
        cfg: LaunchConfig,
        shared: Arc<RtShared>,
        capture: Option<Arc<Mutex<Vec<Tuple<Out>>>>>,
        t0: Instant,
    ) -> Self {
        let clock = pipeline.clock.clone();
        let ings: Vec<StretchIngress<In>> = std::mem::take(&mut pipeline.ingress);
        let n_ing = ings.len();
        let mut egress: Vec<EgressDriver<Tuple<Out>>> = std::mem::take(&mut pipeline.egress)
            .into_iter()
            .map(|r| EgressDriver::new(r, clock.clone()))
            .collect();
        let (lat, lat_total) =
            (egress[0].latency_us.clone(), egress[0].latency_total_us.clone());
        for d in egress.iter_mut().skip(1) {
            d.latency_us = lat.clone();
            d.latency_total_us = lat_total.clone();
        }
        let tracks: Vec<StageTrack> = (0..pipeline.depth())
            .map(|k| StageTrack {
                last_snap: MetricsSnapshot::default(),
                prev_loads: vec![0; pipeline.stages[k].max_parallelism()],
                samples: Vec::new(),
            })
            .collect();
        let duration_s = cfg.schedule.duration_s();
        let quiet = cfg.drain.min(Duration::from_millis(200));
        let stall_after_us = cfg.stall_after_ms.saturating_mul(1_000);
        JobRuntime {
            pipeline,
            source,
            cfg,
            shared,
            capture,
            t0,
            clock,
            ings,
            n_ing,
            egress,
            lat,
            lat_total,
            tracks,
            duration_s,
            pending_event_tuples: 0.0,
            event_ms_total: 0.0,
            feed_bufs: (0..n_ing).map(|_| Vec::new()).collect(),
            alive: vec![true; n_ing],
            n_alive: n_ing,
            ingress_dropped: 0,
            fed: 0,
            max_fed_ts: 0,
            rr: 0,
            rate_override: None,
            override_from_s: 0,
            pending_tickets: Vec::new(),
            next_sample_s: 1,
            eos: false,
            quiesce_at: None,
            drain_deadline: None,
            quiet,
            stall_after_us,
            finalized: false,
        }
    }

    /// Hand every non-empty feed run to its ingress wrapper, retiring
    /// wrappers decommissioned under us.
    fn flush_feed(&mut self) {
        for (i, buf) in self.feed_bufs.iter_mut().enumerate() {
            if self.alive[i] && !buf.is_empty() && self.ings[i].add_batch(buf).is_err() {
                self.ingress_dropped += buf.len() as u64;
                buf.clear();
                self.alive[i] = false;
                self.n_alive -= 1;
            }
        }
    }

    fn run_tick(&mut self) {
        let wall_s = self.t0.elapsed().as_secs_f64();
        let event_s = wall_s * self.cfg.time_scale;
        let cur_rate =
            self.rate_override.unwrap_or_else(|| self.cfg.schedule.rate_at(event_s as u32));

        if !self.eos && event_s < self.duration_s as f64 && !self.source.exhausted() {
            self.source.set_rate(cur_rate);
            // feed the tuples that belong to this tick
            let tick_event_s = RUNTIME_TICK.as_secs_f64() * self.cfg.time_scale;
            self.pending_event_tuples += cur_rate * tick_event_s;
            let n = self.pending_event_tuples.floor() as usize;
            self.pending_event_tuples -= n as f64;
            self.event_ms_total += tick_event_s * 1e3;
            let ingress_batch = self.cfg.ingress_batch.max(1);
            for _ in 0..n {
                if self.source.exhausted() {
                    break;
                }
                let mut t = self.source.next();
                t.ingest_us = self.clock.now_us();
                self.max_fed_ts = self.max_fed_ts.max(t.ts);
                self.fed += 1;
                if self.n_alive == 0 {
                    self.ingress_dropped += 1; // every wrapper decommissioned
                    continue;
                }
                while !self.alive[self.rr] {
                    self.rr = (self.rr + 1) % self.n_ing;
                }
                let rr = self.rr;
                self.feed_bufs[rr].push(t);
                if self.feed_bufs[rr].len() >= ingress_batch
                    && self.ings[rr].add_batch(&mut self.feed_bufs[rr]).is_err()
                {
                    // decommissioned mid-run: retire the wrapper from the
                    // rotation and account for the lost residual
                    self.ingress_dropped += self.feed_bufs[rr].len() as u64;
                    self.feed_bufs[rr].clear();
                    self.alive[rr] = false;
                    self.n_alive -= 1;
                }
                self.rr = (self.rr + 1) % self.n_ing;
            }
            self.flush_feed();
        }

        // drain every egress reader (an undrained sink gate would fill to
        // capacity and stall its stage)
        let mut polled = 0usize;
        for d in self.egress.iter_mut() {
            polled += match &self.capture {
                Some(cap) => {
                    let mut grabbed: Vec<Tuple<Out>> = Vec::new();
                    let n = d.poll_tuples(&mut |t| grabbed.push(t.clone()));
                    if !grabbed.is_empty() {
                        cap.lock().unwrap().append(&mut grabbed);
                    }
                    n
                }
                None => d.poll(),
            };
        }

        // per-event-second sampling, every stage
        while (self.next_sample_s as f64) <= event_s && self.next_sample_s <= self.duration_s {
            for (k, tr) in self.tracks.iter_mut().enumerate() {
                let stage = &self.pipeline.stages[k];
                let metrics = stage.metrics();
                let snap = metrics.snapshot();
                let dt = 1.0 / self.cfg.time_scale; // wall seconds per event second
                let rates = snap.rates_since(&tr.last_snap, dt);
                let active = stage.active_instances();
                // per-interval load CV (Fig. 9 right): deltas, active set only
                let cv = {
                    let deltas: Vec<f64> = active
                        .iter()
                        .map(|&i| (metrics.instance_load(i) - tr.prev_loads[i]) as f64)
                        .collect();
                    for (i, p) in tr.prev_loads.iter_mut().enumerate() {
                        *p = metrics.instance_load(i);
                    }
                    let mean = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
                    if deltas.len() < 2 || mean <= 0.0 {
                        0.0
                    } else {
                        let var = deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
                            / deltas.len() as f64;
                        100.0 * var.sqrt() / mean
                    }
                };
                // Every active instance reads (and counts) every gate
                // tuple, so the summed rate is m× the true arrival rate;
                // dividing by the active count recovers arrivals.
                let arrival_tps =
                    rates.in_tps / self.cfg.time_scale / active.len().max(1) as f64;
                tr.samples.push(RunSample {
                    t_s: self.next_sample_s,
                    // With ONE ingress wrapper, stage 0 is offered the
                    // whole schedule. With several wrappers the runtime
                    // cannot map wrappers to source stages (a DAG may
                    // have several), so every stage reports its measured
                    // arrival rate instead of a guessed split.
                    offered_tps: if k == 0 && self.n_ing == 1 {
                        // the override only describes seconds at/after it
                        // landed — a catch-up sample of an earlier second
                        // reports what the schedule actually offered then
                        match self.rate_override {
                            Some(r) if self.next_sample_s - 1 >= self.override_from_s => r,
                            _ => self.cfg.schedule.rate_at(self.next_sample_s - 1),
                        }
                    } else {
                        arrival_tps
                    },
                    // rates are per wall second; report per *event* second
                    in_tps: arrival_tps,
                    out_tps: rates.out_tps / self.cfg.time_scale,
                    cmp_per_s: rates.cmp_per_s / self.cfg.time_scale,
                    latency_p50_us: self.lat.p50(),
                    latency_mean_us: self.lat.mean(),
                    threads: active.len(),
                    backlog: stage.in_backlog(),
                    load_cv_pct: cv,
                    worker_batch: stage.worker_batch(),
                });
                tr.last_snap = snap;
            }
            // end-to-end latency is a property of the whole topology; the
            // per-second histogram resets once all stages sampled it
            self.lat.reset();
            {
                let mut m = self.shared.metrics.lock().unwrap();
                for (k, tr) in self.tracks.iter().enumerate() {
                    if let Some(&s) = tr.samples.last() {
                        m.stages[k].last = s;
                    }
                }
            }
            self.next_sample_s += 1;
        }

        // control surface: apply queued commands...
        let cmds: Vec<Cmd> = {
            let mut q = self.shared.cmds.lock().unwrap();
            q.drain(..).collect()
        };
        for c in cmds {
            match c {
                Cmd::Scale { stage, target, ticket } => {
                    if self.eos {
                        // after the end-of-stream heartbeats no watermark
                        // will ever pass a new control tuple, so the
                        // reconfiguration could never complete — reject
                        // the ticket immediately instead of letting
                        // wait() stall to its timeout
                        ticket.reject(RejectReason::AfterEos);
                        continue;
                    }
                    // the set the switch would install (Count resolves
                    // through the same pool semantics scale_to applies)
                    let set = match &target {
                        ScaleTarget::Count(n) => crate::elastic::resize_instance_set(
                            &self.pipeline.stages[stage].active_instances(),
                            self.pipeline.stages[stage].max_parallelism(),
                            *n,
                        ),
                        ScaleTarget::Set(set) => set.clone(),
                    };
                    // dead slots are terminal: an epoch containing one
                    // would wait forever for a worker that processes
                    // nothing — refuse up front
                    let has_dead =
                        self.pipeline.stages[stage].worker_health().is_some_and(|h| {
                            set.iter().any(|&i| {
                                i < h.len() && h.state(i) == crate::engine::WorkerState::Dead
                            })
                        });
                    if has_dead {
                        ticket.reject(RejectReason::DeadInstance);
                        continue;
                    }
                    let mapper = Mapper::over(set.clone());
                    let epoch = self.pipeline.stages[stage].reconfigure(set, mapper);
                    ticket.issue(epoch);
                    self.pending_tickets.push((stage, epoch, ticket));
                }
                Cmd::SetWorkerBatch { stage, n } => {
                    self.pipeline.stages[stage].set_worker_batch(n)
                }
                Cmd::InjectFault { stage, worker, fault } => {
                    if let Some(h) = self.pipeline.stages[stage].worker_health() {
                        if worker < h.len() {
                            h.inject(worker, fault);
                        }
                    }
                }
                Cmd::SetRate(tps) => {
                    self.rate_override = Some(tps);
                    // remember WHEN it took effect: catch-up samples of
                    // earlier seconds must not retroactively report it
                    self.override_from_s = event_s as u32;
                }
            }
        }
        // ...then resolve tickets whose reconfiguration completed
        resolve_completed(&mut self.pending_tickets, &self.pipeline.stages);

        // end of stream: the schedule ran out, or a finite source ran dry
        if !self.eos && (event_s >= self.duration_s as f64 + 0.1 || self.source.exhausted()) {
            // flush residual feed runs before the final heartbeat
            self.flush_feed();
            // end-of-stream heartbeat on EVERY ingress wrapper (workers
            // forward it stage to stage; a silent wrapper would hold back
            // every downstream watermark)
            let horizon =
                (self.event_ms_total as EventTime).max(self.max_fed_ts) + self.cfg.flush_slack_ms;
            for (i, ing) in self.ings.iter_mut().enumerate() {
                if self.alive[i] {
                    let _ = ing.heartbeat(horizon); // heartbeats carry no data
                }
            }
            self.eos = true;
            self.quiesce_at = Some(Instant::now() + self.cfg.drain);
            // hard ceiling on the whole drain window: trickling output
            // may extend the quiesce, but never past this deadline
            self.drain_deadline = Some(Instant::now() + self.cfg.drain_cap.max(self.cfg.drain));
            set_phase(&self.shared, JobPhase::Draining);
        }
        if self.eos && polled > 0 {
            if let Some(at) = self.quiesce_at.as_mut() {
                // output still arriving: hold the quiesce back a little
                // (bounded by the drain cap — a sink that never goes
                // quiet must not hold quiesce forever)
                let mut earliest = Instant::now() + self.quiet;
                if let Some(cap) = self.drain_deadline {
                    earliest = earliest.min(cap);
                }
                if earliest > *at {
                    *at = earliest;
                }
            }
        }
        if let Some(at) = self.quiesce_at {
            if Instant::now() >= at {
                set_phase(&self.shared, JobPhase::Quiesced);
                self.quiesce_at = None;
            }
        }

        // supervision detector: classify every stage's worker slots —
        // dead (self-marked on a caught panic) and stalled (progress
        // epoch unchanged past the stall window while backlog is
        // nonzero). Runs every tick, so detection latency is one tick.
        let stall_after_us = self.stall_after_us;
        let health: Vec<StageHealth> = self
            .pipeline
            .stages
            .iter()
            .map(|s| {
                let Some(h) = s.worker_health() else { return StageHealth::default() };
                let backlog = s.in_backlog();
                let now_us = h.now_us();
                let mut sh = StageHealth::default();
                for &i in &s.active_instances() {
                    if i >= h.len() {
                        continue;
                    }
                    match h.state(i) {
                        crate::engine::WorkerState::Dead => sh.dead.push(i),
                        crate::engine::WorkerState::Stalled => sh.stalled.push(i),
                        crate::engine::WorkerState::Live => {
                            if backlog > 0
                                && stall_after_us > 0
                                && now_us.saturating_sub(h.last_advance_us(i)) > stall_after_us
                            {
                                h.mark_stalled(i);
                                sh.stalled.push(i);
                            }
                        }
                    }
                }
                sh
            })
            .collect();

        // publish the live view
        {
            let phase = *self.shared.phase.lock().unwrap();
            let mut m = self.shared.metrics.lock().unwrap();
            m.offered_tps = cur_rate;
            m.fed = self.fed;
            m.ingress_dropped = self.ingress_dropped;
            m.egress_count = self.egress.iter().map(|d| d.count).sum();
            m.phase = phase;
            for (k, s) in self.pipeline.stages.iter().enumerate() {
                let sm = &mut m.stages[k];
                sm.active = s.active_instances();
                sm.backlog = s.in_backlog();
                sm.worker_batch = s.worker_batch();
                sm.health = health[k].clone();
            }
        }
    }

    fn finish(&mut self) {
        if self.finalized {
            return;
        }
        self.finalized = true;
        // one last ticket sweep, then give up on the rest — a
        // reconfiguration that has not completed by shutdown never will
        resolve_completed(&mut self.pending_tickets, &self.pipeline.stages);
        for (_, _, ticket) in self.pending_tickets.drain(..) {
            ticket.kill();
        }
        for c in self.shared.cmds.lock().unwrap().drain(..) {
            if let Cmd::Scale { ticket, .. } = c {
                ticket.kill();
            }
        }
        let latency_p50_us = self.lat_total.p50();
        let latency_mean_us = self.lat_total.mean();
        let egress_count = self.egress.iter().map(|d| d.count).sum();
        let stages = std::mem::take(&mut self.tracks)
            .into_iter()
            .enumerate()
            .map(|(k, tr)| StageRunStats {
                name: self.pipeline.stages[k].name(),
                samples: tr.samples,
                reconfigs: self.pipeline.stages[k].completion_times(),
            })
            .collect();
        self.pipeline.shutdown();
        *self.shared.fin.lock().unwrap() = Some(RtFinal {
            stages,
            egress_count,
            ingress_dropped: self.ingress_dropped,
            latency_p50_us,
            latency_mean_us,
        });
        // the phase flip wakes shutdown()'s wait AFTER fin is published
        set_phase(&self.shared, JobPhase::Stopped);
    }
}

impl<In: Payload + Default, Out: Payload + Default> JobTicker for JobRuntime<In, Out> {
    fn tick(&mut self) {
        self.run_tick();
    }

    fn stop_requested(&self) -> bool {
        self.shared.stop_requested()
    }

    fn finalize(&mut self) {
        self.finish();
    }

    fn shared(&self) -> Arc<RtShared> {
        self.shared.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::pipeline::PipelineBuilder;
    use crate::engine::VsnOptions;
    use crate::workloads::scalejoin_bench::{q3_operator, SjGen};

    #[test]
    fn replay_source_drains_in_order_and_reports_exhaustion() {
        let tuples: Vec<Tuple<u32>> = (0..5).map(|i| Tuple::data(i, i as u32)).collect();
        let mut s = ReplaySource::new(tuples);
        assert!(!PacedSource::exhausted(&s));
        for i in 0..5i64 {
            assert_eq!(PacedSource::next(&mut s).ts, i);
        }
        assert!(PacedSource::exhausted(&s));
    }

    #[test]
    fn ticket_wait_times_out_and_resolves() {
        let t = ReconfigTicket::new(0);
        assert_eq!(t.wait(Duration::from_millis(10)), None);
        assert_eq!(t.outcome(), None);
        t.issue(7);
        t.resolve(1.5);
        assert_eq!(t.epoch(), Some(7));
        assert_eq!(t.wait(Duration::from_millis(10)), Some(1.5));
        assert_eq!(t.outcome(), Some(TicketOutcome::Completed(1.5)));
        let dead = ReconfigTicket::new(1);
        dead.kill();
        assert_eq!(dead.wait(Duration::from_secs(5)), None);
        assert_eq!(dead.outcome(), Some(TicketOutcome::Abandoned));
        let rejected = ReconfigTicket::new(2);
        rejected.reject(RejectReason::AfterEos);
        assert_eq!(rejected.wait(Duration::from_secs(5)), None);
        assert_eq!(
            rejected.wait_outcome(Duration::from_secs(5)),
            Some(TicketOutcome::Rejected(RejectReason::AfterEos))
        );
        // the first terminal outcome wins
        rejected.resolve(9.0);
        assert_eq!(rejected.outcome(), Some(TicketOutcome::Rejected(RejectReason::AfterEos)));
    }

    #[test]
    fn post_eos_scale_rejects_with_after_eos() {
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 3, ..Default::default() },
        )
        .build();
        let handle = Job::new(pipeline, SjGen::new(3, 1.0))
            .with_config(LaunchConfig {
                name: "post-eos".into(),
                schedule: RateSchedule::constant(1, 200.0),
                time_scale: 4.0,
                ..Default::default()
            })
            .launch()
            .unwrap();
        handle.await_quiesce();
        // the feed has ended: a new reconfiguration can never complete,
        // so the ticket resolves immediately with the typed rejection
        // instead of dangling until shutdown
        let ticket = handle.scale(0, 2);
        assert_eq!(
            ticket.wait_outcome(Duration::from_secs(10)),
            Some(TicketOutcome::Rejected(RejectReason::AfterEos))
        );
        assert_eq!(ticket.latency_ms(), None);
        handle.shutdown();
    }

    #[test]
    fn await_quiesce_timeout_returns_typed_error() {
        // a detached ctl has no runtime behind it: the phase stays
        // Running forever — exactly a wedged drain from the caller's view
        let ctl = JobCtl::detached(1);
        let err = ctl
            .await_quiesce_timeout(Duration::from_millis(25))
            .expect_err("must time out, not hang");
        assert_eq!(err.waited, Duration::from_millis(25));
        assert_eq!(err.phase, JobPhase::Running);
    }

    #[test]
    fn launch_observe_scale_quiesce_shutdown_round_trip() {
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 3, ..Default::default() },
        )
        .build();
        let handle = Job::new(pipeline, SjGen::new(3, 1.0))
            .with_config(LaunchConfig {
                name: "round-trip".into(),
                schedule: RateSchedule::constant(3, 400.0),
                time_scale: 3.0,
                ..Default::default()
            })
            .launch()
            .unwrap();
        // live observation
        let m = handle.sample();
        assert_eq!(m.stages.len(), 1);
        assert_eq!(m.ingress, 1);
        assert_eq!(m.duration_s, 3);
        // live reconfiguration with a measured latency
        let ticket = handle.scale(0, 3);
        let ms = ticket
            .wait(Duration::from_secs(30))
            .expect("scale must complete while data flows");
        assert!(ms >= 0.0);
        assert_eq!(ticket.stage(), 0);
        assert!(ticket.epoch().is_some());
        handle.await_quiesce();
        assert!(handle.quiesced());
        let out = handle.shutdown();
        assert_eq!(out.name, "round-trip");
        assert_eq!(out.result.stages.len(), 1);
        assert_eq!(out.result.stages[0].samples.len(), 3);
        assert_eq!(out.result.stages[0].samples.last().unwrap().threads, 3);
        assert_eq!(out.tickets.len(), 1);
        assert!(out.tickets[0].latency_ms().is_some());
    }

    #[test]
    fn launch_rejects_degenerate_topologies_before_spawning() {
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, egress_readers: 0, ..Default::default() },
        )
        .build();
        match Job::new(pipeline, SjGen::new(1, 1.0)).launch() {
            Err(HarnessError::NoEgress) => {}
            other => panic!("expected NoEgress, got {:?}", other.map(|_| ()).err()),
        }
    }
}
