//! Scripted fault injection: the `[faults]` section of a job config,
//! parsed into a [`FaultPlan`] and driven through the live [`JobCtl`] by
//! a [`FaultPolicy`] — the chaos half of the supervision story (the
//! healing half is [`super::policy::SupervisorPolicy`]).
//!
//! Steps use the same `"<second> -> <action>"` arrow idiom as
//! `[schedule.<stage>]` ([`crate::workloads::rates::parse_steps`]), with
//! a fault action on the right-hand side:
//!
//! ```text
//! [faults]
//! steps = [
//!   "2 -> kill filter:0",     # panic worker 0 of stage `filter`
//!   "3 -> stall join:1 300",  # freeze worker 1 of `join` for 300 ms
//!   "1 -> slow left:0 4",     # ~4 ms extra latency per batch on left:0
//!   "5 -> poison right",      # kill EVERY active worker of `right`
//! ]
//! ```
//!
//! Faults are delivered through [`JobCtl::inject_fault`] →
//! [`crate::engine::WorkerHealth::inject`]; the worker picks its fault up
//! at the top of its batch loop, BEFORE popping tuples, so an injected
//! kill is crash-exact: replay after healing re-processes precisely the
//! unprocessed gate suffix (see `engine::vsn`'s supervision notes).
//! `poison` fans a kill out to every active worker, leaving the
//! supervisor no survivor set — the bounded fail-fast path (shed + mark
//! degraded), not a hang.

use super::handle::{JobCtl, JobMetrics};
use super::policy::JobPolicy;
use crate::engine::InjectedFault;
use crate::tuple::InstanceId;

/// One parsed fault action (the right-hand side of a step).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic one worker at the top of its next batch.
    Kill { stage: usize, worker: InstanceId },
    /// Freeze one worker for `ms` wall milliseconds (no reads, no
    /// progress beats); it resumes by itself — exactly-once is automatic.
    Stall { stage: usize, worker: InstanceId, ms: u64 },
    /// Slow one worker down by ~`factor` ms of extra latency per batch.
    Slow { stage: usize, worker: InstanceId, factor: u64 },
    /// Kill EVERY worker active on the stage at fire time.
    Poison { stage: usize },
}

/// One timed step of a [`FaultPlan`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultStep {
    /// Event second the fault fires at.
    pub at: u32,
    pub action: FaultAction,
}

/// A validated, time-sorted fault script (`[faults] steps`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub steps: Vec<FaultStep>,
}

fn stage_index(name: &str, stages: &[(&str, usize)], it: &str) -> Result<usize, String> {
    stages.iter().position(|(n, _)| *n == name).ok_or_else(|| {
        format!(
            "`{it}`: unknown stage `{name}` (declared: {})",
            stages.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
        )
    })
}

/// Parse a `<stage>:<worker>` reference against the declared stages and
/// their pool sizes.
fn worker_ref(
    tok: &str,
    stages: &[(&str, usize)],
    it: &str,
) -> Result<(usize, InstanceId), String> {
    let (name, idx) = tok
        .split_once(':')
        .ok_or_else(|| format!("`{it}`: expected `<stage>:<worker>`, got `{tok}`"))?;
    let k = stage_index(name.trim(), stages, it)?;
    let w: InstanceId = idx
        .trim()
        .parse()
        .map_err(|_| format!("`{it}`: worker index in `{tok}` must be an integer"))?;
    let (sname, max) = stages[k];
    if w >= max {
        return Err(format!(
            "`{it}`: worker {w} is outside stage `{sname}`'s pool (max parallelism {max})"
        ));
    }
    Ok((k, w))
}

impl FaultPlan {
    /// Parse `[faults] steps` items against the declared stages
    /// (`(name, max parallelism)` pairs, topology order). Unknown stages,
    /// unknown verbs, worker indices outside the pool, malformed numbers
    /// and trailing garbage are all errors — a fault script that silently
    /// skips a step would make a chaos run look healthier than it is.
    pub fn parse(items: &[String], stages: &[(&str, usize)]) -> Result<FaultPlan, String> {
        let mut steps = Vec::with_capacity(items.len());
        for it in items {
            let (at, rhs) = it
                .split_once("->")
                .ok_or_else(|| format!("expected `<second> -> <action>`, got `{it}`"))?;
            let at: u32 = at
                .trim()
                .parse()
                .map_err(|_| format!("`{it}`: the part before `->` must be an event second"))?;
            let mut words = rhs.split_whitespace();
            let verb = words
                .next()
                .ok_or_else(|| format!("`{it}`: missing action after `->`"))?;
            let action = match verb {
                "kill" => {
                    let tok = words
                        .next()
                        .ok_or_else(|| format!("`{it}`: kill needs `<stage>:<worker>`"))?;
                    let (stage, worker) = worker_ref(tok, stages, it)?;
                    FaultAction::Kill { stage, worker }
                }
                "stall" => {
                    let tok = words
                        .next()
                        .ok_or_else(|| format!("`{it}`: stall needs `<stage>:<worker> <ms>`"))?;
                    let (stage, worker) = worker_ref(tok, stages, it)?;
                    let ms: u64 = words
                        .next()
                        .ok_or_else(|| format!("`{it}`: stall needs a duration in ms"))?
                        .parse()
                        .map_err(|_| format!("`{it}`: stall duration must be an integer (ms)"))?;
                    if ms == 0 {
                        return Err(format!("`{it}`: stall duration must be ≥ 1 ms"));
                    }
                    FaultAction::Stall { stage, worker, ms }
                }
                "slow" => {
                    let tok = words
                        .next()
                        .ok_or_else(|| format!("`{it}`: slow needs `<stage>:<worker> <factor>`"))?;
                    let (stage, worker) = worker_ref(tok, stages, it)?;
                    let factor: u64 = words
                        .next()
                        .ok_or_else(|| format!("`{it}`: slow needs a factor"))?
                        .parse()
                        .map_err(|_| format!("`{it}`: slow factor must be an integer"))?;
                    if factor == 0 {
                        return Err(format!("`{it}`: slow factor must be ≥ 1"));
                    }
                    FaultAction::Slow { stage, worker, factor }
                }
                "poison" => {
                    let name = words
                        .next()
                        .ok_or_else(|| format!("`{it}`: poison needs a stage name"))?;
                    FaultAction::Poison { stage: stage_index(name, stages, it)? }
                }
                other => {
                    return Err(format!(
                        "`{it}`: unknown fault `{other}` (known: kill, stall, slow, poison)"
                    ))
                }
            };
            if let Some(extra) = words.next() {
                return Err(format!("`{it}`: unexpected trailing `{extra}`"));
            }
            steps.push(FaultStep { at, action });
        }
        steps.sort_by_key(|s| s.at);
        Ok(FaultPlan { steps })
    }
}

/// Drives a [`FaultPlan`] through a live job: each step fires exactly
/// once when event time passes its second, as a [`JobCtl::inject_fault`]
/// call — the same policy shape as [`super::policy::ScriptedScalePolicy`]
/// so [`super::drive`] needs no special casing for chaos runs.
pub struct FaultPolicy {
    steps: Vec<FaultStep>,
    next: usize,
}

impl FaultPolicy {
    pub fn new(plan: FaultPlan) -> Self {
        FaultPolicy { steps: plan.steps, next: 0 }
    }

    /// How many steps have fired so far.
    pub fn fired(&self) -> usize {
        self.next
    }
}

impl JobPolicy for FaultPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        while let Some(step) = self.steps.get(self.next) {
            if (step.at as f64) > m.event_s {
                break;
            }
            match &step.action {
                FaultAction::Kill { stage, worker } => {
                    job.inject_fault(*stage, *worker, InjectedFault::Kill);
                }
                FaultAction::Stall { stage, worker, ms } => {
                    job.inject_fault(*stage, *worker, InjectedFault::Stall(*ms));
                }
                FaultAction::Slow { stage, worker, factor } => {
                    // factor ≈ extra milliseconds per batch
                    job.inject_fault(
                        *stage,
                        *worker,
                        InjectedFault::Slow(factor.saturating_mul(1_000)),
                    );
                }
                FaultAction::Poison { stage } => {
                    // fan a kill out to every worker active RIGHT NOW —
                    // by design this leaves the supervisor no survivors
                    for w in m.stages[*stage].active.clone() {
                        job.inject_fault(*stage, w, InjectedFault::Kill);
                    }
                }
            }
            self.next += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::handle::{JobPhase, StageHealth, StageMetrics};
    use crate::harness::RunSample;

    const STAGES: &[(&str, usize)] = &[("filter", 3), ("join", 2)];

    fn strs(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    fn metrics(event_s: f64) -> JobMetrics {
        JobMetrics {
            event_s,
            duration_s: 10,
            offered_tps: 500.0,
            ingress: 1,
            fed: 0,
            egress_count: 0,
            ingress_dropped: 0,
            phase: JobPhase::Running,
            stages: STAGES
                .iter()
                .map(|&(_name, max)| StageMetrics {
                    name: "s",
                    active: (0..max.min(2)).collect(),
                    max,
                    backlog: 0,
                    worker_batch: 128,
                    health: StageHealth::default(),
                    last: RunSample::default(),
                })
                .collect(),
        }
    }

    #[test]
    fn fault_plan_parses_every_verb_and_sorts() {
        let p = FaultPlan::parse(
            &strs(&["3 -> stall join:1 300", "1 -> kill filter:0", "2 -> slow filter:2 4",
                "4 -> poison join"]),
            STAGES,
        )
        .unwrap();
        assert_eq!(p.steps.len(), 4);
        assert_eq!(p.steps[0],
            FaultStep { at: 1, action: FaultAction::Kill { stage: 0, worker: 0 } });
        assert_eq!(p.steps[1],
            FaultStep { at: 2, action: FaultAction::Slow { stage: 0, worker: 2, factor: 4 } });
        assert_eq!(p.steps[2],
            FaultStep { at: 3, action: FaultAction::Stall { stage: 1, worker: 1, ms: 300 } });
        assert_eq!(p.steps[3], FaultStep { at: 4, action: FaultAction::Poison { stage: 1 } });
    }

    #[test]
    fn fault_plan_rejects_malformed_steps() {
        let bad = |items: &[&str], needle: &str| {
            let err = FaultPlan::parse(&strs(items), STAGES).unwrap_err();
            assert!(err.contains(needle), "error `{err}` should mention `{needle}`");
        };
        bad(&["kill filter:0"], "expected `<second> -> <action>`");
        bad(&["x -> kill filter:0"], "event second");
        bad(&["1 -> vaporize filter:0"], "unknown fault");
        bad(&["1 -> kill ghost:0"], "unknown stage");
        bad(&["1 -> kill filter"], "expected `<stage>:<worker>`");
        bad(&["1 -> kill filter:9"], "outside stage `filter`'s pool");
        bad(&["1 -> stall join:0"], "stall needs a duration");
        bad(&["1 -> stall join:0 0"], "must be ≥ 1 ms");
        bad(&["1 -> slow join:0 x"], "slow factor must be an integer");
        bad(&["1 -> poison"], "poison needs a stage name");
        bad(&["1 -> kill filter:0 extra"], "unexpected trailing");
    }

    #[test]
    fn fault_policy_fires_each_step_once_in_time_order() {
        let plan = FaultPlan::parse(
            &strs(&["1 -> kill filter:0", "3 -> stall join:1 50", "5 -> poison join"]),
            STAGES,
        )
        .unwrap();
        let mut p = FaultPolicy::new(plan);
        let job = JobCtl::detached(2);
        p.tick(&metrics(0.5), &job);
        assert_eq!(p.fired(), 0, "nothing due yet");
        p.tick(&metrics(1.2), &job);
        assert_eq!(p.fired(), 1);
        p.tick(&metrics(1.9), &job);
        assert_eq!(p.fired(), 1, "steps fire once");
        p.tick(&metrics(6.0), &job);
        assert_eq!(p.fired(), 3, "late tick drains every due step");
    }
}
