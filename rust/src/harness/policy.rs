//! Policies: the decision loops of a live job, driven *through* the
//! [`JobCtl`] control surface.
//!
//! STRETCH deliberately separates the reconfiguration *mechanism* (epochs
//! + control tuples, `crate::engine`) from the *policy* that decides when
//! to scale (§3; Röger & Mayer's survey calls these the elasticity
//! mechanism and the elasticity policy). The [`crate::elastic`]
//! controllers are pure policy already — this module is the thin layer
//! that feeds them [`JobMetrics`] samples and forwards their decisions as
//! [`JobCtl::scale_to`] calls, exactly like user-written policies would.
//! The same shape covers scripted reconfigurations (`[schedule.<stage>]`
//! steps, manual test plans) and the adaptive worker-batch sizing, so the
//! run loop has ONE wiring path for all of them: [`drive`].

use super::handle::{JobCtl, JobMetrics, ReconfigTicket, StageHealth, TicketOutcome};
use super::{adaptive_worker_batch, AdaptiveBatch};
use crate::elastic::{Controller, DagController, Decision, Observation};
use crate::tuple::InstanceId;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One decision loop over a live job. `tick` is called with a fresh
/// metrics sample every few milliseconds until the job quiesces; a policy
/// keeps its own cadence (usually against `m.event_s`) and issues
/// commands through `job`.
pub trait JobPolicy: Send {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl);
}

/// Build a per-stage [`Observation`] from a metrics sample. The offered
/// schedule rate only describes stage 0 when a single ingress wrapper
/// feeds it the whole stream; otherwise the measured arrival rate is the
/// controller's load estimate.
pub(crate) fn observation(m: &JobMetrics, stage: usize, period_s: u32) -> Observation {
    let st = &m.stages[stage];
    Observation {
        in_rate: if stage == 0 && m.ingress == 1 { m.offered_tps } else { st.last.in_tps },
        cmp_per_s: st.last.cmp_per_s,
        backlog: st.backlog,
        dt: period_s as f64,
        active: st.active.clone(),
        max: st.max,
    }
}

enum ScaleStep {
    /// Exact instance set (manual test plans).
    Set(Vec<InstanceId>),
    /// Target parallelism (`[schedule.<stage>] scale` steps).
    Count(usize),
}

/// Scripted reconfigurations: at event second `at`, scale one stage —
/// each step fires exactly once, in time order, through the handle (so
/// every step yields a [`super::ReconfigTicket`]).
pub struct ScriptedScalePolicy {
    stage: usize,
    steps: Vec<(u32, ScaleStep)>,
    next: usize,
}

impl ScriptedScalePolicy {
    /// Steps as exact instance sets (the harness `manual_reconfigs`
    /// shape).
    pub fn sets(stage: usize, steps: Vec<(u32, Vec<InstanceId>)>) -> Self {
        let mut steps: Vec<(u32, ScaleStep)> =
            steps.into_iter().map(|(at, s)| (at, ScaleStep::Set(s))).collect();
        steps.sort_by_key(|&(at, _)| at);
        ScriptedScalePolicy { stage, steps, next: 0 }
    }

    /// Steps as target parallelism counts (the `[schedule.<stage>]`
    /// shape).
    pub fn counts(stage: usize, steps: Vec<(u32, usize)>) -> Self {
        let mut steps: Vec<(u32, ScaleStep)> =
            steps.into_iter().map(|(at, n)| (at, ScaleStep::Count(n))).collect();
        steps.sort_by_key(|&(at, _)| at);
        ScriptedScalePolicy { stage, steps, next: 0 }
    }
}

impl JobPolicy for ScriptedScalePolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        while let Some((at, step)) = self.steps.get(self.next) {
            if (*at as f64) > m.event_s {
                break;
            }
            match step {
                ScaleStep::Set(set) => {
                    job.scale_to(self.stage, set.clone());
                }
                ScaleStep::Count(n) => {
                    job.scale(self.stage, *n);
                }
            }
            self.next += 1;
        }
    }
}

/// Timed offered-rate steps (`[schedule.<stage>] rate`): at event second
/// `at`, override the feed rate. The feed is global, so these usually
/// live on a source stage's schedule section.
pub struct RateStepPolicy {
    steps: Vec<(u32, f64)>,
    next: usize,
}

impl RateStepPolicy {
    pub fn new(mut steps: Vec<(u32, f64)>) -> Self {
        steps.sort_by_key(|&(at, _)| at);
        RateStepPolicy { steps, next: 0 }
    }
}

impl JobPolicy for RateStepPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        while let Some(&(at, tps)) = self.steps.get(self.next) {
            if (at as f64) > m.event_s {
                break;
            }
            job.set_rate(tps);
            self.next += 1;
        }
    }
}

/// One per-stage [`Controller`] (reactive/proactive) ticked every
/// `period_s` event seconds — the re-homed single-stage controller path.
pub struct ControllerPolicy {
    stage: usize,
    controller: Box<dyn Controller>,
    period_s: u32,
    next_s: u32,
}

impl ControllerPolicy {
    pub fn new(stage: usize, controller: Box<dyn Controller>, period_s: u32) -> Self {
        let period_s = period_s.max(1);
        ControllerPolicy { stage, controller, period_s, next_s: period_s }
    }
}

impl JobPolicy for ControllerPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        if (self.next_s as f64) > m.event_s {
            return;
        }
        self.next_s += self.period_s;
        let obs = observation(m, self.stage, self.period_s);
        if let Decision::Reconfigure(set) = self.controller.tick(&obs) {
            job.scale_to(self.stage, set);
        }
    }
}

/// Adaptive worker-batch sizing: every `period_s` event seconds, re-derive
/// one stage's batch from its observed backlog ([`adaptive_worker_batch`])
/// and install it live through the handle.
pub struct AdaptiveBatchPolicy {
    stage: usize,
    bounds: AdaptiveBatch,
    period_s: u32,
    next_s: u32,
}

impl AdaptiveBatchPolicy {
    pub fn new(stage: usize, bounds: AdaptiveBatch, period_s: u32) -> Self {
        let period_s = period_s.max(1);
        AdaptiveBatchPolicy { stage, bounds, period_s, next_s: period_s }
    }
}

impl JobPolicy for AdaptiveBatchPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        if (self.next_s as f64) > m.event_s {
            return;
        }
        self.next_s += self.period_s;
        job.set_worker_batch(self.stage, adaptive_worker_batch(m.stages[self.stage].backlog, self.bounds));
    }
}

/// The topology-aware budgeted co-scheduler as a policy: one observation
/// per stage, one decision wave per period, every reconfiguration issued
/// through the handle.
pub struct DagControllerPolicy {
    controller: DagController,
    period_s: u32,
    next_s: u32,
}

impl DagControllerPolicy {
    pub fn new(controller: DagController, period_s: u32) -> Self {
        let period_s = period_s.max(1);
        DagControllerPolicy { controller, period_s, next_s: period_s }
    }
}

impl JobPolicy for DagControllerPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        if (self.next_s as f64) > m.event_s {
            return;
        }
        self.next_s += self.period_s;
        let obs: Vec<Observation> =
            (0..m.stages.len()).map(|k| observation(m, k, self.period_s)).collect();
        for (k, d) in self.controller.tick(&obs).into_iter().enumerate() {
            if let Decision::Reconfigure(set) = d {
                job.scale_to(k, set);
            }
        }
    }
}

/// What a [`RecoveryTicket`] is recovering from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryKind {
    /// The worker panicked ([`crate::engine::WorkerState::Dead`]) —
    /// healed by evicting it through an epoch switch (crash replay).
    Crash,
    /// The worker stopped making progress — healed by the worker itself
    /// (the next processed batch clears the mark); the supervisor only
    /// sheds load if the stall persists.
    Stall,
}

/// Terminal state of a recovery.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RecoveryOutcome {
    /// The fault healed; detection→healed wall ms — one MTTR sample.
    Healed(f64),
    /// The escalation ladder ran out (no survivors, repeated rejected
    /// switches, or shutdown first): the job is degraded and this fault
    /// stays unrepaired.
    Failed,
}

struct RecoveryInner {
    outcome: Option<RecoveryOutcome>,
}

struct RecoveryState {
    inner: Mutex<RecoveryInner>,
    cv: Condvar,
}

/// One detected fault and its repair — the recovery mirror of
/// [`ReconfigTicket`]: issued by the [`SupervisorPolicy`] at detection,
/// resolved when the fault is healed, with the measured detection→healed
/// latency (the `mttr_ms` samples of `BENCH_<job>.json`).
#[derive(Clone)]
pub struct RecoveryTicket {
    stage: usize,
    worker: InstanceId,
    kind: RecoveryKind,
    state: Arc<RecoveryState>,
}

impl RecoveryTicket {
    fn new(stage: usize, worker: InstanceId, kind: RecoveryKind) -> Self {
        RecoveryTicket {
            stage,
            worker,
            kind,
            state: Arc::new(RecoveryState {
                inner: Mutex::new(RecoveryInner { outcome: None }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Stage index the faulted worker belongs to.
    pub fn stage(&self) -> usize {
        self.stage
    }

    /// The faulted worker's instance id.
    pub fn worker(&self) -> InstanceId {
        self.worker
    }

    /// What is being recovered from.
    pub fn kind(&self) -> RecoveryKind {
        self.kind
    }

    /// The terminal outcome, once there is one (non-blocking).
    pub fn outcome(&self) -> Option<RecoveryOutcome> {
        self.state.inner.lock().unwrap().outcome
    }

    /// Measured detection→healed latency, if healed (non-blocking).
    pub fn mttr_ms(&self) -> Option<f64> {
        match self.outcome() {
            Some(RecoveryOutcome::Healed(ms)) => Some(ms),
            _ => None,
        }
    }

    /// Block until the recovery reaches a terminal outcome or `timeout`
    /// elapses (`None` = still open at the deadline).
    pub fn wait(&self, timeout: Duration) -> Option<RecoveryOutcome> {
        let deadline = Instant::now() + timeout;
        let mut g = self.state.inner.lock().unwrap();
        loop {
            if let Some(o) = g.outcome {
                return Some(o);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (ng, _) = self.state.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
    }

    fn finish(&self, o: RecoveryOutcome) {
        let mut g = self.state.inner.lock().unwrap();
        if g.outcome.is_none() {
            g.outcome = Some(o);
        }
        self.state.cv.notify_all();
    }

    fn resolve(&self, mttr_ms: f64) {
        self.finish(RecoveryOutcome::Healed(mttr_ms));
    }

    fn fail(&self) {
        self.finish(RecoveryOutcome::Failed);
    }
}

impl fmt::Debug for RecoveryTicket {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RecoveryTicket")
            .field("stage", &self.stage)
            .field("worker", &self.worker)
            .field("kind", &self.kind)
            .field("outcome", &self.outcome())
            .finish()
    }
}

/// Shared record of every recovery the supervisor opened, plus the job's
/// degraded flag — created by the caller (e.g. [`super::run_job`]),
/// cloned into the [`SupervisorPolicy`], read back after the run.
#[derive(Clone, Default)]
pub struct RecoveryLog {
    tickets: Arc<Mutex<Vec<RecoveryTicket>>>,
    degraded: Arc<AtomicBool>,
}

impl RecoveryLog {
    pub fn new() -> Self {
        RecoveryLog::default()
    }

    fn push(&self, t: RecoveryTicket) {
        self.tickets.lock().unwrap().push(t);
    }

    /// Every recovery ticket opened so far, detection order.
    pub fn tickets(&self) -> Vec<RecoveryTicket> {
        self.tickets.lock().unwrap().clone()
    }

    /// Whether the supervisor exhausted its ladder on some fault.
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Acquire)
    }

    fn mark_degraded(&self) {
        self.degraded.store(true, Ordering::Release);
    }

    /// Fail every still-open ticket (end of run: what has not healed by
    /// now never will). Idempotent.
    pub fn close_unresolved(&self) {
        for t in self.tickets.lock().unwrap().iter() {
            if t.outcome().is_none() {
                t.fail();
            }
        }
    }
}

/// Supervisor tuning: retry/backoff and the escalation ladder.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// First retry delay after a failed heal attempt; doubles per attempt
    /// (capped exponential). The FIRST attempt is always immediate —
    /// while a dead worker's out clock is frozen, survivors can only run
    /// ahead by their SPSC queue capacity, so healing must not idle.
    pub backoff_base_ms: u64,
    /// Retry delay ceiling.
    pub backoff_cap_ms: u64,
    /// Failed heal attempts before escalating to shed-load, and again
    /// before marking the job degraded.
    pub max_attempts: u32,
    /// A heal ticket pending longer than this counts as a failed attempt
    /// (the switch may still land later; a newer epoch supersedes it).
    pub attempt_timeout_ms: u64,
    /// Shed-load escalation: clamp the offered rate to this fraction.
    pub shed_factor: f64,
    /// Shed load if a stall persists this long.
    pub stall_shed_after_ms: u64,
    /// Give up on a stall (mark degraded, fail its ticket) after this.
    pub stall_degraded_after_ms: u64,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            backoff_base_ms: 50,
            backoff_cap_ms: 1_000,
            max_attempts: 4,
            attempt_timeout_ms: 1_500,
            shed_factor: 0.5,
            stall_shed_after_ms: 2_000,
            stall_degraded_after_ms: 8_000,
        }
    }
}

/// An in-flight healing reconfiguration.
struct HealAttempt {
    ticket: ReconfigTicket,
    /// Dead workers this switch evicts (their crash tickets resolve when
    /// it completes).
    evicting: Vec<InstanceId>,
    /// Parallelism to restore after the eviction lands (replace step).
    restore_to: usize,
    /// `true` once this attempt is the regrow (replace) switch.
    regrow: bool,
    issued: Instant,
}

/// One open stall observation.
struct StallTrack {
    worker: InstanceId,
    ticket: RecoveryTicket,
    since: Instant,
    shed: bool,
}

/// Per-stage supervisor state.
#[derive(Default)]
struct StageSup {
    /// Open crash recoveries: (worker, ticket, detection instant).
    crash: Vec<(InstanceId, RecoveryTicket, Instant)>,
    /// Every worker ever seen dead — terminal slots, excluded from
    /// regrow sets and from duplicate ticket issuance.
    known_dead: Vec<InstanceId>,
    heal: Option<HealAttempt>,
    stalls: Vec<StallTrack>,
    /// Failed heal attempts since the last success.
    attempts: u32,
    /// Earliest instant the next heal attempt may be issued.
    not_before: Option<Instant>,
    /// Shed-load fired for this stage (once per escalation).
    shed_done: bool,
}

/// Self-healing supervision: reads the per-stage [`StageHealth`]
/// classification off every [`JobMetrics`] sample and repairs faults
/// through the ordinary reconfiguration path — recovery IS
/// reconfiguration, no state transfer (§Elasticity; Elasticutor makes
/// the same argument for executor-level reassignment).
///
/// Crash ladder: **retry** (evict dead workers onto the survivor set —
/// first attempt immediate, then capped-exponential backoff) →
/// **replace** (re-grow to the pre-fault parallelism from the pool) →
/// **shed load** (clamp the offered rate) → **mark the job degraded**.
/// Stalls are never evicted — a deactivated reader's unread share would
/// be lost — so their ladder is wait → shed load → degraded, and a stall
/// heals itself the moment the worker beats again.
pub struct SupervisorPolicy {
    cfg: SupervisorConfig,
    log: RecoveryLog,
    stages: Vec<StageSup>,
}

impl SupervisorPolicy {
    pub fn new(cfg: SupervisorConfig, log: RecoveryLog) -> Self {
        SupervisorPolicy { cfg, log, stages: Vec::new() }
    }

    /// Capped-exponential retry delay: `base · 2^(attempts−1)`, capped.
    fn backoff(cfg: &SupervisorConfig, attempts: u32) -> Duration {
        let exp = attempts.saturating_sub(1).min(16);
        let ms = cfg.backoff_base_ms.saturating_mul(1u64 << exp).min(cfg.backoff_cap_ms);
        Duration::from_millis(ms)
    }

    /// Survivor set + regrow set for one stage, never containing a slot
    /// ever seen dead.
    fn regrow_set(
        survivors: &[InstanceId],
        known_dead: &[InstanceId],
        max: usize,
        target: usize,
    ) -> Vec<InstanceId> {
        let mut set: Vec<InstanceId> = survivors.to_vec();
        for i in 0..max {
            if set.len() >= target {
                break;
            }
            if !set.contains(&i) && !known_dead.contains(&i) {
                set.push(i);
            }
        }
        set.sort_unstable();
        set
    }

    fn tick_stage(&mut self, k: usize, m: &JobMetrics, job: &JobCtl) {
        let health: StageHealth = m.stages[k].health.clone();
        let active = m.stages[k].active.clone();
        let max = m.stages[k].max;
        let cfg = self.cfg;
        let now = Instant::now();

        // open a crash ticket for every newly-dead worker
        for &w in &health.dead {
            if !self.stages[k].known_dead.contains(&w) {
                self.stages[k].known_dead.push(w);
                let t = RecoveryTicket::new(k, w, RecoveryKind::Crash);
                self.log.push(t.clone());
                self.stages[k].crash.push((w, t, now));
            }
        }

        // drive the in-flight heal attempt, if any
        let mut done_regrow: Option<(Vec<InstanceId>, usize)> = None;
        if let Some(h) = &self.stages[k].heal {
            match h.ticket.outcome() {
                Some(TicketOutcome::Completed(_)) => {
                    if h.regrow {
                        self.stages[k].attempts = 0;
                        self.stages[k].heal = None;
                    } else {
                        // the eviction landed: the dead share is replayed
                        // and the epoch is healthy — resolve MTTR for the
                        // workers THIS switch evicted
                        let evicted = h.evicting.clone();
                        let restore_to = h.restore_to;
                        let st = &mut self.stages[k];
                        st.crash.retain(|(w, t, since)| {
                            if evicted.contains(w) {
                                t.resolve(since.elapsed().as_secs_f64() * 1e3);
                                false
                            } else {
                                true
                            }
                        });
                        st.attempts = 0;
                        st.heal = None;
                        // replace: restore the pre-fault parallelism
                        let survivors: Vec<InstanceId> = active
                            .iter()
                            .copied()
                            .filter(|i| !st.known_dead.contains(i))
                            .collect();
                        if survivors.len() < restore_to {
                            done_regrow = Some((survivors, restore_to));
                        }
                    }
                }
                Some(_) => {
                    // rejected or abandoned: a failed attempt
                    self.stages[k].heal = None;
                    self.stages[k].attempts += 1;
                    let d = Self::backoff(&cfg, self.stages[k].attempts);
                    self.stages[k].not_before = Some(now + d);
                }
                None => {
                    if h.issued.elapsed() > Duration::from_millis(cfg.attempt_timeout_ms) {
                        self.stages[k].heal = None;
                        self.stages[k].attempts += 1;
                        let d = Self::backoff(&cfg, self.stages[k].attempts);
                        self.stages[k].not_before = Some(now + d);
                    }
                }
            }
        }
        if let Some((survivors, target)) = done_regrow {
            let set = Self::regrow_set(&survivors, &self.stages[k].known_dead, max, target);
            if set.len() > survivors.len() {
                let ticket = job.scale_to(k, set);
                self.stages[k].heal = Some(HealAttempt {
                    ticket,
                    evicting: Vec::new(),
                    restore_to: target,
                    regrow: true,
                    issued: now,
                });
            }
        }

        // escalation: past the retry budget, shed load once, then degrade
        if self.stages[k].attempts > cfg.max_attempts {
            if !self.stages[k].shed_done {
                job.set_rate(m.offered_tps * cfg.shed_factor);
                self.stages[k].shed_done = true;
                // one more retry round after shedding
                self.stages[k].attempts = cfg.max_attempts;
            } else {
                self.log.mark_degraded();
                for (_, t, _) in self.stages[k].crash.drain(..) {
                    t.fail();
                }
                self.stages[k].attempts = 0;
                self.stages[k].not_before = None;
            }
        }

        // issue the next heal attempt (the FIRST one immediately)
        let due = self.stages[k].not_before.is_none_or(|t| now >= t);
        if !self.stages[k].crash.is_empty() && self.stages[k].heal.is_none() && due {
            let survivors: Vec<InstanceId> =
                active.iter().copied().filter(|i| !self.stages[k].known_dead.contains(i)).collect();
            if survivors.is_empty() {
                // poison: every active worker of the stage is dead — no
                // epoch can absorb the share. Shed load, degrade, fail.
                if !self.stages[k].shed_done {
                    job.set_rate(m.offered_tps * cfg.shed_factor);
                    self.stages[k].shed_done = true;
                }
                self.log.mark_degraded();
                for (_, t, _) in self.stages[k].crash.drain(..) {
                    t.fail();
                }
            } else {
                let evicting: Vec<InstanceId> =
                    self.stages[k].crash.iter().map(|&(w, _, _)| w).collect();
                let ticket = job.scale_to(k, survivors);
                self.stages[k].heal = Some(HealAttempt {
                    ticket,
                    evicting,
                    restore_to: active.len(),
                    regrow: false,
                    issued: now,
                });
                self.stages[k].not_before = None;
            }
        }

        // stalls: open on first sight, resolve on self-recovery, shed
        // load if persistent, degrade if hopeless. NEVER evict a stalled
        // worker — deactivating its reader would lose its unread share.
        for &w in &health.stalled {
            if !self.stages[k].stalls.iter().any(|s| s.worker == w) {
                let t = RecoveryTicket::new(k, w, RecoveryKind::Stall);
                self.log.push(t.clone());
                self.stages[k].stalls.push(StallTrack {
                    worker: w,
                    ticket: t,
                    since: now,
                    shed: false,
                });
            }
        }
        let mut shed_now = false;
        let log = self.log.clone();
        self.stages[k].stalls.retain_mut(|s| {
            if health.dead.contains(&s.worker) {
                // superseded: the crash path owns this worker now
                s.ticket.fail();
                return false;
            }
            if !health.stalled.contains(&s.worker) {
                s.ticket.resolve(s.since.elapsed().as_secs_f64() * 1e3);
                return false;
            }
            let stalled_ms = s.since.elapsed().as_millis() as u64;
            if stalled_ms > cfg.stall_degraded_after_ms {
                log.mark_degraded();
                s.ticket.fail();
                return false;
            }
            if !s.shed && stalled_ms > cfg.stall_shed_after_ms {
                s.shed = true;
                shed_now = true;
            }
            true
        });
        if shed_now {
            job.set_rate(m.offered_tps * cfg.shed_factor);
        }
    }
}

impl JobPolicy for SupervisorPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        while self.stages.len() < m.stages.len() {
            self.stages.push(StageSup::default());
        }
        for k in 0..m.stages.len() {
            self.tick_stage(k, m, job);
        }
    }
}

/// Drive a set of policies against a live job until it quiesces: sample,
/// tick every policy, sleep, repeat. This is the ONE wiring loop shared
/// by [`super::run_pipeline`] and [`super::run_job`] — and the template
/// for driving a job from your own code.
///
/// Policies only tick while the feed is [`running`](JobPhase::Running):
/// once end-of-stream heartbeats are out, a reconfiguration could never
/// complete (no watermark advances past it), so decisions stop with the
/// schedule — the same invariant the old monolithic loop kept
/// implicitly. The poll period is half the runtime's publish tick:
/// finer polling would mostly re-read identical snapshots.
pub fn drive(job: &JobCtl, policies: &mut [Box<dyn JobPolicy>]) {
    use super::handle::JobPhase;
    loop {
        let m = job.sample();
        // gate on the LIVE phase, not the snapshot's (one tick stale):
        // a decision issued into the end-of-stream window would be
        // silently dropped
        if job.phase() == JobPhase::Running {
            for p in policies.iter_mut() {
                p.tick(&m, job);
            }
        }
        if job.quiesced() {
            break;
        }
        // lint: allow(sleep) — control-plane poll cadence (half the
        // runtime's publish tick); finer polling would only re-read
        // identical metric snapshots.
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::handle::{JobPhase, StageMetrics};
    use crate::harness::RunSample;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    fn metrics(n_stages: usize) -> JobMetrics {
        JobMetrics {
            event_s: 0.0,
            duration_s: 10,
            offered_tps: 1_000.0,
            ingress: 1,
            fed: 0,
            egress_count: 0,
            ingress_dropped: 0,
            phase: JobPhase::Running,
            stages: (0..n_stages)
                .map(|_| StageMetrics {
                    name: "stage",
                    active: vec![0],
                    max: 4,
                    backlog: 0,
                    worker_batch: 128,
                    health: StageHealth::default(),
                    last: RunSample::default(),
                })
                .collect(),
        }
    }

    #[test]
    fn scripted_policy_fires_each_step_once_in_time_order() {
        let job = JobCtl::detached(2);
        // deliberately unsorted input
        let mut p = ScriptedScalePolicy::counts(1, vec![(3, 2), (1, 3)]);
        let mut m = metrics(2);
        m.event_s = 0.5;
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 0, "nothing due yet");
        m.event_s = 1.0;
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 1, "first step due");
        m.event_s = 5.0;
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 2, "catch-up fires the rest");
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 2, "steps must not refire");
        assert!(job.tickets().iter().all(|t| t.stage() == 1));
    }

    struct CountingController(Arc<AtomicU32>);
    impl Controller for CountingController {
        fn tick(&mut self, _obs: &Observation) -> Decision {
            self.0.fetch_add(1, Ordering::Relaxed);
            Decision::Hold
        }
    }

    #[test]
    fn controller_policy_honors_its_period() {
        let job = JobCtl::detached(1);
        let calls = Arc::new(AtomicU32::new(0));
        let mut p = ControllerPolicy::new(0, Box::new(CountingController(calls.clone())), 2);
        let mut m = metrics(1);
        for (event_s, want) in [(1.9, 0), (2.0, 1), (3.9, 1), (4.2, 2), (4.3, 2)] {
            m.event_s = event_s;
            p.tick(&m, &job);
            assert_eq!(calls.load(Ordering::Relaxed), want, "at event_s={event_s}");
        }
    }

    /// Supervisor config with tiny backoffs so the tests run in ms.
    fn sup_cfg() -> SupervisorConfig {
        SupervisorConfig {
            backoff_base_ms: 2,
            backoff_cap_ms: 8,
            max_attempts: 1,
            attempt_timeout_ms: 60_000,
            ..SupervisorConfig::default()
        }
    }

    #[test]
    fn supervisor_heals_a_dead_worker_immediately_with_survivors() {
        let job = JobCtl::detached(1);
        let log = RecoveryLog::new();
        let mut p = SupervisorPolicy::new(sup_cfg(), log.clone());
        let mut m = metrics(1);
        m.stages[0].active = vec![0, 1, 2];
        m.stages[0].health.dead = vec![1];
        p.tick(&m, &job);
        // first attempt is immediate: one eviction onto the survivor set
        let tickets = job.tickets();
        assert_eq!(tickets.len(), 1, "one heal switch issued");
        assert_eq!(tickets[0].stage(), 0);
        // and one crash recovery ticket opened, still pending
        let recs = log.tickets();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].kind(), RecoveryKind::Crash);
        assert_eq!(recs[0].worker(), 1);
        assert_eq!(recs[0].outcome(), None);
        // a second tick must not issue another switch while one is open
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 1);
        // the eviction completes → MTTR resolves, replace step re-grows
        tickets[0].resolve(3.0);
        p.tick(&m, &job);
        assert!(matches!(recs[0].outcome(), Some(RecoveryOutcome::Healed(ms)) if ms >= 0.0));
        assert_eq!(job.tickets().len(), 2, "regrow switch issued after heal");
        assert!(!log.degraded());
    }

    #[test]
    fn supervisor_backoff_then_shed_then_degraded() {
        let job = JobCtl::detached(1);
        let log = RecoveryLog::new();
        let mut p = SupervisorPolicy::new(sup_cfg(), log.clone());
        let mut m = metrics(1);
        m.stages[0].active = vec![0, 1];
        m.stages[0].health.dead = vec![0];
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 1);
        // attempt 1 fails → backoff, then retry (max_attempts = 1)
        job.tickets()[0].kill();
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 1, "backoff holds the retry");
        std::thread::sleep(Duration::from_millis(10));
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 2, "retry issued after backoff");
        // attempt 2 fails → ladder escalates: shed load, one last round
        job.tickets()[1].kill();
        p.tick(&m, &job);
        std::thread::sleep(Duration::from_millis(10));
        p.tick(&m, &job);
        let n = job.tickets().len();
        assert!(n >= 3, "retry after shedding");
        job.tickets()[n - 1].kill();
        p.tick(&m, &job);
        p.tick(&m, &job);
        assert!(log.degraded(), "ladder exhausted: job degraded");
        assert_eq!(log.tickets()[0].outcome(), Some(RecoveryOutcome::Failed));
    }

    #[test]
    fn supervisor_poison_fails_fast_without_survivors() {
        let job = JobCtl::detached(1);
        let log = RecoveryLog::new();
        let mut p = SupervisorPolicy::new(sup_cfg(), log.clone());
        let mut m = metrics(1);
        m.stages[0].active = vec![0, 1];
        m.stages[0].health.dead = vec![0, 1];
        p.tick(&m, &job);
        // no survivor set exists: no switch can heal this — degrade now
        assert_eq!(job.tickets().len(), 0, "no heal switch without survivors");
        assert!(log.degraded());
        assert!(log.tickets().iter().all(|t| t.outcome() == Some(RecoveryOutcome::Failed)));
    }

    #[test]
    fn supervisor_stall_resolves_on_self_recovery() {
        let job = JobCtl::detached(1);
        let log = RecoveryLog::new();
        let mut p = SupervisorPolicy::new(SupervisorConfig::default(), log.clone());
        let mut m = metrics(1);
        m.stages[0].active = vec![0, 1];
        m.stages[0].health.stalled = vec![1];
        p.tick(&m, &job);
        let recs = log.tickets();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].kind(), RecoveryKind::Stall);
        assert_eq!(recs[0].outcome(), None, "still stalled");
        assert_eq!(job.tickets().len(), 0, "stalled workers are never evicted");
        // the worker beats again: the stall heals itself, MTTR measured
        m.stages[0].health.stalled.clear();
        p.tick(&m, &job);
        assert!(matches!(recs[0].outcome(), Some(RecoveryOutcome::Healed(ms)) if ms >= 0.0));
        assert!(!log.degraded());
    }

    #[test]
    fn recovery_log_close_unresolved_fails_open_tickets() {
        let log = RecoveryLog::new();
        let t = RecoveryTicket::new(0, 1, RecoveryKind::Crash);
        log.push(t.clone());
        t.resolve(5.0);
        let open = RecoveryTicket::new(1, 0, RecoveryKind::Stall);
        log.push(open.clone());
        log.close_unresolved();
        assert_eq!(t.mttr_ms(), Some(5.0), "resolved tickets keep their outcome");
        assert_eq!(open.outcome(), Some(RecoveryOutcome::Failed));
        assert_eq!(open.wait(Duration::from_secs(5)), Some(RecoveryOutcome::Failed));
    }

    #[test]
    fn observation_uses_schedule_rate_only_for_single_ingress_stage_zero() {
        let mut m = metrics(2);
        m.stages[1].last.in_tps = 123.0;
        assert_eq!(observation(&m, 0, 1).in_rate, 1_000.0, "stage 0, one wrapper: offered");
        assert_eq!(observation(&m, 1, 1).in_rate, 123.0, "downstream: measured arrivals");
        m.ingress = 2;
        m.stages[0].last.in_tps = 77.0;
        assert_eq!(observation(&m, 0, 1).in_rate, 77.0, "multi-ingress: measured arrivals");
    }
}
