//! Policies: the decision loops of a live job, driven *through* the
//! [`JobCtl`] control surface.
//!
//! STRETCH deliberately separates the reconfiguration *mechanism* (epochs
//! + control tuples, `crate::engine`) from the *policy* that decides when
//! to scale (§3; Röger & Mayer's survey calls these the elasticity
//! mechanism and the elasticity policy). The [`crate::elastic`]
//! controllers are pure policy already — this module is the thin layer
//! that feeds them [`JobMetrics`] samples and forwards their decisions as
//! [`JobCtl::scale_to`] calls, exactly like user-written policies would.
//! The same shape covers scripted reconfigurations (`[schedule.<stage>]`
//! steps, manual test plans) and the adaptive worker-batch sizing, so the
//! run loop has ONE wiring path for all of them: [`drive`].

use super::handle::{JobCtl, JobMetrics};
use super::{adaptive_worker_batch, AdaptiveBatch};
use crate::elastic::{Controller, DagController, Decision, Observation};
use crate::tuple::InstanceId;
use std::time::Duration;

/// One decision loop over a live job. `tick` is called with a fresh
/// metrics sample every few milliseconds until the job quiesces; a policy
/// keeps its own cadence (usually against `m.event_s`) and issues
/// commands through `job`.
pub trait JobPolicy: Send {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl);
}

/// Build a per-stage [`Observation`] from a metrics sample. The offered
/// schedule rate only describes stage 0 when a single ingress wrapper
/// feeds it the whole stream; otherwise the measured arrival rate is the
/// controller's load estimate.
fn observation(m: &JobMetrics, stage: usize, period_s: u32) -> Observation {
    let st = &m.stages[stage];
    Observation {
        in_rate: if stage == 0 && m.ingress == 1 { m.offered_tps } else { st.last.in_tps },
        cmp_per_s: st.last.cmp_per_s,
        backlog: st.backlog,
        dt: period_s as f64,
        active: st.active.clone(),
        max: st.max,
    }
}

enum ScaleStep {
    /// Exact instance set (manual test plans).
    Set(Vec<InstanceId>),
    /// Target parallelism (`[schedule.<stage>] scale` steps).
    Count(usize),
}

/// Scripted reconfigurations: at event second `at`, scale one stage —
/// each step fires exactly once, in time order, through the handle (so
/// every step yields a [`super::ReconfigTicket`]).
pub struct ScriptedScalePolicy {
    stage: usize,
    steps: Vec<(u32, ScaleStep)>,
    next: usize,
}

impl ScriptedScalePolicy {
    /// Steps as exact instance sets (the harness `manual_reconfigs`
    /// shape).
    pub fn sets(stage: usize, steps: Vec<(u32, Vec<InstanceId>)>) -> Self {
        let mut steps: Vec<(u32, ScaleStep)> =
            steps.into_iter().map(|(at, s)| (at, ScaleStep::Set(s))).collect();
        steps.sort_by_key(|&(at, _)| at);
        ScriptedScalePolicy { stage, steps, next: 0 }
    }

    /// Steps as target parallelism counts (the `[schedule.<stage>]`
    /// shape).
    pub fn counts(stage: usize, steps: Vec<(u32, usize)>) -> Self {
        let mut steps: Vec<(u32, ScaleStep)> =
            steps.into_iter().map(|(at, n)| (at, ScaleStep::Count(n))).collect();
        steps.sort_by_key(|&(at, _)| at);
        ScriptedScalePolicy { stage, steps, next: 0 }
    }
}

impl JobPolicy for ScriptedScalePolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        while let Some((at, step)) = self.steps.get(self.next) {
            if (*at as f64) > m.event_s {
                break;
            }
            match step {
                ScaleStep::Set(set) => {
                    job.scale_to(self.stage, set.clone());
                }
                ScaleStep::Count(n) => {
                    job.scale(self.stage, *n);
                }
            }
            self.next += 1;
        }
    }
}

/// Timed offered-rate steps (`[schedule.<stage>] rate`): at event second
/// `at`, override the feed rate. The feed is global, so these usually
/// live on a source stage's schedule section.
pub struct RateStepPolicy {
    steps: Vec<(u32, f64)>,
    next: usize,
}

impl RateStepPolicy {
    pub fn new(mut steps: Vec<(u32, f64)>) -> Self {
        steps.sort_by_key(|&(at, _)| at);
        RateStepPolicy { steps, next: 0 }
    }
}

impl JobPolicy for RateStepPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        while let Some(&(at, tps)) = self.steps.get(self.next) {
            if (at as f64) > m.event_s {
                break;
            }
            job.set_rate(tps);
            self.next += 1;
        }
    }
}

/// One per-stage [`Controller`] (reactive/proactive) ticked every
/// `period_s` event seconds — the re-homed single-stage controller path.
pub struct ControllerPolicy {
    stage: usize,
    controller: Box<dyn Controller>,
    period_s: u32,
    next_s: u32,
}

impl ControllerPolicy {
    pub fn new(stage: usize, controller: Box<dyn Controller>, period_s: u32) -> Self {
        let period_s = period_s.max(1);
        ControllerPolicy { stage, controller, period_s, next_s: period_s }
    }
}

impl JobPolicy for ControllerPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        if (self.next_s as f64) > m.event_s {
            return;
        }
        self.next_s += self.period_s;
        let obs = observation(m, self.stage, self.period_s);
        if let Decision::Reconfigure(set) = self.controller.tick(&obs) {
            job.scale_to(self.stage, set);
        }
    }
}

/// Adaptive worker-batch sizing: every `period_s` event seconds, re-derive
/// one stage's batch from its observed backlog ([`adaptive_worker_batch`])
/// and install it live through the handle.
pub struct AdaptiveBatchPolicy {
    stage: usize,
    bounds: AdaptiveBatch,
    period_s: u32,
    next_s: u32,
}

impl AdaptiveBatchPolicy {
    pub fn new(stage: usize, bounds: AdaptiveBatch, period_s: u32) -> Self {
        let period_s = period_s.max(1);
        AdaptiveBatchPolicy { stage, bounds, period_s, next_s: period_s }
    }
}

impl JobPolicy for AdaptiveBatchPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        if (self.next_s as f64) > m.event_s {
            return;
        }
        self.next_s += self.period_s;
        job.set_worker_batch(self.stage, adaptive_worker_batch(m.stages[self.stage].backlog, self.bounds));
    }
}

/// The topology-aware budgeted co-scheduler as a policy: one observation
/// per stage, one decision wave per period, every reconfiguration issued
/// through the handle.
pub struct DagControllerPolicy {
    controller: DagController,
    period_s: u32,
    next_s: u32,
}

impl DagControllerPolicy {
    pub fn new(controller: DagController, period_s: u32) -> Self {
        let period_s = period_s.max(1);
        DagControllerPolicy { controller, period_s, next_s: period_s }
    }
}

impl JobPolicy for DagControllerPolicy {
    fn tick(&mut self, m: &JobMetrics, job: &JobCtl) {
        if (self.next_s as f64) > m.event_s {
            return;
        }
        self.next_s += self.period_s;
        let obs: Vec<Observation> =
            (0..m.stages.len()).map(|k| observation(m, k, self.period_s)).collect();
        for (k, d) in self.controller.tick(&obs).into_iter().enumerate() {
            if let Decision::Reconfigure(set) = d {
                job.scale_to(k, set);
            }
        }
    }
}

/// Drive a set of policies against a live job until it quiesces: sample,
/// tick every policy, sleep, repeat. This is the ONE wiring loop shared
/// by [`super::run_pipeline`] and [`super::run_job`] — and the template
/// for driving a job from your own code.
///
/// Policies only tick while the feed is [`running`](JobPhase::Running):
/// once end-of-stream heartbeats are out, a reconfiguration could never
/// complete (no watermark advances past it), so decisions stop with the
/// schedule — the same invariant the old monolithic loop kept
/// implicitly. The poll period is half the runtime's publish tick:
/// finer polling would mostly re-read identical snapshots.
pub fn drive(job: &JobCtl, policies: &mut [Box<dyn JobPolicy>]) {
    use super::handle::JobPhase;
    loop {
        let m = job.sample();
        // gate on the LIVE phase, not the snapshot's (one tick stale):
        // a decision issued into the end-of-stream window would be
        // silently dropped
        if job.phase() == JobPhase::Running {
            for p in policies.iter_mut() {
                p.tick(&m, job);
            }
        }
        if job.quiesced() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::handle::{JobPhase, StageMetrics};
    use crate::harness::RunSample;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    fn metrics(n_stages: usize) -> JobMetrics {
        JobMetrics {
            event_s: 0.0,
            duration_s: 10,
            offered_tps: 1_000.0,
            ingress: 1,
            fed: 0,
            egress_count: 0,
            ingress_dropped: 0,
            phase: JobPhase::Running,
            stages: (0..n_stages)
                .map(|_| StageMetrics {
                    name: "stage",
                    active: vec![0],
                    max: 4,
                    backlog: 0,
                    worker_batch: 128,
                    last: RunSample::default(),
                })
                .collect(),
        }
    }

    #[test]
    fn scripted_policy_fires_each_step_once_in_time_order() {
        let job = JobCtl::detached(2);
        // deliberately unsorted input
        let mut p = ScriptedScalePolicy::counts(1, vec![(3, 2), (1, 3)]);
        let mut m = metrics(2);
        m.event_s = 0.5;
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 0, "nothing due yet");
        m.event_s = 1.0;
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 1, "first step due");
        m.event_s = 5.0;
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 2, "catch-up fires the rest");
        p.tick(&m, &job);
        assert_eq!(job.tickets().len(), 2, "steps must not refire");
        assert!(job.tickets().iter().all(|t| t.stage() == 1));
    }

    struct CountingController(Arc<AtomicU32>);
    impl Controller for CountingController {
        fn tick(&mut self, _obs: &Observation) -> Decision {
            self.0.fetch_add(1, Ordering::Relaxed);
            Decision::Hold
        }
    }

    #[test]
    fn controller_policy_honors_its_period() {
        let job = JobCtl::detached(1);
        let calls = Arc::new(AtomicU32::new(0));
        let mut p = ControllerPolicy::new(0, Box::new(CountingController(calls.clone())), 2);
        let mut m = metrics(1);
        for (event_s, want) in [(1.9, 0), (2.0, 1), (3.9, 1), (4.2, 2), (4.3, 2)] {
            m.event_s = event_s;
            p.tick(&m, &job);
            assert_eq!(calls.load(Ordering::Relaxed), want, "at event_s={event_s}");
        }
    }

    #[test]
    fn observation_uses_schedule_rate_only_for_single_ingress_stage_zero() {
        let mut m = metrics(2);
        m.stages[1].last.in_tps = 123.0;
        assert_eq!(observation(&m, 0, 1).in_rate, 1_000.0, "stage 0, one wrapper: offered");
        assert_eq!(observation(&m, 1, 1).in_rate, 123.0, "downstream: measured arrivals");
        m.ingress = 2;
        m.stages[0].last.in_tps = 77.0;
        assert_eq!(observation(&m, 0, 1).in_rate, 77.0, "multi-ingress: measured arrivals");
    }
}
