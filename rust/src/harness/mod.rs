//! Experiment harness: launch, observe and reconfigure live VSN
//! *topologies* — and the batch entry points built on top of that.
//!
//! The core is the live runtime API ([`handle`]): [`Job::launch`] is the
//! ONE way a running topology is owned. It moves the data plane — the
//! paced feed across every ingress wrapper, the egress drain, the
//! per-event-second §8 metrics sampling — onto a background runtime
//! thread and hands back a [`JobHandle`], the control surface:
//! `scale`/`scale_to` (each returns a [`ReconfigTicket`] resolving to the
//! measured reconfiguration latency — the paper's <40 ms claim as an
//! observable), `set_rate`, `set_worker_batch`, `sample()` →
//! [`JobMetrics`], `await_quiesce`, and `shutdown()` →
//! [`JobRunOutcome`].
//!
//! Everything that *decides* is a policy outside the handle ([`policy`]):
//! the `elastic` controllers (reactive / proactive / the budgeted
//! [`DagController`]), scripted `[schedule.<stage>]` steps, and adaptive
//! batch sizing all consume [`JobMetrics`] and call `scale` — exactly the
//! mechanism/policy split of Röger & Mayer's elasticity survey, and the
//! same surface user-written policies get.
//!
//! [`run_pipeline`] and [`run_job`] are thin clients of the handle:
//! launch, [`drive`] the configured policies, await quiesce, shut down.
//! [`run_elastic_join`] — the Q3-Q6 entry point — wraps `run_pipeline`
//! with a single-stage ScaleJoin pipeline. Degenerate topologies (no
//! ingress, no egress) are typed [`HarnessError`]s, not panics.
//!
//! Wall-clock pacing is compressible (`time_scale`) so the paper's
//! 20-minute runs replay in seconds; event time always advances at the
//! schedule's nominal pace.

pub mod faults;
pub mod handle;
pub mod policy;
pub mod server;

pub use faults::{FaultAction, FaultPlan, FaultPolicy, FaultStep};
pub use handle::{
    Job, JobCtl, JobHandle, JobMetrics, JobPhase, JobRunOutcome, LaunchConfig, QuiesceTimeout,
    ReconfigTicket, RejectReason, ReplaySource, StageHealth, StageMetrics, TicketOutcome,
    QUIESCE_CAP,
};
pub use policy::{
    drive, AdaptiveBatchPolicy, ControllerPolicy, DagControllerPolicy, JobPolicy, RateStepPolicy,
    RecoveryKind, RecoveryLog, RecoveryOutcome, RecoveryTicket, ScriptedScalePolicy,
    SupervisorConfig, SupervisorPolicy,
};
pub use server::{
    serve_from_config, Admission, JobId, JobServer, Rebalance, ServerJobView, ServerMetrics,
    ServerOutcome,
};

use crate::config::{BatchTuning, Config, FaultsConfig, PlacementConfig};
use crate::elastic::{
    Controller, DagController, JoinCostModel, ProactiveController, ReactiveController, Thresholds,
};
use crate::engine::job::{string_list, JobError, JobSpec};
use crate::engine::pipeline::{Pipeline, PipelineBuilder};
use crate::engine::VsnOptions;
use crate::runtime::placement::CoreMap;
use crate::sim::calibrate;
use crate::time::EventTime;
use crate::tuple::{Payload, Tuple};
use crate::workloads::nyse::{Trade, TradeStream};
use crate::workloads::rates::{parse_steps, RateSchedule};
use crate::workloads::registry::{JobPayload, JobSource};
use crate::workloads::scalejoin_bench::{q3_operator, SjGen, SjPayload};
use crate::workloads::tweets::{Tweet, TweetGen};
use std::fmt;
use std::time::Duration;

/// A generator the harness can pace against a [`RateSchedule`]: emits
/// ts-sorted tuples whose event time advances at ~`1000 / rate` ms each.
pub trait PacedSource<P>: Send {
    /// Adjust the nominal rate (tuples per event-second).
    fn set_rate(&mut self, _tps: f64) {}
    /// Next tuple (event time must not regress).
    fn next(&mut self) -> Tuple<P>;
    /// A finite source reports `true` once drained; the job runtime then
    /// cuts straight to end-of-stream instead of waiting out the
    /// schedule (see [`ReplaySource`]). Infinite generators keep the
    /// default `false`.
    fn exhausted(&self) -> bool {
        false
    }
}

impl PacedSource<SjPayload> for SjGen {
    fn set_rate(&mut self, tps: f64) {
        SjGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<SjPayload> {
        SjGen::next(self)
    }
}

impl PacedSource<Tweet> for TweetGen {
    fn set_rate(&mut self, tps: f64) {
        TweetGen::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Tweet> {
        TweetGen::next(self)
    }
}

impl PacedSource<Trade> for TradeStream {
    fn set_rate(&mut self, tps: f64) {
        TradeStream::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<Trade> {
        TradeStream::next(self)
    }
}

impl PacedSource<JobPayload> for JobSource {
    fn set_rate(&mut self, tps: f64) {
        JobSource::set_rate(self, tps);
    }
    fn next(&mut self) -> Tuple<JobPayload> {
        self.next_tuple()
    }
}

/// Harness configuration (the Q3-Q6 single-stage ScaleJoin shape).
pub struct JoinRunConfig {
    /// ScaleJoin window size (event-time ms).
    pub ws_ms: EventTime,
    /// Round-robin key count (paper: 1000).
    pub n_keys: u64,
    /// Initial / maximum parallelism (m, n).
    pub initial: usize,
    pub max: usize,
    /// The offered-rate schedule (event-time seconds).
    pub schedule: RateSchedule,
    /// Wall-time compression: 10.0 replays 10 event-seconds per wall-second.
    pub time_scale: f64,
    /// Optional elasticity controller.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    pub seed: u64,
    pub gate_capacity: usize,
    /// Worker gate synchronization granularity (tuples per
    /// `get_batch`/`add_batch`) — the `[batch] worker` config knob.
    pub worker_batch: usize,
    /// Max run length per batched ingress add — the `[batch] ingress`
    /// config knob.
    pub ingress_batch: usize,
    /// Scripted reconfigurations: (event second, new instance set) —
    /// issued directly, bypassing the controller (Q4 protocol timing).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
}

impl Default for JoinRunConfig {
    fn default() -> Self {
        JoinRunConfig {
            ws_ms: 5_000,
            n_keys: 64,
            initial: 1,
            max: 4,
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            controller: None,
            controller_period_s: 1,
            seed: 7,
            gate_capacity: 1 << 13,
            worker_batch: crate::engine::WORKER_BATCH,
            ingress_batch: 256,
            manual_reconfigs: Vec::new(),
        }
    }
}

/// One per-event-second sample of one stage.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunSample {
    pub t_s: u32,
    pub offered_tps: f64,
    pub in_tps: f64,
    pub out_tps: f64,
    pub cmp_per_s: f64,
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
    pub threads: usize,
    pub backlog: u64,
    pub load_cv_pct: f64,
    /// Effective worker batch of the stage at sample time (moves when
    /// adaptive batch sizing is on).
    pub worker_batch: usize,
}

/// Result of a single-stage harness run (the historical shape).
pub struct RunResult {
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times.
    pub reconfigs: Vec<(u64, f64)>,
    /// Total data tuples drained at the egress.
    pub egress_count: u64,
}

/// Bounds of the adaptive worker-batch policy (the `[batch]`
/// `worker_min`/`worker_max` knobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdaptiveBatch {
    pub min: usize,
    pub max: usize,
}

impl From<&BatchTuning> for AdaptiveBatch {
    fn from(t: &BatchTuning) -> Self {
        AdaptiveBatch { min: t.worker_min, max: t.worker_max }
    }
}

/// Adaptive batch sizing policy (ROADMAP follow-up): derive a stage's
/// effective worker batch from its observed `in_backlog`. A cold stage
/// (little queued work) flushes small so tuples don't sit in `out_buf`
/// waiting for batch-mates (latency); a hot stage batches large so the
/// gate synchronization cost amortizes (throughput). `backlog / 4`
/// reaches the upper clamp once ~4 full batches are queued — past that
/// point a bigger batch no longer changes the arrival/service balance,
/// it only adds latency. Clamped to `[min, max]` from
/// [`BatchTuning`]; monotone in `backlog`.
pub fn adaptive_worker_batch(backlog: u64, bounds: AdaptiveBatch) -> usize {
    let lo = bounds.min.max(1);
    let hi = bounds.max.max(lo);
    ((backlog / 4).min(hi as u64) as usize).clamp(lo, hi)
}

/// Per-stage runtime policy for a pipeline run.
pub struct StageRunConfig {
    /// Optional elasticity controller for this stage.
    pub controller: Option<Box<dyn Controller>>,
    /// Controller tick period in event-time seconds.
    pub controller_period_s: u32,
    /// Scripted reconfigurations: (event second, new instance set).
    pub manual_reconfigs: Vec<(u32, Vec<usize>)>,
    /// When set, the stage's worker batch is re-derived from its
    /// `in_backlog` every controller tick via [`adaptive_worker_batch`].
    pub adaptive_batch: Option<AdaptiveBatch>,
}

impl Default for StageRunConfig {
    fn default() -> Self {
        StageRunConfig {
            controller: None,
            controller_period_s: 1,
            manual_reconfigs: Vec::new(),
            adaptive_batch: None,
        }
    }
}

/// Pipeline harness configuration.
pub struct PipelineRunConfig {
    pub schedule: RateSchedule,
    pub time_scale: f64,
    /// One entry per stage (missing trailing entries default).
    pub stages: Vec<StageRunConfig>,
    /// End-of-stream heartbeat horizon beyond the last event ms (flush
    /// windows; use ≥ the largest WS in the pipeline).
    pub flush_slack_ms: EventTime,
    /// Wall time to keep draining the egress after end-of-stream.
    pub drain: Duration,
    /// Max run length handed to the ingress per batched add — the
    /// `[batch] ingress` config knob (bounds gate burstiness).
    pub ingress_batch: usize,
    /// Optional topology-aware controller: co-schedules EVERY stage's
    /// parallelism against a global core budget from their `in_backlog`
    /// (takes priority over nothing — per-stage controllers still run;
    /// use one or the other per stage in practice).
    pub dag_controller: Option<DagController>,
    /// Tick period of the DAG controller in event-time seconds.
    pub dag_controller_period_s: u32,
}

impl Default for PipelineRunConfig {
    fn default() -> Self {
        PipelineRunConfig {
            schedule: RateSchedule::constant(10, 1_000.0),
            time_scale: 1.0,
            stages: Vec::new(),
            flush_slack_ms: 15_000,
            drain: Duration::from_millis(500),
            ingress_batch: 256,
            dag_controller: None,
            dag_controller_period_s: 1,
        }
    }
}

/// Typed configuration errors from [`run_pipeline`] — degenerate
/// topologies are reported, not asserted (no panic path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarnessError {
    /// The pipeline exposes no ingress wrapper to feed.
    NoIngress,
    /// The pipeline exposes no egress reader: the sink gates would fill
    /// to capacity and stall their stages with nobody draining them.
    NoEgress,
    /// More per-stage configs than stages — the extra scripted
    /// reconfigurations/controllers would be silently dropped.
    ExtraStageConfigs { given: usize, stages: usize },
    /// A scripted reconfiguration names an empty instance set — a stage
    /// cannot run with zero instances, and the panic would otherwise
    /// fire mid-run instead of before launch.
    EmptyReconfigSet { stage: usize },
}

impl fmt::Display for HarnessError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HarnessError::NoIngress => write!(f, "pipeline has no ingress source to drive"),
            HarnessError::NoEgress => write!(f, "pipeline has no egress reader to drain"),
            HarnessError::ExtraStageConfigs { given, stages } => write!(
                f,
                "{given} stage configs for a {stages}-stage pipeline — \
                 scripted reconfigs would be dropped"
            ),
            HarnessError::EmptyReconfigSet { stage } => write!(
                f,
                "stage {stage} has a scripted reconfiguration with an empty \
                 instance set — a stage cannot run with zero instances"
            ),
        }
    }
}

impl std::error::Error for HarnessError {}

/// Per-stage outcome of a pipeline run.
#[derive(Clone)]
pub struct StageRunStats {
    pub name: &'static str,
    pub samples: Vec<RunSample>,
    /// (epoch, wall ms) reconfiguration completion times of this stage.
    pub reconfigs: Vec<(u64, f64)>,
}

/// Result of a pipeline run.
#[derive(Clone)]
pub struct PipelineRunResult {
    pub stages: Vec<StageRunStats>,
    /// Data tuples drained at the final egress.
    pub egress_count: u64,
    /// Tuples the harness had to discard because their ingress wrapper's
    /// source slot was decommissioned mid-run (the wrapper leaves the
    /// feed rotation; 0 in healthy runs — nonzero means egress/latency
    /// stats cover only part of the offered stream).
    pub ingress_dropped: u64,
    /// Whole-run end-to-end latency (ingest stamp at stage 0 → final
    /// egress) over every stamped output tuple.
    pub latency_p50_us: u64,
    pub latency_mean_us: f64,
}

/// Drive a live, threaded VSN topology to completion — a thin client of
/// the live runtime API: [`Job::launch`] owns the data plane (paced feed
/// across every ingress wrapper, egress drain, per-event-second
/// sampling), while this function merely translates the
/// [`PipelineRunConfig`] into [`policy`] objects — scripted
/// reconfigurations, per-stage controllers, adaptive batch sizing, the
/// optional global [`DagController`] — and [`drive`]s them through the
/// [`JobHandle`] until the job quiesces.
pub fn run_pipeline<In, Out, S>(
    pipeline: Pipeline<In, Out>,
    mut cfg: PipelineRunConfig,
    source: S,
) -> Result<PipelineRunResult, HarnessError>
where
    In: Payload + Default,
    Out: Payload + Default,
    S: PacedSource<In> + 'static,
{
    let n_stages = pipeline.depth();
    if cfg.stages.len() > n_stages {
        return Err(HarnessError::ExtraStageConfigs { given: cfg.stages.len(), stages: n_stages });
    }
    let mut stage_cfgs: Vec<StageRunConfig> = std::mem::take(&mut cfg.stages);
    while stage_cfgs.len() < n_stages {
        stage_cfgs.push(StageRunConfig::default());
    }
    // degenerate configs are typed errors BEFORE launch, not mid-run
    // panics from the policy loop
    for (k, sc) in stage_cfgs.iter().enumerate() {
        if sc.manual_reconfigs.iter().any(|(_, set)| set.is_empty()) {
            return Err(HarnessError::EmptyReconfigSet { stage: k });
        }
    }

    let handle = Job::new(pipeline, source)
        .with_config(LaunchConfig {
            name: "pipeline".into(),
            stage_names: Vec::new(),
            schedule: cfg.schedule.clone(),
            time_scale: cfg.time_scale,
            flush_slack_ms: cfg.flush_slack_ms,
            drain: cfg.drain,
            ingress_batch: cfg.ingress_batch,
            capture_egress: false,
            pin_core: None,
            ..LaunchConfig::default()
        })
        .launch()?;

    // same per-pass order as the historical loop: scripted steps first,
    // then adaptive batching, then the stage controller, then the global
    // co-scheduler
    let mut policies: Vec<Box<dyn JobPolicy>> = Vec::new();
    for (k, sc) in stage_cfgs.into_iter().enumerate() {
        if !sc.manual_reconfigs.is_empty() {
            policies.push(Box::new(ScriptedScalePolicy::sets(k, sc.manual_reconfigs)));
        }
        if let Some(bounds) = sc.adaptive_batch {
            policies.push(Box::new(AdaptiveBatchPolicy::new(k, bounds, sc.controller_period_s)));
        }
        if let Some(ctl) = sc.controller {
            policies.push(Box::new(ControllerPolicy::new(k, ctl, sc.controller_period_s)));
        }
    }
    if let Some(dc) = cfg.dag_controller.take() {
        policies.push(Box::new(DagControllerPolicy::new(dc, cfg.dag_controller_period_s)));
    }
    // drive() returns once the job has quiesced
    drive(&handle, &mut policies);
    Ok(handle.shutdown().result)
}

/// Run a live, threaded VSN ScaleJoin experiment — the Q3-Q6 entry point,
/// now a thin wrapper over [`run_pipeline`] with a single-stage pipeline.
pub fn run_elastic_join(cfg: JoinRunConfig) -> RunResult {
    let def = q3_operator(cfg.ws_ms, cfg.n_keys);
    let pipeline = PipelineBuilder::new(
        def,
        VsnOptions {
            initial: cfg.initial,
            max: cfg.max,
            upstreams: 1,
            egress_readers: 1,
            gate_capacity: cfg.gate_capacity,
            worker_batch: cfg.worker_batch.max(1),
            ..Default::default()
        },
    )
    .build();
    let gen = SjGen::new(cfg.seed, 1.0);
    let pcfg = PipelineRunConfig {
        schedule: cfg.schedule,
        time_scale: cfg.time_scale,
        stages: vec![StageRunConfig {
            controller: cfg.controller,
            controller_period_s: cfg.controller_period_s,
            manual_reconfigs: cfg.manual_reconfigs,
            adaptive_batch: None,
        }],
        flush_slack_ms: cfg.ws_ms + 10_000,
        drain: Duration::from_millis(500),
        ingress_batch: cfg.ingress_batch.max(1),
        ..Default::default()
    };
    // the builder above wires exactly one ingress and one egress, so the
    // typed degenerate-topology errors cannot occur here
    let r = run_pipeline(pipeline, pcfg, gen)
        .expect("single-stage pipeline always has one ingress and one egress");
    let stage0 = r.stages.into_iter().next().expect("single-stage pipeline");
    RunResult { samples: stage0.samples, reconfigs: stage0.reconfigs, egress_count: r.egress_count }
}

/// Build a reactive ("reactive" or anything unrecognized, the classic
/// default) or proactive ("proactive") controller from the `[elastic]`
/// thresholds — the ONE construction path shared by the classic
/// experiment launcher and the per-stage declarative path, so the two
/// can never drift on thresholds or cooldown.
pub fn controller_from_config(
    cfg: &Config,
    kind: &str,
    model: JoinCostModel,
) -> Box<dyn Controller> {
    if kind == "proactive" {
        Box::new(ProactiveController::new(model))
    } else {
        Box::new(
            ReactiveController::new(
                model,
                Thresholds {
                    upper: cfg.float_or("elastic.upper", 0.90),
                    target: cfg.float_or("elastic.target", 0.70),
                    lower: cfg.float_or("elastic.lower", 0.45),
                },
            )
            .with_cooldown(2),
        )
    }
}

/// Expected value shape of a job config key ([`check_job_section_keys`]).
#[derive(Clone, Copy)]
enum KeyKind {
    Int,
    /// Accepts ints too (the usual numeric widening).
    Float,
    Str,
    Bool,
    /// A list value (element types are the consumer's contract — e.g.
    /// `[faults] steps` strings are parsed by [`FaultPlan::parse`]).
    List,
}

impl KeyKind {
    fn matches(self, v: &crate::config::ConfigValue) -> bool {
        use crate::config::ConfigValue as V;
        match self {
            KeyKind::Int => matches!(v, V::Int(_)),
            KeyKind::Float => matches!(v, V::Int(_) | V::Float(_)),
            KeyKind::Str => matches!(v, V::Str(_)),
            KeyKind::Bool => matches!(v, V::Bool(_)),
            KeyKind::List => matches!(v, V::List(_)),
        }
    }
    fn name(self) -> &'static str {
        match self {
            KeyKind::Int => "an integer",
            KeyKind::Float => "a number",
            KeyKind::Str => "a string",
            KeyKind::Bool => "a bool",
            KeyKind::List => "a list",
        }
    }
}

/// Keys [`run_job`] consumes, per section, with their expected value
/// shapes — an unknown key OR a wrong-typed value under these sections
/// is a typo that would silently change the job, so both are rejected
/// (same contract as `JobSpec`'s `[topology]`/`[stage.*]` validation,
/// which covers those two prefixes itself). This table is the
/// authoritative list for the job path: keep it in sync with
/// [`RateSchedule::from_config`], [`JobSource::for_kind`],
/// [`BatchTuning::from_config`] and the `[elastic]` reads in [`run_job`]
/// (each of those carries a pointer back here).
const JOB_SECTION_KEYS: &[(&str, &[(&str, KeyKind)])] = &[
    (
        "run.",
        &[
            ("duration_s", KeyKind::Int),
            ("rate", KeyKind::Float),
            ("schedule", KeyKind::Str),
            ("seed", KeyKind::Int),
            ("min_rate", KeyKind::Float),
            ("max_rate", KeyKind::Float),
            ("min_phase_s", KeyKind::Int),
            ("max_phase_s", KeyKind::Int),
            ("step_at_s", KeyKind::Int),
            ("step_rate", KeyKind::Float),
            ("time_scale", KeyKind::Float),
            ("flush_slack_ms", KeyKind::Int),
            ("drain_ms", KeyKind::Int),
        ],
    ),
    (
        "elastic.",
        &[
            ("controller", KeyKind::Str),
            ("cores", KeyKind::Int),
            ("grow_backlog", KeyKind::Int),
            ("shrink_backlog", KeyKind::Int),
            ("cooldown_ticks", KeyKind::Int),
            ("period_s", KeyKind::Int),
            ("upper", KeyKind::Float),
            ("target", KeyKind::Float),
            ("lower", KeyKind::Float),
        ],
    ),
    (
        "source.",
        &[("symbols", KeyKind::Int), ("seed", KeyKind::Int), ("vocab", KeyKind::Int)],
    ),
    (
        "batch.",
        &[
            ("worker", KeyKind::Int),
            ("ingress", KeyKind::Int),
            ("queue", KeyKind::Int),
            ("adaptive", KeyKind::Bool),
            ("worker_min", KeyKind::Int),
            ("worker_max", KeyKind::Int),
        ],
    ),
    (
        "placement.",
        &[
            ("enabled", KeyKind::Bool),
            ("pin_runtime", KeyKind::Bool),
            ("pin_workers", KeyKind::Bool),
        ],
    ),
    (
        "faults.",
        &[
            ("steps", KeyKind::List),
            ("supervise", KeyKind::Bool),
            ("stall_after_ms", KeyKind::Int),
        ],
    ),
];

/// Validate a job config's run-level sections: unknown sections, unknown
/// keys inside known sections, and wrong-typed values are all typed
/// errors — a declarative job must never silently run with defaults in
/// place of what the user wrote.
fn check_job_section_keys(cfg: &Config) -> Result<(), JobError> {
    'keys: for k in cfg.keys() {
        // `[topology]`/`[stage.*]` are JobSpec::from_config's territory,
        // `[schedule.*]` is validated against the declared stage names by
        // [`stage_schedules`]; the bare `name` key is the only free-form
        // top-level one.
        if k == "name"
            || k.starts_with("topology.")
            || k.starts_with("stage.")
            || k.starts_with("schedule.")
        {
            continue;
        }
        for (prefix, known) in JOB_SECTION_KEYS {
            if let Some(rest) = k.strip_prefix(prefix) {
                match known.iter().find(|(name, _)| *name == rest) {
                    None => {
                        return Err(JobError::BadValue {
                            key: k.to_string(),
                            msg: format!(
                                "unknown `[{}]` key (known: {})",
                                &prefix[..prefix.len() - 1],
                                known.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                            ),
                        })
                    }
                    Some((_, kind)) => {
                        let v = cfg.get(k).expect("keys() yields existing keys");
                        if !kind.matches(v) {
                            return Err(JobError::BadValue {
                                key: k.to_string(),
                                msg: format!("expected {}, got `{v}`", kind.name()),
                            });
                        }
                        continue 'keys;
                    }
                }
            }
        }
        // a server config handed to the single-job path deserves a
        // pointer at the right verb, not a generic unknown-section error
        if k.starts_with("server.") || k.starts_with("job.") {
            return Err(JobError::BadValue {
                key: k.to_string(),
                msg: "this looks like a JobServer config (`[server]`/`[job.<name>]`) — \
                      run it with `stretch serve`, not `stretch run`"
                    .into(),
            });
        }
        // no known prefix matched: a misspelled section name would
        // silently drop the whole section — reject it by name
        return Err(JobError::BadValue {
            key: k.to_string(),
            msg: "unknown section/key for a job config (expected `name`, `[topology]`, \
                  `[stage.<name>]`, `[schedule.<name>]`, `[run]`, `[elastic]`, `[source]`, \
                  `[batch]`, `[placement]`, or `[faults]`)"
                .into(),
        });
    }
    Ok(())
}

/// One stage's `[schedule.<stage>]` plan: timed `scale` and `rate` steps
/// (both in the `"<event second> -> <value>"` arrow idiom, parsed by
/// [`parse_steps`]), executed through the live [`JobHandle`] by
/// [`run_job`] — the declarative face of [`ScriptedScalePolicy`] and
/// [`RateStepPolicy`].
pub struct StageSchedule {
    /// Stage index into the topologically sorted [`JobSpec::stages`].
    pub stage: usize,
    /// (event second, target parallelism) — executed as `job.scale`.
    pub scale: Vec<(u32, usize)>,
    /// (event second, offered t/s) — executed as `job.set_rate`. The
    /// feed is global, so rate steps usually live on a source stage's
    /// section.
    pub rate: Vec<(u32, f64)>,
}

/// Parse and validate every `[schedule.<stage>]` section against the
/// job's declared stages: unknown stage names, unknown keys, malformed
/// steps and scale targets outside `1..=max` are all typed errors.
pub fn stage_schedules(cfg: &Config, spec: &JobSpec) -> Result<Vec<StageSchedule>, JobError> {
    use std::collections::BTreeMap;
    let mut by_stage: BTreeMap<usize, StageSchedule> = BTreeMap::new();
    for k in cfg.keys() {
        let Some(rest) = k.strip_prefix("schedule.") else { continue };
        let Some((stage, field)) = rest.split_once('.') else {
            return Err(JobError::BadValue {
                key: k.to_string(),
                msg: "expected `schedule.<stage>.<scale|rate>`".into(),
            });
        };
        let Some(idx) = spec.stages.iter().position(|s| s.name == stage) else {
            return Err(JobError::BadValue {
                key: k.to_string(),
                msg: format!(
                    "section `[schedule.{stage}]` does not match any declared stage \
                     (declared: {})",
                    spec.stages.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", ")
                ),
            });
        };
        if field != "scale" && field != "rate" {
            return Err(JobError::BadValue {
                key: k.to_string(),
                msg: "unknown `[schedule.<stage>]` key (known: scale, rate)".into(),
            });
        }
        let items = string_list(cfg, k)?.expect("keys() yields existing keys");
        let steps = parse_steps(&items)
            .map_err(|msg| JobError::BadValue { key: k.to_string(), msg })?;
        let entry = by_stage
            .entry(idx)
            .or_insert_with(|| StageSchedule { stage: idx, scale: Vec::new(), rate: Vec::new() });
        if field == "scale" {
            let max = spec.stages[idx].max;
            let mut scale = Vec::with_capacity(steps.len());
            for (at, v) in steps {
                if v.fract() != 0.0 || v < 1.0 || v > max as f64 {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: format!(
                            "scale step `{at} -> {v}` must target an integer parallelism \
                             in 1..={max} (the stage's max)"
                        ),
                    });
                }
                scale.push((at, v as usize));
            }
            entry.scale = scale;
        } else {
            for &(at, v) in &steps {
                if v < 0.0 {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: format!("rate step `{at} -> {v}` must be ≥ 0 t/s"),
                    });
                }
            }
            entry.rate = steps;
        }
    }
    Ok(by_stage.into_values().collect())
}

/// Run a config-declared job end to end — a thin client of the live
/// runtime API: parse + validate the [`JobSpec`] and its
/// `[schedule.<stage>]` sections, build the topology through the
/// operator registry, [`Job::launch`] it under the `[run]` rate schedule,
/// then [`drive`] the configured policies through the [`JobHandle`] —
/// the `[elastic]` controller choice (`none` / `reactive` / `proactive`
/// per stage, or the global budgeted `dag` controller with
/// `elastic.cores`), the `[batch]` adaptive batch sizing, and the
/// scripted `[schedule.<stage>]` scale/rate steps. Every policy-issued
/// reconfiguration comes back as a [`ReconfigTicket`] in
/// [`JobRunOutcome::tickets`], with its measured latency.
///
/// `budget_ms`, when given, caps the WALL-clock duration of the paced
/// phase by raising `time_scale` — the CI smoke knob (`stretch run
/// --config job.conf --budget-ms 10`).
pub fn run_job(cfg: &Config, budget_ms: Option<u64>) -> Result<JobRunOutcome, JobError> {
    let prep = prepare_job(cfg, JobPrepOptions { budget_ms, ..Default::default() })?;
    let handle = prep.job.launch().map_err(JobError::Harness)?;
    let mut policies = prep.policies;
    // drive() returns once the job has quiesced
    drive(&handle, &mut policies);
    let mut out = handle.shutdown();
    if let Some(log) = prep.recovery_log {
        // anything still open when the run ended never healed — a chaos
        // run must not report an unresolved ticket as success
        log.close_unresolved();
        out.recoveries = log.tickets();
        out.degraded = log.degraded();
    }
    Ok(out)
}

/// Options steering [`prepare_job`] beyond what the config itself says —
/// the deltas between the standalone `stretch run` path and a job
/// prepared for the [`server::JobServer`].
#[derive(Default)]
pub(crate) struct JobPrepOptions {
    /// Wall-clock cap for the paced phase (raises `time_scale`).
    pub(crate) budget_ms: Option<u64>,
    /// Server mode: the fleet-level [`crate::elastic::ServerController`]
    /// owns cross-job scaling, so the sub-config's own `[elastic]`
    /// `controller` choice is ignored instead of double-driving the same
    /// stages from two controllers.
    pub(crate) skip_elastic_controller: bool,
    /// Server mode: socket affinity from `[job.<name>] socket`, applied
    /// to every stage that doesn't pin one itself so co-resident jobs
    /// keep to their own NUMA domain.
    pub(crate) socket: Option<usize>,
    /// Server mode: the `[job.<name>]` section key replaces the
    /// sub-config's own `name`, keeping aggregate metrics unambiguous
    /// when two jobs share a config file.
    pub(crate) name_override: Option<String>,
}

/// A config-declared job, validated and built but not yet launched: the
/// pipeline's worker threads are live and parked, the policy set is
/// assembled, and the caller decides who drives it — [`run_job`] launches
/// it onto its own runtime thread, the [`server::JobServer`] adopts it
/// onto the shared one.
pub(crate) struct PreparedJob {
    pub(crate) job: Job<JobPayload, JobPayload>,
    pub(crate) policies: Vec<Box<dyn JobPolicy>>,
    pub(crate) recovery_log: Option<RecoveryLog>,
    pub(crate) name: String,
    pub(crate) n_stages: usize,
    /// Σ per-stage max parallelism — the most the job could ever hold.
    pub(crate) max_cores: usize,
}

/// The shared config→job construction path behind [`run_job`] and the
/// server's `[job.<name>]` sub-configs: parse + validate the [`JobSpec`]
/// and its `[schedule.<stage>]`/`[faults]` sections, assemble the policy
/// set, plan placement, build the topology, and hand back the un-launched
/// [`Job`] — every error fires BEFORE any runtime thread exists.
pub(crate) fn prepare_job(cfg: &Config, opts: JobPrepOptions) -> Result<PreparedJob, JobError> {
    check_job_section_keys(cfg)?;
    let mut spec = JobSpec::from_config(cfg)?;
    if let Some(name) = &opts.name_override {
        spec.name = name.clone();
    }
    if let Some(socket) = opts.socket {
        for st in &mut spec.stages {
            st.socket.get_or_insert(socket);
        }
    }
    let schedules = stage_schedules(cfg, &spec)?;
    // resolve the generator BEFORE spawning anything — NoSource is a
    // pure config error and must not cost a topology spawn + teardown
    let source =
        JobSource::for_kind(spec.source_kind, cfg).ok_or(JobError::NoSource(spec.source_kind))?;
    let schedule = RateSchedule::from_config(cfg);
    // a step at/after the run's end would silently never execute
    // (policies stop at end-of-stream) — reject it like every other
    // malformed schedule input
    let duration = schedule.duration_s();
    for sch in &schedules {
        let name = &spec.stages[sch.stage].name;
        for (field, ats) in [
            ("scale", sch.scale.iter().map(|&(at, _)| at).collect::<Vec<_>>()),
            ("rate", sch.rate.iter().map(|&(at, _)| at).collect::<Vec<_>>()),
        ] {
            if let Some(&at) = ats.iter().find(|&&at| at >= duration) {
                return Err(JobError::BadValue {
                    key: format!("schedule.{name}.{field}"),
                    msg: format!(
                        "step at second {at} is at/after the run's end \
                         ({duration} s) — it would never execute"
                    ),
                });
            }
        }
    }
    // `[faults]`: parse + validate the scripted fault plan against the
    // declared stages — same arrow idiom, same fail-before-launch
    // contract as `[schedule.*]`
    let faults = FaultsConfig::from_config(cfg);
    let fault_plan = if cfg.get("faults.steps").is_some() {
        let items = cfg
            .str_list("faults.steps")
            .map_err(|e| JobError::BadValue { key: "faults.steps".into(), msg: e.to_string() })?;
        let stages: Vec<(&str, usize)> =
            spec.stages.iter().map(|s| (s.name.as_str(), s.max)).collect();
        let plan = FaultPlan::parse(&items, &stages)
            .map_err(|msg| JobError::BadValue { key: "faults.steps".into(), msg })?;
        if let Some(step) = plan.steps.iter().find(|s| s.at >= duration) {
            return Err(JobError::BadValue {
                key: "faults.steps".into(),
                msg: format!(
                    "fault at second {} is at/after the run's end ({duration} s) — \
                     it would never fire",
                    step.at
                ),
            });
        }
        Some(plan)
    } else {
        None
    };
    let batch = BatchTuning::from_config(cfg);
    let n_stages = spec.stages.len();
    let adaptive = if batch.adaptive { Some(AdaptiveBatch::from(&batch)) } else { None };
    let period = cfg.int_or("elastic.period_s", 1).max(1) as u32;

    // assemble the policy set BEFORE launching — a bad `[elastic]`
    // controller choice must not cost a topology spawn + teardown
    let mut policies: Vec<Box<dyn JobPolicy>> = Vec::new();
    for sch in schedules {
        if !sch.scale.is_empty() {
            policies.push(Box::new(ScriptedScalePolicy::counts(sch.stage, sch.scale)));
        }
        if !sch.rate.is_empty() {
            policies.push(Box::new(RateStepPolicy::new(sch.rate)));
        }
    }
    if let Some(bounds) = adaptive {
        for k in 0..n_stages {
            policies.push(Box::new(AdaptiveBatchPolicy::new(k, bounds, period)));
        }
    }
    // server mode replaces the job's own controller with the fleet-level
    // arbitration — "none" here, whatever the sub-config says
    let controller_kind =
        if opts.skip_elastic_controller { "none" } else { cfg.str_or("elastic.controller", "none") };
    match controller_kind {
        "none" => {}
        "dag" => {
            let dc = DagController::new(cfg.int_or("elastic.cores", 8).max(1) as usize)
                .with_thresholds(
                    cfg.int_or("elastic.grow_backlog", 4096).max(1) as u64,
                    cfg.int_or("elastic.shrink_backlog", 64).max(0) as u64,
                )
                .with_cooldown(cfg.int_or("elastic.cooldown_ticks", 1).max(0) as u32);
            policies.push(Box::new(DagControllerPolicy::new(dc, period)));
        }
        kind if kind == "reactive" || kind == "proactive" => {
            // per-stage controllers, each modelled on this machine's
            // calibrated costs and the stage's own window/parallelism
            let cal = calibrate();
            for (k, st) in spec.stages.iter().enumerate() {
                let model = JoinCostModel::new(
                    cal.cmp_per_sec / st.max.max(1) as f64,
                    st.params.ws_ms as f64 / 1e3,
                );
                policies.push(Box::new(ControllerPolicy::new(
                    k,
                    controller_from_config(cfg, kind, model),
                    period,
                )));
            }
        }
        other => {
            return Err(JobError::BadValue {
                key: "elastic.controller".into(),
                msg: format!("unknown controller `{other}` (expected none/reactive/proactive/dag)"),
            })
        }
    }
    // chaos + healing ride the same policy loop as everything else: the
    // fault script fires through `inject_fault`, and (unless opted out)
    // a supervisor watches the health detector and heals through the
    // ordinary reconfiguration path, logging one RecoveryTicket per fault
    if let Some(plan) = fault_plan {
        policies.push(Box::new(FaultPolicy::new(plan)));
    }
    let recovery_log = if faults.enabled && faults.supervise {
        let log = RecoveryLog::new();
        policies.push(Box::new(SupervisorPolicy::new(SupervisorConfig::default(), log.clone())));
        Some(log)
    } else {
        None
    };

    // `[placement]`: plan core assignments against the live topology map
    // BEFORE building, so workers self-pin as they spawn and gate memory
    // first-touches on the owning socket
    let placement = PlacementConfig::from_config(cfg);
    let plan = if placement.enabled {
        Some(spec.placement_plan(&CoreMap::discover())?)
    } else {
        None
    };
    let built = spec.build_planned(plan.as_ref().filter(|_| placement.pin_workers))?;
    let max_ws = spec.stages.iter().map(|s| s.params.ws_ms).max().unwrap_or(1_000);
    let mut time_scale = cfg.float_or("run.time_scale", 1.0).max(1e-6);
    if let Some(ms) = opts.budget_ms {
        time_scale = time_scale.max(schedule.duration_s() as f64 * 1000.0 / ms.max(1) as f64);
    }
    let job = Job::new(built.pipeline, source).with_config(LaunchConfig {
        name: spec.name.clone(),
        stage_names: built.stage_names.clone(),
        schedule,
        time_scale,
        flush_slack_ms: cfg.int_or("run.flush_slack_ms", max_ws + 10_000),
        drain: Duration::from_millis(cfg.int_or("run.drain_ms", 500).max(0) as u64),
        ingress_batch: batch.ingress,
        capture_egress: false,
        pin_core: plan
            .as_ref()
            .and_then(|p| p.runtime_core)
            .filter(|_| placement.pin_runtime),
        stall_after_ms: faults.stall_after_ms,
        ..LaunchConfig::default()
    });
    Ok(PreparedJob {
        job,
        policies,
        recovery_log,
        name: spec.name.clone(),
        n_stages,
        max_cores: spec.stages.iter().map(|s| s.max).sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::elastic::{JoinCostModel, ReactiveController, Thresholds};
    use crate::workloads::nyse::NyseConfig;
    use crate::workloads::{hedge_join_op, trade_fanout_op};

    #[test]
    fn batch_tuning_reaches_engine_options() {
        let cfg = crate::config::Config::parse("[batch]\nworker = 32\nqueue = 16").unwrap();
        let t = crate::config::BatchTuning::from_config(&cfg);
        let v = VsnOptions::default().with_batch(&t);
        assert_eq!(v.worker_batch, 32);
        let s = crate::engine::SnOptions::default().with_batch(&t);
        assert_eq!(s.batch, 16);
    }

    #[test]
    fn adaptive_batch_policy_clamps_and_is_monotone() {
        let b = AdaptiveBatch { min: 16, max: 256 };
        assert_eq!(adaptive_worker_batch(0, b), 16, "cold stage flushes small");
        assert_eq!(adaptive_worker_batch(63, b), 16);
        assert_eq!(adaptive_worker_batch(256, b), 64);
        assert_eq!(adaptive_worker_batch(1 << 20, b), 256, "hot stage batches large");
        let mut last = 0;
        for backlog in [0u64, 10, 100, 1_000, 10_000, 100_000] {
            let v = adaptive_worker_batch(backlog, b);
            assert!(v >= last, "policy must be monotone in backlog");
            last = v;
        }
        // degenerate bounds can never stall a worker loop
        assert_eq!(adaptive_worker_batch(0, AdaptiveBatch { min: 0, max: 0 }), 1);
    }

    #[test]
    fn adaptive_batch_retunes_stages_from_backlog() {
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, worker_batch: 128, ..Default::default() },
        )
        .build();
        assert_eq!(pipeline.stages[0].worker_batch(), 128);
        let bounds = AdaptiveBatch { min: 8, max: 64 };
        let r = run_pipeline(
            pipeline,
            PipelineRunConfig {
                schedule: RateSchedule::constant(3, 400.0),
                time_scale: 3.0,
                stages: vec![StageRunConfig {
                    adaptive_batch: Some(bounds),
                    ..Default::default()
                }],
                ..Default::default()
            },
            SjGen::new(5, 1.0),
        )
        .unwrap();
        // the first controller tick fires after the first sample; every
        // later sample must reflect a batch re-derived inside the clamp
        // (the configured 128 sits outside it on purpose)
        let samples = &r.stages[0].samples;
        assert_eq!(samples.len(), 3);
        assert!(
            samples[1..].iter().all(|s| (8..=64).contains(&s.worker_batch)),
            "worker batch not re-derived: {:?}",
            samples.iter().map(|s| s.worker_batch).collect::<Vec<_>>()
        );
    }

    #[test]
    fn run_job_drives_a_declarative_two_stage_job() {
        let cfg = crate::config::Config::parse(
            r#"
name = "wc-smoke"
[topology]
stages = ["tok", "count"]
[stage.tok]
operator = "tweet-tokenize"
max = 2
[stage.count]
operator = "word-count"
inputs = ["tok"]
ws_ms = 500
max = 2
[run]
duration_s = 2
rate = 300
time_scale = 4
[batch]
adaptive = true
"#,
        )
        .unwrap();
        let out = run_job(&cfg, None).unwrap();
        assert_eq!(out.name, "wc-smoke");
        assert_eq!(out.stage_names, vec!["tok", "count"]);
        assert_eq!(out.result.stages.len(), 2);
        assert_eq!(out.result.stages[0].samples.len(), 2);
        assert!(
            out.result.egress_count > 0
                || out
                    .result
                    .stages
                    .iter()
                    .any(|s| s.samples.iter().any(|x| x.out_tps > 0.0)),
            "no data moved through the config-built pipeline"
        );
    }

    #[test]
    fn run_job_with_placement_enabled_pins_and_completes() {
        // core 0 always exists (CoreMap::discover never returns an empty
        // map), so this config is machine-independent
        let cfg = crate::config::Config::parse(
            r#"
name = "wc-pinned"
[topology]
stages = ["tok", "count"]
[stage.tok]
operator = "tweet-tokenize"
max = 2
cores = [0]
[stage.count]
operator = "word-count"
inputs = ["tok"]
ws_ms = 500
max = 2
[run]
duration_s = 2
rate = 300
time_scale = 4
[placement]
enabled = true
"#,
        )
        .unwrap();
        let out = run_job(&cfg, None).unwrap();
        assert_eq!(out.result.stages.len(), 2);
        assert!(
            out.result.egress_count > 0
                || out
                    .result
                    .stages
                    .iter()
                    .any(|s| s.samples.iter().any(|x| x.out_tps > 0.0)),
            "no data moved through the pinned pipeline"
        );
    }

    #[test]
    fn run_job_rejects_unknown_controller() {
        let cfg = crate::config::Config::parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"tweet-tokenize\"\n\
             [elastic]\ncontroller = \"warp\"",
        )
        .unwrap();
        match run_job(&cfg, None) {
            Err(JobError::BadValue { key, .. }) => assert_eq!(key, "elastic.controller"),
            other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn run_job_rejects_typod_section_keys() {
        const STAGES: &str = "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"tweet-tokenize\"\n";
        let bad_key = |body: &str| {
            let cfg = crate::config::Config::parse(&format!("{STAGES}{body}")).unwrap();
            match run_job(&cfg, None) {
                Err(JobError::BadValue { key, .. }) => key,
                other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
            }
        };
        // typo'd key inside a known section: must not silently become
        // the 30 s default schedule
        assert_eq!(bad_key("[run]\nduraton_s = 60"), "run.duraton_s");
        // typo'd SECTION name: must not silently drop the whole section
        assert_eq!(bad_key("[elastc]\ncontroller = \"dag\""), "elastc.controller");
        // right key, wrong value type: must not silently use the default
        assert_eq!(bad_key("[run]\nrate = \"fast\""), "run.rate");
        assert_eq!(bad_key("[run]\nduration_s = 2.5"), "run.duration_s");
        assert_eq!(bad_key("[batch]\nadaptive = 1"), "batch.adaptive");
        assert_eq!(bad_key("[placement]\npin_wrokers = true"), "placement.pin_wrokers");
        assert_eq!(bad_key("[placement]\nenabled = 1"), "placement.enabled");
        // numeric widening still allowed: an int where a float is expected
        let cfg = crate::config::Config::parse(&format!(
            "{STAGES}[run]\nduration_s = 1\nrate = 200\ntime_scale = 4"
        ))
        .unwrap();
        assert!(run_job(&cfg, None).is_ok(), "int-for-float must stay accepted");
    }

    #[test]
    fn harness_steady_run_produces_samples() {
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::constant(4, 500.0),
            time_scale: 4.0, // 4 event-seconds in ~1 wall-second
            initial: 2,
            max: 4,
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert_eq!(r.samples.len(), 4);
        assert!(r.egress_count > 0 || r.samples.iter().any(|s| s.cmp_per_s > 0.0));
        assert!(r.samples.iter().all(|s| s.threads == 2));
    }

    #[test]
    fn harness_controller_provisions_under_ramp() {
        // calibrate a model, then drive well past 1-thread capacity
        let model = JoinCostModel::new(5e5, 1.0); // deliberately small capacity
        let ctl = ReactiveController::new(model, Thresholds::default()).with_cooldown(1);
        let cfg = JoinRunConfig {
            ws_ms: 1000,
            schedule: RateSchedule::step(6, 2, 200.0, 1500.0),
            time_scale: 3.0,
            initial: 1,
            max: 4,
            controller: Some(Box::new(ctl)),
            ..Default::default()
        };
        let r = run_elastic_join(cfg);
        assert!(!r.reconfigs.is_empty(), "controller should have reconfigured");
        assert!(r.samples.last().unwrap().threads > 1);
    }

    #[test]
    fn degenerate_topologies_are_typed_errors_not_panics() {
        // no egress reader: the sink gate would fill with nobody draining
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, egress_readers: 0, ..Default::default() },
        )
        .build();
        match run_pipeline(pipeline, PipelineRunConfig::default(), SjGen::new(1, 1.0)) {
            Err(HarnessError::NoEgress) => {}
            other => panic!("expected NoEgress, got {:?}", other.map(|_| ()).err()),
        }
        // more stage configs than stages: scripted reconfigs would drop
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, ..Default::default() },
        )
        .build();
        let cfg = PipelineRunConfig {
            stages: vec![StageRunConfig::default(), StageRunConfig::default()],
            ..Default::default()
        };
        match run_pipeline(pipeline, cfg, SjGen::new(1, 1.0)) {
            Err(HarnessError::ExtraStageConfigs { given: 2, stages: 1 }) => {}
            other => panic!("expected ExtraStageConfigs, got {:?}", other.map(|_| ()).err()),
        }
        // scripted reconfig to an empty instance set: rejected up front,
        // not a mid-run panic from the policy loop
        let pipeline = PipelineBuilder::new(
            q3_operator(1_000, 8),
            VsnOptions { initial: 1, max: 2, ..Default::default() },
        )
        .build();
        let cfg = PipelineRunConfig {
            stages: vec![StageRunConfig {
                manual_reconfigs: vec![(1, Vec::new())],
                ..Default::default()
            }],
            ..Default::default()
        };
        match run_pipeline(pipeline, cfg, SjGen::new(1, 1.0)) {
            Err(HarnessError::EmptyReconfigSet { stage: 0 }) => {}
            other => panic!("expected EmptyReconfigSet, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn pipeline_harness_runs_two_stages_with_manual_reconfigs() {
        // NYSE fan-out → hedge join, reconfiguring EACH stage once
        let pipeline = PipelineBuilder::new(
            trade_fanout_op(64),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .stage(
            hedge_join_op(1_000, 32),
            VsnOptions { initial: 1, max: 2, gate_capacity: 4096, ..Default::default() },
        )
        .build();
        let source = TradeStream::new(&NyseConfig::default(), 400.0);
        let r = run_pipeline(
            pipeline,
            PipelineRunConfig {
                schedule: RateSchedule::constant(4, 400.0),
                time_scale: 4.0,
                stages: vec![
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                    StageRunConfig {
                        manual_reconfigs: vec![(2, vec![0, 1])],
                        ..Default::default()
                    },
                ],
                flush_slack_ms: 5_000,
                drain: Duration::from_millis(500),
                ..Default::default()
            },
            source,
        )
        .unwrap();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages[0].samples.len(), 4);
        assert_eq!(r.stages[1].samples.len(), 4);
        // both stages completed their independent reconfigurations
        assert_eq!(r.stages[0].reconfigs.len(), 1, "stage 0 reconfig lost");
        assert_eq!(r.stages[1].reconfigs.len(), 1, "stage 1 reconfig lost");
        assert_eq!(r.stages[0].samples.last().unwrap().threads, 2);
        assert_eq!(r.stages[1].samples.last().unwrap().threads, 2);
        // data flowed through the shared gate into stage 2
        assert!(r.stages[1].samples.iter().any(|s| s.in_tps > 0.0));
    }

    const SCHED_STAGES: &str = "[topology]\nstages = [\"tok\", \"count\"]\n\
        [stage.tok]\noperator = \"tweet-tokenize\"\nmax = 3\n\
        [stage.count]\noperator = \"word-count\"\ninputs = [\"tok\"]\nws_ms = 500\nmax = 2\n";

    #[test]
    fn stage_schedules_parse_and_validate() {
        let parse = |extra: &str| {
            let cfg =
                crate::config::Config::parse(&format!("{SCHED_STAGES}{extra}")).unwrap();
            let spec = JobSpec::from_config(&cfg).unwrap();
            stage_schedules(&cfg, &spec)
        };
        // happy path: steps sorted by second, per stage
        let s = parse("[schedule.tok]\nscale = [\"4 -> 2\", \"1 -> 3\"]\nrate = [\"2 -> 800\"]")
            .unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].scale, vec![(1, 3), (4, 2)]);
        assert_eq!(s[0].rate, vec![(2, 800.0)]);
        // `tok` sorts first topologically, so its index is 0
        assert_eq!(s[0].stage, 0);

        let bad_key = |extra: &str| match parse(extra) {
            Err(JobError::BadValue { key, .. }) => key,
            other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
        };
        // undeclared stage: must not be silently dropped
        assert_eq!(bad_key("[schedule.ghost]\nscale = [\"1 -> 2\"]"), "schedule.ghost.scale");
        // typo'd field
        assert_eq!(bad_key("[schedule.tok]\nscael = [\"1 -> 2\"]"), "schedule.tok.scael");
        // malformed step
        assert_eq!(bad_key("[schedule.tok]\nscale = [\"soon: 2\"]"), "schedule.tok.scale");
        // scale target outside the stage's pool
        assert_eq!(bad_key("[schedule.tok]\nscale = [\"1 -> 9\"]"), "schedule.tok.scale");
        assert_eq!(bad_key("[schedule.tok]\nscale = [\"1 -> 1.5\"]"), "schedule.tok.scale");
    }

    #[test]
    fn run_job_rejects_schedule_steps_past_the_run_end() {
        // duration_s = 2 but the step is due at second 5: it would
        // silently never execute, so it must be a typed error
        let cfg = crate::config::Config::parse(&format!(
            "{SCHED_STAGES}[schedule.tok]\nscale = [\"5 -> 2\"]\n[run]\nduration_s = 2\n"
        ))
        .unwrap();
        match run_job(&cfg, None) {
            Err(JobError::BadValue { key, msg }) => {
                assert_eq!(key, "schedule.tok.scale");
                assert!(msg.contains("never execute"), "{msg}");
            }
            other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn run_job_rejects_bad_fault_configs() {
        let bad = |faults: &str| {
            let cfg = crate::config::Config::parse(&format!(
                "{SCHED_STAGES}[run]\nduration_s = 2\n[faults]\n{faults}"
            ))
            .unwrap();
            match run_job(&cfg, None) {
                Err(JobError::BadValue { key, msg }) => (key, msg),
                other => panic!("expected BadValue, got {:?}", other.map(|_| ()).err()),
            }
        };
        // unknown stage in a step: a script that silently skips a fault
        // would make the chaos run look healthier than it is
        let (key, msg) = bad("steps = [\"1 -> kill ghost:0\"]");
        assert_eq!(key, "faults.steps");
        assert!(msg.contains("unknown stage"), "{msg}");
        // a fault at/after the run's end would never fire
        let (_, msg) = bad("steps = [\"5 -> kill tok:0\"]");
        assert!(msg.contains("never fire"), "{msg}");
        // typo'd key inside [faults]: same contract as every section
        let (key, _) = bad("stpes = [\"1 -> kill tok:0\"]");
        assert_eq!(key, "faults.stpes");
        // wrong value shape
        let (key, _) = bad("steps = \"1 -> kill tok:0\"");
        assert_eq!(key, "faults.steps");
    }

    #[test]
    fn run_job_chaos_kill_heals_and_reports_mttr() {
        // one worker of a two-worker stage is killed mid-run; the
        // supervisor must evict it through an ordinary epoch switch,
        // re-grow, and report the measured detection→healed latency
        let cfg = crate::config::Config::parse(
            r#"
name = "wc-chaos"
[topology]
stages = ["tok", "count"]
[stage.tok]
operator = "tweet-tokenize"
initial = 2
max = 3
[stage.count]
operator = "word-count"
inputs = ["tok"]
ws_ms = 500
max = 2
[run]
duration_s = 3
rate = 300
time_scale = 3
[faults]
steps = ["1 -> kill tok:0"]
"#,
        )
        .unwrap();
        let out = run_job(&cfg, None).unwrap();
        assert!(!out.degraded, "a single kill with a live survivor must heal");
        assert_eq!(out.recoveries.len(), 1, "exactly one fault, one recovery ticket");
        let r = &out.recoveries[0];
        assert_eq!((r.stage(), r.worker()), (0, 0));
        assert!(
            r.mttr_ms().is_some(),
            "recovery ticket must resolve with an MTTR, got {:?}",
            r.outcome()
        );
        assert!(mttr_sane(r.mttr_ms().unwrap()));
    }

    fn mttr_sane(ms: f64) -> bool {
        ms.is_finite() && ms >= 0.0
    }

    #[test]
    fn run_job_executes_stage_schedules_through_the_handle() {
        let cfg = crate::config::Config::parse(&format!(
            "name = \"wc-scripted\"\n{SCHED_STAGES}\
             [schedule.tok]\nscale = [\"1 -> 3\"]\nrate = [\"1 -> 500\"]\n\
             [schedule.count]\nscale = [\"1 -> 2\"]\n\
             [run]\nduration_s = 3\nrate = 300\ntime_scale = 3\n"
        ))
        .unwrap();
        let out = run_job(&cfg, None).unwrap();
        assert_eq!(out.tickets.len(), 2, "one ticket per scripted scale step");
        for t in &out.tickets {
            let ms = t.latency_ms();
            assert!(ms.is_some(), "scripted reconfig for stage {} unresolved", t.stage());
            assert!(ms.unwrap() >= 0.0);
        }
        // the steps actually moved parallelism
        assert_eq!(out.result.stages[0].samples.last().unwrap().threads, 3);
        assert_eq!(out.result.stages[1].samples.last().unwrap().threads, 2);
    }
}
