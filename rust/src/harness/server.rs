//! The multi-job server: N declarative jobs on ONE shared runtime
//! thread, under ONE global core budget.
//!
//! STRETCH's elasticity story is per-job — a topology stretches across
//! however many cores its controller grants (§8.4-§8.5). A real
//! deployment runs *several* such jobs on one machine, and that is where
//! virtual shared-nothing earns its keep twice over: because
//! reconfiguration moves no state and completes in milliseconds
//! ([`ReconfigTicket`]), cores can be re-arbitrated *between* jobs at
//! the same cadence a single job scales, with the same mechanism. This
//! module is that fleet layer:
//!
//! * **One runtime thread for N jobs.** [`Job::launch`] gives every job
//!   its own drive thread; the server instead adopts each launched
//!   job's [`JobTicker`] onto a single `stretch-server` loop that
//!   interleaves `tick()`s at the shared [`RUNTIME_TICK`] cadence — the
//!   runtime overhead of a job is a list entry, not a thread.
//! * **A global core budget.** A fleet-level
//!   [`ServerController`] (the [`crate::elastic::DagController`] wave
//!   generalized across jobs) re-runs shrink-then-grant over every
//!   *(job, stage)* pair each period: weighted by [`JobShare::weight`],
//!   floored by [`JobShare::min_cores`], forced-fit when the fleet is
//!   over budget. Every cross-job move is an ordinary epoch
//!   reconfiguration on some stage — no state transfer, ever.
//! * **Admission control.** [`JobServer::submit`] refuses a job whose
//!   minimum footprint (one core per stage, raised by `min_cores`)
//!   cannot fit in the unclaimed budget, *before* the job is adopted —
//!   a refused job never competes for cores.
//! * **An aggregate surface.** [`JobServer::metrics`] rolls every live
//!   job's [`JobMetrics`] (and open [`RecoveryTicket`]s) into one
//!   [`ServerMetrics`]; [`JobServer::rebalances`] exposes every
//!   cross-job reconfiguration the arbiter issued, with its measured
//!   latency, for `BENCH_server.json`.
//!
//! The declarative face is [`serve_from_config`]: a `[server]` section
//! (budget, arbitration period, thresholds) plus one `[job.<name>]`
//! section per job referencing an ordinary single-job config — the
//! `stretch serve` CLI verb wraps it.

use super::handle::{JobTicker, StopGuard, RUNTIME_TICK};
use super::policy::observation;
use super::{
    prepare_job, Job, JobCtl, JobHandle, JobMetrics, JobPhase, JobPolicy, JobPrepOptions,
    JobRunOutcome, KeyKind, ReconfigTicket, RecoveryLog, RecoveryTicket, QUIESCE_CAP,
};
use crate::config::{Config, ConfigValue, ServerConfig};
use crate::elastic::{Decision, JobShare, Observation, ServerController};
use crate::engine::job::JobError;
use crate::tuple::Tuple;
use crate::workloads::registry::JobPayload;
use std::collections::BTreeSet;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Opaque identifier of a submitted job, unique within its server.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(u64);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Why [`JobServer::submit`] refused a job. Admission failures are
/// *pre-launch* by contract: a rejected job's pipeline is torn down
/// before this value is returned, and nothing of it reaches the runtime
/// loop or the core arbiter.
#[derive(Clone, Debug)]
pub enum Admission {
    /// The job's minimum footprint does not fit the unclaimed budget
    /// (or the built topology could not be driven at all).
    Rejected { reason: String },
}

impl fmt::Display for Admission {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Admission::Rejected { reason } => write!(f, "admission rejected: {reason}"),
        }
    }
}

impl std::error::Error for Admission {}

/// One cross-job reconfiguration the server's core arbiter issued — an
/// ordinary epoch reconfiguration on one stage of one job, observable
/// through its [`ReconfigTicket`] like any handle-issued scale.
#[derive(Clone)]
pub struct Rebalance {
    pub job: JobId,
    pub job_name: String,
    /// Stage index within the job (topological order).
    pub stage: usize,
    pub ticket: ReconfigTicket,
}

/// One live job's slice of the aggregate view.
pub struct ServerJobView {
    pub id: JobId,
    pub name: String,
    pub metrics: JobMetrics,
    /// Recovery tickets the job's supervisor has opened so far (empty
    /// when the job runs unsupervised).
    pub recoveries: Vec<RecoveryTicket>,
}

/// Point-in-time roll-up over every job still running on the server.
pub struct ServerMetrics {
    /// The global core budget the arbiter enforces.
    pub budget: usize,
    /// Σ active instances across every live job and stage.
    pub used_cores: usize,
    pub jobs: Vec<ServerJobView>,
}

/// Everything a finished server run produced: one [`JobRunOutcome`] per
/// job (submission order) plus every cross-job rebalance the arbiter
/// issued over the run's lifetime.
pub struct ServerOutcome {
    pub budget: usize,
    pub jobs: Vec<(JobId, JobRunOutcome)>,
    pub rebalances: Vec<Rebalance>,
}

/// A job as the server *loop* owns it: the type-erased ticker it paces,
/// the control surface and policies it drives, and the share/footprint
/// the arbiter and admission ledger account it under.
struct ServerJob {
    id: JobId,
    name: String,
    ctl: JobCtl,
    rt: Box<dyn JobTicker>,
    policies: Vec<Box<dyn JobPolicy>>,
    share: JobShare,
    /// Cores held against the admission ledger; released on retirement.
    footprint: usize,
    /// Wakes the job's waiters even if the server loop panics.
    _guard: StopGuard,
}

/// State shared between the caller-facing [`JobServer`] and its loop.
struct ServerShared {
    /// Freshly submitted jobs, awaiting adoption by the loop.
    inbox: Mutex<Vec<ServerJob>>,
    /// Server-wide stop: the loop force-stops every remaining job, then
    /// exits once the fleet has retired.
    stop: AtomicBool,
    /// Admission ledger: Σ footprint of every admitted, un-retired job.
    /// Incremented by `submit` (under the lock that decides admission),
    /// decremented by the loop when it retires a job.
    committed: Mutex<usize>,
    /// Every cross-job reconfiguration the arbiter issued.
    rebalances: Mutex<Vec<Rebalance>>,
}

/// A job as the *caller* keeps it: the payload-typed handle (egress,
/// shutdown) plus the recovery log to fold into its final outcome.
struct JobEntry {
    id: JobId,
    name: String,
    handle: JobHandle<JobPayload>,
    recovery: Option<RecoveryLog>,
    /// Cached once the job is stopped — a second stop returns this.
    outcome: Option<JobRunOutcome>,
}

/// A multi-job runtime: submit jobs against a global core budget, read
/// the aggregate view, stop jobs individually or shut the fleet down.
/// All methods are `&self`; the server is shareable across threads.
pub struct JobServer {
    budget: usize,
    period: Duration,
    grow_backlog: u64,
    shrink_backlog: u64,
    cooldown_ticks: u32,
    shared: Arc<ServerShared>,
    /// The `stretch-server` loop thread, spawned on first submit.
    thread: Mutex<Option<JoinHandle<()>>>,
    jobs: Mutex<Vec<JobEntry>>,
    next_id: AtomicU64,
}

impl JobServer {
    /// A server arbitrating `budget` cores, with default thresholds
    /// (grow ≥ 4096 backlog, shrink ≤ 64, 250 ms waves, 1-wave
    /// per-job cooldown).
    pub fn new(budget: usize) -> Self {
        JobServer {
            budget: budget.max(1),
            period: Duration::from_millis(250),
            grow_backlog: 4096,
            shrink_backlog: 64,
            cooldown_ticks: 1,
            shared: Arc::new(ServerShared {
                inbox: Mutex::new(Vec::new()),
                stop: AtomicBool::new(false),
                committed: Mutex::new(0),
                rebalances: Mutex::new(Vec::new()),
            }),
            thread: Mutex::new(None),
            jobs: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(0),
        }
    }

    /// Arbitration wave period (builder; set before the first submit).
    pub fn with_period(mut self, period: Duration) -> Self {
        self.period = period.max(Duration::from_millis(1));
        self
    }

    /// Backlog thresholds of the fleet arbiter (builder).
    pub fn with_thresholds(mut self, grow_backlog: u64, shrink_backlog: u64) -> Self {
        self.grow_backlog = grow_backlog.max(1);
        self.shrink_backlog = shrink_backlog;
        self
    }

    /// Per-job wave cooldown of the fleet arbiter (builder).
    pub fn with_cooldown(mut self, ticks: u32) -> Self {
        self.cooldown_ticks = ticks;
        self
    }

    /// The global core budget this server arbitrates.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Submit a built job under `share`. Admission: the job's minimum
    /// footprint — one core per stage, raised to [`JobShare::min_cores`]
    /// — must fit in the unclaimed budget, else the job is torn down and
    /// refused. On admission the job is adopted by the shared runtime
    /// loop (no per-job thread) and competes for cores from the next
    /// arbitration wave on.
    pub fn submit(
        &self,
        job: Job<JobPayload, JobPayload>,
        share: JobShare,
    ) -> Result<JobId, Admission> {
        self.submit_with_policies(job, share, Vec::new(), None)
    }

    /// [`submit`](Self::submit) with a policy set (schedules, faults,
    /// supervision) ticked by the server loop while the job runs, and an
    /// optional recovery log folded into the job's final outcome.
    pub fn submit_with_policies(
        &self,
        job: Job<JobPayload, JobPayload>,
        share: JobShare,
        policies: Vec<Box<dyn JobPolicy>>,
        recovery: Option<RecoveryLog>,
    ) -> Result<JobId, Admission> {
        let depth = job.pipeline.depth();
        let footprint = share.min_cores.max(depth).max(1);
        {
            let mut committed = self.shared.committed.lock().unwrap();
            if *committed + footprint > self.budget {
                let free = self.budget.saturating_sub(*committed);
                drop(committed);
                // refused before adoption: park nothing, leak nothing
                let mut job = job;
                job.pipeline.shutdown();
                return Err(Admission::Rejected {
                    reason: format!(
                        "job needs {footprint} core(s) at minimum (min_cores {}, {} stage(s) \
                         ≥ 1 core each) but only {free} of the {}-core budget remain",
                        share.min_cores, depth, self.budget
                    ),
                });
            }
            *committed += footprint;
        }
        let name = job.cfg.name.clone();
        let (handle, rt) = match job.launch_parts() {
            Ok(parts) => parts,
            Err(e) => {
                *self.shared.committed.lock().unwrap() -= footprint;
                return Err(Admission::Rejected { reason: format!("launch failed: {e}") });
            }
        };
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let guard = StopGuard::new(rt.shared());
        self.shared.inbox.lock().unwrap().push(ServerJob {
            id,
            name: name.clone(),
            ctl: handle.ctl(),
            rt: Box::new(rt),
            policies,
            share,
            footprint,
            _guard: guard,
        });
        self.jobs.lock().unwrap().push(JobEntry {
            id,
            name,
            handle,
            recovery,
            outcome: None,
        });
        self.ensure_started();
        Ok(id)
    }

    /// Stop one job: drain it (wait for quiesce, capped at
    /// [`QUIESCE_CAP`] so a wedged job cannot hold the server hostage),
    /// then shut it down and return its outcome. The loop retires the
    /// job and releases its cores back to the admission ledger.
    /// Idempotent — a second stop returns the cached outcome. `None`
    /// for an unknown id.
    pub fn stop(&self, id: JobId) -> Option<JobRunOutcome> {
        let ctl = {
            let mut jobs = self.jobs.lock().unwrap();
            let e = jobs.iter_mut().find(|e| e.id == id)?;
            if let Some(out) = &e.outcome {
                return Some(out.clone());
            }
            e.handle.ctl()
        };
        // drain OUTSIDE the registry lock: metrics()/submit() stay
        // responsive while this job winds down
        let _ = ctl.await_quiesce_timeout(QUIESCE_CAP);
        let mut jobs = self.jobs.lock().unwrap();
        let e = jobs.iter_mut().find(|e| e.id == id)?;
        let mut out = e.handle.shutdown();
        if let Some(log) = &e.recovery {
            log.close_unresolved();
            out.recoveries = log.tickets();
            out.degraded = log.degraded();
        }
        e.outcome = Some(out.clone());
        Some(out)
    }

    /// Aggregate view over every job still running: per-job
    /// [`JobMetrics`] and open recovery tickets, plus the fleet-wide
    /// core usage against the budget.
    pub fn metrics(&self) -> ServerMetrics {
        let jobs = self.jobs.lock().unwrap();
        let mut views = Vec::new();
        let mut used = 0usize;
        for e in jobs.iter() {
            if e.outcome.is_some() {
                continue;
            }
            let m = e.handle.sample();
            used += m.stages.iter().map(|s| s.active.len()).sum::<usize>();
            let recoveries = e.recovery.as_ref().map(|l| l.tickets()).unwrap_or_default();
            views.push(ServerJobView {
                id: e.id,
                name: e.name.clone(),
                metrics: m,
                recoveries,
            });
        }
        ServerMetrics { budget: self.budget, used_cores: used, jobs: views }
    }

    /// Drain a job's captured egress (jobs launched with
    /// `capture_egress`; empty otherwise or for an unknown id). Works
    /// after [`stop`](Self::stop) — the handle retains the tail.
    pub fn take_egress(&self, id: JobId) -> Vec<Tuple<JobPayload>> {
        let jobs = self.jobs.lock().unwrap();
        match jobs.iter().find(|e| e.id == id) {
            Some(e) => e.handle.take_egress(),
            None => Vec::new(),
        }
    }

    /// Every cross-job reconfiguration the arbiter has issued so far.
    pub fn rebalances(&self) -> Vec<Rebalance> {
        self.shared.rebalances.lock().unwrap().clone()
    }

    /// Shut the whole fleet down: stop every remaining job (those
    /// already [`stop`](Self::stop)ped contribute their cached
    /// outcomes), retire the loop thread, and return the per-job
    /// outcomes plus the full rebalance record.
    pub fn shutdown(self) -> ServerOutcome {
        let mut out_jobs = Vec::new();
        {
            let mut jobs = self.jobs.lock().unwrap();
            for e in jobs.iter_mut() {
                let out = match e.outcome.take() {
                    Some(o) => o,
                    None => {
                        let mut o = e.handle.shutdown();
                        if let Some(log) = &e.recovery {
                            log.close_unresolved();
                            o.recoveries = log.tickets();
                            o.degraded = log.degraded();
                        }
                        o
                    }
                };
                out_jobs.push((e.id, out));
            }
        }
        // ORDERING — Release pairs with the loop's Acquire load: the
        // loop must observe the stop only after every job above has been
        // asked to stop and published its outcome.
        self.shared.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
        let rebalances = self.shared.rebalances.lock().unwrap().clone();
        ServerOutcome { budget: self.budget, jobs: out_jobs, rebalances }
    }

    fn ensure_started(&self) {
        let mut t = self.thread.lock().unwrap();
        if t.is_none() {
            let shared = Arc::clone(&self.shared);
            let budget = self.budget;
            let period = self.period;
            let (grow, shrink) = (self.grow_backlog, self.shrink_backlog);
            let cooldown = self.cooldown_ticks;
            *t = Some(
                std::thread::Builder::new()
                    .name("stretch-server".into())
                    .spawn(move || server_loop(&shared, budget, period, grow, shrink, cooldown))
                    .expect("spawn stretch-server thread"),
            );
        }
    }
}

impl Drop for JobServer {
    /// Abandon path (dropped without [`shutdown`](Self::shutdown)): the
    /// loop force-stops and finalizes every remaining job, then exits —
    /// no thread outlives the server. Idempotent after `shutdown`
    /// (thread already taken). Job handles dropped afterwards find
    /// their outcomes already published.
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

/// The shared runtime loop: adopt, tick and retire jobs at the
/// [`RUNTIME_TICK`] cadence, tick their policies, and run one fleet
/// arbitration wave per `period`. Exits once a server-wide stop is
/// observed and the last job has retired.
fn server_loop(
    shared: &Arc<ServerShared>,
    budget: usize,
    period: Duration,
    grow_backlog: u64,
    shrink_backlog: u64,
    cooldown_ticks: u32,
) {
    let mut arbiter = ServerController::new(budget)
        .with_thresholds(grow_backlog, shrink_backlog)
        .with_cooldown(cooldown_ticks);
    // Observation dt for the arbiter, in whole seconds (the backlog
    // thresholds are dt-independent; sub-second waves just report 1 s).
    let period_s = period.as_secs().max(1) as u32;
    let mut live: Vec<ServerJob> = Vec::new();
    let mut next_tick = Instant::now();
    let mut next_wave = Instant::now() + period;
    loop {
        live.append(&mut shared.inbox.lock().unwrap());
        // ORDERING — Acquire pairs with shutdown's Release store (see
        // `JobServer::shutdown`).
        let stopping = shared.stop.load(Ordering::Acquire);
        if stopping {
            for j in &live {
                j.rt.shared().request_stop();
            }
        }
        // retire stopped jobs: finalize (kill open tickets, shut the
        // pipeline down, publish the final stats) and release their
        // cores back to the admission ledger
        live.retain_mut(|j| {
            if j.rt.stop_requested() {
                j.rt.finalize();
                *shared.committed.lock().unwrap() -= j.footprint;
                false
            } else {
                j.rt.tick();
                true
            }
        });
        if stopping && live.is_empty() && shared.inbox.lock().unwrap().is_empty() {
            return;
        }
        // per-job policies, gated on the live phase exactly like the
        // single-job `drive` loop
        for j in &mut live {
            let m = j.ctl.sample();
            if m.phase == JobPhase::Running {
                for p in &mut j.policies {
                    p.tick(&m, &j.ctl);
                }
            }
        }
        let now = Instant::now();
        if now >= next_wave {
            next_wave += period;
            arbitrate(&mut arbiter, &live, shared, period_s);
        }
        next_tick += RUNTIME_TICK;
        let now = Instant::now();
        if next_tick > now {
            // lint: allow(sleep) — wall-clock pacing of the shared
            // runtime tick (feed/sample cadence for every adopted job),
            // not a data-plane wait: nothing can arrive earlier than the
            // next scheduled tick.
            std::thread::sleep(next_tick - now);
        } else {
            next_tick = now; // fell behind: don't try to catch up the wall
        }
    }
}

/// One fleet arbitration wave: sample every *running* job (draining jobs
/// release their cores on retirement, not by wave), run the
/// shrink-then-grant pass, and issue each move as an ordinary epoch
/// reconfiguration on the owning job's stage.
fn arbitrate(
    arbiter: &mut ServerController,
    live: &[ServerJob],
    shared: &ServerShared,
    period_s: u32,
) {
    let mut idx: Vec<usize> = Vec::new();
    let mut fleet: Vec<(JobShare, Vec<Observation>)> = Vec::new();
    for (i, j) in live.iter().enumerate() {
        let m = j.ctl.sample();
        if m.phase != JobPhase::Running {
            continue;
        }
        let obs: Vec<Observation> =
            (0..m.stages.len()).map(|k| observation(&m, k, period_s)).collect();
        idx.push(i);
        fleet.push((j.share, obs));
    }
    if fleet.is_empty() {
        return;
    }
    let decisions = arbiter.tick(&fleet);
    for (fi, per_stage) in decisions.iter().enumerate() {
        let j = &live[idx[fi]];
        for (stage, d) in per_stage.iter().enumerate() {
            if let Decision::Reconfigure(set) = d {
                let ticket = j.ctl.scale_to(stage, set.clone());
                shared.rebalances.lock().unwrap().push(Rebalance {
                    job: j.id,
                    job_name: j.name.clone(),
                    stage,
                    ticket,
                });
            }
        }
    }
}

/// `[job.<name>]` keys of a server config.
const JOB_KEYS: &[(&str, KeyKind)] = &[
    ("config", KeyKind::Str),
    ("weight", KeyKind::Float),
    ("min_cores", KeyKind::Int),
    ("socket", KeyKind::Int),
];

/// `[server]` keys — keep in sync with
/// [`crate::config::ServerConfig::from_config`] (which carries a pointer
/// back here).
const SERVER_KEYS: &[(&str, KeyKind)] = &[
    ("budget", KeyKind::Int),
    ("period_ms", KeyKind::Int),
    ("grow_backlog", KeyKind::Int),
    ("shrink_backlog", KeyKind::Int),
    ("cooldown_ticks", KeyKind::Int),
];

/// Validate a server config's sections: unknown sections/keys and
/// wrong-typed values are typed errors (same contract as the single-job
/// path's `check_job_section_keys`), and a single-job config handed to
/// the server path gets pointed at `stretch run` by name.
fn check_server_section_keys(cfg: &Config) -> Result<(), JobError> {
    const JOB_CONFIG_PREFIXES: &[&str] = &[
        "topology.", "stage.", "schedule.", "run.", "elastic.", "source.", "batch.",
        "placement.", "faults.",
    ];
    'keys: for k in cfg.keys() {
        if k == "name" {
            continue;
        }
        if let Some(rest) = k.strip_prefix("server.") {
            match SERVER_KEYS.iter().find(|(name, _)| *name == rest) {
                None => {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: format!(
                            "unknown `[server]` key (known: {})",
                            SERVER_KEYS.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                        ),
                    })
                }
                Some((_, kind)) => {
                    let v = cfg.get(k).expect("keys() yields existing keys");
                    if !kind.matches(v) {
                        return Err(JobError::BadValue {
                            key: k.to_string(),
                            msg: format!("expected {}, got `{v}`", kind.name()),
                        });
                    }
                    continue 'keys;
                }
            }
        }
        if let Some(rest) = k.strip_prefix("job.") {
            let Some((job, field)) = rest.split_once('.') else {
                return Err(JobError::BadValue {
                    key: k.to_string(),
                    msg: "expected `job.<name>.<field>`".into(),
                });
            };
            match JOB_KEYS.iter().find(|(name, _)| *name == field) {
                None => {
                    return Err(JobError::BadValue {
                        key: k.to_string(),
                        msg: format!(
                            "unknown `[job.{job}]` key (known: {})",
                            JOB_KEYS.iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
                        ),
                    })
                }
                Some((_, kind)) => {
                    let v = cfg.get(k).expect("keys() yields existing keys");
                    if !kind.matches(v) {
                        return Err(JobError::BadValue {
                            key: k.to_string(),
                            msg: format!("expected {}, got `{v}`", kind.name()),
                        });
                    }
                    continue 'keys;
                }
            }
        }
        // a single-job config handed to the server path deserves a
        // pointer at the right verb (mirror of `check_job_section_keys`'s
        // hint in the other direction)
        if JOB_CONFIG_PREFIXES.iter().any(|p| k.starts_with(p)) {
            return Err(JobError::BadValue {
                key: k.to_string(),
                msg: "this looks like a single-job config — run it with `stretch run`, or \
                      reference it from a `[job.<name>] config = \"...\"` entry"
                    .into(),
            });
        }
        return Err(JobError::BadValue {
            key: k.to_string(),
            msg: "unknown section/key for a server config (expected `name`, `[server]`, or \
                  `[job.<name>]`)"
                .into(),
        });
    }
    Ok(())
}

/// Run a whole server config to completion: build every `[job.<name>]`
/// sub-config through the shared [`prepare_job`] path (its own
/// `[elastic]` controller choice is ignored — the fleet arbiter owns
/// cross-job scaling), submit them under one budget, drain each job,
/// and return the aggregate outcome. Job config paths resolve relative
/// to `conf_dir` (the server config's directory), so a config tree is
/// relocatable. `budget_ms` caps each job's paced phase, exactly like
/// `stretch run --budget-ms`.
pub fn serve_from_config(
    cfg: &Config,
    conf_dir: &Path,
    budget_ms: Option<u64>,
) -> Result<ServerOutcome, JobError> {
    check_server_section_keys(cfg)?;
    let sc = ServerConfig::from_config(cfg);
    let mut names: BTreeSet<String> = BTreeSet::new();
    for k in cfg.keys() {
        if let Some(rest) = k.strip_prefix("job.") {
            if let Some((job, _)) = rest.split_once('.') {
                names.insert(job.to_string());
            }
        }
    }
    if names.is_empty() {
        return Err(JobError::BadValue {
            key: "job".into(),
            msg: "a server config needs at least one `[job.<name>]` section".into(),
        });
    }
    let server = JobServer::new(sc.budget)
        .with_period(Duration::from_millis(sc.period_ms))
        .with_thresholds(sc.grow_backlog, sc.shrink_backlog)
        .with_cooldown(sc.cooldown_ticks);
    let mut ids: Vec<JobId> = Vec::new();
    for name in &names {
        let key = |f: &str| format!("job.{name}.{f}");
        let path = match cfg.get(&key("config")) {
            Some(ConfigValue::Str(s)) => s.clone(),
            _ => {
                return Err(JobError::BadValue {
                    key: key("config"),
                    msg: "every `[job.<name>]` needs a `config = \"<job .conf path>\"`".into(),
                })
            }
        };
        let sub = Config::load(conf_dir.join(&path)).map_err(|e| JobError::BadValue {
            key: key("config"),
            msg: format!("{path}: {e}"),
        })?;
        let socket = match cfg.get(&key("socket")) {
            None => None,
            Some(ConfigValue::Int(v)) if *v >= 0 => Some(*v as usize),
            Some(other) => {
                return Err(JobError::BadValue {
                    key: key("socket"),
                    msg: format!("expected a socket index ≥ 0, got `{other}`"),
                })
            }
        };
        let share = JobShare {
            weight: cfg.float_or(&key("weight"), 1.0).max(0.0),
            min_cores: cfg.int_or(&key("min_cores"), 0).max(0) as usize,
        };
        let prep = prepare_job(
            &sub,
            JobPrepOptions {
                budget_ms,
                skip_elastic_controller: true,
                socket,
                name_override: Some(name.clone()),
            },
        )?;
        // a job whose floor exceeds the whole budget can NEVER fit — a
        // config error, reported against the section rather than left to
        // runtime admission (which handles the "other jobs hold the
        // cores" case)
        let floor = share.min_cores.max(prep.n_stages);
        if floor > sc.budget {
            let mut job = prep.job;
            job.pipeline.shutdown();
            return Err(JobError::BadValue {
                key: format!("job.{name}"),
                msg: format!(
                    "minimum footprint {floor} core(s) ({} stage(s), min_cores {}) exceeds \
                     the server budget of {} (the job's own maximum is {})",
                    prep.n_stages, share.min_cores, sc.budget, prep.max_cores
                ),
            });
        }
        let id = server
            .submit_with_policies(prep.job, share, prep.policies, prep.recovery_log)
            .map_err(|e| JobError::BadValue { key: format!("job.{name}"), msg: e.to_string() })?;
        ids.push(id);
    }
    for id in &ids {
        server.stop(*id);
    }
    Ok(server.shutdown())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Config {
        Config::parse(text).unwrap()
    }

    #[test]
    fn job_id_and_admission_display() {
        assert_eq!(JobId(3).to_string(), "job-3");
        let e = Admission::Rejected { reason: "no room".into() };
        assert!(e.to_string().contains("no room"), "{e}");
    }

    #[test]
    fn server_section_keys_validate() {
        // the CI config shape passes
        check_server_section_keys(&parse(
            "name = \"two\"\n[server]\nbudget = 8\nperiod_ms = 100\n\
             [job.alpha]\nconfig = \"a.conf\"\nweight = 2.0\nmin_cores = 4\n\
             [job.beta]\nconfig = \"b.conf\"\nsocket = 0",
        ))
        .unwrap();
        // unknown `[server]` key
        let err = check_server_section_keys(&parse("[server]\nbudgets = 8")).unwrap_err();
        assert!(matches!(err, JobError::BadValue { .. }), "{err}");
        // wrong-typed value
        let err =
            check_server_section_keys(&parse("[server]\nbudget = \"eight\"")).unwrap_err();
        assert!(err.to_string().contains("expected an integer"), "{err}");
        // unknown `[job.<name>]` key
        let err =
            check_server_section_keys(&parse("[job.a]\nconf = \"a.conf\"")).unwrap_err();
        assert!(err.to_string().contains("unknown `[job.a]` key"), "{err}");
    }

    #[test]
    fn single_job_config_is_pointed_at_stretch_run() {
        let err = check_server_section_keys(&parse(
            "[topology]\nstages = [\"a\"]\n[stage.a]\noperator = \"trade-filter\"",
        ))
        .unwrap_err();
        assert!(err.to_string().contains("stretch run"), "{err}");
        let err = check_server_section_keys(&parse("[run]\nduration_s = 5")).unwrap_err();
        assert!(err.to_string().contains("stretch run"), "{err}");
    }

    #[test]
    fn serve_requires_a_job_section() {
        let err = serve_from_config(&parse("[server]\nbudget = 4"), Path::new("."), None)
            .unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
    }

    #[test]
    fn missing_job_config_path_is_a_typed_error() {
        let err = serve_from_config(
            &parse("[server]\nbudget = 4\n[job.a]\nweight = 1.0"),
            Path::new("."),
            None,
        )
        .unwrap_err();
        match err {
            JobError::BadValue { key, .. } => assert_eq!(key, "job.a.config"),
            other => panic!("{other}"),
        }
    }

    #[test]
    fn unreadable_job_config_is_reported_against_its_key() {
        let err = serve_from_config(
            &parse("[server]\nbudget = 4\n[job.a]\nconfig = \"does-not-exist.conf\""),
            Path::new("/nonexistent-dir"),
            None,
        )
        .unwrap_err();
        match err {
            JobError::BadValue { key, msg } => {
                assert_eq!(key, "job.a.config");
                assert!(msg.contains("does-not-exist.conf"), "{msg}");
            }
            other => panic!("{other}"),
        }
    }
}
