//! Event time and wall-clock time (§2.1).
//!
//! Event time progresses in SPE-specific discrete δ increments; as in Flink
//! (and the paper's experiments) δ = 1 ms. `EventTime` is a plain `i64`
//! millisecond count from an arbitrary epoch. Wall-clock time is only used
//! for metrics (latency, reconfiguration time), never for semantics.

/// Event time in δ = 1 ms units from an arbitrary epoch.
pub type EventTime = i64;

/// The smallest event-time increment (δ), in ms. Matches Flink/paper.
pub const DELTA: EventTime = 1;

/// Sentinel: before any watermark has been observed (§2.3: W initially 0,
/// we use i64::MIN so event-time 0 workloads behave; algorithms only rely
/// on monotonicity).
pub const TIME_MIN: EventTime = i64::MIN / 4;

/// Sentinel: end-of-stream watermark. Strictly greater than any data ts.
pub const TIME_MAX: EventTime = i64::MAX / 4;

/// Convert seconds to event time units.
#[inline]
pub const fn secs(s: i64) -> EventTime {
    s * 1000
}

/// Convert minutes to event time units.
#[inline]
pub const fn mins(m: i64) -> EventTime {
    m * 60 * 1000
}

/// Window geometry helpers shared by every stateful operator (§2.1).
/// Windows cover `[l*WA, l*WA + WS)` for integer l.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSpec {
    /// Window advance (WA), in event-time units. WA <= WS.
    pub advance: EventTime,
    /// Window size (WS), in event-time units.
    pub size: EventTime,
}

impl WindowSpec {
    pub fn new(advance: EventTime, size: EventTime) -> Self {
        assert!(advance > 0, "WA must be positive");
        assert!(size >= advance, "WS must be >= WA (sliding window: WA < WS)");
        WindowSpec { advance, size }
    }

    /// Left boundary of the *earliest* window instance containing `ts`
    /// (paper's `earliestWinL`). A tuple with timestamp ts falls in windows
    /// with left boundary in `(ts - WS, ts]` aligned to WA.
    #[inline]
    pub fn earliest_win_l(&self, ts: EventTime) -> EventTime {
        // smallest multiple of WA strictly greater than ts - WS
        let lo = ts - self.size; // exclusive
        // ceil((lo+1)/WA)*WA  (for possibly negative values)
        let q = (lo + 1).div_euclid(self.advance);
        let r = (lo + 1).rem_euclid(self.advance);
        if r == 0 {
            q * self.advance
        } else {
            (q + 1) * self.advance
        }
    }

    /// Left boundary of the *latest* window instance containing `ts`
    /// (paper's `latestWinL`): largest multiple of WA that is <= ts.
    #[inline]
    pub fn latest_win_l(&self, ts: EventTime) -> EventTime {
        ts.div_euclid(self.advance) * self.advance
    }

    /// Number of window instances a tuple falls into when WT = multi.
    #[inline]
    pub fn instances_per_tuple(&self, ts: EventTime) -> usize {
        (((self.latest_win_l(ts) - self.earliest_win_l(ts)) / self.advance) + 1) as usize
    }

    /// A window starting at `l` is expired w.r.t. watermark `w` iff its
    /// right boundary (exclusive) is <= w (§2.3).
    #[inline]
    pub fn is_expired(&self, l: EventTime, watermark: EventTime) -> bool {
        l + self.size <= watermark
    }

    /// Right boundary (exclusive) of a window with left boundary `l`; this
    /// is the timestamp assigned to output tuples produced from it
    /// (Observation 1: t_out.ts > t_in.ts for every contributing t_in).
    #[inline]
    pub fn right_boundary(&self, l: EventTime) -> EventTime {
        l + self.size
    }
}

/// A stopwatch for metrics (wall-clock only).
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_latest_tumbling() {
        // Tumbling window: WA == WS == 10
        let w = WindowSpec::new(10, 10);
        assert_eq!(w.earliest_win_l(0), 0);
        assert_eq!(w.latest_win_l(0), 0);
        assert_eq!(w.earliest_win_l(9), 0);
        assert_eq!(w.latest_win_l(9), 0);
        assert_eq!(w.earliest_win_l(10), 10);
        assert_eq!(w.instances_per_tuple(5), 1);
    }

    #[test]
    fn earliest_latest_sliding() {
        // WA=10, WS=30: tuple at ts=25 falls into windows starting at 0,10,20
        let w = WindowSpec::new(10, 30);
        assert_eq!(w.earliest_win_l(25), 0);
        assert_eq!(w.latest_win_l(25), 20);
        assert_eq!(w.instances_per_tuple(25), 3);
        // ts=30 falls into 10,20,30
        assert_eq!(w.earliest_win_l(30), 10);
        assert_eq!(w.latest_win_l(30), 30);
    }

    #[test]
    fn window_membership_is_consistent() {
        // Brute-force check: for all ts in a range, every window [l, l+WS)
        // with l in [earliest, latest] aligned to WA contains ts, and the
        // neighbours outside do not.
        let w = WindowSpec::new(7, 23);
        for ts in -100i64..200 {
            let e = w.earliest_win_l(ts);
            let l = w.latest_win_l(ts);
            assert_eq!(e.rem_euclid(w.advance), 0);
            assert_eq!(l.rem_euclid(w.advance), 0);
            let mut b = e;
            while b <= l {
                assert!(b <= ts && ts < b + w.size, "ts={ts} b={b}");
                b += w.advance;
            }
            // window before earliest must NOT contain ts
            assert!(ts >= (e - w.advance) + w.size, "ts={ts} e={e}");
            // window after latest must NOT contain ts
            assert!(ts < l + w.advance, "ts={ts} l={l}");
        }
    }

    #[test]
    fn expiry() {
        let w = WindowSpec::new(10, 30);
        assert!(!w.is_expired(0, 29));
        assert!(w.is_expired(0, 30));
        assert!(w.is_expired(0, 31));
        assert_eq!(w.right_boundary(0), 30);
    }

    #[test]
    fn negative_timestamps() {
        let w = WindowSpec::new(10, 30);
        assert_eq!(w.latest_win_l(-5), -10);
        assert_eq!(w.earliest_win_l(-5), -30);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_advance() {
        WindowSpec::new(0, 10);
    }
}
