//! # STRETCH — Virtual Shared-Nothing parallelism for stream processing
//!
//! A from-scratch reproduction of *"STRETCH: Virtual Shared-Nothing
//! Parallelism for Scalable and Elastic Stream Processing"* (Gulisano et
//! al., TPDS 2021) as a Rust streaming runtime with a JAX/Pallas-compiled
//! compute offload path (AOT via PJRT; Python never runs on the request
//! path).
//!
//! ## Layers
//! * [`scalegate`] — the ScaleGate / Elastic ScaleGate shared tuple buffer
//!   (the paper's TB object, Table 2).
//! * [`operator`] — the generalized stateful operator `O+` (§4) and the
//!   operator library (Map, Aggregate, Join, ScaleJoin, …).
//! * [`engine`] — the SN baseline engine and the VSN (STRETCH) engine with
//!   epoch-based, state-transfer-free elasticity (§5, §7).
//! * [`elastic`] — reconfiguration controllers (reactive + proactive).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled kernels.
//! * [`workloads`] — generators for every evaluation workload (§8).
//! * [`sim`] — calibrated multicore discrete-event simulator (testbed
//!   substitution; see DESIGN.md §5).
//!
//! ## Quickstart
//! See `examples/quickstart.rs`: build an `O+`, wrap it in a VSN engine,
//! feed tuples, read results — then trigger a live reconfiguration.

pub mod cli;
pub mod config;
pub mod elastic;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod operator;
pub mod runtime;
pub mod scalegate;
pub mod schema;
pub mod sim;
pub mod testkit;
pub mod time;
pub mod tuple;
pub mod util;
pub mod watermark;
pub mod workloads;

pub use time::{EventTime, WindowSpec};
pub use tuple::{Key, Kind, Mapper, ReconfigSpec, Tuple};
