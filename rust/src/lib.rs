//! # STRETCH — Virtual Shared-Nothing parallelism for stream processing
//!
//! A from-scratch reproduction of *"STRETCH: Virtual Shared-Nothing
//! Parallelism for Scalable and Elastic Stream Processing"* (Gulisano et
//! al., TPDS 2021) as a Rust streaming runtime with a JAX/Pallas-compiled
//! compute offload path (AOT via PJRT; Python never runs on the request
//! path).
//!
//! ## Layers
//! * [`scalegate`] — the ScaleGate / Elastic ScaleGate shared tuple buffer
//!   (the paper's TB object, Table 2), with a batch-native data plane
//!   (`add_batch`/`get_batch`, run-granularity cooperative merge, one
//!   log publish per run), cache-padded slot arrays, and runtime
//!   source/reader membership.
//! * [`operator`] — the generalized stateful operator `O+` (§4) and the
//!   operator library (Map, Aggregate, Join, ScaleJoin, …), including
//!   Map-as-elastic-stage ([`operator::map::MapStageLogic`]).
//! * [`engine`] — the SN baseline engine, the VSN (STRETCH) engine with
//!   epoch-based, state-transfer-free elasticity (§5, §7), and the
//!   multi-stage pipeline layer ([`engine::pipeline`]); all hot loops
//!   move tuples in runs (tunable via [`config::BatchTuning`] /
//!   `VsnOptions::worker_batch`), with control tuples still cutting
//!   batches so reconfiguration latency is batching-independent.
//! * [`elastic`] — reconfiguration controllers (reactive + proactive).
//! * [`harness`] — rate-scheduled pipeline run loop with per-stage
//!   controllers and per-stage metrics sampling.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled kernels
//!   (stubbed unless built with `--features pjrt`).
//! * [`workloads`] — generators for every evaluation workload (§8), plus
//!   2-stage pipeline operator sets (tokenize → count, fan-out → join).
//! * [`sim`] — calibrated multicore discrete-event simulator (testbed
//!   substitution; see DESIGN.md §5).
//! * [`metrics`] — §8 counters/histograms plus
//!   [`metrics::BenchReport`]: every bench writes a machine-readable
//!   `BENCH_<name>.json` (throughput, p50/p99 latency, reconfiguration
//!   times) so the perf trajectory is a diffable record.
//!
//! ## Pipelines
//! Applications compose as DAG chains `source → stage₁ → … → stageₖ →
//! sink` via [`engine::pipeline::PipelineBuilder`]: typed
//! `stage(OperatorDef, VsnOptions)` chaining where stage N's ESG_out
//! **is** stage N+1's ESG_in — one shared gate, zero-copy hand-off, no
//! re-ingestion. Watermarks propagate through the gate's source clocks
//! (Lemma 2) plus forwarded heartbeat entries; each stage keeps its own
//! instance pool and [`engine::ControlPlane`], so stages scale
//! independently at runtime with no state transfer (first stage: control
//! tuples ride the ingress wrappers, Alg. 5; later stages: a reserved
//! control slot on the shared gate, [`engine::pipeline::ControlInjector`]).
//! `examples/dag_pipeline.rs` runs a two-stage tokenize → wordcount
//! pipeline, reconfigures both stages mid-run, and checks the output
//! against a sequential reference.
//!
//! ## Quickstart
//! See `examples/quickstart.rs`: build an `O+`, wrap it in a VSN engine,
//! feed tuples, read results — then trigger a live reconfiguration.

pub mod cli;
pub mod config;
pub mod elastic;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod operator;
pub mod runtime;
pub mod scalegate;
pub mod schema;
pub mod sim;
pub mod testkit;
pub mod time;
pub mod tuple;
pub mod util;
pub mod watermark;
pub mod workloads;

pub use time::{EventTime, WindowSpec};
pub use tuple::{Key, Kind, Mapper, ReconfigSpec, Tuple};
