//! # STRETCH — Virtual Shared-Nothing parallelism for stream processing
//!
//! A from-scratch reproduction of *"STRETCH: Virtual Shared-Nothing
//! Parallelism for Scalable and Elastic Stream Processing"* (Gulisano et
//! al., TPDS 2021) as a Rust streaming runtime with a JAX/Pallas-compiled
//! compute offload path (AOT via PJRT; Python never runs on the request
//! path).
//!
//! ## Layers
//! * [`scalegate`] — the ScaleGate / Elastic ScaleGate shared tuple buffer
//!   (the paper's TB object, Table 2), with a batch-native data plane
//!   (`add_batch`/`get_batch`, run-granularity cooperative merge, one
//!   log publish per run), cache-padded slot arrays, and runtime
//!   source/reader membership.
//! * [`operator`] — the generalized stateful operator `O+` (§4) and the
//!   operator library (Map, Aggregate, Join, ScaleJoin, …), including
//!   Map-as-elastic-stage ([`operator::map::MapStageLogic`]).
//! * [`engine`] — the SN baseline engine, the VSN (STRETCH) engine with
//!   epoch-based, state-transfer-free elasticity (§5, §7), the linear
//!   pipeline layer ([`engine::pipeline`]) and the true DAG layer
//!   ([`engine::dag`]: fan-out = reader groups, fan-in = source-slot
//!   groups, per-edge control slots); all hot loops move tuples in runs
//!   (tunable via [`config::BatchTuning`] / `VsnOptions::worker_batch`),
//!   with control tuples still cutting batches so reconfiguration
//!   latency is batching-independent.
//! * [`elastic`] — reconfiguration controllers (reactive + proactive
//!   per-stage, plus the topology-aware budgeted
//!   [`elastic::DagController`]).
//! * [`harness`] — rate-scheduled topology run loop (N ingress sources,
//!   M egress readers — degenerate shapes are typed errors, not panics)
//!   with per-stage controllers, an optional global DAG controller, and
//!   per-stage metrics sampling.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled kernels
//!   (stubbed unless built with `--features pjrt`).
//! * [`workloads`] — generators for every evaluation workload (§8), plus
//!   2-stage pipeline operator sets (tokenize → count, fan-out → join).
//! * [`sim`] — calibrated multicore discrete-event simulator (testbed
//!   substitution; see DESIGN.md §5).
//! * [`metrics`] — §8 counters/histograms plus
//!   [`metrics::BenchReport`]: every bench writes a machine-readable
//!   `BENCH_<name>.json` (throughput, p50/p99 latency, reconfiguration
//!   times) so the perf trajectory is a diffable record.
//!
//! ## Topologies
//! Linear chains compose via [`engine::pipeline::PipelineBuilder`]:
//! typed `stage(OperatorDef, VsnOptions)` chaining where stage N's
//! ESG_out **is** stage N+1's ESG_in — one shared gate, zero-copy
//! hand-off, no re-ingestion. True DAGs compose via
//! [`engine::dag::DagBuilder`] (`source`/`node`/`build`): a stage fans
//! OUT by every downstream registering a reader group on its shared
//! ESG_out (exactly-once per group, no data duplication), and fans IN by
//! owning one ESG_in with a source-slot group per upstream (the
//! cooperative merge composes watermarks across branches). Watermarks
//! propagate through the gate's source clocks (Lemma 2) plus forwarded
//! heartbeat entries; each stage keeps its own instance pool and
//! [`engine::ControlPlane`], so stages scale independently at runtime
//! with no state transfer (source stages: control tuples ride the
//! ingress wrappers, Alg. 5; downstream stages: a reserved per-edge
//! control slot + tag on the shared gate,
//! [`engine::pipeline::ControlInjector`]). `examples/dag_pipeline.rs`
//! runs a two-stage tokenize → wordcount chain;
//! `examples/diamond_dag.rs` runs the diamond
//! (filter → L-leg ∥ R-leg → hedge join), reconfigures all four stages
//! mid-run, and checks exact equivalence against a sequential
//! reference; `bench_q7_dag` drives the same diamond under a rate step
//! with [`elastic::DagController`] dividing a global core budget by
//! per-stage backlog.
//!
//! ## Quickstart
//! See `examples/quickstart.rs`: build an `O+`, wrap it in a VSN engine,
//! feed tuples, read results — then trigger a live reconfiguration.

pub mod cli;
pub mod config;
pub mod elastic;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod operator;
pub mod runtime;
pub mod scalegate;
pub mod schema;
pub mod sim;
pub mod testkit;
pub mod time;
pub mod tuple;
pub mod util;
pub mod watermark;
pub mod workloads;

pub use time::{EventTime, WindowSpec};
pub use tuple::{Key, Kind, Mapper, ReconfigSpec, Tuple};
