//! # STRETCH — Virtual Shared-Nothing parallelism for stream processing
//!
//! A from-scratch reproduction of *"STRETCH: Virtual Shared-Nothing
//! Parallelism for Scalable and Elastic Stream Processing"* (Gulisano et
//! al., TPDS 2021) as a Rust streaming runtime with a JAX/Pallas-compiled
//! compute offload path (AOT via PJRT; Python never runs on the request
//! path).
//!
//! ## Layers
//! * [`scalegate`] — the ScaleGate / Elastic ScaleGate shared tuple buffer
//!   (the paper's TB object, Table 2), with a batch-native data plane
//!   (`add_batch`/`get_batch`, run-granularity cooperative merge, one
//!   log publish per run), cache-padded slot arrays, and runtime
//!   source/reader membership.
//! * [`operator`] — the generalized stateful operator `O+` (§4) and the
//!   operator library (Map, Aggregate, Join, ScaleJoin, …), including
//!   Map-as-elastic-stage ([`operator::map::MapStageLogic`]).
//! * [`engine`] — the SN baseline engine, the VSN (STRETCH) engine with
//!   epoch-based, state-transfer-free elasticity (§5, §7), ONE topology
//!   construction path ([`engine::dag`]: fan-out = reader groups, fan-in
//!   = source-slot groups, per-edge control slots; linear chains via the
//!   thin [`engine::pipeline::PipelineBuilder`] façade), and the
//!   declarative JobSpec layer ([`engine::job`]: `[topology]`/`[stage.*]`
//!   config sections → validated, registry-resolved running topologies);
//!   all hot loops move tuples in runs (tunable via
//!   [`config::BatchTuning`] / `VsnOptions::worker_batch`, retunable
//!   live for adaptive batch sizing), with control tuples still cutting
//!   batches so reconfiguration latency is batching-independent.
//! * [`elastic`] — reconfiguration controllers (reactive + proactive
//!   per-stage, plus the topology-aware budgeted
//!   [`elastic::DagController`]) — pure *policies*, driven through the
//!   live job handle below.
//! * [`harness`] — the live runtime API and the batch entry points on
//!   top of it. [`harness::Job::launch`] is the ONE way a running
//!   topology is owned: it moves the data plane (paced feed over N
//!   ingress sources, M egress drains, per-event-second sampling;
//!   degenerate shapes are typed errors, not panics) onto a runtime
//!   thread and returns a [`harness::JobHandle`] — `scale` →
//!   [`harness::ReconfigTicket`] (resolves to the measured reconfig
//!   latency), `set_rate`, `set_worker_batch`, `sample()` →
//!   [`harness::JobMetrics`], `await_quiesce`, `shutdown()` →
//!   [`harness::JobRunOutcome`]. Decisions live outside as
//!   [`harness::policy`] objects (controllers, scripted
//!   `[schedule.<stage>]` steps, adaptive batch sizing);
//!   [`harness::run_pipeline`] and [`harness::run_job`] — the
//!   config-to-running-job entrypoint behind `stretch run --config
//!   job.conf`, emitting `BENCH_<job>.json` with per-reconfig ticket
//!   latencies — are thin clients: launch, drive policies, quiesce,
//!   shut down. The same layer SUPERVISES: per-worker health
//!   ([`engine::WorkerHealth`]: Live/Stalled/Dead, panics contained at
//!   the worker batch loop) is classified into [`harness::StageHealth`]
//!   every runtime tick, scripted faults ([`harness::FaultPlan`], the
//!   `[faults]` config section) are injected through the handle, and
//!   [`harness::SupervisorPolicy`] heals crashes by reconfiguration
//!   alone — evict the dead worker through a normal epoch switch (its
//!   zombie replays the unprocessed share, no state transfer), re-grow
//!   on fresh slots, escalate retry → replace → shed load → degraded —
//!   each recovery a [`harness::RecoveryTicket`] whose detection→healed
//!   latency lands as `mttr_ms` in `BENCH_<job>.json` (informational,
//!   never a bench-diff gate). Above the single job sits the FLEET
//!   layer ([`harness::server`]): a [`harness::JobServer`] runs N jobs
//!   on ONE shared runtime thread (a job costs a list entry, not a
//!   thread) under ONE global core budget, arbitrated per (job, stage)
//!   each wave by [`elastic::ServerController`] — the DagController's
//!   shrink-then-grant generalized across jobs, weighted by
//!   [`elastic::JobShare`], floored by `min_cores`, forced to fit —
//!   with every cross-job move an ordinary epoch reconfiguration
//!   carried by a [`harness::Rebalance`] ticket, no state transfer
//!   ever. `submit` is ADMISSION CONTROL: a job whose minimum
//!   footprint cannot fit the unclaimed budget is refused
//!   ([`harness::Admission`]) before it competes for cores; `metrics()`
//!   rolls every live job into one [`harness::ServerMetrics`].
//!   Declaratively: a `[server]` + `[job.<name>]` config behind
//!   `stretch serve fleet.conf` ([`harness::serve_from_config`]),
//!   emitting `BENCH_server.json` with per-job throughput and
//!   cross-job rebalance latencies
//!   (`examples/configs/server_two_jobs.conf` is two diamonds under an
//!   8-core budget).
//! * [`runtime`] — machine-facing services: the PJRT loader/executor for
//!   the AOT-compiled kernels (stubbed unless built with `--features
//!   pjrt`) and the placement-aware data plane
//!   ([`runtime::placement`]): [`runtime::CoreMap`] discovers the
//!   socket/core topology from sysfs, [`runtime::PlacementPlan`] assigns
//!   stage workers, reader groups, and the runtime thread to cores so a
//!   stage's readers stay NUMA-local to their upstream's ESG_out, and
//!   gate slot/log arrays are first-touch-initialized on the owning
//!   socket. Opt in per job with `[placement] enabled = true` (plus
//!   optional per-stage `cores = [..]` / `socket = N` overrides);
//!   everything degrades to a no-op on single-socket or non-Linux hosts.
//!   `bench_micro` measures the local-vs-cross gate penalty and `stretch
//!   bench-diff` gates the committed `BENCH_*.json` trajectory against
//!   regressions.
//! * [`workloads`] — generators for every evaluation workload (§8), plus
//!   2-stage pipeline operator sets (tokenize → count, fan-out → join).
//! * [`sim`] — calibrated multicore discrete-event simulator (testbed
//!   substitution; see DESIGN.md §5).
//! * [`metrics`] — §8 counters/histograms plus
//!   [`metrics::BenchReport`]: every bench writes a machine-readable
//!   `BENCH_<name>.json` (throughput, p50/p99 latency, reconfiguration
//!   times) so the perf trajectory is a diffable record.
//!
//! ## Topologies
//! ONE construction path builds every shape:
//! [`engine::dag::DagBuilder`] (`source`/`node`/`build`). A stage fans
//! OUT by every downstream registering a reader group on its shared
//! ESG_out (exactly-once per group, no data duplication), and fans IN by
//! owning one ESG_in with a source-slot group per upstream (the
//! cooperative merge composes watermarks across branches). Watermarks
//! propagate through the gate's source clocks (Lemma 2) plus forwarded
//! heartbeat entries; each stage keeps its own instance pool and
//! [`engine::ControlPlane`], so stages scale independently at runtime
//! with no state transfer (source stages: control tuples ride the
//! ingress wrappers, Alg. 5; downstream stages: a reserved per-edge
//! control slot + tag on the shared gate,
//! [`engine::pipeline::ControlInjector`]). Linear chains are degenerate
//! DAGs: [`engine::pipeline::PipelineBuilder`] is a thin typed façade
//! that delegates everything to the DAG builder.
//!
//! On top sits the **declarative layer**: [`engine::job::JobSpec`]
//! parses a `[topology]`/`[stage.*]` config (stages by name, edges,
//! per-stage parallelism, per-stage operator params, controller choice +
//! core budget, adaptive `[batch]` sizing, scripted `[schedule.<stage>]`
//! scale/rate steps), validates it with typed errors (cycle, unknown
//! operator, dangling edge, edge payload-type mismatch — polymorphic
//! operators like `forward` resolve their kind from their upstream),
//! resolves operator names through [`workloads::registry`] and builds
//! the running topology — `stretch run --config
//! examples/configs/diamond.conf` is a whole elastic diamond with zero
//! topology code, and `examples/configs/diamond_scripted.conf` scales
//! all four stages on a timed plan with no controller at all.
//! `examples/dag_pipeline.rs` and `examples/diamond_dag.rs` build their
//! topologies from `examples/configs/*.conf` and check exact output
//! equivalence against sequential references while every stage
//! reconfigures mid-run (`integration_dag` additionally proves
//! config-built ≡ hand-built ≡ handle-scripted); `bench_q7_dag` drives
//! the diamond under a rate step with [`elastic::DagController`]
//! dividing a global core budget by per-stage backlog.
//!
//! ## Drive a live job from your own code
//! The harness entry points are conveniences, not the API. Your code
//! can own a running topology directly (see `examples/quickstart.rs`
//! and `examples/diamond_dag.rs` for compiled versions of this flow):
//!
//! ```text
//! let handle = Job::new(pipeline, source)        // any PacedSource
//!     .with_config(LaunchConfig { schedule, time_scale, ..Default::default() })
//!     .launch()?;                                // feed/drain/sampling move behind it
//! let m = handle.sample();                       // JobMetrics: backlog, Π, rates, latency
//! let ticket = handle.scale(2, 3);               // stage 2 → 3 instances, live
//! let ms = ticket.wait(timeout);                 // measured reconfig latency (<40 ms claim)
//! handle.set_rate(8_000.0);                      // retune the offered load
//! handle.await_quiesce();                        // feed done, egress quiet
//! let outcome = handle.shutdown();               // samples, reconfigs, tickets
//! ```
//!
//! Anything that *decides* — thresholds, models, schedules — is a
//! [`harness::policy::JobPolicy`]: it reads [`harness::JobMetrics`] and
//! calls `scale`/`set_rate`, which is exactly how the built-in
//! controllers are wired in.
//!
//! ## Quickstart
//! See `examples/quickstart.rs`: build an `O+`, wrap it in a VSN engine,
//! feed tuples, read results, trigger a live reconfiguration — then
//! declare the same kind of topology as a 20-line job config and let
//! [`harness::run_job`] drive it, and finally kill a worker mid-run
//! (`[faults] steps = ["1 -> kill tokenize:0"]`) and watch the
//! supervisor heal it. `examples/configs/diamond_faults.conf` is the
//! full chaos scenario: kills on every stateless diamond stage plus a
//! stalled join worker, healed under an exact-output oracle
//! (`integration_dag::chaos_diamond_heals_every_fault_and_matches_reference`).
//! The quickstart ends with the fleet layer: TWO jobs on one runtime
//! thread under one 4-core budget, the arbiter re-fitting them live and
//! a third job refused admission — on disk, that flow is
//! `stretch serve examples/configs/server_two_jobs.conf`.
//!
//! ## Concurrency correctness
//! The exactly-once / ready-order guarantees rest on hand-placed atomic
//! orderings and `unsafe` blocks in the lock-free data plane
//! ([`scalegate`], [`util::spsc`], the VSN engine internals). The repo
//! machine-checks the *arguments* for those sites with an in-tree
//! analyzer, [`analysis`], run as `stretch lint` (a blocking CI gate
//! plus the `analysis::tests::committed_tree_is_clean` self-test):
//!
//! * **L1** — every `unsafe` block/fn/impl is immediately preceded by a
//!   `// SAFETY:` argument stating the invariant that makes it sound.
//! * **L2** — every atomic load/store/RMW/fence in the data-plane
//!   modules carries an `// ORDERING:` justification on the statement
//!   or its enclosing fn's doc comment, naming the acquire/release
//!   *pairing* it participates in (e.g. "Release publish of `ready`
//!   pairs with the reader's Acquire load in `Log::get`").
//!   `Ordering::SeqCst` is justify-or-weaken: the comment must say why
//!   nothing weaker works, or the site gets downgraded.
//! * **L3** — no `thread::sleep` / `spin_loop` / `yield_now` outside
//!   [`util::backoff`]; deliberate wall-clock waits carry a
//!   `lint: allow(sleep) — <reason>` waiver.
//! * **L4** — per-slot shared arrays in [`scalegate`] wrap elements in
//!   `CachePadded` (no false sharing between adjacent slots).
//! * **L5** — files declaring `//! lint: lock-free` (the SPSC ring, the
//!   epoch barrier) may not reference `Mutex`/`RwLock`/`Condvar`.
//! * **L6** — functions whose doc comment carries `lint: no-alloc` (the
//!   gate/worker hot paths) may not call `Vec::new`, `with_capacity`,
//!   `collect`, `to_vec`, or `Box::new`; a deliberate allocation inside
//!   one carries a `lint: allow(alloc) — <reason>` waiver.
//!
//! To justify a new site, write the pairing, not the mechanism: say
//! *which* Acquire observes *which* Release and what state that edge
//! publishes. To run the sanitizers locally:
//!
//! ```sh
//! # Miri (nightly): the SPSC ring + ScaleGate log/gate unit tests
//! rustup +nightly component add miri
//! MIRIFLAGS="-Zmiri-many-seeds" cargo +nightly miri test \
//!     util::spsc util::pool scalegate::log scalegate::esg
//! # ThreadSanitizer (nightly): the threaded exactly-once stress tests
//! RUSTFLAGS="-Zsanitizer=thread" cargo +nightly test -Zbuild-std \
//!     --target x86_64-unknown-linux-gnu --lib scalegate engine::barrier
//! ```
//!
//! ## Perf: memory discipline
//! The steady state is **allocation-free**: once capacities settle, a
//! tuple travels ingress → gate → worker → gate → egress without the
//! allocator being called. Two mechanisms make that true and keep it
//! true:
//!
//! **Run-buffer lifecycle.** Every gate owns a [`util::pool::BufferPool`]
//! reachable from all of its endpoints (`Esg::pool`, `SourceHandle::pool`,
//! `ReaderHandle::pool`). Run buffers circulate through it only at cold
//! transitions — steady state never touches the pool:
//!
//! ```text
//!        worker spawn                      worker exit
//!   in-pool ──get──▶ batch scratch ──────────put──▶ in-pool
//!  out-pool ──get──▶ out_buf       ──────────put──▶ out-pool
//!                        │ (shutdown, or a healed zombie's
//!                        ▼  decommission — PR 7 crash replay)
//!          steady state: the same two Vecs forever;
//!          `put` clears, so recycled buffers never alias
//!          a successor's tuples; burst capacity decays at
//!          batch boundaries (`pool::shrink_excess`)
//! ```
//!
//! The [`Log`](scalegate::log) recycles its segments the same way (a
//! small free list, reset eagerly at truncation), and merge/egress
//! scratch is pool-drawn or capacity-bounded.
//!
//! **Last-target move.** Fan-out never clones for every edge: the SN
//! forwarder ([`engine::SnIngress::forward`]) and the DAG's
//! per-downstream replication hand the *original* tuple to the last
//! target and clone only for the first N−1 — so the dominant
//! single-target case is zero-copy, and N-way fan-out costs exactly
//! N−1 clones (proved by `engine::sn::tests`).
//!
//! The contract is *measured*, not asserted from inspection:
//! `bench_micro` installs a counting `#[global_allocator]`
//! ([`metrics::CountingAlloc`]) and records `allocs_per_tuple_*` /
//! `bytes_per_tuple_*` into `BENCH_micro.json`; the batched-gate
//! steady state must stay < 0.01 allocs/tuple. Because allocation
//! counts are deterministic where timings are noisy, CI gates these
//! fields at a tight 1.2× tolerance (`stretch bench-diff --tolerance
//! 1.2 --gate-kinds alloc`) next to the loose 50× timing pass. Lint
//! rule **L6** (above) keeps the marked hot paths honest in review,
//! before the bench ever runs.
//!
//! **Fault-model boundary (shard-lock poisoning).** Worker panics are
//! contained at the batch loop and healed by reconfiguration
//! ([`harness::SupervisorPolicy`]), because a worker's in-flight batch
//! is replayable from the shared gate. A panic *inside a shared-state
//! critical section* — while holding the cooperative-merge mutex or a
//! join shard's write lock — is outside that recoverable model: the
//! poisoned lock is the detector, and the supervisor deliberately
//! treats it as fail-stop for the whole stage (escalate → replace →
//! degraded) rather than pretending the shared state is still
//! consistent. Keep critical sections panic-free: no user-code
//! callbacks, no allocation-heavy paths, assertions outside the lock.

// The two crate-wide unsafety lints behind lint rule L1: every unsafe
// operation must sit in an explicit `unsafe {}` block (even inside an
// `unsafe fn`), and no block may be wider than the operation it guards —
// so each block is a distinct site for a distinct `// SAFETY:` argument.
#![deny(unsafe_op_in_unsafe_fn)]
#![deny(unused_unsafe)]

pub mod analysis;
pub mod cli;
pub mod config;
pub mod elastic;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod operator;
pub mod runtime;
pub mod scalegate;
pub mod schema;
pub mod sim;
pub mod testkit;
pub mod time;
pub mod tuple;
pub mod util;
pub mod watermark;
pub mod workloads;

pub use time::{EventTime, WindowSpec};
pub use tuple::{Key, Kind, Mapper, ReconfigSpec, Tuple};
