//! # STRETCH — Virtual Shared-Nothing parallelism for stream processing
//!
//! A from-scratch reproduction of *"STRETCH: Virtual Shared-Nothing
//! Parallelism for Scalable and Elastic Stream Processing"* (Gulisano et
//! al., TPDS 2021) as a Rust streaming runtime with a JAX/Pallas-compiled
//! compute offload path (AOT via PJRT; Python never runs on the request
//! path).
//!
//! ## Layers
//! * [`scalegate`] — the ScaleGate / Elastic ScaleGate shared tuple buffer
//!   (the paper's TB object, Table 2), with a batch-native data plane
//!   (`add_batch`/`get_batch`, run-granularity cooperative merge, one
//!   log publish per run), cache-padded slot arrays, and runtime
//!   source/reader membership.
//! * [`operator`] — the generalized stateful operator `O+` (§4) and the
//!   operator library (Map, Aggregate, Join, ScaleJoin, …), including
//!   Map-as-elastic-stage ([`operator::map::MapStageLogic`]).
//! * [`engine`] — the SN baseline engine, the VSN (STRETCH) engine with
//!   epoch-based, state-transfer-free elasticity (§5, §7), ONE topology
//!   construction path ([`engine::dag`]: fan-out = reader groups, fan-in
//!   = source-slot groups, per-edge control slots; linear chains via the
//!   thin [`engine::pipeline::PipelineBuilder`] façade), and the
//!   declarative JobSpec layer ([`engine::job`]: `[topology]`/`[stage.*]`
//!   config sections → validated, registry-resolved running topologies);
//!   all hot loops move tuples in runs (tunable via
//!   [`config::BatchTuning`] / `VsnOptions::worker_batch`, retunable
//!   live for adaptive batch sizing), with control tuples still cutting
//!   batches so reconfiguration latency is batching-independent.
//! * [`elastic`] — reconfiguration controllers (reactive + proactive
//!   per-stage, plus the topology-aware budgeted
//!   [`elastic::DagController`]).
//! * [`harness`] — rate-scheduled topology run loop (N ingress sources,
//!   M egress readers — degenerate shapes are typed errors, not panics)
//!   with per-stage controllers, an optional global DAG controller,
//!   backlog-driven adaptive worker-batch sizing, per-stage metrics
//!   sampling, and [`harness::run_job`]: the config-to-running-job
//!   entrypoint behind `stretch run --config job.conf`
//!   (emitting `BENCH_<job>.json`).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled kernels
//!   (stubbed unless built with `--features pjrt`).
//! * [`workloads`] — generators for every evaluation workload (§8), plus
//!   2-stage pipeline operator sets (tokenize → count, fan-out → join).
//! * [`sim`] — calibrated multicore discrete-event simulator (testbed
//!   substitution; see DESIGN.md §5).
//! * [`metrics`] — §8 counters/histograms plus
//!   [`metrics::BenchReport`]: every bench writes a machine-readable
//!   `BENCH_<name>.json` (throughput, p50/p99 latency, reconfiguration
//!   times) so the perf trajectory is a diffable record.
//!
//! ## Topologies
//! ONE construction path builds every shape:
//! [`engine::dag::DagBuilder`] (`source`/`node`/`build`). A stage fans
//! OUT by every downstream registering a reader group on its shared
//! ESG_out (exactly-once per group, no data duplication), and fans IN by
//! owning one ESG_in with a source-slot group per upstream (the
//! cooperative merge composes watermarks across branches). Watermarks
//! propagate through the gate's source clocks (Lemma 2) plus forwarded
//! heartbeat entries; each stage keeps its own instance pool and
//! [`engine::ControlPlane`], so stages scale independently at runtime
//! with no state transfer (source stages: control tuples ride the
//! ingress wrappers, Alg. 5; downstream stages: a reserved per-edge
//! control slot + tag on the shared gate,
//! [`engine::pipeline::ControlInjector`]). Linear chains are degenerate
//! DAGs: [`engine::pipeline::PipelineBuilder`] is a thin typed façade
//! that delegates everything to the DAG builder.
//!
//! On top sits the **declarative layer**: [`engine::job::JobSpec`]
//! parses a `[topology]`/`[stage.*]` config (stages by name, edges,
//! per-stage parallelism, per-stage operator params, controller choice +
//! core budget, adaptive `[batch]` sizing), validates it with typed
//! errors (cycle, unknown operator, dangling edge, edge payload-type
//! mismatch), resolves operator names through
//! [`workloads::registry`] and builds the running topology —
//! `stretch run --config examples/configs/diamond.conf` is a whole
//! elastic diamond with zero topology code.
//! `examples/dag_pipeline.rs` and `examples/diamond_dag.rs` build their
//! topologies from `examples/configs/*.conf` and check exact output
//! equivalence against sequential references while every stage
//! reconfigures mid-run (`integration_dag` additionally proves
//! config-built ≡ hand-built); `bench_q7_dag` drives the diamond under
//! a rate step with [`elastic::DagController`] dividing a global core
//! budget by per-stage backlog.
//!
//! ## Quickstart
//! See `examples/quickstart.rs`: build an `O+`, wrap it in a VSN engine,
//! feed tuples, read results, trigger a live reconfiguration — then
//! declare the same kind of topology as a 20-line job config and let
//! [`harness::run_job`] drive it.

pub mod cli;
pub mod config;
pub mod elastic;
pub mod engine;
pub mod harness;
pub mod metrics;
pub mod operator;
pub mod runtime;
pub mod scalegate;
pub mod schema;
pub mod sim;
pub mod testkit;
pub mod time;
pub mod tuple;
pub mod util;
pub mod watermark;
pub mod workloads;

pub use time::{EventTime, WindowSpec};
pub use tuple::{Key, Kind, Mapper, ReconfigSpec, Tuple};
