//! The reactive threshold controller of §8.4 (Q4).
//!
//! Thresholds: upper 90%, target 70%, lower 45% of processing capacity.
//! When the load of the active threads exceeds the upper threshold, the
//! smallest number of new threads that brings average utilization below
//! the target is provisioned; when load drops below the lower threshold,
//! the largest number of threads that keeps utilization below the target
//! is decommissioned.

use crate::elastic::controller::{resize_instance_set, Controller, Decision, Observation};
use crate::elastic::model::JoinCostModel;

#[derive(Clone, Copy, Debug)]
pub struct Thresholds {
    pub upper: f64,
    pub target: f64,
    pub lower: f64,
}

impl Default for Thresholds {
    fn default() -> Self {
        // §8.4: 90% / 70% / 45%
        Thresholds { upper: 0.90, target: 0.70, lower: 0.45 }
    }
}

pub struct ReactiveController {
    pub model: JoinCostModel,
    pub thresholds: Thresholds,
    /// Cooldown: one reconfiguration must complete before the next is
    /// issued (§6: reconfigurations are serialized).
    cooldown_ticks: u32,
    since_last: u32,
}

impl ReactiveController {
    pub fn new(model: JoinCostModel, thresholds: Thresholds) -> Self {
        ReactiveController { model, thresholds, cooldown_ticks: 2, since_last: u32::MAX }
    }

    pub fn with_cooldown(mut self, ticks: u32) -> Self {
        self.cooldown_ticks = ticks;
        self
    }
}

impl Controller for ReactiveController {
    fn tick(&mut self, obs: &Observation) -> Decision {
        self.since_last = self.since_last.saturating_add(1);
        if self.since_last < self.cooldown_ticks {
            return Decision::Hold;
        }
        let pi = obs.active.len();
        let u = self.model.utilization(obs.in_rate, pi);
        let decision = if u > self.thresholds.upper {
            // provision the smallest amount that reaches the target
            let need = self.model.threads_needed(obs.in_rate, self.thresholds.target);
            if need > pi {
                Some(need.min(obs.max))
            } else {
                None
            }
        } else if u < self.thresholds.lower {
            // decommission the largest amount that keeps below target
            let need = self.model.threads_needed(obs.in_rate, self.thresholds.target);
            if need < pi {
                Some(need.max(1))
            } else {
                None
            }
        } else {
            None
        };
        match decision {
            Some(target) if target != pi => {
                self.since_last = 0;
                Decision::Reconfigure(resize_instance_set(&obs.active, obs.max, target))
            }
            _ => Decision::Hold,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(rate: f64, active: Vec<usize>, max: usize) -> Observation {
        Observation { in_rate: rate, cmp_per_s: 0.0, backlog: 0, dt: 1.0, active, max }
    }

    fn controller() -> ReactiveController {
        // C = 1e6 c/s, WS = 10 s → Π(R) = R²·10/(2e6·0.7)
        ReactiveController::new(JoinCostModel::new(1e6, 10.0), Thresholds::default())
            .with_cooldown(0)
    }

    #[test]
    fn provisions_on_overload() {
        let mut c = controller();
        // R=1000: demand 5e6 c/s = 5 threads at 100%; with 2 threads → u=2.5
        match c.tick(&obs(1000.0, vec![0, 1], 16)) {
            Decision::Reconfigure(set) => {
                // target 0.7 → need ceil(5/0.7)=8
                assert_eq!(set.len(), 8);
                assert!(set.starts_with(&[0, 1]));
            }
            d => panic!("expected provision, got {d:?}"),
        }
    }

    #[test]
    fn decommissions_on_underload() {
        let mut c = controller();
        // R=100: demand 5e4 → 0.05 threads; with 8 threads u ≈ 0.006 < 0.45
        match c.tick(&obs(100.0, (0..8).collect(), 16)) {
            Decision::Reconfigure(set) => assert_eq!(set, vec![0]),
            d => panic!("expected decommission, got {d:?}"),
        }
    }

    #[test]
    fn holds_in_band() {
        let mut c = controller();
        // choose rate so utilization with 4 threads is ~0.6 (between 0.45 and 0.9)
        // u = R²·10/(2e6·4)=0.6 → R² = 480_000 → R ≈ 692.8
        assert_eq!(c.tick(&obs(692.8, vec![0, 1, 2, 3], 16)), Decision::Hold);
    }

    #[test]
    fn respects_max() {
        let mut c = controller();
        match c.tick(&obs(10_000.0, vec![0], 4)) {
            Decision::Reconfigure(set) => assert_eq!(set.len(), 4),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn cooldown_spaces_reconfigs() {
        let mut c = controller().with_cooldown(3);
        c.since_last = u32::MAX; // first tick allowed
        assert!(matches!(c.tick(&obs(1000.0, vec![0], 16)), Decision::Reconfigure(_)));
        // immediately after: held even though still overloaded
        assert_eq!(c.tick(&obs(1000.0, vec![0], 16)), Decision::Hold);
        assert_eq!(c.tick(&obs(1000.0, vec![0], 16)), Decision::Hold);
        assert!(matches!(c.tick(&obs(1000.0, vec![0], 16)), Decision::Reconfigure(_)));
    }
}
