//! The proactive model-based controller of §8.5 (Q5).
//!
//! Differences from the reactive controller, as the paper describes:
//! narrowed thresholds ([70%, 80%] of capacity), and the cost is matched
//! not only against current consumption but against *pending* (gate
//! backlog) and *predicted* (rate-trend extrapolation) workload, per the
//! DEBS'17 join performance model [22].

use crate::elastic::controller::{resize_instance_set, Controller, Decision, Observation};
use crate::elastic::model::JoinCostModel;

pub struct ProactiveController {
    pub model: JoinCostModel,
    /// Narrowed band: provision above `upper`, decommission below `lower`,
    /// aim for `target` (§8.5 uses [0.70, 0.80]).
    pub lower: f64,
    pub upper: f64,
    pub target: f64,
    /// EWMA smoothing for the rate estimate.
    alpha: f64,
    rate_ewma: f64,
    prev_rate: f64,
    /// Horizon (seconds) over which the rate trend is extrapolated.
    pub horizon: f64,
    /// Weight of backlog drain in the demand estimate (fraction of the
    /// horizon in which the backlog should be absorbed).
    pub drain_frac: f64,
}

impl ProactiveController {
    pub fn new(model: JoinCostModel) -> Self {
        ProactiveController {
            model,
            lower: 0.70,
            upper: 0.80,
            target: 0.75,
            alpha: 0.5,
            rate_ewma: 0.0,
            prev_rate: 0.0,
            horizon: 5.0,
            drain_frac: 0.5,
        }
    }

    /// Predicted input rate over the horizon: EWMA + linear trend.
    fn predict_rate(&mut self, obs: &Observation) -> f64 {
        if self.rate_ewma == 0.0 {
            self.rate_ewma = obs.in_rate;
        } else {
            self.rate_ewma = self.alpha * obs.in_rate + (1.0 - self.alpha) * self.rate_ewma;
        }
        let slope = if obs.dt > 0.0 { (obs.in_rate - self.prev_rate) / obs.dt } else { 0.0 };
        self.prev_rate = obs.in_rate;
        // extrapolate, never below the smoothed estimate during ramp-down
        // faster than the backlog justifies
        (self.rate_ewma + slope.max(0.0) * self.horizon).max(0.0)
    }

    /// Effective demand rate: predicted arrival rate plus the extra rate
    /// needed to drain the pending backlog within the drain window.
    fn effective_rate(&mut self, obs: &Observation) -> f64 {
        let predicted = self.predict_rate(obs);
        let drain_window = (self.horizon * self.drain_frac).max(0.1);
        predicted + obs.backlog as f64 / drain_window
    }
}

impl Controller for ProactiveController {
    fn tick(&mut self, obs: &Observation) -> Decision {
        let rate = self.effective_rate(obs);
        let pi = obs.active.len();
        let u = self.model.utilization(rate, pi);
        if u > self.upper || u < self.lower {
            let need = self.model.threads_needed(rate, self.target).clamp(1, obs.max);
            if need != pi {
                return Decision::Reconfigure(resize_instance_set(&obs.active, obs.max, need));
            }
        }
        Decision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(rate: f64, backlog: u64, active: Vec<usize>, max: usize) -> Observation {
        Observation { in_rate: rate, cmp_per_s: 0.0, backlog, dt: 1.0, active, max }
    }

    fn ctl() -> ProactiveController {
        ProactiveController::new(JoinCostModel::new(1e6, 10.0))
    }

    #[test]
    fn reacts_to_rate_ramp_before_reactive_would() {
        let mut c = ctl();
        // warm up at a steady 300 t/s (needs 1 thread: u=0.45/thread)
        for _ in 0..5 {
            let _ = c.tick(&obs(300.0, 0, vec![0], 16));
        }
        // sudden ramp to 600 t/s: trend extrapolation over 5 s predicts
        // ~1800+ t/s → provisions well beyond the instantaneous need
        match c.tick(&obs(600.0, 0, vec![0], 16)) {
            Decision::Reconfigure(set) => {
                let instantaneous = JoinCostModel::new(1e6, 10.0).threads_needed(600.0, 0.75);
                assert!(set.len() > instantaneous, "proactive must lead the ramp");
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn backlog_adds_demand() {
        let mut a = ctl();
        let mut b = ctl();
        for _ in 0..5 {
            let _ = a.tick(&obs(400.0, 0, vec![0, 1], 16));
            let _ = b.tick(&obs(400.0, 0, vec![0, 1], 16));
        }
        let da = a.tick(&obs(400.0, 0, vec![0, 1], 16));
        let db = b.tick(&obs(400.0, 5000, vec![0, 1], 16));
        // same rate, but a big backlog must demand more threads
        let na = match da {
            Decision::Reconfigure(ref s) => s.len(),
            Decision::Hold => 2,
        };
        let nb = match db {
            Decision::Reconfigure(ref s) => s.len(),
            Decision::Hold => 2,
        };
        assert!(nb > na, "backlog must raise the target ({na} vs {nb})");
    }

    #[test]
    fn decommissions_when_rate_drops() {
        let mut c = ctl();
        for _ in 0..8 {
            let _ = c.tick(&obs(1200.0, 0, (0..11).collect(), 16));
        }
        // rate collapses; EWMA converges down over a few ticks
        let mut last = Decision::Hold;
        for _ in 0..8 {
            last = c.tick(&obs(100.0, 0, (0..11).collect(), 16));
        }
        match last {
            Decision::Reconfigure(set) => assert!(set.len() < 11),
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn holds_in_band() {
        let mut c = ctl();
        // pick Π so utilization lands inside [0.70, 0.80]:
        // R=1000 → demand 5e6 c/s = 5 thread-equivalents; Π=7 → u≈0.714
        for _ in 0..6 {
            let _ = c.tick(&obs(1000.0, 0, (0..7).collect(), 16));
        }
        assert_eq!(c.tick(&obs(1000.0, 0, (0..7).collect(), 16)), Decision::Hold);
    }
}
