//! Controller interface: STRETCH "does not aim at embedding a specific
//! policy ... but rather defines a generic API for external modules" (§3).
//!
//! A controller is polled with [`Observation`]s (metrics snapshots) and
//! returns the next instance set when a reconfiguration is warranted; the
//! driver forwards it to [`crate::engine::ControlPlane::reconfigure`].

use crate::tuple::InstanceId;

/// A metrics snapshot handed to the controller each tick.
#[derive(Clone, Debug)]
pub struct Observation {
    /// Observed/estimated input rate (t/s).
    pub in_rate: f64,
    /// Observed comparison throughput (c/s) since last tick.
    pub cmp_per_s: f64,
    /// Input-gate backlog (pending tuples) — the controller's signal for
    /// pending workload (§8.5's "accounts also for the pending ...
    /// workload").
    pub backlog: u64,
    /// Seconds since the previous observation.
    pub dt: f64,
    /// Currently active instance ids (𝕆).
    pub active: Vec<InstanceId>,
    /// Maximum parallelism n (pool included).
    pub max: usize,
}

/// Decision returned by a controller tick.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Keep the current configuration.
    Hold,
    /// Reconfigure to this instance set.
    Reconfigure(Vec<InstanceId>),
}

/// The policy interface.
pub trait Controller: Send {
    fn tick(&mut self, obs: &Observation) -> Decision;
}

/// Choose the next instance set of size `target` given the current set:
/// keep existing ids, grow from the lowest free ids, shrink from the
/// highest active ids (the paper's pool semantics, §7).
///
/// O(active + max): one boolean-membership pass replaces the former
/// `set.contains` scan inside the free-id loop (O(active·max)), which
/// stalled controller ticks on pools with `max` in the hundreds.
pub fn resize_instance_set(active: &[InstanceId], max: usize, target: usize) -> Vec<InstanceId> {
    let target = target.clamp(1, max);
    let mut set: Vec<InstanceId> = active.to_vec();
    set.sort_unstable();
    if target <= set.len() {
        set.truncate(target);
        return set;
    }
    let mut member = vec![false; max];
    for &i in &set {
        if i < max {
            member[i] = true;
        }
    }
    for (id, used) in member.iter().enumerate() {
        if set.len() == target {
            break;
        }
        if !used {
            set.push(id);
        }
    }
    set.sort_unstable();
    set
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resize_grows_from_pool() {
        assert_eq!(resize_instance_set(&[0, 2], 6, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn resize_shrinks_highest_first() {
        assert_eq!(resize_instance_set(&[0, 1, 2, 3], 6, 2), vec![0, 1]);
    }

    #[test]
    fn resize_clamps() {
        assert_eq!(resize_instance_set(&[0], 4, 0), vec![0]);
        assert_eq!(resize_instance_set(&[0], 4, 99).len(), 4);
    }

    #[test]
    fn resize_identity() {
        assert_eq!(resize_instance_set(&[1, 3], 6, 2), vec![1, 3]);
    }
}
