//! Topology-aware elasticity: co-schedule per-stage parallelism against
//! a global core budget.
//!
//! Per-stage controllers (reactive/proactive) decide in isolation — on a
//! multi-stage topology they can collectively over-subscribe the
//! machine, or starve the stage that actually gates end-to-end
//! throughput. [`DagController`] looks at every stage of a pipeline/DAG
//! at once (Röger & Mayer's survey calls this the *global* scaling
//! scope; Elasticutor's coordinator plays the same role) and divides a
//! fixed core budget by need, where need is each stage's `in_backlog` —
//! the one signal that composes across stages, because a bottleneck
//! stage's gate is where tuples visibly pile up.
//!
//! Policy per tick (deterministic, O(stages·log stages)):
//! 1. cold stages (backlog ≤ `shrink_backlog`) release one core;
//! 2. hot stages (backlog ≥ `grow_backlog`) request one core, granted in
//!    descending-backlog order while the budget holds — a core released
//!    in step 1 is re-grantable in the same tick, so load shifts between
//!    stages in one reconfiguration wave instead of two;
//! 3. if the budget is exceeded (e.g. a shrunken budget), the coldest
//!    stages are forcibly shrunk until the sum fits.
//!
//! Instance-id selection reuses [`resize_instance_set`] (keep existing,
//! grow from the lowest pool ids, shrink from the highest).

use crate::elastic::controller::{resize_instance_set, Decision, Observation};

/// Global, budgeted multi-stage controller. Tick it with one
/// [`Observation`] per stage (same order every tick); it returns one
/// [`Decision`] per stage.
pub struct DagController {
    /// Global core budget: Σ per-stage parallelism stays ≤ this.
    pub cores: usize,
    /// Backlog at/above which a stage requests one more core.
    pub grow_backlog: u64,
    /// Backlog at/below which a stage releases one core.
    pub shrink_backlog: u64,
    /// Ticks a stage holds still after a reconfiguration it took part in.
    pub cooldown_ticks: u32,
    cool: Vec<u32>,
}

impl DagController {
    pub fn new(cores: usize) -> Self {
        DagController {
            cores: cores.max(1),
            grow_backlog: 4096,
            shrink_backlog: 64,
            cooldown_ticks: 1,
            cool: Vec::new(),
        }
    }

    pub fn with_thresholds(mut self, grow_backlog: u64, shrink_backlog: u64) -> Self {
        self.grow_backlog = grow_backlog.max(1);
        self.shrink_backlog = shrink_backlog.min(self.grow_backlog.saturating_sub(1));
        self
    }

    pub fn with_cooldown(mut self, ticks: u32) -> Self {
        self.cooldown_ticks = ticks;
        self
    }

    /// One co-scheduling round over every stage.
    pub fn tick(&mut self, obs: &[Observation]) -> Vec<Decision> {
        if self.cool.len() < obs.len() {
            self.cool.resize(obs.len(), 0);
        }
        let mut target: Vec<usize> = obs.iter().map(|o| o.active.len()).collect();
        let mut movable: Vec<bool> = Vec::with_capacity(obs.len());
        for (i, o) in obs.iter().enumerate() {
            let free = self.cool[i] == 0;
            if !free {
                self.cool[i] -= 1;
            }
            movable.push(free);
            // 1. cold stages release a core
            if free && o.backlog <= self.shrink_backlog && target[i] > 1 {
                target[i] -= 1;
            }
        }
        // 2. hot stages take cores in descending-backlog order
        let mut used: usize = target.iter().sum();
        let mut want: Vec<usize> = (0..obs.len())
            .filter(|&i| {
                movable[i] && obs[i].backlog >= self.grow_backlog && target[i] < obs[i].max
            })
            .collect();
        want.sort_by_key(|&i| std::cmp::Reverse(obs[i].backlog));
        for i in want {
            if used < self.cores {
                target[i] += 1;
                used += 1;
            }
        }
        // 3. over budget (shrunk budget or oversized initial config):
        // force the coldest movable stages down until the sum fits
        if used > self.cores {
            let mut by_cold: Vec<usize> = (0..obs.len()).collect();
            by_cold.sort_by_key(|&i| obs[i].backlog);
            'fit: while used > self.cores {
                let mut any = false;
                for &i in &by_cold {
                    if movable[i] && target[i] > 1 {
                        target[i] -= 1;
                        used -= 1;
                        any = true;
                        if used <= self.cores {
                            break 'fit;
                        }
                    }
                }
                if !any {
                    break; // every stage at 1 or cooling — nothing to take
                }
            }
        }
        obs.iter()
            .enumerate()
            .map(|(i, o)| {
                if target[i] == o.active.len() {
                    Decision::Hold
                } else {
                    self.cool[i] = self.cooldown_ticks;
                    Decision::Reconfigure(resize_instance_set(&o.active, o.max, target[i]))
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(active: usize, max: usize, backlog: u64) -> Observation {
        Observation {
            in_rate: 0.0,
            cmp_per_s: 0.0,
            backlog,
            dt: 1.0,
            active: (0..active).collect(),
            max,
        }
    }

    #[test]
    fn hottest_stage_wins_the_last_core() {
        let mut c = DagController::new(4).with_thresholds(100, 10).with_cooldown(0);
        // 3 stages × 1 core used; 1 core free; two stages hot
        let d = c.tick(&[obs(1, 4, 5_000), obs(1, 4, 500), obs(1, 4, 50)]);
        assert_eq!(d[0], Decision::Reconfigure(vec![0, 1]), "hottest grows");
        assert_eq!(d[1], Decision::Hold, "budget exhausted for the cooler stage");
        assert_eq!(d[2], Decision::Hold);
    }

    #[test]
    fn cold_stage_releases_core_for_hot_stage_same_tick() {
        let mut c = DagController::new(4).with_thresholds(100, 10).with_cooldown(0);
        // budget fully used (2+2); stage 1 idle, stage 0 overloaded
        let d = c.tick(&[obs(2, 4, 10_000), obs(2, 4, 0)]);
        assert_eq!(d[0], Decision::Reconfigure(vec![0, 1, 2]), "hot stage takes the freed core");
        assert_eq!(d[1], Decision::Reconfigure(vec![0]), "cold stage yields");
    }

    #[test]
    fn holds_inside_the_band_and_respects_max() {
        let mut c = DagController::new(8).with_thresholds(100, 10).with_cooldown(0);
        let d = c.tick(&[obs(2, 2, 50_000), obs(1, 4, 50)]);
        assert_eq!(d[0], Decision::Hold, "already at max");
        assert_eq!(d[1], Decision::Hold, "inside the hold band");
    }

    #[test]
    fn over_budget_config_is_forced_down() {
        let mut c = DagController::new(3).with_thresholds(1_000_000, 0).with_cooldown(0);
        // 2+2+2 = 6 on a 3-core budget, nobody hot or cold
        let d = c.tick(&[obs(2, 4, 500), obs(2, 4, 400), obs(2, 4, 300)]);
        let total: usize = d
            .iter()
            .zip([2, 2, 2])
            .map(|(dec, cur)| match dec {
                Decision::Hold => cur,
                Decision::Reconfigure(set) => set.len(),
            })
            .sum();
        assert!(total <= 3, "budget must be enforced, got {total}");
        assert!(d.iter().all(|dec| match dec {
            Decision::Hold => true,
            Decision::Reconfigure(set) => !set.is_empty(),
        }));
    }

    #[test]
    fn cooldown_freezes_a_stage_for_a_tick() {
        let mut c = DagController::new(8).with_thresholds(100, 10).with_cooldown(1);
        let d = c.tick(&[obs(1, 4, 5_000)]);
        assert!(matches!(d[0], Decision::Reconfigure(_)));
        let d = c.tick(&[obs(2, 4, 5_000)]);
        assert_eq!(d[0], Decision::Hold, "cooling down");
        let d = c.tick(&[obs(2, 4, 5_000)]);
        assert!(matches!(d[0], Decision::Reconfigure(_)), "cooldown expired");
    }

    #[test]
    fn never_shrinks_below_one() {
        let mut c = DagController::new(4).with_thresholds(100, 10).with_cooldown(0);
        let d = c.tick(&[obs(1, 4, 0), obs(1, 4, 0)]);
        assert_eq!(d, vec![Decision::Hold, Decision::Hold]);
    }
}
