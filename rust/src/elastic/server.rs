//! Fleet-level elasticity: arbitrate ONE core budget across many jobs.
//!
//! [`super::dag::DagController`] co-schedules the stages of a single
//! topology. A multi-job server (`harness::server::JobServer`) faces the
//! tier above — Röger & Mayer's survey (PAPERS.md) calls cross-application
//! resource arbitration the open problem past per-operator elasticity —
//! so [`ServerController`] generalizes the same shrink-then-grant wave
//! per job × per stage:
//!
//! 1. cold stages of any non-cooling job release one core;
//! 2. hot stages take cores in descending *weighted*-backlog order
//!    ([`JobShare::weight`] biases the contest — a weight-2 job wins
//!    against a weight-1 job with the same backlog) while the global
//!    budget holds;
//! 3. if the fleet is over budget, the coldest movable stages are forced
//!    down — but never below one instance per stage nor below a job's
//!    admitted [`JobShare::min_cores`] floor, so admission control's
//!    guarantee (Σ min ≤ budget) makes the fit loop converge.
//!
//! Cooldown is per *job*: any reconfiguration freezes the whole job for
//! [`ServerController::cooldown_ticks`] waves, so one job's epoch churn
//! cannot starve the arbitration of the others.

use crate::elastic::controller::{resize_instance_set, Decision, Observation};

/// A job's standing in the arbitration: how hard it pulls on the budget
/// and how far it can be squeezed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobShare {
    /// Backlog multiplier in the grant/steal ordering (≥ 0; 1.0 =
    /// neutral). Higher weight wins contested cores and loses forced
    /// shrinks last.
    pub weight: f64,
    /// Admission floor: the arbitration never takes the job's total
    /// below this many cores (clamped up to one per stage implicitly —
    /// no stage ever goes below one instance).
    pub min_cores: usize,
}

impl Default for JobShare {
    fn default() -> Self {
        JobShare { weight: 1.0, min_cores: 0 }
    }
}

/// Global, budgeted multi-job controller. Tick it with one
/// `(share, per-stage observations)` pair per job (same order every
/// wave); it returns one [`Decision`] per stage per job, aligned.
pub struct ServerController {
    /// Global core budget: Σ over every job's per-stage parallelism
    /// stays ≤ this (once reachable under the min-cores floors).
    pub cores: usize,
    /// Backlog at/above which a stage requests one more core.
    pub grow_backlog: u64,
    /// Backlog at/below which a stage releases one core.
    pub shrink_backlog: u64,
    /// Waves a job holds still after a reconfiguration it took part in.
    pub cooldown_ticks: u32,
    cool: Vec<u32>,
}

impl ServerController {
    pub fn new(cores: usize) -> Self {
        ServerController {
            cores: cores.max(1),
            grow_backlog: 4096,
            shrink_backlog: 64,
            cooldown_ticks: 1,
            cool: Vec::new(),
        }
    }

    pub fn with_thresholds(mut self, grow_backlog: u64, shrink_backlog: u64) -> Self {
        self.grow_backlog = grow_backlog.max(1);
        self.shrink_backlog = shrink_backlog.min(self.grow_backlog.saturating_sub(1));
        self
    }

    pub fn with_cooldown(mut self, ticks: u32) -> Self {
        self.cooldown_ticks = ticks;
        self
    }

    /// One arbitration wave over the whole fleet.
    pub fn tick(&mut self, jobs: &[(JobShare, Vec<Observation>)]) -> Vec<Vec<Decision>> {
        if self.cool.len() < jobs.len() {
            self.cool.resize(jobs.len(), 0);
        }
        // (job, stage)-indexed working state
        let mut target: Vec<Vec<usize>> = jobs
            .iter()
            .map(|(_, obs)| obs.iter().map(|o| o.active.len()).collect())
            .collect();
        let mut free: Vec<bool> = Vec::with_capacity(jobs.len());
        for j in 0..jobs.len() {
            let f = self.cool[j] == 0;
            if !f {
                self.cool[j] -= 1;
            }
            free.push(f);
        }
        let job_total = |t: &Vec<Vec<usize>>, j: usize| -> usize { t[j].iter().sum() };
        let floor = |share: &JobShare| -> usize { share.min_cores };

        // 1. cold stages release a core (never below 1, never taking the
        // job under its admitted floor)
        for (j, (share, obs)) in jobs.iter().enumerate() {
            if !free[j] {
                continue;
            }
            for (i, o) in obs.iter().enumerate() {
                if o.backlog <= self.shrink_backlog
                    && target[j][i] > 1
                    && job_total(&target, j) > floor(share)
                {
                    target[j][i] -= 1;
                }
            }
        }

        // 2. hot stages take cores in descending weighted-backlog order
        let mut used: usize = (0..jobs.len()).map(|j| job_total(&target, j)).sum();
        let mut want: Vec<(usize, usize)> = Vec::new();
        for (j, (_, obs)) in jobs.iter().enumerate() {
            if !free[j] {
                continue;
            }
            for (i, o) in obs.iter().enumerate() {
                if o.backlog >= self.grow_backlog && target[j][i] < o.max {
                    want.push((j, i));
                }
            }
        }
        let heat = |j: usize, i: usize| -> f64 {
            jobs[j].1[i].backlog as f64 * jobs[j].0.weight.max(0.0)
        };
        want.sort_by(|&(aj, ai), &(bj, bi)| {
            heat(bj, bi).partial_cmp(&heat(aj, ai)).unwrap_or(std::cmp::Ordering::Equal)
        });
        for (j, i) in want {
            if used < self.cores {
                target[j][i] += 1;
                used += 1;
            }
        }

        // 3. over budget: force the globally coldest movable stages down
        // until the fleet fits (or nothing movable remains — every
        // remaining stage is at 1, at its job's floor, or cooling)
        if used > self.cores {
            let mut by_cold: Vec<(usize, usize)> = Vec::new();
            for (j, (_, obs)) in jobs.iter().enumerate() {
                for i in 0..obs.len() {
                    by_cold.push((j, i));
                }
            }
            by_cold.sort_by(|&(aj, ai), &(bj, bi)| {
                heat(aj, ai).partial_cmp(&heat(bj, bi)).unwrap_or(std::cmp::Ordering::Equal)
            });
            'fit: while used > self.cores {
                let mut any = false;
                for &(j, i) in &by_cold {
                    if free[j] && target[j][i] > 1 && job_total(&target, j) > floor(&jobs[j].0) {
                        target[j][i] -= 1;
                        used -= 1;
                        any = true;
                        if used <= self.cores {
                            break 'fit;
                        }
                    }
                }
                if !any {
                    break;
                }
            }
        }

        jobs.iter()
            .enumerate()
            .map(|(j, (_, obs))| {
                obs.iter()
                    .enumerate()
                    .map(|(i, o)| {
                        if target[j][i] == o.active.len() {
                            Decision::Hold
                        } else {
                            self.cool[j] = self.cooldown_ticks;
                            Decision::Reconfigure(resize_instance_set(
                                &o.active,
                                o.max,
                                target[j][i],
                            ))
                        }
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(active: usize, max: usize, backlog: u64) -> Observation {
        Observation {
            in_rate: 0.0,
            cmp_per_s: 0.0,
            backlog,
            dt: 1.0,
            active: (0..active).collect(),
            max,
        }
    }

    fn share(weight: f64, min_cores: usize) -> JobShare {
        JobShare { weight, min_cores }
    }

    fn totals(cur: &[(JobShare, Vec<Observation>)], d: &[Vec<Decision>]) -> Vec<usize> {
        cur.iter()
            .zip(d)
            .map(|((_, obs), dj)| {
                obs.iter()
                    .zip(dj)
                    .map(|(o, dec)| match dec {
                        Decision::Hold => o.active.len(),
                        Decision::Reconfigure(set) => set.len(),
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn hot_job_takes_the_idle_jobs_core_same_wave() {
        let mut c = ServerController::new(4).with_thresholds(100, 10).with_cooldown(0);
        // budget fully used (2+2); job 1 idle, job 0 overloaded
        let jobs = vec![
            (share(1.0, 1), vec![obs(2, 4, 10_000)]),
            (share(1.0, 1), vec![obs(2, 4, 0)]),
        ];
        let d = c.tick(&jobs);
        assert_eq!(d[0][0], Decision::Reconfigure(vec![0, 1, 2]), "hot job grows");
        assert_eq!(d[1][0], Decision::Reconfigure(vec![0]), "idle job yields");
    }

    #[test]
    fn weight_breaks_the_tie_for_the_last_core() {
        let mut c = ServerController::new(3).with_thresholds(100, 10).with_cooldown(0);
        // one spare core, both jobs equally hot — the heavier weight wins
        let jobs = vec![
            (share(1.0, 1), vec![obs(1, 4, 5_000)]),
            (share(2.0, 1), vec![obs(1, 4, 5_000)]),
        ];
        let d = c.tick(&jobs);
        assert_eq!(d[0][0], Decision::Hold, "light job loses the contest");
        assert_eq!(d[1][0], Decision::Reconfigure(vec![0, 1]), "heavy job wins");
    }

    #[test]
    fn forced_fit_respects_job_floors_and_converges() {
        let mut c = ServerController::new(4).with_thresholds(1_000_000, 0).with_cooldown(0);
        // 3 + 3 = 6 on a 4-core budget; job 0's floor is 3 so job 1
        // absorbs the whole squeeze
        let jobs = vec![
            (share(1.0, 3), vec![obs(3, 4, 500)]),
            (share(1.0, 1), vec![obs(3, 4, 400)]),
        ];
        let d = c.tick(&jobs);
        let t = totals(&jobs, &d);
        assert_eq!(t[0], 3, "floored job untouched");
        assert_eq!(t[1], 1, "unfloored job squeezed");
        assert!(t.iter().sum::<usize>() <= 4);
    }

    #[test]
    fn budget_is_enforced_across_jobs() {
        let mut c = ServerController::new(5).with_thresholds(100, 10).with_cooldown(0);
        // every stage hot: grants stop exactly at the budget
        let jobs = vec![
            (share(1.0, 2), vec![obs(1, 4, 9_000), obs(1, 4, 8_000)]),
            (share(1.0, 2), vec![obs(1, 4, 7_000), obs(1, 4, 6_000)]),
        ];
        let d = c.tick(&jobs);
        let t = totals(&jobs, &d);
        assert_eq!(t.iter().sum::<usize>(), 5, "grants fill the budget exactly");
        // the hottest stage (job 0, stage 0) got the spare core
        assert_eq!(d[0][0], Decision::Reconfigure(vec![0, 1]));
    }

    #[test]
    fn cooldown_freezes_the_whole_job_for_a_wave() {
        let mut c = ServerController::new(8).with_thresholds(100, 10).with_cooldown(1);
        let jobs = vec![(share(1.0, 1), vec![obs(1, 4, 5_000), obs(1, 4, 5_000)])];
        let d = c.tick(&jobs);
        assert!(matches!(d[0][0], Decision::Reconfigure(_)));
        let jobs2 = vec![(share(1.0, 1), vec![obs(2, 4, 5_000), obs(2, 4, 5_000)])];
        let d = c.tick(&jobs2);
        assert_eq!(d[0], vec![Decision::Hold, Decision::Hold], "whole job cooling");
        let d = c.tick(&jobs2);
        assert!(matches!(d[0][0], Decision::Reconfigure(_)), "cooldown expired");
    }

    #[test]
    fn never_shrinks_a_stage_below_one() {
        let mut c = ServerController::new(2).with_thresholds(100, 10).with_cooldown(0);
        let jobs = vec![
            (share(1.0, 0), vec![obs(1, 4, 0), obs(1, 4, 0)]),
            (share(1.0, 0), vec![obs(1, 4, 0)]),
        ];
        let d = c.tick(&jobs);
        for dj in &d {
            for dec in dj {
                assert_eq!(*dec, Decision::Hold);
            }
        }
    }
}
