//! Stream-join performance model (Gulisano et al., DEBS'17 [22] — the
//! model both §8.4's controller and §8.5's proactive controller build on).
//!
//! For a ScaleJoin-style operator fed at rate R (t/s across both streams)
//! with window size WS (seconds), every incoming tuple is compared against
//! the tuples currently stored in the opposite window (≈ R·WS/2 per side
//! → ≈ R·WS/2 comparisons per tuple against the opposite stream, i.e.
//! total comparison throughput D(R) ≈ R²·WS/2 c/s for balanced streams).
//! With Π threads each sustaining C comparisons/second, the operator is
//! feasible iff D(R) ≤ Π·C, giving
//!
//! * threads needed:      Π(R) = ⌈R²·WS / (2C)⌉
//! * max sustainable rate: R_max(Π) = sqrt(2·Π·C / WS)
//!
//! C is *calibrated*, not assumed: [`JoinCostModel::calibrate`] measures
//! the single-thread comparison throughput of this build on this machine.

/// Calibrated cost model for a band-join workload.
#[derive(Clone, Copy, Debug)]
pub struct JoinCostModel {
    /// Comparisons per second one thread sustains (calibrated).
    pub cmp_per_sec: f64,
    /// Window size in seconds.
    pub ws_secs: f64,
    /// Per-tuple fixed overhead (seconds): gate + window maintenance.
    pub per_tuple_overhead: f64,
}

impl JoinCostModel {
    pub fn new(cmp_per_sec: f64, ws_secs: f64) -> Self {
        assert!(cmp_per_sec > 0.0 && ws_secs > 0.0);
        JoinCostModel { cmp_per_sec, ws_secs, per_tuple_overhead: 0.0 }
    }

    /// Comparison demand (c/s) at input rate `rate` t/s (both streams).
    pub fn demand(&self, rate: f64) -> f64 {
        rate * rate * self.ws_secs / 2.0
    }

    /// Fraction of one thread consumed per tuple-rate overhead.
    fn overhead_load(&self, rate: f64) -> f64 {
        rate * self.per_tuple_overhead
    }

    /// Utilization of Π threads at input rate `rate` (1.0 = saturated).
    pub fn utilization(&self, rate: f64, threads: usize) -> f64 {
        if threads == 0 {
            return f64::INFINITY;
        }
        (self.demand(rate) / self.cmp_per_sec + self.overhead_load(rate)) / threads as f64
    }

    /// Threads needed to keep utilization at or below `target` (0-1].
    pub fn threads_needed(&self, rate: f64, target: f64) -> usize {
        assert!(target > 0.0);
        let load = self.demand(rate) / self.cmp_per_sec + self.overhead_load(rate);
        (load / target).ceil().max(1.0) as usize
    }

    /// Max sustainable input rate with Π threads at full utilization.
    pub fn max_rate(&self, threads: usize) -> f64 {
        // solve R²·WS/(2C) + R·o = Π  (quadratic in R)
        let a = self.ws_secs / (2.0 * self.cmp_per_sec);
        let b = self.per_tuple_overhead;
        let c = -(threads as f64);
        if a == 0.0 {
            return -c / b.max(1e-12);
        }
        (-b + (b * b - 4.0 * a * c).sqrt()) / (2.0 * a)
    }

    /// Calibrate single-thread comparison throughput with the actual
    /// predicate evaluation loop (used by benches and controllers).
    pub fn calibrate<F: FnMut() -> u64>(ws_secs: f64, mut run_batch: F) -> Self {
        let t0 = std::time::Instant::now();
        let mut total = 0u64;
        while t0.elapsed().as_millis() < 200 {
            total += run_batch();
        }
        let cps = total as f64 / t0.elapsed().as_secs_f64();
        JoinCostModel::new(cps.max(1.0), ws_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demand_quadratic_in_rate() {
        let m = JoinCostModel::new(1e6, 10.0);
        assert!((m.demand(100.0) - 50_000.0).abs() < 1e-6);
        assert!((m.demand(200.0) / m.demand(100.0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn threads_needed_matches_utilization() {
        let m = JoinCostModel::new(1e6, 10.0);
        for rate in [50.0, 100.0, 400.0, 1000.0] {
            let n = m.threads_needed(rate, 0.7);
            assert!(m.utilization(rate, n) <= 0.7 + 1e-9, "rate={rate} n={n}");
            if n > 1 {
                assert!(m.utilization(rate, n - 1) > 0.7, "rate={rate} n={n}");
            }
        }
    }

    #[test]
    fn max_rate_inverts_threads() {
        let m = JoinCostModel::new(1e6, 10.0);
        for pi in [1usize, 4, 16, 64] {
            let r = m.max_rate(pi);
            let u = m.utilization(r, pi);
            assert!((u - 1.0).abs() < 1e-6, "pi={pi} u={u}");
        }
        // R_max grows with sqrt(Π)
        let r1 = m.max_rate(1);
        let r4 = m.max_rate(4);
        assert!((r4 / r1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn overhead_shifts_capacity() {
        let mut m = JoinCostModel::new(1e6, 10.0);
        let base = m.max_rate(4);
        m.per_tuple_overhead = 1e-4;
        assert!(m.max_rate(4) < base);
    }

    #[test]
    fn calibration_produces_positive_rate() {
        let m = JoinCostModel::calibrate(5.0, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc += (i % 7 == 0) as u64;
            }
            std::hint::black_box(acc);
            10_000
        });
        assert!(m.cmp_per_sec > 10_000.0);
    }
}
