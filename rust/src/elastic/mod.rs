//! Elasticity controllers (§8.4-§8.5) and the join performance model.
//!
//! STRETCH itself only defines the reconfiguration *mechanism* (epochs +
//! control tuples, `crate::engine`); these are the external policy modules
//! the evaluation plugs in: the reactive 90/70/45 threshold controller
//! (Q4) and the proactive model-based controller (Q5), both built on the
//! calibrated stream-join cost model of DEBS'17 [22], plus the
//! topology-aware [`DagController`] that co-schedules every stage of a
//! pipeline/DAG against a global core budget, and the fleet-level
//! [`ServerController`] that arbitrates one budget across many jobs
//! (`harness::server::JobServer`).

pub mod controller;
pub mod dag;
pub mod model;
pub mod proactive;
pub mod reactive;
pub mod server;

pub use controller::{resize_instance_set, Controller, Decision, Observation};
pub use dag::DagController;
pub use model::JoinCostModel;
pub use proactive::ProactiveController;
pub use reactive::{ReactiveController, Thresholds};
pub use server::{JobShare, ServerController};
