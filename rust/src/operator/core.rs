//! The per-instance processing core shared by `processSN` (Alg. 2) and
//! `processVSN` (Alg. 4): watermark maintenance, the expired-window loop
//! (L33-35 / L22-24) driven by a per-instance expiry index, and
//! `handleInputTuple` (L19-30).
//!
//! The same core runs in both setups; only the state location (private vs
//! shared σ) and the epoch/membership handling around it (in
//! [`crate::engine`]) differ — that is precisely the VSN virtualization
//! argument of §5.

use crate::metrics::OperatorMetrics;
use crate::operator::state::{KeyState, SharedState, WindowSet};
use crate::operator::{Ctx, OperatorDef, OperatorLogic, WindowType};
use crate::time::{EventTime, TIME_MAX};
use crate::tuple::{InstanceId, Key, Mapper, Tuple};
use crate::watermark::Watermark;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// One instance's processing state for an `O+`.
pub struct OperatorCore<L: OperatorLogic> {
    pub def: OperatorDef<L>,
    pub id: InstanceId,
    state: Arc<SharedState<L::State>>,
    w: Watermark,
    /// Earliest-first (expiry_ts, key) index over this instance's keys.
    expiry: BinaryHeap<Reverse<(EventTime, Key)>>,
    keys_buf: Vec<Key>,
    /// Shard-grouped plan of this instance's keys, valid only for
    /// constant-key operators (keys_are_constant); rebuilt per mapper.
    key_plan: Option<Vec<(usize, Vec<Key>)>>,
    key_plan_stamp: u64,
    pub metrics: Arc<OperatorMetrics>,
}

impl<L: OperatorLogic> OperatorCore<L> {
    pub fn new(
        def: OperatorDef<L>,
        id: InstanceId,
        state: Arc<SharedState<L::State>>,
        metrics: Arc<OperatorMetrics>,
    ) -> Self {
        OperatorCore {
            def,
            id,
            state,
            w: Watermark::new(),
            expiry: BinaryHeap::new(),
            keys_buf: Vec::with_capacity(16),
            key_plan: None,
            key_plan_stamp: u64::MAX,
            metrics,
        }
    }

    /// Build (or reuse) the shard-grouped plan of this instance's keys
    /// under `f_mu` — only for constant-key operators. The stamp is a
    /// cheap fingerprint of the mapper's instance set.
    fn key_plan_for(&mut self, f_mu: &Mapper, probe: &Tuple<L::In>) -> bool {
        if !self.def.logic.keys_are_constant() {
            return false;
        }
        let stamp = {
            let insts = f_mu.instances();
            insts.iter().fold(insts.len() as u64, |a, &i| {
                a.wrapping_mul(1099511628211).wrapping_add(i as u64 + 1)
            })
        };
        if self.key_plan.is_some() && self.key_plan_stamp == stamp {
            return true;
        }
        let mut keys = Vec::new();
        self.def.logic.keys(probe, &mut keys);
        let mut groups: std::collections::BTreeMap<usize, Vec<Key>> = Default::default();
        for k in keys {
            if f_mu.map(k) == self.id {
                groups.entry(self.state.shard_index(k)).or_default().push(k);
            }
        }
        self.key_plan = Some(groups.into_iter().collect());
        self.key_plan_stamp = stamp;
        true
    }

    /// Current instance watermark W.
    #[inline]
    pub fn watermark(&self) -> EventTime {
        self.w.get()
    }

    /// updateW: returns `true` iff W strictly increased (the reconfig
    /// trigger precondition of Alg. 4 L17).
    #[inline]
    pub fn observe(&mut self, ts: EventTime) -> bool {
        self.w.update(ts)
    }

    /// Shared state handle (for diagnostics / engine wiring).
    pub fn state(&self) -> &Arc<SharedState<L::State>> {
        &self.state
    }

    /// The expired-window loop (Alg. 2 L33-35 / Alg. 4 L22-24): handle, in
    /// global (expiry-ts, key) order, every expired window set whose key is
    /// this instance's responsibility under `f_mu`.
    pub fn advance(&mut self, f_mu: &Mapper, ctx: &mut Ctx<'_, L::Out>) {
        let w = self.w.get();
        let ws = self.def.spec.size;
        let wa = self.def.spec.advance;
        let wt = self.def.wt;
        let logic = &self.def.logic;
        let has_output = logic.has_output();
        while let Some(&Reverse((at, key))) = self.expiry.peek() {
            if at > w {
                break;
            }
            self.expiry.pop();
            // Responsibility check (Alg. 4 L23). Entries are rebuilt on
            // epoch switches, but a stale entry must not touch foreign keys.
            if f_mu.map(key) != self.id {
                continue;
            }
            let state = &self.state;
            let expiry = &mut self.expiry;
            state.with_existing(key, |ks: &mut KeyState<L::State>| {
                if ks.next_expiry != at {
                    return ((), true); // stale heap entry: a newer one exists
                }
                ks.next_expiry = TIME_MAX;
                let Some(front) = ks.wins.front_mut() else { return ((), false) };
                debug_assert!(
                    front.l + ws <= at || (wt == WindowType::Single && !has_output),
                    "expiry index out of sync"
                );
                ctx.win_right = at;
                match wt {
                    WindowType::Multi => {
                        logic.output(front, ctx);
                        ks.wins.pop_front();
                        match ks.front_expiry(ws) {
                            Some(e) => {
                                ks.next_expiry = e;
                                expiry.push(Reverse((e, key)));
                                ((), true)
                            }
                            None => ((), false), // no windows left: σ.remove
                        }
                    }
                    WindowType::Single => {
                        let new_l = if has_output {
                            logic.output(front, ctx);
                            front.l + wa
                        } else {
                            // fast-forward: every skipped step emits nothing
                            self_first_unexpired(front.l, wa, ws, w)
                        };
                        if logic.slide(front, new_l) {
                            front.l = new_l;
                            // With f_O defined, the next step is exactly one
                            // WA later. Without it (ScaleJoin, WA = δ) the
                            // slide is pure purge hygiene — f_U already
                            // purges on every probe — so re-arm lazily:
                            // per-tuple re-sliding of every key was the #1
                            // hot-path cost (§Perf, EXPERIMENTS.md).
                            let e = if has_output {
                                new_l + ws
                            } else {
                                w + (ws / 4).max(wa)
                            };
                            ks.next_expiry = e;
                            expiry.push(Reverse((e, key)));
                            ((), true)
                        } else {
                            ks.wins.pop_front();
                            ((), ks.wins.front().is_some())
                        }
                    }
                }
            });
            ctx.flush(); // sink emissions with no shard lock held
        }
    }

    /// handleInputTuple (Alg. 2 L19-30): create/update the window sets of
    /// every key of `t` that is this instance's responsibility.
    pub fn handle_input(&mut self, t: &Tuple<L::In>, f_mu: &Mapper, ctx: &mut Ctx<'_, L::Out>) {
        // Fast path for constant-key operators (ScaleJoin, Operator 6):
        // shard-grouped key plan, one lock per shard per tuple (§Perf).
        if self.def.wt == WindowType::Single && self.key_plan_for(f_mu, t) {
            let logic = self.def.logic.clone();
            let spec = self.def.spec;
            let ws = spec.size;
            let inputs = self.def.inputs;
            let t1 = spec.earliest_win_l(t.ts);
            let plan = self.key_plan.take().unwrap();
            let state = self.state.clone();
            let expiry = &mut self.expiry;
            for (shard, keys) in &plan {
                state.with_key_group(*shard, keys, |k, ks| {
                    if ks.wins.is_empty() {
                        ks.wins.push_back(WindowSet::new(k, t1, inputs));
                    }
                    let set = ks.wins.front_mut().unwrap();
                    ctx.win_right = (set.l + ws).max(t.ts + 1);
                    logic.update(set, t, ctx);
                    if let Some(e) = ks.front_expiry(ws) {
                        if e < ks.next_expiry {
                            ks.next_expiry = e;
                            expiry.push(Reverse((e, k)));
                        }
                    }
                    !ks.wins.is_empty()
                });
                ctx.flush();
            }
            self.key_plan = Some(plan);
            return;
        }
        let logic = self.def.logic.clone();
        self.keys_buf.clear();
        logic.keys(t, &mut self.keys_buf);
        if self.keys_buf.is_empty() {
            return;
        }
        let spec = self.def.spec;
        let inputs = self.def.inputs;
        let wt = self.def.wt;
        let ws = spec.size;
        let t1 = spec.earliest_win_l(t.ts);
        let t2 = match wt {
            WindowType::Single => t1,
            WindowType::Multi => spec.latest_win_l(t.ts),
        };
        let id = self.id;
        let state = self.state.clone();
        let expiry = &mut self.expiry;
        for idx in 0..self.keys_buf.len() {
            let k = self.keys_buf[idx];
            if f_mu.map(k) != id {
                continue;
            }
            state.with_key(k, |ks: &mut KeyState<L::State>| {
                match wt {
                    WindowType::Single => {
                        if ks.wins.is_empty() {
                            ks.wins.push_back(WindowSet::new(k, t1, inputs));
                        }
                        let set = ks.wins.front_mut().unwrap();
                        // Lazy sliding (above) can leave l behind the
                        // watermark; emissions must still carry a right
                        // boundary beyond every processed tuple
                        // (Observation 1 + per-source ts-sortedness).
                        ctx.win_right = (set.l + ws).max(t.ts + 1);
                        logic.update(set, t, ctx);
                    }
                    WindowType::Multi => {
                        // σ.check&Create for every window t falls in
                        let mut l = t1;
                        while l <= t2 {
                            let pos = match ks.wins.iter().position(|w| w.l >= l) {
                                Some(p) if ks.wins[p].l == l => p,
                                Some(p) => {
                                    ks.wins.insert(p, WindowSet::new(k, l, inputs));
                                    p
                                }
                                None => {
                                    ks.wins.push_back(WindowSet::new(k, l, inputs));
                                    ks.wins.len() - 1
                                }
                            };
                            ctx.win_right = l + ws;
                            logic.update(&mut ks.wins[pos], t, ctx);
                            l += spec.advance;
                        }
                    }
                }
                // (re)schedule the key's earliest expiry
                if let Some(e) = ks.front_expiry(ws) {
                    if e < ks.next_expiry {
                        ks.next_expiry = e;
                        expiry.push(Reverse((e, k)));
                    }
                }
                ((), !ks.wins.is_empty())
            });
            ctx.flush(); // sink emissions with no shard lock held
        }
    }

    /// Full SN processing step (Alg. 2 processSN): updateW, expire, handle.
    /// Returns `true` iff the watermark strictly increased.
    pub fn process(&mut self, t: &Tuple<L::In>, f_mu: &Mapper, ctx: &mut Ctx<'_, L::Out>) -> bool {
        let grew = self.observe(t.ts);
        if grew {
            self.advance(f_mu, ctx);
        }
        if t.kind.is_data() {
            self.handle_input(t, f_mu, ctx);
        }
        grew
    }

    /// Rebuild the expiry index after an epoch switch: this instance is now
    /// responsible (under the *new* f_μ) for a different key set.
    pub fn rebuild_expiry_index(&mut self, f_mu: &Mapper) {
        self.expiry.clear();
        let ws = self.def.spec.size;
        let id = self.id;
        let expiry = &mut self.expiry;
        self.state.scan(|k, ks| {
            if f_mu.map(k) == id {
                if let Some(e) = ks.front_expiry(ws) {
                    ks.next_expiry = e;
                    expiry.push(Reverse((e, k)));
                }
            }
        });
    }

    /// Number of scheduled expiry entries (diagnostics).
    pub fn expiry_len(&self) -> usize {
        self.expiry.len()
    }
}

/// Smallest aligned left boundary that is NOT expired w.r.t. watermark `w`,
/// starting from `cur_l` (never moves backwards).
#[inline]
fn self_first_unexpired(cur_l: EventTime, wa: EventTime, ws: EventTime, w: EventTime) -> EventTime {
    let target = (w - ws).div_euclid(wa) * wa + wa;
    target.max(cur_l + wa)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::time::WindowSpec;

    /// Toy aggregate: counts tuples per key per window (WT = Multi),
    /// emitting (key, count) on expiry. Keys = payload's key list.
    struct CountLogic;
    impl OperatorLogic for CountLogic {
        type In = Vec<Key>;
        type Out = (Key, u64);
        type State = u64;

        fn keys(&self, t: &Tuple<Vec<Key>>, keys: &mut Vec<Key>) {
            keys.extend_from_slice(&t.payload);
        }
        fn update(&self, w: &mut WindowSet<u64>, _t: &Tuple<Vec<Key>>, _ctx: &mut Ctx<'_, Self::Out>) {
            w.states[0] += 1;
        }
        fn output(&self, w: &WindowSet<u64>, ctx: &mut Ctx<'_, Self::Out>) {
            ctx.emit((w.key, w.states[0]));
        }
    }

    fn count_core(wa: i64, ws: i64) -> OperatorCore<CountLogic> {
        OperatorCore::new(
            OperatorDef::new("count", WindowSpec::new(wa, ws), 1, WindowType::Multi, CountLogic),
            0,
            SharedState::private(),
            OperatorMetrics::new(1),
        )
    }

    fn drive(core: &mut OperatorCore<CountLogic>, tuples: Vec<Tuple<Vec<Key>>>) -> Vec<Tuple<(Key, u64)>> {
        let f_mu = Mapper::hash_mod(1);
        let mut out = Vec::new();
        for t in tuples {
            let mut sink = |o: Tuple<(Key, u64)>| out.push(o);
            let mut ctx = Ctx::new(&mut sink);
            ctx.ingest_us = t.ingest_us;
            core.process(&t, &f_mu, &mut ctx);
        }
        out
    }

    #[test]
    fn tumbling_count_per_key() {
        let mut core = count_core(10, 10);
        let out = drive(
            &mut core,
            vec![
                Tuple::data(1, vec![7]),
                Tuple::data(2, vec![7, 8]),
                Tuple::data(9, vec![8]),
                Tuple::data(15, vec![7]), // window [0,10) of 7,8 expires at W=15? no: 10+? l+WS=10 <= 15 yes
                Tuple::data(25, vec![9]), // expires [10,20)
            ],
        );
        // [0,10): key7 count 2, key8 count 2 → emitted when W reaches 15
        // [10,20): key7 count 1 → emitted when W reaches 25
        let mut got: Vec<(Key, u64, i64)> = out.iter().map(|t| (t.payload.0, t.payload.1, t.ts)).collect();
        got.sort();
        assert_eq!(got, vec![(7, 1, 20), (7, 2, 10), (8, 2, 10)]);
    }

    #[test]
    fn sliding_multi_counts_overlaps() {
        // WA=5, WS=10: a tuple at ts=7 falls into windows l=0 and l=5
        let mut core = count_core(5, 10);
        let out = drive(&mut core, vec![Tuple::data(7, vec![1]), Tuple::data(30, vec![2])]);
        let mut got: Vec<(Key, u64, i64)> = out.iter().map(|t| (t.payload.0, t.payload.1, t.ts)).collect();
        got.sort();
        assert_eq!(got, vec![(1, 1, 10), (1, 1, 15)]);
    }

    #[test]
    fn expiry_emissions_are_ts_ordered() {
        let mut core = count_core(5, 10);
        let mut tuples = Vec::new();
        let mut rng = crate::util::Rng::new(3);
        let mut ts = 0i64;
        for _ in 0..500 {
            ts += rng.gen_range(4) as i64;
            tuples.push(Tuple::data(ts, vec![rng.gen_range(5)]));
        }
        tuples.push(Tuple::data(ts + 100, vec![0]));
        let out = drive(&mut core, tuples);
        assert!(!out.is_empty());
        assert!(out.windows(2).all(|w| w[0].ts <= w[1].ts), "f_O emissions out of order");
    }

    #[test]
    fn watermark_only_advances_on_heartbeat() {
        let mut core = count_core(10, 10);
        let out = drive(
            &mut core,
            vec![Tuple::data(1, vec![1]), Tuple::heartbeat(50)],
        );
        // heartbeat expires window [0,10) without contributing data
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].payload, (1, 1));
    }

    #[test]
    fn responsibility_filter() {
        // 2 instances: each processes only its keys
        let shared = SharedState::new(4);
        let metrics = OperatorMetrics::new(2);
        let def = OperatorDef::new("count", WindowSpec::new(10, 10), 1, WindowType::Multi, CountLogic);
        let mut c0 = OperatorCore::new(def.clone(), 0, shared.clone(), metrics.clone());
        let mut c1 = OperatorCore::new(def, 1, shared, metrics);
        let f_mu = Mapper::hash_mod(2);
        let keys: Vec<Key> = (0..20).collect();
        let t = Tuple::data(1, keys.clone());
        let done = Tuple::<Vec<Key>>::heartbeat(100);
        let mut out0 = Vec::new();
        let mut out1 = Vec::new();
        for (core, out) in [(&mut c0, &mut out0), (&mut c1, &mut out1)] {
            let mut sink = |o: Tuple<(Key, u64)>| out.push(o.payload.0);
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
            core.process(&done, &f_mu, &mut ctx);
        }
        // between them, every key counted exactly once
        let mut all = [out0.clone(), out1.clone()].concat();
        all.sort();
        assert_eq!(all, keys);
        // each instance only emitted its own keys
        assert!(out0.iter().all(|&k| f_mu.map(k) == 0));
        assert!(out1.iter().all(|&k| f_mu.map(k) == 1));
    }

    /// Single-window logic mirroring an incremental max (f_R as slide).
    struct MaxLogic;
    impl OperatorLogic for MaxLogic {
        type In = (Key, i64);
        type Out = (Key, i64);
        type State = Vec<(EventTime, i64)>; // (ts, value) retained tuples

        fn keys(&self, t: &Tuple<Self::In>, keys: &mut Vec<Key>) {
            keys.push(t.payload.0);
        }
        fn update(&self, w: &mut WindowSet<Self::State>, t: &Tuple<Self::In>, _ctx: &mut Ctx<'_, Self::Out>) {
            w.states[0].push((t.ts, t.payload.1));
        }
        fn output(&self, w: &WindowSet<Self::State>, ctx: &mut Ctx<'_, Self::Out>) {
            if let Some(m) = w.states[0].iter().map(|&(_, v)| v).max() {
                ctx.emit((w.key, m));
            }
        }
        fn slide(&self, w: &mut WindowSet<Self::State>, new_l: EventTime) -> bool {
            w.states[0].retain(|&(ts, _)| ts >= new_l);
            !w.states[0].is_empty()
        }
    }

    #[test]
    fn single_window_slides_and_purges() {
        let def = OperatorDef::new("max", WindowSpec::new(10, 20), 1, WindowType::Single, MaxLogic);
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out: Vec<(i64, (Key, i64))> = Vec::new();
        let tuples = vec![
            Tuple::data(1, (1u64, 5i64)),
            Tuple::data(12, (1, 9)),
            Tuple::data(35, (1, 2)), // W=35: windows [0,20) and [10,30) expired
            Tuple::heartbeat(100),
        ];
        for t in tuples {
            let mut sink = |o: Tuple<(Key, i64)>| out.push((o.ts, o.payload));
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        // Window instances cover ℓ·WA for ℓ ∈ ℤ (§2.1), so the first
        // window containing ts=1 is [-10,10) → max 5 @10. Then [0,20):
        // max(5,9)=9 @20; [10,30): 9 @30; ts=35 lands in the slid window;
        // the heartbeat expires [20,40) → 2 @40 and [30,50) → 2 @50,
        // after which the purge empties the state.
        assert_eq!(
            out,
            vec![(10, (1, 5)), (20, (1, 9)), (30, (1, 9)), (40, (1, 2)), (50, (1, 2))]
        );
    }

    #[test]
    fn first_unexpired_math() {
        // wa=10, ws=30, w=45: expired l <= 15 → first unexpired = 20
        assert_eq!(self_first_unexpired(0, 10, 30, 45), 20);
        // exactly aligned: w=40 → l <= 10 expired → 20
        assert_eq!(self_first_unexpired(0, 10, 30, 40), 20);
        // never move backwards
        assert_eq!(self_first_unexpired(100, 10, 30, 45), 110);
    }
}
