//! The generalized stateful operator `O+` (§4.2).
//!
//! `O+(WA, WS, I, f_MK, WT, S, f_μ, f_U, f_O, f_S)` is captured by
//! [`OperatorDef`] (window geometry, input count, window type) plus an
//! [`OperatorLogic`] implementation providing the user functions:
//!
//! | paper | trait method | default |
//! |-------|--------------|---------|
//! | f_MK  | [`OperatorLogic::keys`]   | — (must implement) |
//! | f_U   | [`OperatorLogic::update`] | — (must implement) |
//! | f_O   | [`OperatorLogic::output`] | emits nothing |
//! | f_S   | [`OperatorLogic::slide`]  | drop the state |
//!
//! The operator library (Map [`map`], Aggregate [`aggregate`], Joins and
//! ScaleJoin [`join`]) instantiates `O+` exactly as Theorem 2 describes:
//! A is `I = 1` with f_A as f_O / f_R as f_S; J is `I = 2` matching in
//! f_U or f_O.

pub mod aggregate;
pub mod core;
pub mod join;
pub mod map;
pub mod state;

pub use self::core::OperatorCore;
pub use state::{KeyState, SharedState, WindowSet};

use crate::time::{EventTime, WindowSpec};
use crate::tuple::{Key, Kind, Payload, Tuple};
use std::sync::Arc;

/// Window type WT (§2.1): one evolving window instance per key (`Single`)
/// or all overlapping instances materialized per key (`Multi`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WindowType {
    Single,
    Multi,
}

/// Emission + accounting context handed to f_U / f_O.
///
/// Emissions are *buffered* and only handed to the sink by
/// [`Ctx::flush`], which the processing core calls **after** releasing
/// the σ shard lock. The sink may block on downstream backpressure
/// (bounded ESG); blocking while holding a shard lock would deadlock the
/// other instances whose output clocks gate the downstream merge.
pub struct Ctx<'a, Out> {
    /// Right boundary of the window set being processed — the event time
    /// stamped on emissions (§2.1 / Observation 1).
    pub win_right: EventTime,
    /// Ingest stamp of the tuple driving this processing step (latency).
    pub ingest_us: u64,
    /// Join-comparison counter (the paper's join throughput metric).
    pub comparisons: u64,
    buf: Vec<Tuple<Out>>,
    emit_fn: &'a mut dyn FnMut(Tuple<Out>),
}

impl<'a, Out> Ctx<'a, Out> {
    pub fn new(emit_fn: &'a mut dyn FnMut(Tuple<Out>)) -> Self {
        Ctx { win_right: 0, ingest_us: 0, comparisons: 0, buf: Vec::new(), emit_fn }
    }

    /// Emit an output payload, stamped with the window's right boundary
    /// (prepareOutTuples in Alg. 2). Buffered until [`Ctx::flush`].
    #[inline]
    pub fn emit(&mut self, payload: Out) {
        self.buf.push(Tuple {
            ts: self.win_right,
            kind: Kind::Data,
            input: 0,
            ingest_us: self.ingest_us,
            payload,
        });
    }

    /// Emit with an explicit timestamp — for stateless Map stages, whose
    /// contract is `t_out.τ ← t_in.τ` (§2.1), not the window boundary.
    /// The caller must keep `ts` ≥ every timestamp it already emitted
    /// this epoch (true for τ-preserving maps fed a sorted stream), or
    /// downstream per-source sortedness breaks. Checked within the
    /// emission buffer: a regression would silently corrupt the
    /// downstream gate's merge order, so it fails loudly in debug builds
    /// instead.
    #[inline]
    pub fn emit_at(&mut self, ts: EventTime, payload: Out) {
        debug_assert!(
            self.buf.last().map_or(true, |prev| ts >= prev.ts),
            "emit_at: ts {ts} regresses behind ts {} already buffered — \
             the per-source sortedness contract is broken",
            self.buf.last().map(|p| p.ts).unwrap_or_default(),
        );
        self.buf.push(Tuple { ts, kind: Kind::Data, input: 0, ingest_us: self.ingest_us, payload });
    }

    /// Hand buffered emissions to the sink. Must be called with no state
    /// locks held (the core does this; see module docs).
    #[inline]
    pub fn flush(&mut self) {
        for t in self.buf.drain(..) {
            (self.emit_fn)(t);
        }
    }

    /// Record `n` join comparisons.
    #[inline]
    pub fn record_comparisons(&mut self, n: u64) {
        self.comparisons += n;
    }
}


/// The user-defined functions of `O+`.
pub trait OperatorLogic: Send + Sync + 'static {
    type In: Payload;
    type Out: Payload;
    /// ζ: per-(key, window, input) state.
    type State: Send + Sync + Default + 'static;

    /// f_MK: append the keys of `t` to `keys` (possibly none, Def. 4).
    fn keys(&self, t: &Tuple<Self::In>, keys: &mut Vec<Key>);

    /// f_U: update the window set (its I states) for one of `t`'s keys;
    /// may emit output payloads through `ctx`.
    fn update(&self, w: &mut WindowSet<Self::State>, t: &Tuple<Self::In>, ctx: &mut Ctx<'_, Self::Out>);

    /// f_O: produce results when the window set expires. Default: nothing.
    fn output(&self, _w: &WindowSet<Self::State>, _ctx: &mut Ctx<'_, Self::Out>) {}

    /// f_S (WT = Single only): slide the window set to left boundary
    /// `new_l`, purging stale contributions. Return `false` to drop the
    /// key's state entirely (the "all states empty" test of Alg. 2 L16-17).
    /// Default: drop.
    fn slide(&self, _w: &mut WindowSet<Self::State>, _new_l: EventTime) -> bool {
        false
    }

    /// Whether f_O is user-defined. When `false` and WT = Single, expiry
    /// fast-forwards the window in one `slide` call instead of stepping
    /// through every WA increment — semantically equivalent (each skipped
    /// step would emit nothing) and essential when WA = δ (ScaleJoin).
    fn has_output(&self) -> bool {
        true
    }

    /// Whether f_MK returns the SAME key set for every tuple (ScaleJoin's
    /// {1..n_keys}, Operator 6's {1..n}). Enables the shard-grouped key
    /// plan: keys are binned by σ shard once per epoch and each shard is
    /// locked once per tuple instead of once per key (§Perf).
    fn keys_are_constant(&self) -> bool {
        false
    }
}

/// The declarative half of `O+`: geometry + input count + WT + logic.
pub struct OperatorDef<L: OperatorLogic> {
    pub spec: WindowSpec,
    pub inputs: usize,
    pub wt: WindowType,
    pub logic: Arc<L>,
    /// Human-readable name (metrics, logs).
    pub name: &'static str,
}

impl<L: OperatorLogic> Clone for OperatorDef<L> {
    fn clone(&self) -> Self {
        OperatorDef {
            spec: self.spec,
            inputs: self.inputs,
            wt: self.wt,
            logic: self.logic.clone(),
            name: self.name,
        }
    }
}

impl<L: OperatorLogic> OperatorDef<L> {
    pub fn new(
        name: &'static str,
        spec: WindowSpec,
        inputs: usize,
        wt: WindowType,
        logic: L,
    ) -> Self {
        assert!(inputs >= 1 && inputs <= u8::MAX as usize);
        OperatorDef { spec, inputs, wt, logic: Arc::new(logic), name }
    }
}
