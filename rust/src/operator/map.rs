//! The Map/Flatmap operator M (§2.1): stateless, transforms each input
//! tuple into zero or more output tuples with `t_out.τ ← t_in.τ`.
//!
//! In the SN baseline, M is the Corollary-1 duplication stage: it turns an
//! `A+`'s multi-key tuples into one single-key tuple per key so a plain
//! key-by A can route them.

use crate::tuple::{Payload, Tuple};
use std::sync::Arc;

/// Stateless transform logic.
pub trait MapLogic: Send + Sync + 'static {
    type In: Payload;
    type Out: Payload;

    /// Emit zero or more outputs for `t`. Implementations must preserve
    /// the timestamp (`t_out.τ ← t_in.τ`) — enforced by [`MapOp::apply`].
    fn flat_map(&self, t: &Tuple<Self::In>, emit: &mut dyn FnMut(Self::Out));
}

/// Closure-backed [`MapLogic`].
pub struct FnMapLogic<In, Out, F> {
    f: F,
    _marker: std::marker::PhantomData<fn(In) -> Out>,
}

impl<In, Out, F> FnMapLogic<In, Out, F>
where
    In: Payload,
    Out: Payload,
    F: Fn(&Tuple<In>, &mut dyn FnMut(Out)) + Send + Sync + 'static,
{
    pub fn new(f: F) -> Self {
        FnMapLogic { f, _marker: std::marker::PhantomData }
    }
}

impl<In, Out, F> MapLogic for FnMapLogic<In, Out, F>
where
    In: Payload,
    Out: Payload,
    F: Fn(&Tuple<In>, &mut dyn FnMut(Out)) + Send + Sync + 'static,
{
    type In = In;
    type Out = Out;
    fn flat_map(&self, t: &Tuple<In>, emit: &mut dyn FnMut(Out)) {
        (self.f)(t, emit)
    }
}

/// A deployable M operator.
pub struct MapOp<L: MapLogic> {
    pub logic: Arc<L>,
    pub name: &'static str,
}

impl<L: MapLogic> Clone for MapOp<L> {
    fn clone(&self) -> Self {
        MapOp { logic: self.logic.clone(), name: self.name }
    }
}

impl<L: MapLogic> MapOp<L> {
    pub fn new(name: &'static str, logic: L) -> Self {
        MapOp { logic: Arc::new(logic), name }
    }

    /// Apply to one tuple, stamping outputs with the input's τ, kind
    /// passthrough for heartbeats, and the ingest stamp for latency.
    pub fn apply(&self, t: &Tuple<L::In>, out: &mut dyn FnMut(Tuple<L::Out>)) {
        if !t.kind.is_data() {
            return;
        }
        let ts = t.ts;
        let ingest = t.ingest_us;
        let mut emit = |p: L::Out| {
            out(Tuple { ts, kind: crate::tuple::Kind::Data, input: 0, ingest_us: ingest, payload: p })
        };
        self.logic.flat_map(t, &mut emit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatmap_preserves_timestamp() {
        let m = MapOp::new(
            "split",
            FnMapLogic::new(|t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| {
                for i in 0..t.payload {
                    emit(i);
                }
            }),
        );
        let mut out = Vec::new();
        m.apply(&Tuple::data(42, 3).with_ingest(7), &mut |o| out.push(o));
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|o| o.ts == 42 && o.ingest_us == 7));
        assert_eq!(out.iter().map(|o| o.payload).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn heartbeats_not_mapped() {
        let m = MapOp::new(
            "id",
            FnMapLogic::new(|t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| emit(t.payload)),
        );
        let mut out: Vec<Tuple<u32>> = Vec::new();
        m.apply(&Tuple::heartbeat(10), &mut |o| out.push(o));
        assert!(out.is_empty());
    }
}
