//! The Map/Flatmap operator M (§2.1): stateless, transforms each input
//! tuple into zero or more output tuples with `t_out.τ ← t_in.τ`.
//!
//! In the SN baseline, M is the Corollary-1 duplication stage: it turns an
//! `A+`'s multi-key tuples into one single-key tuple per key so a plain
//! key-by A can route them.

use crate::operator::state::WindowSet;
use crate::operator::{Ctx, OperatorDef, OperatorLogic, WindowType};
use crate::time::{EventTime, WindowSpec, DELTA};
use crate::tuple::{mix64, Key, Payload, Tuple};
use std::sync::Arc;

/// Stateless transform logic.
pub trait MapLogic: Send + Sync + 'static {
    type In: Payload;
    type Out: Payload;

    /// Emit zero or more outputs for `t`. Implementations must preserve
    /// the timestamp (`t_out.τ ← t_in.τ`) — enforced by [`MapOp::apply`].
    fn flat_map(&self, t: &Tuple<Self::In>, emit: &mut dyn FnMut(Self::Out));
}

/// Closure-backed [`MapLogic`].
pub struct FnMapLogic<In, Out, F> {
    f: F,
    _marker: std::marker::PhantomData<fn(In) -> Out>,
}

impl<In, Out, F> FnMapLogic<In, Out, F>
where
    In: Payload,
    Out: Payload,
    F: Fn(&Tuple<In>, &mut dyn FnMut(Out)) + Send + Sync + 'static,
{
    pub fn new(f: F) -> Self {
        FnMapLogic { f, _marker: std::marker::PhantomData }
    }
}

impl<In, Out, F> MapLogic for FnMapLogic<In, Out, F>
where
    In: Payload,
    Out: Payload,
    F: Fn(&Tuple<In>, &mut dyn FnMut(Out)) + Send + Sync + 'static,
{
    type In = In;
    type Out = Out;
    fn flat_map(&self, t: &Tuple<In>, emit: &mut dyn FnMut(Out)) {
        (self.f)(t, emit)
    }
}

/// A deployable M operator.
pub struct MapOp<L: MapLogic> {
    pub logic: Arc<L>,
    pub name: &'static str,
}

impl<L: MapLogic> Clone for MapOp<L> {
    fn clone(&self) -> Self {
        MapOp { logic: self.logic.clone(), name: self.name }
    }
}

impl<L: MapLogic> MapOp<L> {
    pub fn new(name: &'static str, logic: L) -> Self {
        MapOp { logic: Arc::new(logic), name }
    }

    /// Apply to one tuple, stamping outputs with the input's τ, kind
    /// passthrough for heartbeats, and the ingest stamp for latency.
    pub fn apply(&self, t: &Tuple<L::In>, out: &mut dyn FnMut(Tuple<L::Out>)) {
        if !t.kind.is_data() {
            return;
        }
        let ts = t.ts;
        let ingest = t.ingest_us;
        let mut emit = |p: L::Out| {
            out(Tuple { ts, kind: crate::tuple::Kind::Data, input: 0, ingest_us: ingest, payload: p })
        };
        self.logic.flat_map(t, &mut emit);
    }
}

/// Deploy a stateless [`MapLogic`] as a full VSN *pipeline stage*: a
/// degenerate `O+` (I = 1, WT = Single, WA = WS = δ, empty ζ) whose f_U
/// emits the mapped outputs immediately with τ preserved
/// ([`Ctx::emit_at`]). f_MK assigns one synthetic load-balancing key
/// derived from τ, so f_μ spreads tuples over the stage's instances
/// while keeping routing deterministic across epochs (Theorem 3 applies
/// unchanged: a reconfiguration just re-partitions the key space, and
/// there is no state to move).
///
/// Tuples sharing a timestamp land on the same instance; pick
/// `lb_keys ≫ Π` (e.g. 64) so balance comes from timestamp variety.
pub struct MapStageLogic<L: MapLogic> {
    pub logic: Arc<L>,
    /// Synthetic key space for load balancing.
    pub lb_keys: u64,
}

impl<L: MapLogic> OperatorLogic for MapStageLogic<L> {
    type In = L::In;
    type Out = L::Out;
    type State = ();

    #[inline]
    fn keys(&self, t: &Tuple<L::In>, keys: &mut Vec<Key>) {
        keys.push(mix64(t.ts as u64) % self.lb_keys);
    }

    #[inline]
    fn update(&self, _w: &mut WindowSet<()>, t: &Tuple<L::In>, ctx: &mut Ctx<'_, L::Out>) {
        let ts = t.ts;
        self.logic.flat_map(t, &mut |p| ctx.emit_at(ts, p));
    }

    fn slide(&self, _w: &mut WindowSet<()>, _new_l: EventTime) -> bool {
        false // stateless: drop the bookkeeping window on expiry
    }

    fn has_output(&self) -> bool {
        false // no f_O — expiry fast-forwards (WA = δ)
    }
}

impl<In, Out, F> OperatorDef<MapStageLogic<FnMapLogic<In, Out, F>>>
where
    In: Payload,
    Out: Payload,
    F: Fn(&Tuple<In>, &mut dyn FnMut(Out)) + Send + Sync + 'static,
{
    /// Closure escape hatch: build a deployable Map stage straight from a
    /// `Fn(&Tuple<In>, emit)` without naming a [`MapLogic`] type. The
    /// closure must preserve timestamps implicitly — outputs are stamped
    /// with the input's τ by the stage ([`Ctx::emit_at`]).
    ///
    /// ```ignore
    /// let def = OperatorDef::from_fn("double", 64, |t: &Tuple<u32>, emit| {
    ///     emit(t.payload * 2);
    /// });
    /// ```
    pub fn from_fn(name: &'static str, lb_keys: u64, f: F) -> Self {
        map_stage_op(name, FnMapLogic::new(f), lb_keys)
    }
}

/// Build a Map pipeline stage from a [`MapLogic`].
pub fn map_stage_op<L: MapLogic>(
    name: &'static str,
    logic: L,
    lb_keys: u64,
) -> OperatorDef<MapStageLogic<L>> {
    assert!(lb_keys >= 1);
    OperatorDef::new(
        name,
        WindowSpec::new(DELTA, DELTA),
        1,
        WindowType::Single,
        MapStageLogic { logic: Arc::new(logic), lb_keys },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatmap_preserves_timestamp() {
        let m = MapOp::new(
            "split",
            FnMapLogic::new(|t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| {
                for i in 0..t.payload {
                    emit(i);
                }
            }),
        );
        let mut out = Vec::new();
        m.apply(&Tuple::data(42, 3).with_ingest(7), &mut |o| out.push(o));
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|o| o.ts == 42 && o.ingest_us == 7));
        assert_eq!(out.iter().map(|o| o.payload).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn map_stage_preserves_ts_through_core() {
        use crate::metrics::OperatorMetrics;
        use crate::operator::state::SharedState;
        use crate::operator::OperatorCore;
        use crate::tuple::Mapper;
        let def = map_stage_op(
            "double",
            FnMapLogic::new(|t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| {
                emit(t.payload);
                emit(t.payload * 10);
            }),
            8,
        );
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out: Vec<(i64, u32)> = Vec::new();
        for ts in 1..=5i64 {
            let t = Tuple::data(ts, ts as u32);
            let mut sink = |o: Tuple<u32>| out.push((o.ts, o.payload));
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        // τ preserved exactly, two outputs per input, input order kept
        assert_eq!(
            out,
            vec![
                (1, 1), (1, 10), (2, 2), (2, 20), (3, 3), (3, 30),
                (4, 4), (4, 40), (5, 5), (5, 50),
            ]
        );
    }

    #[test]
    fn map_stage_splits_work_across_instances_exactly_once() {
        use crate::metrics::OperatorMetrics;
        use crate::operator::state::SharedState;
        use crate::operator::OperatorCore;
        use crate::tuple::Mapper;
        let def = map_stage_op(
            "id",
            FnMapLogic::new(|t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| emit(t.payload)),
            64,
        );
        let shared = SharedState::new(4);
        let metrics = OperatorMetrics::new(2);
        let f_mu = Mapper::hash_mod(2);
        let mut cores: Vec<_> = (0..2)
            .map(|i| OperatorCore::new(def.clone(), i, shared.clone(), metrics.clone()))
            .collect();
        let mut per_core = [Vec::new(), Vec::new()];
        for ts in 0..200i64 {
            let t = Tuple::data(ts, ts as u32);
            for (c, out) in cores.iter_mut().zip(per_core.iter_mut()) {
                let mut sink = |o: Tuple<u32>| out.push(o.payload);
                let mut ctx = Ctx::new(&mut sink);
                c.process(&t, &f_mu, &mut ctx);
            }
        }
        // exactly-once across the two instances, and both did real work
        assert!(!per_core[0].is_empty() && !per_core[1].is_empty());
        let mut out = [per_core[0].clone(), per_core[1].clone()].concat();
        out.sort_unstable();
        assert_eq!(out, (0..200).collect::<Vec<u32>>());
    }

    #[test]
    fn from_fn_builds_a_working_stage() {
        use crate::metrics::OperatorMetrics;
        use crate::operator::state::SharedState;
        use crate::operator::OperatorCore;
        use crate::tuple::Mapper;
        let def = OperatorDef::from_fn("triple", 8, |t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| {
            emit(t.payload * 3);
        });
        assert_eq!(def.name, "triple");
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out: Vec<(i64, u32)> = Vec::new();
        for ts in 1..=3i64 {
            let t = Tuple::data(ts, ts as u32);
            let mut sink = |o: Tuple<u32>| out.push((o.ts, o.payload));
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        assert_eq!(out, vec![(1, 3), (2, 6), (3, 9)]);
    }

    #[test]
    fn heartbeats_not_mapped() {
        let m = MapOp::new(
            "id",
            FnMapLogic::new(|t: &Tuple<u32>, emit: &mut dyn FnMut(u32)| emit(t.payload)),
        );
        let mut out: Vec<Tuple<u32>> = Vec::new();
        m.apply(&Tuple::heartbeat(10), &mut |o| out.push(o));
        assert!(out.is_empty());
    }
}
