//! Aggregate operators: A (single key-by, §2.1) and A+ (multi key-by,
//! Def. 5), instantiated from `O+` per Theorem 2 (I = 1, f_A as f_O,
//! f_R as f_S).
//!
//! Two forms:
//! * [`FnAggLogic`] — closure-assembled `O+` for ad-hoc aggregates (the
//!   user-facing builder, mirrors how the paper's operators are "defined
//!   by specializing functions");
//! * [`CountPerKey`] — the wordcount/paircount counting aggregate
//!   (Operators 4/5 of Appendix D), implemented directly for speed.

use crate::operator::state::WindowSet;
use crate::operator::{Ctx, OperatorDef, OperatorLogic, WindowType};
use crate::time::{EventTime, WindowSpec};
use crate::tuple::{Key, Payload, Tuple};

/// Closure-assembled aggregate logic (an `O+` with I = 1).
pub struct FnAggLogic<In, Out, S> {
    keys: Box<dyn Fn(&Tuple<In>, &mut Vec<Key>) + Send + Sync>,
    update: Box<dyn Fn(&mut WindowSet<S>, &Tuple<In>, &mut Ctx<'_, Out>) + Send + Sync>,
    output: Box<dyn Fn(&WindowSet<S>, &mut Ctx<'_, Out>) + Send + Sync>,
    slide: Option<Box<dyn Fn(&mut WindowSet<S>, EventTime) -> bool + Send + Sync>>,
}

impl<In: Payload, Out: Payload, S: Send + Sync + Default + 'static> FnAggLogic<In, Out, S> {
    pub fn new(
        keys: impl Fn(&Tuple<In>, &mut Vec<Key>) + Send + Sync + 'static,
        update: impl Fn(&mut WindowSet<S>, &Tuple<In>, &mut Ctx<'_, Out>) + Send + Sync + 'static,
        output: impl Fn(&WindowSet<S>, &mut Ctx<'_, Out>) + Send + Sync + 'static,
    ) -> Self {
        FnAggLogic {
            keys: Box::new(keys),
            update: Box::new(update),
            output: Box::new(output),
            slide: None,
        }
    }

    /// Provide f_S (for WT = Single).
    pub fn with_slide(
        mut self,
        slide: impl Fn(&mut WindowSet<S>, EventTime) -> bool + Send + Sync + 'static,
    ) -> Self {
        self.slide = Some(Box::new(slide));
        self
    }
}

impl<In: Payload, Out: Payload, S: Send + Sync + Default + 'static> OperatorLogic
    for FnAggLogic<In, Out, S>
{
    type In = In;
    type Out = Out;
    type State = S;

    fn keys(&self, t: &Tuple<In>, keys: &mut Vec<Key>) {
        (self.keys)(t, keys)
    }
    fn update(&self, w: &mut WindowSet<S>, t: &Tuple<In>, ctx: &mut Ctx<'_, Out>) {
        (self.update)(w, t, ctx)
    }
    fn output(&self, w: &WindowSet<S>, ctx: &mut Ctx<'_, Out>) {
        (self.output)(w, ctx)
    }
    fn slide(&self, w: &mut WindowSet<S>, new_l: EventTime) -> bool {
        match &self.slide {
            Some(f) => f(w, new_l),
            None => false,
        }
    }
}

/// The counting aggregate of Operators 4/5 (wordcount / paircount):
/// input payloads already carry their key set (produced by f_MK at the
/// workload layer); the state is a plain count; expiry emits (key, count).
pub struct CountPerKey<In, KF> {
    key_fn: KF,
    _marker: std::marker::PhantomData<fn(In)>,
}

impl<In, KF> CountPerKey<In, KF>
where
    In: Payload,
    KF: Fn(&Tuple<In>, &mut Vec<Key>) + Send + Sync + 'static,
{
    pub fn new(key_fn: KF) -> Self {
        CountPerKey { key_fn, _marker: std::marker::PhantomData }
    }
}

impl<In, KF> OperatorLogic for CountPerKey<In, KF>
where
    In: Payload,
    KF: Fn(&Tuple<In>, &mut Vec<Key>) + Send + Sync + 'static,
{
    type In = In;
    type Out = (Key, u64);
    type State = u64;

    #[inline]
    fn keys(&self, t: &Tuple<In>, keys: &mut Vec<Key>) {
        (self.key_fn)(t, keys)
    }
    #[inline]
    fn update(&self, w: &mut WindowSet<u64>, _t: &Tuple<In>, _ctx: &mut Ctx<'_, Self::Out>) {
        w.states[0] += 1;
    }
    fn output(&self, w: &WindowSet<u64>, ctx: &mut Ctx<'_, Self::Out>) {
        ctx.emit((w.key, w.states[0]));
    }
}

/// Build the wordcount/paircount `A+` (WT = Multi) with the paper's Q1
/// window geometry (Operator 4: WA = 60 s, WS = 120 s by default).
pub fn count_per_key_op<In, KF>(
    name: &'static str,
    spec: WindowSpec,
    key_fn: KF,
) -> OperatorDef<CountPerKey<In, KF>>
where
    In: Payload,
    KF: Fn(&Tuple<In>, &mut Vec<Key>) + Send + Sync + 'static,
{
    OperatorDef::new(name, spec, 1, WindowType::Multi, CountPerKey::new(key_fn))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OperatorMetrics;
    use crate::operator::state::SharedState;
    use crate::operator::OperatorCore;
    use crate::tuple::Mapper;

    #[test]
    fn fn_agg_longest_tweet_per_hashtag() {
        // Operator 2 (App. D): A+ computing the longest tweet per hashtag.
        // In = (hashtag keys, length); State = max length.
        type In = (Vec<Key>, u64);
        let logic = FnAggLogic::<In, (Key, u64), u64>::new(
            |t, keys| keys.extend_from_slice(&t.payload.0),
            |w, t, _ctx| {
                if t.payload.1 > w.states[0] {
                    w.states[0] = t.payload.1;
                }
            },
            |w, ctx| ctx.emit((w.key, w.states[0])),
        );
        let def = OperatorDef::new(
            "longest-tweet",
            WindowSpec::new(30, 60),
            1,
            WindowType::Multi,
            logic,
        );
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out = Vec::new();
        let tuples: Vec<Tuple<In>> = vec![
            Tuple::data(10, (vec![1], 5)),
            Tuple::data(20, (vec![1, 2], 13)),
            Tuple::data(200, (vec![9], 1)), // expire everything
        ];
        for t in tuples {
            let mut sink = |o: Tuple<(Key, u64)>| out.push(o.payload);
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        out.sort();
        // windows [-30,30) and [0,60) both see the tuples → two emissions per key
        assert_eq!(out, vec![(1, 13), (1, 13), (2, 13), (2, 13)]);
    }

    #[test]
    fn count_per_key_counts() {
        let def = count_per_key_op::<Key, _>(
            "wc",
            WindowSpec::new(10, 10),
            |t, keys| keys.push(t.payload),
        );
        let mut core = OperatorCore::new(def, 0, SharedState::private(), OperatorMetrics::new(1));
        let f_mu = Mapper::hash_mod(1);
        let mut out = Vec::new();
        for t in [
            Tuple::data(1, 5u64),
            Tuple::data(2, 5),
            Tuple::data(3, 6),
            Tuple::data(50, 0),
        ] {
            let mut sink = |o: Tuple<(Key, u64)>| out.push(o.payload);
            let mut ctx = Ctx::new(&mut sink);
            core.process(&t, &f_mu, &mut ctx);
        }
        out.sort();
        assert_eq!(out, vec![(5, 2), (6, 1)]);
    }
}
